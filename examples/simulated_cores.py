"""The paper's IO-vs-OOO study on the 11 simulated device profiles.

    PYTHONPATH=src python examples/simulated_cores.py

Shows per-profile best tuning points adapting to the hardware (lean cores
want deeper unrolling + DMA lookahead; fat cores rely on hardware
scheduling), and whether online tuning on lean cores can match static
code on fat cores (paper Fig. 6).
"""

import sys

sys.path.insert(0, "src")

from benchmarks.fig5_simulated_cores import run

if __name__ == "__main__":
    run()
