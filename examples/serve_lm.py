"""Serving example: batched prefill + greedy decode with a KV cache, for
any assigned architecture (reduced config so it runs on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b --tokens 32

With ``--autotune`` the prefill/decode step-programs are tuned online by
the process-wide TuningCoordinator while the request streams tokens;
``--requests N`` sends N requests through the same coordinator so tuning
pays off across requests (warm variants, no re-exploration).
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.core import available_strategies
from repro.runtime.kernel_plane import parse_kernel_strategies
from repro.runtime.serve_loop import (
    ServeConfig, generate, make_serve_coordinator)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=sorted(REGISTRY))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--registry", default=None)
    ap.add_argument("--strategy", default="two_phase",
                    choices=available_strategies(),
                    help="search strategy for the serve tuners")
    ap.add_argument("--seq-buckets", dest="seq_buckets",
                    action="store_true", default=True,
                    help="pow2-bucket seq/max_len tuner keys (default)")
    ap.add_argument("--no-seq-buckets", dest="seq_buckets",
                    action="store_false")
    ap.add_argument("--kernel-tuning", default="program",
                    choices=["off", "program", "kernel", "both"],
                    help="tune whole step-programs, individual Pallas "
                         "kernels, or both levels hierarchically")
    ap.add_argument("--kernel-strategy", action="append", default=[],
                    metavar="KERNEL=STRATEGY",
                    help="per-kernel search strategy (repeatable), "
                         "e.g. matmul=greedy")
    args = ap.parse_args()

    kernel_strategies = parse_kernel_strategies(args.kernel_strategy)

    cfg = REGISTRY[args.arch].reduced()
    serve = ServeConfig(max_new_tokens=args.tokens, autotune=args.autotune,
                        tune_max_overhead=0.2, registry_path=args.registry,
                        tune_strategy=args.strategy,
                        seq_buckets=args.seq_buckets,
                        kernel_tuning=args.kernel_tuning,
                        kernel_strategies=kernel_strategies)
    tuning_on = args.autotune and args.kernel_tuning != "off"
    coordinator = make_serve_coordinator(serve) if tuning_on else None

    for req in range(args.requests):
        batch = {
            "tokens": jax.random.randint(
                jax.random.PRNGKey(req), (args.batch, args.prompt_len),
                0, cfg.vocab)
        }
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
        if cfg.family == "vlm":
            batch["vision"] = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05

        t0 = time.perf_counter()
        out = generate(cfg, batch, serve, coordinator=coordinator)
        print(f"req {req}  arch={args.arch} (reduced)  batch={args.batch}")
        print(f"  prefill {out['prefill_s']*1e3:.0f} ms   "
              f"decode {out['decode_s']*1e3:.0f} ms   "
              f"{out['decode_tokens_per_s']:.1f} tok/s   "
              f"total {time.perf_counter()-t0:.1f}s")
        if tuning_on:
            a = out["autotune"]
            lc = a["lifecycle"]
            print(f"  tuning[{args.strategy}/{args.kernel_tuning}]: "
                  f"{a['regenerations']} regens {a['swaps']} swaps "
                  f"overhead {a['overhead_frac']*100:.1f}% "
                  f"(budget {a['budget_s']*1e3:.0f} ms, "
                  f"init {a['init_spent_s']*1e3:.0f} ms) "
                  f"tuners {a['n_kernels']} "
                  f"({lc['converged']} converged {lc['retired']} retired)")
            if args.kernel_tuning in ("kernel", "both"):
                for name, k in sorted(a["kernels"].items()):
                    if not k.get("plane_managed"):
                        continue
                    print(f"    kernel {name}: {k['strategy']} "
                          f"{k['regenerations']} regens "
                          f"gen {k['gen_spent_s']*1e3:.1f} ms "
                          f"eval {k['eval_spent_s']*1e3:.1f} ms")
    if args.requests > 0:
        print("first sequence:", out["tokens"][0].tolist())


if __name__ == "__main__":
    main()
