"""Serving example: batched prefill + greedy decode with a KV cache, for
any assigned architecture (reduced config so it runs on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b --tokens 32

With ``--autotune`` the request streams tokens while one
:class:`repro.TuningSession` tunes the step-programs and (with
``--kernel-tuning kernel|both``) their constituent Pallas kernels online;
``--requests N`` sends N requests through the same session so tuning pays
off across requests (warm variants, no re-exploration). The tuning flags
are the canonical ``repro.tune`` set declared by
``repro.TuningConfig.add_flags``.
"""

import argparse
import dataclasses
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.api import TuningConfig, TuningSession
from repro.configs import REGISTRY
from repro.runtime.serve_loop import (
    ServeConfig, generate, serve_tuning_defaults)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=sorted(REGISTRY))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=1)
    # demo-friendly base: a generous overhead cap for short runs
    base = dataclasses.replace(serve_tuning_defaults(), max_overhead=0.2)
    TuningConfig.add_flags(ap, base=base)
    args = ap.parse_args()

    tcfg = TuningConfig.from_flags(args, base=base)
    cfg = REGISTRY[args.arch].reduced()
    serve = ServeConfig(max_new_tokens=args.tokens, tuning=tcfg)
    session = TuningSession(tcfg) if tcfg.active else None

    for req in range(args.requests):
        batch = {
            "tokens": jax.random.randint(
                jax.random.PRNGKey(req), (args.batch, args.prompt_len),
                0, cfg.vocab)
        }
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
        if cfg.family == "vlm":
            batch["vision"] = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05

        t0 = time.perf_counter()
        out = generate(cfg, batch, serve, session=session)
        print(f"req {req}  arch={args.arch} (reduced)  batch={args.batch}")
        print(f"  prefill {out['prefill_s']*1e3:.0f} ms   "
              f"decode {out['decode_s']*1e3:.0f} ms   "
              f"{out['decode_tokens_per_s']:.1f} tok/s   "
              f"total {time.perf_counter()-t0:.1f}s")
        if session is not None:
            a = out["autotune"]
            lc = a["lifecycle"]
            print(f"  tuning[{args.strategy}/{args.kernel_tuning}]: "
                  f"{a['regenerations']} regens {a['swaps']} swaps "
                  f"overhead {a['overhead_frac']*100:.1f}% "
                  f"(budget {a['budget_s']*1e3:.0f} ms, "
                  f"init {a['init_spent_s']*1e3:.0f} ms) "
                  f"tuners {a['n_kernels']} "
                  f"({lc['converged']} converged {lc['retired']} retired)")
            if args.kernel_tuning in ("kernel", "both"):
                for name, k in sorted(a["kernels"].items()):
                    if not k.get("plane_managed"):
                        continue
                    print(f"    kernel {name}: {k['strategy']} "
                          f"{k['regenerations']} regens "
                          f"gen {k['gen_spent_s']*1e3:.1f} ms "
                          f"eval {k['eval_spent_s']*1e3:.1f} ms")
    if session is not None:
        session.close()
    if args.requests > 0:
        print("first sequence:", out["tokens"][0].tolist())


if __name__ == "__main__":
    main()
