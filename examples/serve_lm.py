"""Serving example: batched prefill + greedy decode with a KV cache, for
any assigned architecture (reduced config so it runs on CPU).

    PYTHONPATH=src python examples/serve_lm.py --arch rwkv6-1.6b --tokens 32
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import REGISTRY
from repro.runtime.serve_loop import ServeConfig, generate


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-7b", choices=sorted(REGISTRY))
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    cfg = REGISTRY[args.arch].reduced()
    batch = {
        "tokens": jax.random.randint(
            jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0, cfg.vocab)
    }
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05

    t0 = time.perf_counter()
    out = generate(cfg, batch, ServeConfig(max_new_tokens=args.tokens))
    print(f"arch={args.arch} (reduced)  batch={args.batch}")
    print(f"prefill {out['prefill_s']*1e3:.0f} ms   "
          f"decode {out['decode_s']*1e3:.0f} ms   "
          f"{out['decode_tokens_per_s']:.1f} tok/s   "
          f"total {time.perf_counter()-t0:.1f}s")
    print("first sequence:", out["tokens"][0].tolist())


if __name__ == "__main__":
    main()
