"""End-to-end training driver: train a ~100M-param LM with the full
framework stack (data pipeline, AdamW, checkpointing, fault tolerance,
integrated online kernel auto-tuning).

    PYTHONPATH=src python examples/train_lm.py --steps 200 \
        --params 100m --autotune

On CPU this takes a while at 100m; --params 10m runs a quick demo.
The run is resumable: re-running the same command continues from the last
checkpoint.
"""

import argparse
import sys

sys.path.insert(0, "src")

from repro.api import TuningConfig
from repro.configs.base import ModelConfig, ShapeSpec
from repro.runtime.train_loop import (
    TrainLoopConfig, train, train_tuning_defaults)

SIZES = {
    "1m": dict(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
               d_ff=512, vocab=2048),
    "10m": dict(n_layers=4, d_model=384, n_heads=6, n_kv_heads=2, d_head=64,
                d_ff=1536, vocab=8192),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_head=64, d_ff=3072, vocab=32768),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--params", choices=SIZES, default="10m")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--compress-grads", action="store_true")
    # the canonical repro.tune flag set (--autotune, --strategy,
    # --kernel-tuning, ...) declared once from the train-loop defaults
    base = train_tuning_defaults()
    TuningConfig.add_flags(ap, base=base)
    args = ap.parse_args()

    cfg = ModelConfig(name=f"lm-{args.params}", family="dense",
                      **SIZES[args.params])
    print(f"model: {cfg.n_params()/1e6:.1f}M params")
    shape = ShapeSpec("train", "train", args.seq, args.batch)
    loop = TrainLoopConfig(
        steps=args.steps,
        ckpt_every=max(args.steps // 10, 1),
        ckpt_dir=args.ckpt_dir,
        compress_grads=args.compress_grads,
        tuning=TuningConfig.from_flags(args, base=base),
    )
    out = train(cfg, shape, loop)
    print(f"steps {out['start_step']} -> {out['steps']}   "
          f"loss {out['first_loss']:.3f} -> {out['final_loss']:.3f}   "
          f"wall {out['wall_s']:.1f}s   "
          f"stragglers flagged: {out['stragglers_flagged']}")
    if "autotune" in out:
        a = out["autotune"]
        print(f"autotune: {a['regenerations']} variants, {a['swaps']} swaps, "
              f"overhead {a['overhead_frac']:.1%}, best {a['best_point']}")


if __name__ == "__main__":
    main()
