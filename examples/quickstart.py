"""Quickstart: online auto-tuning of a short-running kernel (the paper's
core result, end to end on the real backend).

    PYTHONPATH=src python examples/quickstart.py

Runs the Streamcluster euclidean-distance kernel for ~1 s of application
time. The online auto-tuner explores machine-code variants *while the
application runs*, swapping in faster kernels under a bounded overhead
budget, exactly as in the paper.
"""

import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import Evaluator, OnlineAutotuner, RegenerationPolicy
from repro.kernels.euclid.ops import (
    euclid_ref, make_euclid_compilette, reference_sisd)


def main() -> None:
    N, M, D = 2048, 64, 64           # points × centers × dimension
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (N, D), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (M, D), jnp.float32)

    # the reference kernel a compiler would give you
    ref = jax.jit(reference_sisd(D))

    # the compilette: generates specialized machine-code variants at runtime
    comp = make_euclid_compilette(N, M, D, backend="jnp")
    evaluator = Evaluator(mode="training", groups=2, group_size=3,
                          make_args=lambda: (x, c))
    tuner = OnlineAutotuner(
        comp, evaluator,
        policy=RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.5),
        specialization={"dim": D},
        reference_fn=ref,
        wake_every=2,
    )

    print(f"tuning space: {comp.space.n_code_variants} variants "
          f"({comp.space.n_valid_variants()} valid)")
    t0 = time.perf_counter()
    calls = 200
    for i in range(calls):
        out = tuner(x, c)            # the application just calls the kernel
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0

    s = tuner.stats()
    print(f"app ran {calls} kernel calls in {wall*1e3:.0f} ms")
    print(f"explored {s['n_explored']} variants, {s['swaps']} swaps, "
          f"tuning overhead {s['overhead_frac']:.1%}")
    print(f"reference {s['reference_score_s']*1e6:.0f} us/call -> "
          f"active {s['active_score_s']*1e6:.0f} us/call "
          f"(speedup {s['reference_score_s']/s['active_score_s']:.2f}x)")
    print(f"best point: {s['best_point']}")

    err = jnp.abs(tuner.active_fn(x, c) - euclid_ref(x, c)).max()
    print(f"max abs err vs oracle: {float(err):.2e}")


if __name__ == "__main__":
    main()
