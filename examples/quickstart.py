"""Quickstart: online auto-tuning through the one front door, `repro.tune`.

    PYTHONPATH=src python examples/quickstart.py             # real backend
    PYTHONPATH=src python examples/quickstart.py --virtual   # CI smoke

The whole integration is ~20 lines: build a ``repro.TuningSession``,
decorate your jax function with ``@repro.tuned(space=...)``, and keep
calling it. The session explores machine-code variants *while the
application runs* — each tuning point's keys are baked into the function
as trace-time constants (the paper's run-time specialization), variants
compile off the hot path, and the active function pointer swaps when a
variant measures faster, all under a bounded overhead budget.

``--virtual`` runs the same control loop on a ``VirtualClock`` (costs
declared, no sleeps, bit-deterministic) — the no-hardware smoke CI runs.
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import repro
from repro.core import Param, product_space


def main() -> None:
    import jax
    import jax.numpy as jnp

    from repro.kernels.euclid.ref import euclid_ref

    N, M, D = 2048, 64, 64           # points × centers × dimension
    x = jax.random.normal(jax.random.PRNGKey(0), (N, D), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (M, D), jnp.float32)

    # --- the canonical ~20-line integration --------------------------------
    session = repro.TuningSession(repro.TuningConfig(
        max_overhead=0.05, invest=0.5, pump_every=2))

    @repro.tuned(session=session, space=product_space([
        Param("chunk", (8, 16, 32, 64), phase=1)]))
    def distances(x, c, *, chunk):
        # Streamcluster euclidean distances, the paper's CPU-bound kernel:
        # `chunk` is a trace-time constant, so every point unrolls into
        # its own compiled variant (the deGoal specialization analogue)
        acc = jnp.zeros((x.shape[0], c.shape[0]), jnp.float32)
        for i in range(0, x.shape[1], chunk):
            diff = x[:, None, i:i + chunk] - c[None, :, i:i + chunk]
            acc = acc + jnp.sum(diff * diff, axis=-1)
        return acc

    t0 = time.perf_counter()
    calls = 200
    for _ in range(calls):
        out = distances(x, c)        # the application just calls the kernel
    jax.block_until_ready(out)
    wall = time.perf_counter() - t0
    # -----------------------------------------------------------------------

    s = distances.stats()
    print(f"app ran {calls} kernel calls in {wall*1e3:.0f} ms")
    print(f"explored {s['n_explored']} variants, {s['swaps']} swaps, "
          f"tuning overhead {s['tuning_spent_s']/wall:.1%}")
    print(f"reference {s['reference_score_s']*1e6:.0f} us/call -> "
          f"active {s['active_score_s']*1e6:.0f} us/call")
    print(f"best point: {distances.best_point}")

    err = jnp.abs(distances(x, c) - euclid_ref(x, c)).max()
    print(f"max abs err vs oracle: {float(err):.2e}")
    session.close()
    if float(err) > 1e-3:
        raise SystemExit("tuned kernel diverged from the oracle")


def main_virtual() -> None:
    """The same loop, deterministic: declared costs, VirtualClock, no sleeps."""
    from repro.core import VirtualClock, VirtualClockEvaluator

    clock = VirtualClock()
    # gate_mode="canary": every variant passes the oracle gate, then
    # serves a canary fraction of calls before promotion — the trusted
    # swaps path the fault-injection scenarios exercise under traffic
    session = repro.TuningSession(repro.TuningConfig(
        max_overhead=1.0, invest=0.5, pump_every=1,
        gate_mode="canary", canary_fraction=0.5, canary_calls=4),
        clock=clock)

    def cost(unroll: int) -> float:
        return 0.010 / unroll        # known optimum: the largest unroll

    @repro.tuned(session=session, jit=False, gen_cost_s=0.002,
                 space=product_space([Param("unroll", (1, 2, 4, 8),
                                            phase=1)]),
                 evaluator=VirtualClockEvaluator(
                     clock, score_fn=lambda f: cost(f.point["unroll"])))
    def kernel(step, *, unroll):
        clock.advance(cost(unroll))  # 'execution' burns simulated time
        return step

    # run the full trace: the last candidate still needs to serve its
    # canary probation (canary_calls canaried calls) after the explorer
    # finishes before it can be promoted to incumbent
    for step in range(400):
        kernel(step)

    s = kernel.stats()
    print(f"virtual: explored {s['n_explored']} variants in "
          f"{clock():.3f} simulated s, best {kernel.best_point}, "
          f"gen stall {s['gen_stall_s']:.3f} s")
    print(f"trusted swaps: {s['gate_checks']} gate checks "
          f"({s['gate_failures']} failed), {s['canary_calls']} canary "
          f"calls, {s['canary_promotions']} promotions, "
          f"{s['rollbacks']} rollbacks, {s['quarantined']} quarantined")
    session.close()
    if kernel.best_point != {"unroll": 8}:
        raise SystemExit(f"did not converge to the optimum: "
                         f"{kernel.best_point}")
    if s["gen_stall_s"] != 0.0:
        raise SystemExit("async generation stalled the hot path")
    if s["canary_promotions"] < 1:
        raise SystemExit("no variant survived its canary probation")
    if s["rollbacks"] or s["quarantined"] or s["gate_failures"]:
        raise SystemExit("clean variants tripped the trusted-swaps "
                         "defenses (expected none)")


def main_fleet() -> None:
    """Two-replica fleet: one shared backend, disjoint exploration.

    Each replica hash-owns half the search space (``replica_id`` /
    ``replica_count``), publishes its measurements and best through the
    shared ``registry_backend``, and adopts the peer's best as a gated
    CANDIDATE — so the fleet pays for each variant's compile once and
    both replicas converge to the same optimum. Swap the in-memory
    ``FleetBus`` for ``registry_backend="shared:/tmp/fleet.json"`` to
    run real replicas in separate processes against one file.
    """
    from repro.core import FleetBus, VirtualClock, VirtualClockEvaluator

    bus = FleetBus()

    def cost(p) -> float:
        return 0.010 / p["unroll"] + 0.001 * p["lane"]

    kernels, clocks = [], []
    for rid in range(2):
        clock = VirtualClock()
        session = repro.TuningSession(repro.TuningConfig(
            max_overhead=1.0, invest=0.5, pump_every=1,
            replica_id=rid, replica_count=2, sync_every_s=0.05),
            clock=clock, registry_backend=bus)

        def make(session, clock):
            @repro.tuned(session=session, jit=False, gen_cost_s=0.002,
                         space=product_space([
                             Param("unroll", (1, 2, 4, 8), phase=1),
                             Param("lane", (0, 1, 2, 3), phase=1)]),
                         evaluator=VirtualClockEvaluator(
                             clock, score_fn=lambda f: cost(f.point)))
            def kernel(step, *, unroll, lane):
                clock.advance(cost({"unroll": unroll, "lane": lane}))
                return step
            return kernel

        kernels.append((make(session, clock), session))
        clocks.append(clock)

    for step in range(800):
        for kernel, _ in kernels:
            kernel(step)

    total = 0
    for rid, (kernel, session) in enumerate(kernels):
        s = kernel.stats()
        total += s["n_explored"]
        print(f"replica {rid}: explored {s['n_explored']}/16 variants "
              f"in {clocks[rid]():.3f} simulated s, "
              f"best {kernel.best_point}")
        if s["n_explored"] >= 16:
            raise SystemExit(f"replica {rid} explored the whole space — "
                             "partitioning did not stick")
        if kernel.best_point != {"unroll": 8, "lane": 0}:
            raise SystemExit(f"replica {rid} missed the fleet optimum: "
                             f"{kernel.best_point}")
        session.close()
    # 16 points compiled once per fleet, plus at most a couple of
    # peer-best re-validations (the CANDIDATE path measures locally)
    print(f"fleet total: {total} evaluations for a 16-point space")
    if total > 20:
        raise SystemExit("fleet re-compiled peers' work")


def main_transfer() -> None:
    """Transfer plane: an UNSEEN device warm-starts from a similar one.

    Device A tunes a 16-point space to convergence and publishes its
    best into a shared registry — stamped with its ``DeviceTraits``.
    Device B has a fingerprint the registry has *never* seen, so the
    exact warm start misses; with ``transfer=True`` the nearest-
    fingerprint lookup ranks A's best by trait similarity and injects
    it as a gated CANDIDATE seed. B serves the fleet optimum within two
    regenerations instead of re-sweeping the space from cold.
    """
    from repro.core import TunedRegistry, VirtualClock, VirtualClockEvaluator

    registry = TunedRegistry()   # shared across both devices

    def cost(rate, p) -> float:
        return rate / p["unroll"] + 0.0005 * p["lane"]

    def bring_up(device, rate, transfer, calls):
        clock = VirtualClock()
        session = repro.TuningSession(repro.TuningConfig(
            max_overhead=1.0, invest=0.5, pump_every=1,
            gate_mode="check", transfer=transfer),
            clock=clock, registry=registry, device=device)

        @repro.tuned(session=session, jit=False, gen_cost_s=0.002,
                     space=product_space([
                         Param("unroll", (1, 2, 4, 8), phase=1),
                         Param("lane", (0, 1, 2, 3), phase=1)]),
                     evaluator=VirtualClockEvaluator(
                         clock, score_fn=lambda f: cost(rate, f.point)))
        def kernel(step, *, unroll, lane):
            clock.advance(cost(rate, {"unroll": unroll, "lane": lane}))
            return step

        for step in range(calls):
            kernel(step)
        return kernel, session

    # device A: a known core explores all 16 points and publishes its
    # best (trait-stamped) into the shared registry
    k_a, s_a = bring_up("gpu:sim-a", 0.010, False, 600)
    sa = k_a.stats()
    print(f"device A (cold): explored {sa['n_explored']}/16 variants, "
          f"best {k_a.best_point}")
    s_a.close()

    # device B: same platform, different silicon (20% slower clock) and
    # a fingerprint no registry entry matches — only the transfer plane
    # can warm it up, and only through the gate
    k_b, s_b = bring_up("gpu:sim-b", 0.012, True, 40)
    sb = k_b.stats()
    fleet = s_b.stats()
    print(f"device B (transfer): {fleet['transfer_hits']} seeds injected, "
          f"{fleet['transfer_adopted']} adopted, best found in "
          f"{fleet['seeded_regens_to_best']:.0f} regen(s) after "
          f"{sb['n_explored']} evaluations ({sb['gate_checks']} gate "
          f"checks), best {k_b.best_point}")
    s_b.close()

    if k_a.best_point != {"unroll": 8, "lane": 0}:
        raise SystemExit(f"device A missed the optimum: {k_a.best_point}")
    if fleet["transfer_hits"] < 1 or not k_b.handle.transfer_seed_keys:
        raise SystemExit("no transfer seeds reached device B")
    if k_b.best_point != {"unroll": 8, "lane": 0}:
        raise SystemExit(f"device B missed the optimum: {k_b.best_point}")
    if fleet["seeded_regens_to_best"] is None \
            or fleet["seeded_regens_to_best"] > 2:
        raise SystemExit("transfer seed did not shortcut the search "
                         f"(regens to best: {fleet['seeded_regens_to_best']})")
    if sb["gate_checks"] < 1:
        raise SystemExit("transfer seed bypassed the gate")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--virtual", action="store_true",
                    help="deterministic VirtualClock smoke (no hardware, "
                         "no sleeps) — what CI runs")
    ap.add_argument("--fleet", action="store_true",
                    help="two-replica fleet demo: shared registry backend "
                         "+ partitioned exploration (virtual, no hardware)")
    ap.add_argument("--transfer", action="store_true",
                    help="transfer-plane demo: an unseen device warm-"
                         "starts from a trait-similar one (virtual)")
    args = ap.parse_args()
    if args.transfer:
        main_transfer()
    elif args.fleet:
        main_fleet()
    elif args.virtual:
        main_virtual()
    else:
        main()
