"""Kernel-granular tuning plane: catalog, compilettes, coordinator handles.

Control-loop tests run deterministically on the ``VirtualClock`` with the
catalog's *virtual* backend (variants priced by the analytical cost
models, compile cost declared); the catalog/AOT tests build and run the
real (interpret-mode) kernels at tiny shapes.
"""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    Param,
    RegenerationPolicy,
    TPU_V5E,
    VirtualClock,
    VirtualClockEvaluator,
    product_space,
    virtual_compilette,
    virtual_kernel,
)
from repro.kernels import KernelCompilette, KernelDef, get_catalog
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.kernel_plane import (
    KernelTuningPlane,
    active_plane,
    parse_kernel_strategies,
    use_kernel_plane,
)
from repro.runtime.lifecycle import TunerLifecycle, TunerState

# Shapes at which every kernel has a rich valid space (virtual tests).
SPECS = {
    "matmul": {"M": 512, "N": 512, "K": 512, "dtype": "float32"},
    "attention": {"B": 4, "Tq": 512, "Tkv": 512, "H": 8, "Hk": 4,
                  "Dh": 64, "causal": True, "dtype": "float32"},
    "rmsnorm": {"N": 2048, "d": 512, "dtype": "float32"},
}

GEN_COST = 0.002


def first_valid(comp):
    return next(iter(comp.space.iter_valid()))


def make_virtual_plane(clock, coord, **kw):
    return KernelTuningPlane(
        coord, virtual=(clock, TPU_V5E), gen_cost_s=GEN_COST,
        evaluator_factory=lambda c: VirtualClockEvaluator(clock), **kw)


# ---------------------------------------------------------------- catalog
def test_catalog_discovers_every_ops_compilette():
    """Every kernels/*/ops.py module must expose a registered KERNEL."""
    import repro.kernels as pkg

    expected = set()
    for root in pkg.__path__:
        for entry in pathlib.Path(root).iterdir():
            if (entry / "ops.py").is_file():
                expected.add(entry.name)
    assert expected, "kernel packages vanished?"
    cat = get_catalog()
    assert set(cat.names()) == expected
    for name in expected:
        defn = cat.get(name)
        assert isinstance(defn, KernelDef) and defn.name == name


@pytest.mark.parametrize("name,spec", [
    ("matmul", {"M": 64, "N": 128, "K": 128, "dtype": "float32"}),
    ("attention", {"B": 1, "Tq": 16, "Tkv": 16, "H": 2, "Hk": 1, "Dh": 8,
                   "causal": True, "dtype": "float32"}),
    ("rmsnorm", {"N": 16, "d": 8, "dtype": "float32"}),
    ("lintra", {"H": 8, "W": 16, "bands": 3, "dtype": "float32"}),
    ("euclid", {"N": 128, "M": 64, "D": 32, "dtype": "float32"}),
])
def test_kernel_compilette_builds_and_runs(name, spec):
    """Real backend: generate a variant, run it on example args."""
    comp = get_catalog().compilette(name, spec)
    assert isinstance(comp, KernelCompilette)
    kern = comp.generate(first_valid(comp))
    out = kern.fn(*comp.example_call_args())
    assert np.all(np.isfinite(np.asarray(out, dtype=np.float32)))
    assert kern.generation_time_s > 0


def test_extract_spec_roundtrip():
    """spec → example args → extract_spec is the identity (handles key
    on specs extracted from live arguments)."""
    cat = get_catalog()
    for name, spec in SPECS.items():
        comp = cat.compilette(name, spec)
        extracted = cat.spec_of(name, *comp.example_call_args())
        for k, v in spec.items():
            assert extracted[k] == v, (name, k)


def test_aot_compile_cost_lands_in_generation_time():
    """Satellite: `jit(f).lower(...).compile()` runs inside _generate, so
    the real XLA compile is measured into generation_time_s (charged to
    gen_spent_s) instead of polluting the first evaluation."""
    cat = get_catalog()
    spec = {"N": 64, "d": 32, "dtype": "float32"}
    comp = cat.compilette("rmsnorm", spec, aot=True)
    pt = first_valid(comp)
    kern = comp.generate(pt)
    assert comp.aot_compiles == 1 and comp.aot_fallbacks == 0
    assert kern.generation_time_s > 0
    x, w = comp.example_call_args()
    from repro.kernels.rmsnorm.ops import rmsnorm_ref
    np.testing.assert_allclose(kern.fn(x, w), rmsnorm_ref(x, w),
                               rtol=1e-5, atol=1e-5)
    # lazy mode keeps the pre-PR-4 behaviour
    lazy = cat.compilette("rmsnorm", spec, aot=False)
    kern2 = lazy.generate(pt)
    assert lazy.aot_compiles == 0
    np.testing.assert_allclose(kern2.fn(x, w), rmsnorm_ref(x, w),
                               rtol=1e-5, atol=1e-5)


def test_virtual_backend_prices_by_cost_model():
    clock = VirtualClock()
    comp = get_catalog().compilette(
        "matmul", SPECS["matmul"], virtual=(clock, TPU_V5E),
        gen_cost_s=GEN_COST)
    pt = first_valid(comp)
    kern = comp.generate(pt)
    assert kern.meta["simulated"] and kern.generation_time_s == GEN_COST
    expected = comp.simulate(pt, TPU_V5E)
    assert kern.fn.score_s == pytest.approx(expected)
    t0 = clock()
    kern.fn()
    assert clock() - t0 == pytest.approx(expected)


def test_untunable_spec_is_skippable_not_fatal():
    """A spec at which every point is a hole (tiny euclid) registers as
    None with require=False and raises loudly with require=True."""
    clock = VirtualClock()
    coord = TuningCoordinator(policy=RegenerationPolicy(1.0, 0.5),
                              device="test:v", clock=clock)
    plane = make_virtual_plane(clock, coord)
    dead = {"N": 16, "M": 8, "D": 4, "dtype": "float32"}
    assert plane.register_spec("euclid", dead, require=False) is None
    with pytest.raises(ValueError):
        plane.register_spec("euclid", dead)
    assert coord.stats()["n_kernels"] == 0


# ------------------------------------------------------------- acceptance
def test_kernel_plane_virtual_acceptance():
    """Acceptance: with kernel-granular tuning, matmul/attention/rmsnorm
    each register as an independent coordinator-managed compilette with
    its own strategy and registry key, and stats() reports per-kernel
    gen/stall/eval accounting that sums consistently into the aggregate
    — all deterministic under the VirtualClock."""
    clock = VirtualClock()
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock,
        async_generation=True, prefetch=1)
    plane = make_virtual_plane(
        clock, coord,
        strategies={"matmul": "greedy", "attention": "random"})
    handles = {n: plane.register_spec(n, s) for n, s in SPECS.items()}
    assert all(h is not None for h in handles.values())
    for i in range(3000):
        for h in handles.values():
            h(i)
        coord.maybe_pump()
        if all(h.tuner.explorer.finished for h in handles.values()):
            break
    s = coord.stats()
    assert s["n_kernels"] == 3
    assert set(s["kernels"]) == {"matmul", "attention", "rmsnorm"}
    # per-kernel strategies took effect
    assert s["kernels"]["matmul"]["strategy"] == "greedy"
    assert s["kernels"]["attention"]["strategy"] == "random"
    assert s["kernels"]["rmsnorm"]["strategy"] == "two_phase"
    # independent registry keys: one tuned entry per (kernel, spec),
    # persisted under the source-hashed device fingerprint
    for m in coord._managed:
        coord._flush_best(m)
    by_name = {m.name: m for m in coord._managed}
    for name, spec in SPECS.items():
        dev = by_name[name].registry_device
        assert dev.startswith("test:v:src-"), name
        assert coord.registry.get(name, spec, dev) is not None, name
    # every kernel explored and was billed for generation
    for name, k in s["kernels"].items():
        assert k["regenerations"] > 0, name
        assert k["gen_spent_s"] > 0, name
    # double-buffered pipeline: the budget paid, the hot path never did
    assert s["gen_spent_s"] > 0 and s["gen_stall_s"] == 0.0
    # per-kernel accounting sums consistently into the aggregate
    for f in ("gen_spent_s", "gen_stall_s", "eval_spent_s"):
        rollup = (sum(k[f] for k in s["kernels"].values())
                  + s["retired_accounts"][f])
        assert rollup == pytest.approx(s[f]), f


def test_kernel_plane_shares_budget_with_step_program():
    """Satellite: two catalog kernels + one whole-step-program compilette
    under ONE shared budget — fairness gives every unit slots, the total
    stays within the cap, and a retired unit's accounting survives in
    the tombstone."""
    clock = VirtualClock()
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=0.2, invest_frac=0.5),
        device="test:v", clock=clock, async_generation=True,
        lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=0.05))
    plane = make_virtual_plane(clock, coord)
    k1 = plane.register_spec("matmul", SPECS["matmul"])
    k2 = plane.register_spec("rmsnorm", SPECS["rmsnorm"])
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1)])
    step = coord.register(
        "step_program",
        virtual_compilette(clock, "step_program", sp,
                           lambda p: 0.008 / p["unroll"],
                           gen_cost_s=GEN_COST),
        VirtualClockEvaluator(clock),
        reference_fn=virtual_kernel(clock, 0.008))
    for i in range(3000):
        k1(i)
        k2(i)
        step(i)
        coord.pump()
    s = coord.stats()
    # hierarchical set: step-program and kernels side by side
    assert set(s["kernels"]) == {"matmul", "rmsnorm", "step_program"}
    # fairness under the shared budget: every unit got productive slots
    for name, k in s["kernels"].items():
        assert k["regenerations"] > 0, name
    # one budget bounds the SUM of all tuning time
    assert s["budget_spent_s"] <= s["budget_s"] + 1e-9
    # retire the step program only: kernels keep refreshing last_used
    spent_before = coord._aggregate_accounts().tuning_spent_s
    step_spent = step.tuner.accounts.tuning_spent_s
    assert step_spent > 0
    clock.advance(0.06)
    k1(0)
    k2(0)
    retired = coord.sweep()
    assert retired == [step] and step.state is TunerState.RETIRED
    # the tombstone keeps the shared budget honest
    agg = coord._aggregate_accounts()
    assert agg.tuning_spent_s == pytest.approx(spent_before)
    s = coord.stats()
    assert s["retired_accounts"]["tuning_spent_s"] == pytest.approx(
        step_spent)
    for f in ("gen_spent_s", "gen_stall_s", "eval_spent_s"):
        rollup = (sum(k[f] for k in s["kernels"].values())
                  + s["retired_accounts"][f])
        assert rollup == pytest.approx(s[f]), f


def test_kernel_handles_warm_start_from_registry():
    """A second process (same registry + generation cache + host clock)
    re-validates each kernel's persisted best with one regeneration and
    recompiles nothing."""
    from repro.core import GenerationCache, TunedRegistry

    registry = TunedRegistry()
    cache = GenerationCache()
    clock = VirtualClock()

    def run_process():
        coord = TuningCoordinator(
            policy=RegenerationPolicy(1.0, 0.5), device="test:v",
            clock=clock, registry=registry, async_generation=True,
            generation_cache=cache)
        plane = make_virtual_plane(clock, coord)
        h = plane.register_spec("rmsnorm", SPECS["rmsnorm"])
        # the budget gate paces regenerations at the candidate's full
        # predicted cost (gen + eval), so exhausting the space takes
        # ~space_size * gen_cost / per-call-cost iterations
        for i in range(6000):
            h(i)
            coord.pump()
            if h.tuner.explorer.finished:
                break
        for m in coord._managed:
            coord._flush_best(m)
        return h, coord.stats()

    h_cold, s_cold = run_process()
    assert h_cold.tuner.explorer.finished
    assert s_cold["gen_spent_s"] > 0
    h_warm, s_warm = run_process()
    assert h_warm.warm_started
    # the warm process re-proposes only cold-compiled points: pure hits
    assert s_warm["gen_spent_s"] == 0.0
    assert s_warm["gen_stall_s"] == 0.0
    assert (h_warm.tuner.explorer.best_point
            == h_cold.tuner.explorer.best_point)


def test_shared_plane_is_one_per_coordinator():
    """Serve builds its plane via shared(): request 2+ must reuse the
    handle memo and live-args table, not rebuild compilettes."""
    clock = VirtualClock()
    coord = TuningCoordinator(policy=RegenerationPolicy(1.0, 0.5),
                              device="test:v", clock=clock)
    p1 = KernelTuningPlane.shared(
        coord, virtual=(clock, TPU_V5E), gen_cost_s=GEN_COST,
        evaluator_factory=lambda c: VirtualClockEvaluator(clock))
    p2 = KernelTuningPlane.shared(coord)
    assert p1 is p2
    h1 = p1.register_spec("rmsnorm", SPECS["rmsnorm"])
    h2 = p2.register_spec("rmsnorm", SPECS["rmsnorm"])
    assert h1 is h2
    # a different coordinator gets its own plane
    other = TuningCoordinator(policy=RegenerationPolicy(1.0, 0.5),
                              device="test:v", clock=clock)
    assert KernelTuningPlane.shared(other) is not p1


def test_shared_plane_reapplies_mutable_config():
    """A request that switches tuning mode must not inherit a stale
    adopt_points/strategies from the memoized plane."""
    clock = VirtualClock()
    coord = TuningCoordinator(policy=RegenerationPolicy(1.0, 0.5),
                              device="test:v", clock=clock)
    p = KernelTuningPlane.shared(coord, adopt_points=True,
                                 strategies={"matmul": "greedy"})
    assert p.adopt_points and p.strategies == {"matmul": "greedy"}
    p2 = KernelTuningPlane.shared(coord, adopt_points=False,
                                  strategies={"rmsnorm": "random"})
    assert p2 is p
    assert p.adopt_points is False
    assert p.strategies == {"matmul": "greedy", "rmsnorm": "random"}


def test_converged_handle_releases_live_args():
    """Live call arguments are pinned only while the handle can still
    evaluate: convergence must drop the plane's reference too (the
    lifecycle already releases the evaluator closure)."""
    import jax.numpy as jnp2

    coord = TuningCoordinator(policy=RegenerationPolicy(1.0, 0.5),
                              device="test:r")
    plane = KernelTuningPlane(coord, aot=False)
    x = jnp2.ones((16, 8), jnp2.float32)
    w = jnp2.ones((8,), jnp2.float32)
    for i in range(60):
        out = plane.call("rmsnorm", x, w)
        assert out is not None
        coord.pump()
        if all(m.tuner.explorer.finished for m in coord._managed):
            break
    coord.sweep()
    (m,) = coord._managed
    assert m.state is TunerState.CONVERGED
    assert m.tuner.evaluator.make_args is None
    # explicit prune releases the pinned live arguments…
    plane.prune_released()
    assert plane._live_args == {}
    # …and the fast-path memo still serves the converged best function
    # without re-pinning anything
    assert plane.call("rmsnorm", x, w) is not None
    assert plane._live_args == {}
    coord.close()


def test_parse_kernel_strategies_validates_both_sides():
    assert parse_kernel_strategies([]) is None
    assert parse_kernel_strategies(
        ["matmul=greedy", "attention=random"]) == {
            "matmul": "greedy", "attention": "random"}
    with pytest.raises(SystemExit):          # typo'd kernel: fail fast
        parse_kernel_strategies(["matmull=greedy"])
    with pytest.raises(SystemExit):          # unknown strategy
        parse_kernel_strategies(["matmul=simulated_annealing"])
    with pytest.raises(SystemExit):          # missing '='
        parse_kernel_strategies(["matmul"])


# ------------------------------------------------------ layers integration
def test_layers_route_rmsnorm_through_plane():
    coord = TuningCoordinator(policy=RegenerationPolicy(1.0, 0.5),
                              device="test:r")
    plane = KernelTuningPlane(coord)
    from repro.models import layers

    x = jax.random.normal(jax.random.PRNGKey(0), (2, 8, 16), jnp.float32)
    w = jnp.ones((16,), jnp.float32)
    ref = layers.rms_norm(x, w)
    assert active_plane() is None
    with use_kernel_plane(plane):
        out = layers.rms_norm(x, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    (m,) = coord._managed
    assert m.name == "rmsnorm"
    assert m.tuner.accounts.kernel_calls == 1
    # inside a jit trace the plane must NOT intercept (tracer args)…
    jitted = jax.jit(lambda x, w: layers.rms_norm(x, w))
    with use_kernel_plane(plane):
        out2 = jitted(x, w)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # …so no new handle appeared and no extra managed call was counted
    assert len(coord._managed) == 1
    assert m.tuner.accounts.kernel_calls == 1
    coord.close()


def test_traced_programs_adopt_tuned_attention_chunks():
    """Trace-time half of the plane: a jitted step-program picks up the
    attention kernel's best block sizes instead of cfg's hard-coded
    chunks — unless a program-level tuner owns those knobs."""
    from repro.configs import REGISTRY
    from repro.models.layers import plane_attn_chunks

    cfg = REGISTRY["deepseek-7b"].reduced()
    clock = VirtualClock()
    coord = TuningCoordinator(policy=RegenerationPolicy(1.0, 0.5),
                              device="test:v", clock=clock)
    plane = make_virtual_plane(clock, coord)
    h = plane.register_spec("attention", SPECS["attention"])
    for i in range(2000):
        h(i)
        coord.pump()
        if h.tuner.explorer.finished:
            break
    best = h.tuner.explorer.best_point
    assert best is not None
    assert plane_attn_chunks(cfg) == (cfg.attn_q_chunk, cfg.attn_k_chunk)
    with use_kernel_plane(plane):
        assert plane_attn_chunks(cfg) == (best["block_q"],
                                          best["block_kv"])
    # "both" mode: program points own the chunk knobs — no adoption
    plane.adopt_points = False
    with use_kernel_plane(plane):
        assert plane_attn_chunks(cfg) == (cfg.attn_q_chunk,
                                          cfg.attn_k_chunk)


# ------------------------------------------------- source-hash identity
def test_discovery_stamps_source_hash_of_ops_py():
    """Satellite: every discovered KERNEL carries the sha256 prefix of
    its defining ops.py, and the compilette turns it into a persistence
    fingerprint + cache-token suffix."""
    import hashlib
    import repro.kernels as pkg

    cat = get_catalog()
    for name in cat.names():
        defn = cat.get(name)
        src = None
        for root in pkg.__path__:
            p = pathlib.Path(root) / name / "ops.py"
            if p.is_file():
                src = p
                break
        assert src is not None, name
        expect = hashlib.sha256(src.read_bytes()).hexdigest()[:12]
        assert defn.source_hash == expect, name
    comp = cat.compilette("rmsnorm", {"N": 16, "d": 8, "dtype": "float32"})
    h = cat.get("rmsnorm").source_hash
    assert comp.fingerprint_extra == f"src-{h}"
    assert comp.cache_token.endswith(f"src-{h}")


def test_edited_kernel_source_cold_starts_only_that_kernel():
    """Changing a kernel's source hash must miss its persisted best (the
    tuned point may be wrong for the new code) while an unchanged hash
    still warm-starts — and the registry fallback chain never crosses
    from one hash to another."""
    import dataclasses

    from repro.core import TunedRegistry

    registry = TunedRegistry()
    clock = VirtualClock()
    defn = get_catalog().get("rmsnorm")

    def run(source_hash):
        coord = TuningCoordinator(
            policy=RegenerationPolicy(1.0, 0.5), device="test:v",
            clock=clock, registry=registry, async_generation=True)
        comp = KernelCompilette(
            dataclasses.replace(defn, source_hash=source_hash),
            SPECS["rmsnorm"], virtual=(clock, TPU_V5E), gen_cost_s=GEN_COST)
        h = coord.register("rmsnorm", comp,
                           VirtualClockEvaluator(clock))
        for i in range(6000):
            h(i)
            coord.pump()
            if h.tuner.explorer.finished:
                break
        for m in coord._managed:
            coord._flush_best(m)
        return h

    cold = run("aaaa00000001")
    assert cold.tuner.explorer.finished
    assert cold.registry_device == "test:v:src-aaaa00000001"
    # same source: warm start hits the persisted best
    same = run("aaaa00000001")
    assert same.warm_started
    # edited source (different hash): cold start — stale best never leaks
    edited = run("bbbb00000002")
    assert not edited.warm_started
