"""Tuning space + two-phase explorer: unit + property tests."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import Param, TwoPhaseExplorer, TuningSpace, product_space


def space_2p(validator=lambda p: True, no_leftover=lambda p: True):
    return TuningSpace(
        params=(
            Param("hotUF", (1, 2, 4), phase=1, switch_rank=0),
            Param("coldUF", (1, 2, 4, 8), phase=1, switch_rank=1),
            Param("vectLen", (1, 2, 4), phase=1, switch_rank=2),
            Param("IS", (0, 1), phase=2),
            Param("SM", (0, 1), phase=2),
        ),
        validator=validator,
        no_leftover=no_leftover,
    )


def test_eq1_variant_count():
    sp = space_2p()
    # Eq. (1): product of range sizes
    assert sp.n_code_variants == 3 * 4 * 3 * 2 * 2


def test_holes_reduce_valid_count():
    sp = space_2p(validator=lambda p: p["hotUF"] * p["vectLen"] <= 4)
    assert sp.n_valid_variants() < sp.n_code_variants
    for point in sp.iter_valid():
        assert point["hotUF"] * point["vectLen"] <= 4


def test_phase1_order_least_to_most_switched():
    sp = space_2p()
    pts = list(sp.iter_phase1(sp.default_point()))
    # least-switched param (hotUF) changes slowest
    hot = [p["hotUF"] for p in pts]
    assert hot == sorted(hot)


def test_explorer_two_phases_and_dedup():
    sp = space_2p()
    ex = TwoPhaseExplorer(sp)
    seen = set()
    n = 0
    while True:
        pt = ex.next_point()
        if pt is None:
            break
        key = sp.key(pt)
        assert key not in seen
        seen.add(key)
        n += 1
        ex.report(pt, float(n))  # first point stays best
    # phase1 grid (36) + phase2 combos of the best (4, one dup) = 39
    assert n == 36 + 3
    assert ex.finished


def test_explorer_leftover_free_first():
    sp = space_2p(no_leftover=lambda p: p["coldUF"] <= 2)
    ex = TwoPhaseExplorer(sp)
    ranks = []
    while True:
        pt = ex.next_point()
        if pt is None or ex.state.phase == 2:
            break
        ranks.append(0 if pt["coldUF"] <= 2 else 1)
        ex.report(pt, 1.0)
    # all leftover-free points precede leftover ones
    assert ranks == sorted(ranks)


@settings(max_examples=30, deadline=None)
@given(
    costs=st.dictionaries(
        st.tuples(st.sampled_from([1, 2, 4]), st.sampled_from([1, 2, 4, 8])),
        st.floats(0.001, 1.0),
        min_size=1,
    )
)
def test_explorer_finds_global_minimum_property(costs):
    """The explorer's best equals the true minimum over visited points."""
    sp = TuningSpace(params=(
        Param("a", (1, 2, 4), phase=1, switch_rank=0),
        Param("b", (1, 2, 4, 8), phase=1, switch_rank=1),
    ))

    def cost(p):
        return costs.get((p["a"], p["b"]), 0.5)

    ex = TwoPhaseExplorer(sp)
    best, score = ex.run_to_completion(cost)
    all_costs = [cost(p) for p in sp.iter_valid()]
    assert math.isclose(score, min(all_costs), rel_tol=1e-9)


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_explorer_never_proposes_holes(seed):
    import random

    rng = random.Random(seed)
    banned = {(a, b) for a in (1, 2, 4) for b in (1, 2, 4, 8)
              if rng.random() < 0.4}
    # keep at least one valid point
    if len(banned) == 12:
        banned.pop()
    sp = TuningSpace(
        params=(
            Param("a", (1, 2, 4), phase=1, switch_rank=0),
            Param("b", (1, 2, 4, 8), phase=1, switch_rank=1),
        ),
        validator=lambda p: (p["a"], p["b"]) not in banned,
    )
    ex = TwoPhaseExplorer(sp)
    while True:
        pt = ex.next_point()
        if pt is None:
            break
        assert (pt["a"], pt["b"]) not in banned
        ex.report(pt, 1.0)
