"""Deterministic fallback for `hypothesis` when the package is absent.

The tier-1 suite must collect and run in minimal containers (no optional
test extras). When the real `hypothesis` is importable, conftest.py leaves
it alone and this module is never used. Otherwise conftest installs this
module under the name ``hypothesis``: property tests degrade to a seeded,
reproducible sweep of examples drawn from the same strategy expressions.

Only the strategy surface the test suite uses is implemented:
``floats``, ``integers``, ``sampled_from``, ``tuples``, ``dictionaries``.
Example draws are seeded per test function (CRC of the qualified name), so
a failure reproduces bit-identically across runs and machines.
"""

from __future__ import annotations

import functools
import inspect
import random
import types
import zlib
from typing import Any, Callable

DEFAULT_MAX_EXAMPLES = 20


class SearchStrategy:
    def __init__(self, draw: Callable[[random.Random], Any]) -> None:
        self._draw = draw

    def draw(self, rng: random.Random) -> Any:
        return self._draw(rng)

    def map(self, fn: Callable[[Any], Any]) -> "SearchStrategy":
        return SearchStrategy(lambda rng: fn(self._draw(rng)))

    def filter(self, pred: Callable[[Any], bool]) -> "SearchStrategy":
        def draw(rng: random.Random) -> Any:
            for _ in range(1000):
                v = self._draw(rng)
                if pred(v):
                    return v
            raise RuntimeError("filter predicate rejected 1000 draws")
        return SearchStrategy(draw)


def floats(min_value: float = 0.0, max_value: float = 1.0, **_: Any) -> SearchStrategy:
    lo, hi = float(min_value), float(max_value)

    def draw(rng: random.Random) -> float:
        # Bias towards the edges occasionally: boundary values are where
        # budget/validity predicates break.
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.uniform(lo, hi)

    return SearchStrategy(draw)


def integers(min_value: int = 0, max_value: int = 1 << 30, **_: Any) -> SearchStrategy:
    lo, hi = int(min_value), int(max_value)

    def draw(rng: random.Random) -> int:
        r = rng.random()
        if r < 0.05:
            return lo
        if r < 0.10:
            return hi
        return rng.randint(lo, hi)

    return SearchStrategy(draw)


def sampled_from(options: Any) -> SearchStrategy:
    opts = list(options)
    return SearchStrategy(lambda rng: opts[rng.randrange(len(opts))])


def tuples(*strats: SearchStrategy) -> SearchStrategy:
    return SearchStrategy(lambda rng: tuple(s.draw(rng) for s in strats))


def lists(elements: SearchStrategy, min_size: int = 0, max_size: int = 8,
          **_: Any) -> SearchStrategy:
    def draw(rng: random.Random) -> list:
        n = rng.randint(min_size, max(max_size, min_size))
        return [elements.draw(rng) for _ in range(n)]
    return SearchStrategy(draw)


def dictionaries(keys: SearchStrategy, values: SearchStrategy,
                 min_size: int = 0, max_size: int = 8, **_: Any) -> SearchStrategy:
    def draw(rng: random.Random) -> dict:
        want = rng.randint(min_size, max(max_size, min_size))
        out: dict = {}
        # Key strategies over small finite domains collide; cap the attempts
        # so a domain smaller than min_size cannot loop forever.
        for _ in range(50 * (want + 1)):
            if len(out) >= want:
                break
            out[keys.draw(rng)] = values.draw(rng)
        return out
    return SearchStrategy(draw)


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def given(*arg_strats: SearchStrategy, **kw_strats: SearchStrategy):
    """Decorator: run the test once per drawn example (seeded per test)."""

    def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
        sig = inspect.signature(fn)
        names = [n for n in sig.parameters if n not in kw_strats]
        # hypothesis binds positional strategies to the RIGHTMOST
        # parameters (the left ones stay free for pytest fixtures)
        pos_names = names[len(names) - len(arg_strats):] if arg_strats else []
        drawn_names = set(kw_strats) | set(pos_names)

        @functools.wraps(fn)
        def wrapper(*args: Any, **kwargs: Any) -> None:
            n = getattr(wrapper, "_stub_max_examples", DEFAULT_MAX_EXAMPLES)
            rng = random.Random(zlib.crc32(fn.__qualname__.encode()))
            for _ in range(n):
                drawn = {name: s.draw(rng)
                         for name, s in zip(pos_names, arg_strats)}
                drawn.update((k, s.draw(rng)) for k, s in kw_strats.items())
                fn(*args, **drawn, **kwargs)

        # pytest must not treat the drawn parameters as fixtures: expose a
        # signature with them removed (and drop __wrapped__, which pytest
        # would otherwise follow back to the original signature).
        del wrapper.__wrapped__
        keep = [p for name, p in sig.parameters.items()
                if name not in drawn_names]
        wrapper.__signature__ = sig.replace(parameters=keep)  # type: ignore[attr-defined]
        wrapper._stub_is_hypothesis = True  # type: ignore[attr-defined]
        return wrapper

    return deco


def settings(max_examples: int = DEFAULT_MAX_EXAMPLES, **_: Any):
    """Decorator (applied above @given): caps the example count."""

    def deco(fn: Callable[..., Any]) -> Callable[..., Any]:
        fn._stub_max_examples = max_examples  # type: ignore[attr-defined]
        return fn

    return deco


# ``from hypothesis import strategies as st`` needs a module-like attribute.
strategies = types.ModuleType("hypothesis.strategies")
for _name in ("floats", "integers", "sampled_from", "tuples", "lists",
              "dictionaries", "just", "booleans", "SearchStrategy"):
    setattr(strategies, _name, globals()[_name])
