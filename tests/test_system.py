"""End-to-end system behaviour: the paper's full pipeline in miniature.

Runs the online auto-tuner on the two case-study kernels on the REAL
backend (XLA:CPU machine-code variants), checks paper-shaped claims:
positive speedup direction, bounded overhead, online result close to the
static optimum.
"""

import jax
import jax.numpy as jnp
import pytest

from repro.core import (
    Evaluator, OnlineAutotuner, RegenerationPolicy, TwoPhaseExplorer,
    static_autotune)
from repro.kernels.euclid.ops import (
    make_euclid_compilette, reference_sisd)
from repro.kernels.lintra.ops import (
    make_lintra_compilette, reference_sisd as lintra_ref_sisd)


@pytest.fixture(scope="module")
def euclid_inputs():
    N, M, D = 512, 64, 64
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (N, D), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (M, D), jnp.float32)
    return N, M, D, x, c


def test_online_autotune_euclid_end_to_end(euclid_inputs):
    N, M, D, x, c = euclid_inputs
    comp = make_euclid_compilette(N, M, D, backend="jnp")
    ev = Evaluator(mode="training", groups=2, group_size=3,
                   make_args=lambda: (x, c))
    ref = reference_sisd(D)
    # generous budget: this test checks the mechanism (swap correctness),
    # not pacing; CI hosts can be heavily loaded.
    at = OnlineAutotuner(
        comp, ev, policy=RegenerationPolicy(5.0, 0.9),
        specialization={"dim": D}, reference_fn=jax.jit(ref), wake_every=1)
    for i in range(60):
        at(x, c)
    s = at.stats()
    assert s["regenerations"] > 5
    # the tuner must never activate a slower-than-reference kernel
    assert s["active_score_s"] <= s["reference_score_s"] * 1.05
    # correctness of the tuned kernel
    import numpy as np
    from repro.kernels.euclid.ops import euclid_ref
    np.testing.assert_allclose(at.active_fn(x, c), euclid_ref(x, c),
                               rtol=1e-3, atol=1e-3)


@pytest.mark.flaky(reruns=2)
def test_online_close_to_static_optimum(euclid_inputs):
    """Paper: online lands within ~6 % of the best static variant. Timing
    noise on a loaded shared CPU can spread independent measurements of the
    same variant by >2x, so the asserted bound is deliberately loose; the
    benchmark harness (table3) reports the measured gap."""
    N, M, D, x, c = euclid_inputs
    comp = make_euclid_compilette(N, M, D, backend="jnp")
    ev = Evaluator(mode="training", groups=2, group_size=3,
                   make_args=lambda: (x, c))

    at = OnlineAutotuner(comp, ev, policy=RegenerationPolicy(0.9, 0.9),
                         specialization={"dim": D}, wake_every=1)
    at.exhaust(max_wakes=80)
    online_best = at.explorer.best_score

    best_pt, best_score, hist = static_autotune(
        comp, ev, specialization={"dim": D}, only_no_leftover=True,
        max_points=40)
    assert online_best <= best_score * 3.0


def test_lintra_memory_bound_overhead_negligible():
    H, W, bands = 128, 200, 3
    img = jax.random.normal(jax.random.PRNGKey(0), (H, W, bands))
    a = jnp.array([1.5, 0.5, 2.0])
    b = jnp.array([0.1, -0.2, 0.3])
    comp = make_lintra_compilette(H, W, bands, backend="jnp")
    ev = Evaluator(mode="training", groups=1, group_size=3,
                   make_args=lambda: (img, a, b))
    at = OnlineAutotuner(
        comp, ev, policy=RegenerationPolicy(max_overhead_frac=0.05,
                                            invest_frac=0.1),
        specialization={"bands": bands, "width": W},
        reference_fn=jax.jit(lintra_ref_sisd(bands, W)), wake_every=2)
    for _ in range(200):
        at(img, a, b)
    s = at.stats()
    # overhead bounded even if nothing better is found (paper's claim).
    # The bound is loose because the first regeneration is admitted before
    # any cost estimate exists (cold start) and CI hosts run loaded.
    assert s["overhead_frac"] < 0.6
    import numpy as np
    from repro.kernels.lintra.ops import lintra_ref
    np.testing.assert_allclose(at.active_fn(img, a, b),
                               lintra_ref(img, a, b), rtol=1e-4, atol=1e-4)


def test_two_phase_explores_fewer_than_full_space(euclid_inputs):
    """Paper Table 4: two-phase exploration visits far fewer variants than
    the full space in one run."""
    N, M, D, x, c = euclid_inputs
    comp = make_euclid_compilette(N, M, D)
    full = comp.space.n_valid_variants()
    ex = TwoPhaseExplorer(comp.space)
    n = 0
    while True:
        pt = ex.next_point()
        if pt is None:
            break
        ex.report(pt, 1.0)
        n += 1
    assert n < full / 2, (n, full)
