"""Online auto-tuner: decision policy, filtering, replacement, overheads."""

import time

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Compilette, Evaluator, OnlineAutotuner, Param, RegenerationPolicy,
    SimulatedEvaluator, TuningAccounts, filtered_training_time, product_space,
)
from repro.core.profiles import ALL_PROFILES, DI_F1, SI_L1


# --------------------------------------------------------------- decision
@settings(max_examples=50, deadline=None)
@given(
    spent=st.floats(0, 10),
    gained=st.floats(0, 100),
    elapsed=st.floats(0.01, 1000),
    frac=st.floats(0.001, 0.2),
    invest=st.floats(0, 1),
)
def test_budget_monotonicity(spent, gained, elapsed, frac, invest):
    pol = RegenerationPolicy(max_overhead_frac=frac, invest_frac=invest)
    acc = TuningAccounts(app_start_s=0.0, tuning_spent_s=spent, gained_s=gained)
    budget = pol.budget_s(acc, elapsed)
    assert budget >= frac * elapsed - 1e-12
    # investment can only increase the budget
    pol0 = RegenerationPolicy(max_overhead_frac=frac, invest_frac=0.0)
    assert budget >= pol0.budget_s(acc, elapsed) - 1e-12
    # decision consistent with the budget
    ok = pol.should_regenerate(acc, elapsed, 0.0)
    assert ok == (spent <= budget)


def test_budget_overhead_bound():
    """If the tuner respects the policy, spent stays within budget."""
    pol = RegenerationPolicy(max_overhead_frac=0.01, invest_frac=0.0)
    acc = TuningAccounts(app_start_s=0.0)
    t, spent = 100.0, 0.0
    for _ in range(1000):
        if pol.should_regenerate(acc, t, 0.05):
            acc.tuning_spent_s += 0.05
            spent += 0.05
    assert spent <= 0.01 * t + 0.05 + 1e-9


# --------------------------------------------------------------- filtering
def test_filtered_training_time_robust_to_spikes():
    seq = iter([5.0, 1.0, 1.0, 1.0, 1.0,      # warmup + group 1
                9.0, 1.1, 1.1, 1.1, 1.1,      # group 2 w/ spike
                1.2, 1.2, 9.9, 1.2, 1.2,
                1.0])
    times = iter([0.0])

    calls = {"n": 0}

    def fake(_x):
        calls["n"] += 1
        time.sleep(0)
        return _x

    # monkeypatch time_once by measuring a deterministic sequence
    import repro.core.evaluator as ev

    orig = ev.time_once
    vals = [5.0, 1.0, 1.0, 1.0, 1.0, 9.0,
            1.1, 1.1, 1.1, 1.1, 1.2, 1.2, 9.9, 1.2, 1.2, 1.0]
    it = iter(vals)
    ev.time_once = lambda fn, args: next(it)
    try:
        out = filtered_training_time(fake, (1,), groups=3, group_size=5, warmup=1)
    finally:
        ev.time_once = orig
    # groups: [1.0,1.0,1.0,1.0,9.0] -> 1.0 ; [1.1]*4+[1.2] -> 1.1 ;
    # [1.2,9.9,1.2,1.2,1.0] -> 1.0 ; worst of bests = 1.1
    assert abs(out - 1.1) < 1e-9


# ------------------------------------------------------------- end-to-end
def busy_wait(seconds):
    """Spin instead of sleep: time.sleep() granularity (~50-100us on a
    loaded host) swamps the sub-100us cost differences between variants."""
    t0 = time.perf_counter()
    while time.perf_counter() - t0 < seconds:
        pass


def make_fake_compilette(cost_fn):
    sp = product_space([
        Param("unroll", (1, 2, 4, 8), phase=1, switch_rank=0),
        Param("sched", (0, 1), phase=2),
    ])

    def gen(point, **spec):
        c = cost_fn(point)

        def fn(x):
            busy_wait(c)
            return x
        return fn

    return Compilette("fake", sp, gen)


def test_autotuner_finds_best_and_swaps():
    comp = make_fake_compilette(
        lambda p: 0.0004 / p["unroll"] + (0 if p["sched"] else 5e-5))
    ev = Evaluator(mode="training", groups=2, group_size=3,
                   make_args=lambda: (1,))
    at = OnlineAutotuner(
        comp, ev,
        policy=RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.3),
        wake_every=4)
    for i in range(2000):
        at(i)
    s = at.stats()
    assert s["best_point"] == {"unroll": 8, "sched": 1}
    assert s["swaps"] >= 1
    assert s["active_score_s"] <= s["reference_score_s"]


def test_autotuner_negligible_overhead_when_no_gain():
    """Paper: overhead bounded even when tuning finds nothing better."""
    comp = make_fake_compilette(lambda p: 0.0008)  # all variants equal
    ev = Evaluator(mode="training", groups=1, group_size=2,
                   make_args=lambda: (1,))
    at = OnlineAutotuner(
        comp, ev,
        policy=RegenerationPolicy(max_overhead_frac=0.02, invest_frac=0.1),
        wake_every=2)
    for i in range(300):
        at(i)
    s = at.stats()
    # measurement noise may cause an occasional swap between equal variants
    # (the paper's "oscillations can lead to wrong replacement" remark);
    # the bound that matters is the overhead budget.
    assert s["overhead_frac"] < 0.05   # 2% target + estimation slack


def test_autotuner_generation_failure_is_hole():
    def gen_cost(p):
        if p["unroll"] == 4:
            raise RuntimeError("cannot generate")
        return 0.0002

    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1)])

    def gen(point, **spec):
        c = gen_cost(point)

        def fn(x):
            time.sleep(c)
            return x
        return fn

    comp = Compilette("failing", sp, gen)
    ev = Evaluator(mode="training", groups=1, group_size=2,
                   make_args=lambda: (1,))
    # unbounded budget: this test is about hole handling, not pacing
    at = OnlineAutotuner(comp, ev,
                         policy=RegenerationPolicy(100.0, 0.0), wake_every=1)
    at.exhaust()
    s = at.stats()
    assert s["exploration_finished"]
    assert (s["best_point"] or {}).get("unroll") != 4


def test_threaded_mode_swaps_safely():
    comp = make_fake_compilette(lambda p: 0.0005 / p["unroll"])
    ev = Evaluator(mode="training", groups=1, group_size=2,
                   make_args=lambda: (1,))
    at = OnlineAutotuner(comp, ev,
                         policy=RegenerationPolicy(0.9, 0.9), wake_every=10**9)
    at.start_thread(wake_period_s=0.0005)
    for i in range(300):
        at(i)
    at.stop_thread()
    s = at.stats()
    assert s["regenerations"] > 0


# -------------------------------------------------------------- simulated
def test_simulated_profiles_prefer_different_points():
    """Lean cores should demand more unrolling than fat cores (paper §5.4)."""
    from repro.kernels.matmul.ops import make_matmul_compilette

    comp = make_matmul_compilette(1024, 1024, 1024)
    from repro.core import TwoPhaseExplorer

    best = {}
    for prof in (SI_L1, DI_F1):
        ex = TwoPhaseExplorer(comp.space)
        pt, _ = ex.run_to_completion(lambda p: comp.simulate(p, prof))
        best[prof.name] = pt
    assert best["SI-L1"]["unroll"] >= best["DI-F1"]["unroll"]


def test_all_profiles_give_finite_best():
    from repro.kernels.euclid.ops import make_euclid_compilette
    from repro.core import TwoPhaseExplorer

    comp = make_euclid_compilette(512, 64, 64)
    for prof in ALL_PROFILES:
        ex = TwoPhaseExplorer(comp.space)
        pt, score = ex.run_to_completion(lambda p: comp.simulate(p, prof))
        assert pt is not None and score < float("inf"), prof.name
