"""Per-architecture smoke tests + family-specific invariants.

Each assigned architecture is instantiated at a REDUCED config of the same
family and runs one forward/train step on CPU asserting output shapes and
the absence of NaNs. Decode paths are checked for consistency against a
longer prefill.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import REGISTRY
from repro.models.model import build_model
from repro.models.params import count_params, init_tree

ARCHS = sorted(REGISTRY)


def make_batch(cfg, B=2, T=32, key=jax.random.PRNGKey(1)):
    batch = {
        "tokens": jax.random.randint(key, (B, T), 0, cfg.vocab),
    }
    batch["labels"] = batch["tokens"]
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            key, (B, cfg.enc_frames, cfg.d_model)) * 0.05
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(key, (B, 16, cfg.d_model)) * 0.05
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_loss_and_grads(arch):
    cfg = REGISTRY[arch].reduced()
    model = build_model(cfg)
    params = init_tree(model.param_defs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(model.loss))(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), arch
    leaves = jax.tree.leaves(grads)
    assert all(bool(jnp.all(jnp.isfinite(g))) for g in leaves), arch


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_prefill_decode_consistency(arch):
    """decode(prefill(T), token_T) == prefill(T+1) last logits.

    MoE uses a drop-free capacity factor here: capacity-based dropping is
    group-dependent by construction (GShard), so tokens dropped in a long
    prefill group can survive in a single-token decode group.
    """
    cfg = REGISTRY[arch].reduced(capacity_factor=8.0)
    model = build_model(cfg)
    params = init_tree(model.param_defs(), jax.random.PRNGKey(0))
    B, T = 2, 16
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, T + 1), 0, cfg.vocab)

    def mk(t):
        b = {"tokens": t, "labels": t}
        if cfg.family == "encdec":
            b["audio_embeds"] = jax.random.normal(
                jax.random.PRNGKey(2), (B, cfg.enc_frames, cfg.d_model)) * 0.05
        if cfg.family == "vlm":
            b["vision"] = jax.random.normal(
                jax.random.PRNGKey(3), (B, 16, cfg.d_model)) * 0.05
        return b

    logits_p, cache = jax.jit(model.prefill)(params, mk(toks[:, :T]))
    assert bool(jnp.all(jnp.isfinite(logits_p)))
    full = model.init_cache(B, 64)
    widened = []
    for got, want in zip(cache, full):
        if got.shape == want.shape:
            widened.append(got.astype(want.dtype))
        else:
            pads = [(0, w - g) for g, w in zip(got.shape, want.shape)]
            widened.append(jnp.pad(got, pads).astype(want.dtype))
    pos0 = T if cfg.family != "vlm" else T + 16
    logits_d, new_cache = jax.jit(model.decode_step)(
        params, tuple(widened), toks[:, T:T + 1], jnp.int32(pos0))
    logits_p2, _ = jax.jit(model.prefill)(params, mk(toks))
    np.testing.assert_allclose(
        np.asarray(logits_p2, np.float32), np.asarray(logits_d, np.float32),
        rtol=2e-2, atol=2e-2)


def test_param_counts_match_analytic():
    for arch in ARCHS:
        cfg = REGISTRY[arch]
        model = build_model(cfg)
        analytic = cfg.n_params()
        from repro.models.params import _iter_defs
        exact = count_params(model.param_defs())
        # analytic formula ignores norms/small vectors: within 5 %
        assert abs(exact - analytic) / exact < 0.05, (arch, exact, analytic)


def test_rwkv_chunk_invariance():
    cfg = REGISTRY["rwkv6-1.6b"].reduced()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for chunk in (4, 8, 24, 7):   # incl. ragged chunking
        m = build_model(dataclasses.replace(cfg, scan_chunk=chunk))
        params = init_tree(m.param_defs(), jax.random.PRNGKey(0))
        losses.append(float(jax.jit(m.loss)(params, batch)))
    for l in losses[1:]:
        assert abs(l - losses[0]) < 1e-4, losses


def test_hymba_ssm_chunk_invariance():
    cfg = REGISTRY["hymba-1.5b"].reduced()
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, cfg.vocab)
    batch = {"tokens": toks, "labels": toks}
    losses = []
    for chunk in (4, 12, 24):
        m = build_model(dataclasses.replace(cfg, scan_chunk=chunk))
        params = init_tree(m.param_defs(), jax.random.PRNGKey(0))
        losses.append(float(jax.jit(m.loss)(params, batch)))
    for l in losses[1:]:
        assert abs(l - losses[0]) < 1e-4, losses


def test_moe_aux_loss_and_capacity():
    from repro.models.moe import capacity, moe_ffn
    cfg = REGISTRY["qwen3-moe-30b-a3b"].reduced()
    m = build_model(cfg)
    params = init_tree(m.param_defs(), jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    lp = jax.tree.map(lambda a: a[0], params["layers"])
    out, aux = moe_ffn(x, lp["ffn"], cfg)
    assert out.shape == x.shape
    assert bool(jnp.isfinite(aux)) and float(aux) > 0
    assert capacity(cfg, 32) >= 4


def test_moe_dropped_tokens_pass_through():
    """With capacity saturated, output stays finite (dropped → zero)."""
    cfg = REGISTRY["qwen3-moe-30b-a3b"].reduced(capacity_factor=0.01)
    m = build_model(cfg)
    params = init_tree(m.param_defs(), jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss = jax.jit(m.loss)(params, batch)
    assert bool(jnp.isfinite(loss))


def test_vlm_mrope_positions():
    from repro.models.vlm import mrope_positions
    pos = mrope_positions(16, 8, 2)
    assert pos.shape == (3, 2, 24)
    # text positions strictly increase on every stream
    txt = pos[:, 0, 16:]
    assert bool(jnp.all(txt[:, 1:] > txt[:, :-1]))


def test_rope_pair_locality():
    """Interleaved-pair RoPE: rotating a head dim sharded in pair units is
    equivalent to rotating the full head dim (no cross-pair mixing)."""
    from repro.models.layers import apply_rope
    B, T, H, Dh = 1, 8, 2, 16
    x = jax.random.normal(jax.random.PRNGKey(0), (B, T, H, Dh))
    pos = jnp.arange(T)[None]
    full = apply_rope(x, pos, 1e4)
    # pairs (2i, 2i+1) only mix among themselves
    x2 = x.at[..., 2:].set(0)
    part = apply_rope(x2, pos, 1e4)
    np.testing.assert_allclose(part[..., :2], full[..., :2], rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(part[..., 2:], jnp.zeros_like(part[..., 2:]),
                               atol=1e-6)
