import os
import sys

# Tests must see exactly ONE device (the dry-run alone uses 512 fake
# devices, in its own process).
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
sys.path.insert(0, os.path.dirname(__file__))

# ---- optional test extras -------------------------------------------------
# `hypothesis` is an optional extra: fall back to the deterministic stub so
# the tier-1 suite collects and runs in minimal containers.
try:
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_stub

    sys.modules["hypothesis"] = _hypothesis_stub
    sys.modules["hypothesis.strategies"] = _hypothesis_stub.strategies

import jax

jax.config.update("jax_enable_x64", False)


# ---- @pytest.mark.flaky fallback -----------------------------------------
# pytest-rerunfailures implements the mark when installed; this minimal
# rerun protocol keeps the mark functional (and the suite warning-free)
# without it. Only the final attempt's reports are logged.
try:
    import pytest_rerunfailures  # noqa: F401

    _HAVE_RERUNFAILURES = True
except ImportError:
    _HAVE_RERUNFAILURES = False

if not _HAVE_RERUNFAILURES:
    from _pytest.runner import runtestprotocol

    def pytest_runtest_protocol(item, nextitem):
        marker = item.get_closest_marker("flaky")
        if marker is None:
            return None
        reruns = int(marker.kwargs.get("reruns", marker.args[0] if marker.args else 1))
        item.ihook.pytest_runtest_logstart(
            nodeid=item.nodeid, location=item.location)
        for attempt in range(reruns + 1):
            reports = runtestprotocol(item, nextitem=nextitem, log=False)
            failed = any(r.failed for r in reports)
            if not failed or attempt == reruns:
                for report in reports:
                    item.ihook.pytest_runtest_logreport(report=report)
                break
        item.ihook.pytest_runtest_logfinish(
            nodeid=item.nodeid, location=item.location)
        return True
