import os
import sys

# Tests must see exactly ONE device (the dry-run alone uses 512 fake
# devices, in its own process).
os.environ.pop("XLA_FLAGS", None)

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

jax.config.update("jax_enable_x64", False)
