"""Tuner lifecycle: bucketing, convergence, eviction, registry hygiene.

Control-loop tests run on the ``VirtualClock``; the serve-loop tests run
the real (reduced) model end-to-end to show bucketing/eviction on the
actual request path.
"""

import os
import tempfile

import jax.numpy as jnp
import pytest

from repro.configs import REGISTRY
from repro.core import (
    Compilette,
    Param,
    RegenerationPolicy,
    TunedRegistry,
    VirtualClock,
    VirtualClockEvaluator,
    compiler_version,
    device_fallbacks,
    device_fingerprint,
    product_space,
    virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.lifecycle import (
    TunerLifecycle,
    TunerState,
    pow2_bucket,
    release_evaluator_closure,
)


def make_virtual_compilette(clock, name="k"):
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1)])

    def gen(point, **spec):
        return virtual_kernel(clock, 0.008 / point["unroll"])

    return Compilette(name, sp, gen)


# --------------------------------------------------------------- bucketing
def test_pow2_bucket_rounds_in_log_space():
    assert pow2_bucket(1) == 1
    assert pow2_bucket(2) == 2
    assert pow2_bucket(120) == 128
    assert pow2_bucket(150) == 128    # geometric midpoint of 128/256 ≈ 181
    assert pow2_bucket(200) == 256
    assert pow2_bucket(128) == 128
    # boundary: n^2 == lo*hi goes to the smaller bucket
    assert pow2_bucket(181) == 128
    assert pow2_bucket(182) == 256


def test_bucket_specialization_only_touches_shape_keys():
    lc = TunerLifecycle(seq_buckets=True)
    spec = {"seq": 150, "max_len": 200, "batch": 3, "dtype": "bf16"}
    out = lc.bucket_specialization(spec)
    assert out == {"seq": 128, "max_len": 256, "batch": 3, "dtype": "bf16"}
    assert spec["seq"] == 150          # input not mutated
    off = TunerLifecycle(seq_buckets=False)
    assert off.bucket_specialization(spec) == spec
    assert off.bucket_length(150) == 150


def test_coordinator_buckets_share_one_tuner():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock,
        lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=None))
    comp = make_virtual_compilette(clock)
    a = coord.register("prefill", comp, ev, specialization={"seq": 120},
                       reference_fn=virtual_kernel(clock, 0.008))
    b = coord.register("prefill", comp, ev, specialization={"seq": 150},
                       reference_fn=virtual_kernel(clock, 0.008))
    assert a is b
    assert a.specialization == {"seq": 128}
    assert coord.stats()["n_kernels"] == 1
    # a genuinely different bucket gets its own tuner
    c = coord.register("prefill", comp, ev, specialization={"seq": 300},
                       reference_fn=virtual_kernel(clock, 0.008))
    assert c is not a and c.specialization == {"seq": 256}


# ------------------------------------------------------------- convergence
def drive_to_convergence(coord, m, calls=500):
    for i in range(calls):
        m(i)
        coord.pump()
        if m.tuner.explorer.finished:
            break
    coord.sweep()


def test_converged_tuner_releases_closure_but_keeps_serving():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    ev.make_args = lambda: ()          # simulate a pinned request closure
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock)
    m = coord.register("k", make_virtual_compilette(clock), ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    drive_to_convergence(coord, m)
    assert m.state is TunerState.CONVERGED
    assert ev.make_args is None                    # closure released
    assert coord.stats()["lifecycle"]["converged"] == 1
    # still registered and still serving its tuned best function
    again = coord.register("k", make_virtual_compilette(clock), ev,
                           reference_fn=virtual_kernel(clock, 0.008))
    assert again is m
    assert m.tuner._active_life.point == {"unroll": 8}
    # a re-pinned closure (serve re-registers per request) is re-released
    ev.make_args = lambda: ()
    coord.sweep()
    assert ev.make_args is None


# ---------------------------------------------------------------- eviction
def test_idle_tuner_is_evicted_with_closure_released():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    ev.make_args = lambda: ()
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock,
        lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=10.0))
    m = coord.register("k", make_virtual_compilette(clock), ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    for i in range(50):
        m(i)
        coord.pump()
    spent_before = coord._aggregate_accounts().tuning_spent_s
    assert spent_before > 0
    clock.advance(11.0)                # idle past the eviction horizon
    retired = coord.sweep()
    assert retired == [m]
    assert m.state is TunerState.RETIRED
    assert ev.make_args is None                    # closure released
    assert coord.stats()["n_kernels"] == 0
    assert coord.stats()["lifecycle"]["retired"] == 1
    # the shared budget keeps counting what the retired tuner spent
    assert coord._aggregate_accounts().tuning_spent_s == \
        pytest.approx(spent_before)
    # its best point was flushed: a re-register warm-starts
    again = coord.register("k", make_virtual_compilette(clock), ev,
                           reference_fn=virtual_kernel(clock, 0.008))
    assert again is not m
    assert again.warm_started


def test_busy_tuner_is_not_evicted():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock,
        lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=10.0))
    m = coord.register("k", make_virtual_compilette(clock), ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    for _ in range(2000):
        m(1)                           # keeps touching last_used_s
        coord.pump()
        assert coord.sweep() == []
    assert m.state in (TunerState.ACTIVE, TunerState.CONVERGED)


def test_release_evaluator_closure_tolerates_any_evaluator():
    clock = VirtualClock()
    release_evaluator_closure(object())                   # no evaluator attr
    tuner = type("T", (), {"evaluator": VirtualClockEvaluator(clock)})()
    release_evaluator_closure(tuner)                      # no make_args attr


# ----------------------------------------------- compiler-version keys
def test_device_fingerprint_includes_compiler_version():
    fp = device_fingerprint()
    assert compiler_version() in fp
    assert fp.count(":") >= 2


def test_stale_compiler_entry_degrades_to_cold_start():
    """An entry persisted under an older jax/jaxlib has a different
    fingerprint: exact lookup misses, and the fallback chain must NOT
    resurrect it (only versionless legacy layouts fall back)."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    reg = TunedRegistry()
    reg.put("k", {}, "cpu:x:jax0.1-jaxlib0.1", {"unroll": 8}, 0.001)
    coord = TuningCoordinator(
        registry=reg, device=f"cpu:x:{compiler_version()}", clock=clock)
    m = coord.register("k", make_virtual_compilette(clock), ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    assert not m.warm_started


def test_legacy_layout_entries_still_warm_start():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    device = f"cpu:x:{compiler_version()}"
    assert device_fallbacks(device) == ("cpu:x", "x")
    for legacy_key in ("cpu:x", "x"):
        reg = TunedRegistry()
        reg.put("k", {}, legacy_key, {"unroll": 8}, 0.001)
        coord = TuningCoordinator(registry=reg, device=device, clock=clock)
        m = coord.register(
            f"k", make_virtual_compilette(clock), ev,
            reference_fn=virtual_kernel(clock, 0.008))
        assert m.warm_started, legacy_key


# ---------------------------------------------------------- registry aging
def test_registry_entry_ages_out_after_idle_saves():
    """An entry untouched for max_idle_saves save cycles is compacted."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        reg = TunedRegistry(max_idle_saves=3)
        reg.put("dead", {}, "test:v", {"unroll": 1}, 0.1)
        reg.put("live", {}, "test:v", {"unroll": 8}, 0.1)
        for _ in range(3):
            reg.get("live", {}, "test:v")      # lookups refresh the stamp
            reg.save(path)
        assert reg.get("dead", {}, "test:v") is None
        assert reg.get("live", {}, "test:v") == {"unroll": 8}
        assert reg.compacted_total == 1
        # the surviving file round-trips with its generation counter
        loaded = TunedRegistry.load(path)
        assert len(loaded) == 1
        assert loaded._generation == reg._generation


def test_registry_put_and_get_warm_refresh_the_stamp():
    """put (even with a worse score) and get_warm hits both count as use;
    aging only bites entries nobody touches."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        reg = TunedRegistry(max_idle_saves=2)
        reg.put("k", {}, "test:v", {"unroll": 8}, 0.1)
        for _ in range(5):
            reg.put("k", {}, "test:v", {"unroll": 1}, 9.0)   # worse: kept
            reg.save(path)
        assert reg.get("k", {}, "test:v") == {"unroll": 8}
        for _ in range(5):
            assert reg.get_warm("k", {}, "test:v") is not None
            reg.save(path)
        assert len(reg) == 1 and reg.compacted_total == 0


def test_registry_foreign_compiler_entries_compacted_on_save():
    """Entries recorded under a different jax/jaxlib can only ever miss:
    save() drops them. Versionless legacy keys make no compiler claim and
    are kept (they still warm-start via the fallback chain)."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        reg = TunedRegistry(max_idle_saves=None)
        reg.put("k", {}, "cpu:x:jax0.1-jaxlib0.1", {"unroll": 8}, 0.1)
        reg.put("k", {}, f"cpu:x:{compiler_version()}", {"unroll": 4}, 0.1)
        reg.put("k", {}, "cpu:x", {"unroll": 2}, 0.1)        # legacy layout
        reg.save(path)
        loaded = TunedRegistry.load(path)
        assert len(loaded) == 2
        assert loaded.get("k", {}, "cpu:x:jax0.1-jaxlib0.1") is None
        assert loaded.get("k", {}, f"cpu:x:{compiler_version()}") is not None
        assert loaded.get("k", {}, "cpu:x") is not None


def test_registry_aging_disabled_keeps_idle_entries():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        reg = TunedRegistry(max_idle_saves=None)
        reg.put("k", {}, "test:v", {"unroll": 8}, 0.1)
        for _ in range(50):
            reg.save(path)
        assert len(TunedRegistry.load(path)) == 1


def test_registry_pre_aging_file_loads_as_freshly_used():
    """Files written before aging existed (no stamps, no meta) must not
    be instantly compacted on the next save."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        import json as _json
        with open(path, "w") as f:
            _json.dump({TunedRegistry.key("k", {}, "test:v"):
                        {"point": {"unroll": 8}, "score_s": 0.1}}, f)
        reg = TunedRegistry.load(path)
        assert reg.get("k", {}, "test:v") == {"unroll": 8}
        reg.save(path)                             # one save: still fresh
        assert len(TunedRegistry.load(path)) == 1


def test_registry_records_strategy_provenance():
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        reg = TunedRegistry()
        reg.put("k", {}, "d", {"unroll": 8}, 0.001, strategy="greedy")
        reg.save(path)
        loaded = TunedRegistry.load(path)
        assert loaded.get("k", {}, "d") == {"unroll": 8}
        entry = loaded._table[TunedRegistry.key("k", {}, "d")]
        assert entry["strategy"] == "greedy"


# ------------------------------------------------------------- serve loop
@pytest.mark.parametrize("strategy", ["two_phase", "greedy"])
def test_serve_requests_share_bucketed_prefill_tuner(strategy):
    """Acceptance: prompts of length 120 and 150 (same pow2 bucket, 128)
    must share ONE prefill tuner instead of spawning one per shape."""
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=4, autotune=True,
                        tune_max_overhead=0.5, tune_strategy=strategy,
                        seq_buckets=True, idle_evict_s=None)
    coordinator = make_serve_coordinator(serve)
    for seq in (120, 150):
        batch = {"tokens": jnp.ones((2, seq), jnp.int32)}
        out = generate(cfg, batch, serve, coordinator=coordinator)
        assert out["tokens"].shape == (2, 4)
    stats = out["autotune"]
    prefill_keys = [k for k in stats["kernels"] if "serve_prefill" in k]
    assert len(prefill_keys) == 1, stats["kernels"].keys()
    pf = stats["kernels"][prefill_keys[0]]
    assert pf["strategy"] == strategy
    # both requests' prefill calls landed on the shared tuner
    assert pf["kernel_calls"] == 2
    # the tuner is keyed by the bucket, not either raw length
    (m,) = [m for m in coordinator._managed if m.name == "serve_prefill"]
    assert m.specialization["seq"] == 128
    # init-time reference measurements are surfaced (and budgeted)
    assert stats["init_spent_s"] > 0
    assert stats["budget_spent_s"] >= stats["init_spent_s"]


def test_serve_unbucketed_accumulates_tuners():
    """Control: with bucketing off, the same traffic spawns one prefill
    tuner per exact shape (the leak the lifecycle exists to stop)."""
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=4, autotune=True,
                        tune_max_overhead=0.5, seq_buckets=False,
                        idle_evict_s=None)
    coordinator = make_serve_coordinator(serve)
    for seq in (120, 150):
        batch = {"tokens": jnp.ones((2, seq), jnp.int32)}
        out = generate(cfg, batch, serve, coordinator=coordinator)
    stats = out["autotune"]
    prefill_keys = [k for k in stats["kernels"] if "serve_prefill" in k]
    assert len(prefill_keys) == 2


def test_serve_hierarchical_registration_both_levels():
    """Acceptance (e2e): kernel_tuning="both" registers the step-programs
    AND their constituent matmul/attention/rmsnorm kernels as independent
    coordinator-managed compilettes — each with its own strategy — under
    one shared budget, with per-kernel accounting that sums consistently
    into the aggregate."""
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=4, autotune=True,
                        tune_max_overhead=0.5, kernel_tuning="both",
                        kernel_strategies={"attention": "greedy"},
                        seq_buckets=True, idle_evict_s=None)
    coordinator = make_serve_coordinator(serve)
    try:
        batch = {"tokens": jnp.ones((2, 24), jnp.int32)}
        out = generate(cfg, batch, serve, coordinator=coordinator)
        assert out["tokens"].shape == (2, 4)
        assert out["kernel_tuning"] == "both"
        stats = out["autotune"]
        names = {m.name for m in coordinator._managed}
        assert {"serve_prefill", "serve_decode",
                "matmul", "attention", "rmsnorm"} <= names
        # per-kernel strategy beside the coordinator default
        assert stats["kernels"]["attention"]["strategy"] == "greedy"
        assert stats["kernels"]["matmul"]["strategy"] == "two_phase"
        # every kernel is an independent compilette with its own space
        specs = {m.name: m.tuner.compilette.space for m in
                 coordinator._managed}
        assert specs["matmul"] is not specs["attention"]
        # per-kernel accounting rolls up into the aggregate exactly
        for f in ("gen_spent_s", "gen_stall_s", "eval_spent_s"):
            rollup = (sum(k[f] for k in stats["kernels"].values())
                      + stats["retired_accounts"][f])
            assert rollup == pytest.approx(stats[f]), f
    finally:
        coordinator.close()


def test_serve_kernel_only_mode_skips_step_programs():
    """kernel_tuning="kernel": only the constituent kernels register; the
    un-managed step-programs still credit busy time to the shared
    budget (a busy-time policy would otherwise starve kernel tuning)."""
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=4, autotune=True,
                        tune_max_overhead=0.5, kernel_tuning="kernel",
                        seq_buckets=True, idle_evict_s=None)
    coordinator = make_serve_coordinator(serve)
    try:
        batch = {"tokens": jnp.ones((2, 24), jnp.int32)}
        out = generate(cfg, batch, serve, coordinator=coordinator)
        names = {m.name for m in coordinator._managed}
        assert "serve_prefill" not in names and "serve_decode" not in names
        assert {"matmul", "attention", "rmsnorm"} <= names
        # the step-programs' real traffic accrued busy-time budget
        assert out["autotune"]["busy_s"] > 0
        assert coordinator._external_busy_s > 0
    finally:
        coordinator.close()


def test_serve_rejects_unknown_kernel_tuning_mode():
    from repro.runtime.serve_loop import ServeConfig, generate

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=2, kernel_tuning="bogus")
    with pytest.raises(ValueError, match="kernel_tuning"):
        generate(cfg, {"tokens": jnp.ones((1, 8), jnp.int32)}, serve)


def test_serve_kernel_tuning_off_disables_autotune():
    """kernel_tuning="off" wins over autotune=True: no tuners, no
    "autotune" stats block (the CLIs key their report off its absence)."""
    from repro.runtime.serve_loop import ServeConfig, generate

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=2, autotune=True,
                        kernel_tuning="off")
    out = generate(cfg, {"tokens": jnp.ones((1, 8), jnp.int32)}, serve)
    assert out["tokens"].shape == (1, 2)
    assert "autotune" not in out


def test_serve_idle_tuner_evicted_between_requests():
    """Acceptance: a tuner idle past the eviction horizon is unregistered
    at the next request's lifecycle pass, its evaluator closure released."""
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    import time

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=4, autotune=True,
                        tune_max_overhead=0.5, seq_buckets=True,
                        idle_evict_s=None)
    coordinator = make_serve_coordinator(serve)
    batch = {"tokens": jnp.ones((2, 12), jnp.int32)}
    generate(cfg, batch, serve, coordinator=coordinator)
    managed_before = list(coordinator._managed)
    assert managed_before
    # the server goes quiet: shrink the horizon so the idle gap between
    # requests crosses it, then run the next lifecycle pass
    coordinator.lifecycle.idle_evict_s = 1e-6
    time.sleep(0.002)
    retired = coordinator.sweep()
    assert set(retired) == set(managed_before)
    for m in retired:
        assert m.state is TunerState.RETIRED
        assert m.tuner.evaluator.make_args is None
    assert coordinator.stats()["n_kernels"] == 0
    assert coordinator.stats()["lifecycle"]["retired"] == len(retired)
    # traffic returning later re-registers cleanly (warm from registry)
    out = generate(cfg, batch, serve, coordinator=coordinator)
    assert out["tokens"].shape == (2, 4)
