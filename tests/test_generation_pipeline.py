"""Double-buffered variant generation: cache, async pipeline, prefetch.

Everything runs on the ``VirtualClock`` with DECLARED compile costs
(``Compilette.gen_cost_s``), so stall-vs-overlap is exact arithmetic:
a synchronous wake advances the clock by the compile cost (the hot path
stalls, like an inline XLA compile), while the async pipeline and cache
hits charge the same cost to the budget without moving the clock. No
test sleeps; the ``"manual"`` AsyncGenerator completes jobs only at
``run_pending()`` — i.e. at the next coordinator pump.
"""

import pytest

from repro.core import (
    AsyncGenerator,
    GenerationCache,
    LatencyHeadroomGate,
    OnlineAutotuner,
    Param,
    RegenerationPolicy,
    VirtualClock,
    VirtualClockEvaluator,
    product_space,
    virtual_compilette,
    virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.lifecycle import TunerLifecycle, TunerState

GEN_COST = 0.010


def space(n_unroll=4):
    return product_space(
        [Param("unroll", (1, 2, 4, 8)[:n_unroll], phase=1)])


def cost(p):
    return 0.008 / p["unroll"]


def counted_compilette(clock, name="k", gen_cost_s=GEN_COST, counter=None):
    """virtual_compilette whose underlying ``_generate`` counts calls."""
    comp = virtual_compilette(clock, name, space(), cost,
                              gen_cost_s=gen_cost_s)
    counter = counter if counter is not None else {"n": 0}
    inner = comp._generate

    def counting(point, **spec):
        counter["n"] += 1
        return inner(point, **spec)

    comp._generate = counting
    comp.compiles = counter  # type: ignore[attr-defined]
    return comp


def make_coord(clock, *, async_generation=False, cache=None, prefetch=0,
               policy=None, lifecycle=None):
    return TuningCoordinator(
        policy=policy or RegenerationPolicy(1.0, 0.5),
        device="test:v", clock=clock, async_generation=async_generation,
        generation_cache=cache, prefetch=prefetch,
        lifecycle=lifecycle or TunerLifecycle(seq_buckets=True,
                                              idle_evict_s=None))


def drive(coord, m, calls=300):
    for i in range(calls):
        m(i)
        coord.pump()


# ---------------------------------------------------------------- cache
def test_cache_hit_skips_generate_and_costs_nothing():
    clock = VirtualClock()
    cache = GenerationCache()
    comp = counted_compilette(clock)
    comp.attach_cache(cache, "test:v")
    a = comp.generate({"unroll": 2})
    assert a.meta["source"] == "compiled" and a.generation_time_s == GEN_COST
    b = comp.generate({"unroll": 2})
    assert b.meta["source"] == "cache"
    assert b.generation_time_s == 0.0            # nothing charged on a hit
    assert b.meta["compiled_in_s"] == GEN_COST   # provenance kept
    assert b.fn is a.fn                          # the SAME executable
    assert comp.compiles["n"] == 1               # _generate ran once, ever
    from repro.core import DEFAULT_ENTRY_BYTES
    assert cache.stats() == {"entries": 1, "bytes": DEFAULT_ENTRY_BYTES,
                             "max_bytes": None, "effective_max_bytes": None,
                             "hits": 1, "misses": 1, "evictions": 0,
                             "pressure_evictions": 0, "hit_rate": 0.5}


def test_cache_key_separates_identities():
    pt, spec = {"unroll": 2}, {"seq": 128}
    base = GenerationCache.key("k", pt, spec, "dev", None)
    assert GenerationCache.key("k", pt, spec, "dev", None) == base
    # dict-order independence
    assert GenerationCache.key(
        "k", pt, dict(reversed(list({"seq": 128, "b": 1}.items()))),
        "dev", None) == GenerationCache.key(
        "k", pt, {"b": 1, "seq": 128}, "dev", None)
    for other in (
        GenerationCache.key("k2", pt, spec, "dev", None),     # kernel
        GenerationCache.key("k", {"unroll": 4}, spec, "dev", None),  # point
        GenerationCache.key("k", pt, {"seq": 256}, "dev", None),     # spec
        GenerationCache.key("k", pt, spec, "dev2", None),     # device
        GenerationCache.key("k", pt, spec, "dev", "modelB"),  # token
    ):
        assert other != base


def test_cache_lru_bound_evicts_oldest():
    cache = GenerationCache(max_entries=2)
    clock = VirtualClock()
    comp = counted_compilette(clock)
    comp.attach_cache(cache, "test:v")
    for u in (1, 2, 4):
        comp.generate({"unroll": u})
    assert len(cache) == 2 and cache.evictions == 1
    comp.generate({"unroll": 1})                 # evicted: recompiles
    assert comp.compiles["n"] == 4
    comp.generate({"unroll": 4})                 # still resident: hit
    assert comp.compiles["n"] == 4


def test_cost_weighted_eviction_keeps_expensive_entries():
    """Satellite: within the LRU window the CHEAPEST-to-regenerate entry
    is evicted first, so one expensive variant is not displaced by a
    parade of trivial ones (equal costs degrade to plain LRU above)."""
    clock = VirtualClock()
    cache = GenerationCache(max_entries=2)
    costly = counted_compilette(clock, "costly", gen_cost_s=1.0)
    costly.attach_cache(cache, "test:v")
    cheap = counted_compilette(clock, "cheap", gen_cost_s=0.001)
    cheap.attach_cache(cache, "test:v")
    costly.generate({"unroll": 1})     # least recently used AND priciest
    cheap.generate({"unroll": 1})
    cheap.generate({"unroll": 2})      # overflow
    # the cheap older entry went; the expensive one survived being LRU
    assert costly.cache_key({"unroll": 1}, {}) in cache
    assert cheap.cache_key({"unroll": 1}, {}) not in cache
    assert cache.evictions == 1
    # the survivor is a hit (no recompile), the evicted one recompiles
    costly.generate({"unroll": 1})
    assert costly.compiles["n"] == 1
    cheap.generate({"unroll": 1})
    assert cheap.compiles["n"] == 3


def test_cache_disabled_with_zero_max_entries():
    """max_entries=0 caches nothing and must not crash the put path."""
    clock = VirtualClock()
    cache = GenerationCache(max_entries=0)
    comp = counted_compilette(clock)
    comp.attach_cache(cache, "test:v")
    comp.generate({"unroll": 1})
    comp.generate({"unroll": 1})            # recompiles: nothing resident
    assert len(cache) == 0
    assert comp.compiles["n"] == 2
    assert cache.evictions == 2


def test_fresh_expensive_compile_never_evicts_itself():
    """The eviction window stops short of the newest entry: a just-landed
    expensive compile among cheap residents must not be its own victim."""
    clock = VirtualClock()
    cache = GenerationCache(max_entries=2)
    cheap = counted_compilette(clock, "cheap", gen_cost_s=0.001)
    cheap.attach_cache(cache, "test:v")
    costly = counted_compilette(clock, "costly", gen_cost_s=1.0)
    costly.attach_cache(cache, "test:v")
    cheap.generate({"unroll": 1})
    cheap.generate({"unroll": 2})
    costly.generate({"unroll": 1})     # overflow ON the expensive insert
    assert costly.cache_key({"unroll": 1}, {}) in cache
    assert cache.evictions == 1


def test_cache_entries_survive_retire_and_reregister():
    """Acceptance: a bucket retired by the lifecycle and re-registered
    later re-validates (and re-explores) from the cache — the same
    (point, spec, fingerprint) never reaches ``_generate`` twice."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = make_coord(
        clock, lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=10.0))
    counter = {"n": 0}
    m = coord.register(
        "k", counted_compilette(clock, counter=counter), ev,
        specialization={"seq": 120},
        reference_fn=virtual_kernel(clock, 0.008))
    drive(coord, m, 200)
    assert m.tuner.explorer.finished and counter["n"] == 4
    clock.advance(11.0)
    assert coord.sweep() == [m]                  # idle-evicted
    assert m.state is TunerState.RETIRED
    # same pow2 bucket (150 -> 128) comes back: every generation must hit
    m2 = coord.register(
        "k", counted_compilette(clock, counter=counter), ev,
        specialization={"seq": 150},
        reference_fn=virtual_kernel(clock, 0.008))
    assert m2 is not m and m2.warm_started
    drive(coord, m2, 200)
    assert m2.tuner.accounts.regenerations > 0
    assert counter["n"] == 4                     # zero recompiles
    assert m2.tuner.accounts.gen_spent_s == 0.0  # hits charge nothing
    assert coord.stats()["generation_cache"]["hits"] > 0


def test_distinct_buckets_miss_each_other():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = make_coord(clock)
    counter = {"n": 0}
    a = coord.register("k", counted_compilette(clock, counter=counter), ev,
                       specialization={"seq": 120},
                       reference_fn=virtual_kernel(clock, 0.008))
    b = coord.register("k", counted_compilette(clock, counter=counter), ev,
                       specialization={"seq": 300},
                       reference_fn=virtual_kernel(clock, 0.008))
    assert a is not b
    drive(coord, a, 200)
    drive(coord, b, 200)
    # different buckets (128 vs 256) are different specializations:
    # each compiles its own 4 variants, no cross-bucket aliasing
    assert counter["n"] == 8


# ------------------------------------------------------------- pipeline
def test_async_wake_requests_then_harvests_after_run_pending():
    """The double-buffer protocol, step by step: wake #1 requests (no
    stall, no measurement), the compile completes at run_pending, wake #2
    harvests (evaluation only)."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    gen = AsyncGenerator(mode="manual")
    comp = counted_compilette(clock)
    comp.attach_cache(GenerationCache(), "test:v")
    at = OnlineAutotuner(
        comp, ev, policy=RegenerationPolicy(1.0, 0.5),
        reference_fn=virtual_kernel(clock, 0.008),
        wake_every=None, clock=clock, generator=gen)
    t0 = clock()
    assert at.wake() is False
    assert at.generation_in_flight
    assert at.accounts.gen_requests == 1 and at.accounts.regenerations == 0
    assert clock() == t0                         # request cost: zero clock
    assert at.wake() is False                    # still compiling: no-op
    assert clock() == t0
    assert gen.run_pending() == 1
    assert not at.generation_in_flight           # ready, awaiting harvest
    at.wake()                                    # harvest: evaluate only
    assert at.accounts.regenerations == 1
    assert at.accounts.gen_spent_s == GEN_COST   # budget charged in full
    assert at.accounts.gen_stall_s == 0.0        # ...but nothing stalled
    assert clock() == t0 + 0.008                 # only the evaluation ran


def test_hot_path_never_stalls_under_async_generation():
    """Acceptance: with async generation the virtual clock NEVER advances
    by compile cost (all generation overlapped or cache-hit), yet
    ``gen_spent_s`` accrues the full compile cost against the budget."""
    results = {}
    for mode in ("sync", "async"):
        clock = VirtualClock()
        ev = VirtualClockEvaluator(clock)
        coord = make_coord(clock, async_generation=(mode == "async"))
        m = coord.register("k", counted_compilette(clock), ev,
                           reference_fn=virtual_kernel(clock, 0.008))
        drive(coord, m, 400)
        assert m.tuner.explorer.finished
        results[mode] = (coord.stats(), clock())
    sync_s, sync_t = results["sync"]
    async_s, async_t = results["async"]
    # both charge the identical full compile bill to the shared budget
    assert sync_s["gen_spent_s"] == async_s["gen_spent_s"] == 4 * GEN_COST
    # the synchronous cycle stalls the app by exactly that; async by zero
    assert sync_s["gen_stall_s"] == 4 * GEN_COST
    assert async_s["gen_stall_s"] == 0.0
    # and the app-visible difference is real wall time saved
    assert async_t < sync_t


def test_async_generation_failure_is_reported_hole():
    """A late-found hole is reported once and — even when prefetch
    already tried (and was billed for) the same point — never handed to
    ``_generate`` a second time (negative memo)."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1)])
    attempts = {"hole": 0}

    def gen(point, **spec):
        if point["unroll"] == 4:
            attempts["hole"] += 1
            raise RuntimeError("cannot generate")
        return virtual_kernel(clock, cost(point))

    from repro.core import Compilette
    comp = Compilette("holey", sp, gen, gen_cost_s=GEN_COST)
    coord = make_coord(clock, async_generation=True, prefetch=2)
    m = coord.register("holey", comp, ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    drive(coord, m, 400)
    assert m.tuner.explorer.finished
    assert (m.tuner.explorer.best_point or {}).get("unroll") != 4
    # the failed point was reported as a hole, not retried forever
    holes = [s for _, s in m.tuner.explorer.history if s == float("inf")]
    assert len(holes) == 1
    assert attempts["hole"] == 1    # speculative failure memoized


def test_repeated_point_never_compiles_twice_across_processes():
    """Acceptance: cold process compiles each point once; a warm-start
    replay sharing the process-wide cache compiles NOTHING (100% hit
    rate, zero stall) while still re-validating through the registry."""
    from repro.core import TunedRegistry

    cache = GenerationCache()
    registry = TunedRegistry()
    counter = {"n": 0}

    def run_process():
        clock = VirtualClock()
        ev = VirtualClockEvaluator(clock)
        coord = TuningCoordinator(
            policy=RegenerationPolicy(1.0, 0.5), device="test:v",
            clock=clock, registry=registry, async_generation=True,
            generation_cache=cache, prefetch=1)
        m = coord.register("k", counted_compilette(clock, counter=counter),
                           ev, reference_fn=virtual_kernel(clock, 0.008))
        h0, mi0 = cache.hits, cache.misses
        drive(coord, m, 400)
        s = coord.stats()
        return m, s, cache.hits - h0, cache.misses - mi0

    m_cold, s_cold, _, _ = run_process()
    assert m_cold.tuner.explorer.finished and counter["n"] == 4
    m_warm, s_warm, hits, misses = run_process()
    assert m_warm.warm_started
    assert counter["n"] == 4                     # nothing recompiled
    assert misses == 0 and hits > 0              # 100% generation-cache hit
    assert s_warm["gen_stall_s"] == 0.0
    assert s_warm["gen_spent_s"] == 0.0          # hits cost the budget nothing
    assert m_warm.tuner._active_life.point == {"unroll": 8}


# ------------------------------------------------------------- prefetch
def test_prefetch_compiles_ahead_without_duplicates():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = make_coord(clock, async_generation=True, prefetch=2)
    m = coord.register("k", counted_compilette(clock), ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    drive(coord, m, 400)
    assert m.tuner.explorer.finished
    g = coord.stats()["generation"]
    assert g["speculative_submitted"] > 0        # prefetch actually ran
    # speculation never duplicates work: one compile per unique point,
    # and every compile is charged exactly once
    assert m.tuner.compilette.compiles["n"] == 4
    assert coord.stats()["gen_spent_s"] == pytest.approx(4 * GEN_COST)
    assert coord.stats()["gen_stall_s"] == 0.0


def test_speculative_compile_charged_even_if_tuner_retires():
    """Prefetch spends real compute: if the requesting tuner retires
    before the job completes, the bill lands in the tombstone so the
    shared budget keeps counting it."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = make_coord(
        clock, async_generation=True, prefetch=2,
        lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=5.0))
    m = coord.register("k", counted_compilette(clock), ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    m(0)
    coord.pump()          # slot: request + 2 prefetch submissions queue up
    assert coord.generator.in_flight == 3
    assert coord._aggregate_accounts().gen_spent_s == 0.0
    clock.advance(6.0)    # idle past the horizon while jobs are queued
    retired = coord.sweep()
    assert retired == [m]
    coord.generator.drain()   # compiles complete after retirement
    # every queued compile — the tuner's own pending request (disowned at
    # retirement) AND both prefetches — is billed to the tombstone
    agg = coord._aggregate_accounts()
    assert agg.gen_spent_s == pytest.approx(3 * GEN_COST)
    # and the compiled variants are still in the process-wide cache
    assert coord.stats()["generation_cache"]["entries"] > 0


# ------------------------------------------------- virtual serve scenario
def test_virtual_serve_loop_zero_stall_with_full_budget_charge():
    """Serving-grade regime (busy budget, charge_init, SLO gate) under
    async generation: zero hot-path stall attributable to compilation,
    while the shared budget still pays the full compile bill."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(
            max_overhead_frac=0.3, invest_frac=0.5,
            budget_from="busy", charge_init=True,
            headroom=LatencyHeadroomGate(slo_s=0.050,
                                         min_headroom_frac=0.25)),
        device="test:v", clock=clock, async_generation=True, prefetch=1,
        lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=None))
    prefill = coord.register(
        "prefill", counted_compilette(clock, "prefill"), ev,
        specialization={"seq": 128},
        reference_fn=virtual_kernel(clock, 0.008))
    decode = coord.register(
        "decode", counted_compilette(clock, "decode"), ev,
        specialization={"max_len": 256},
        reference_fn=virtual_kernel(clock, 0.004))
    for req in range(80):                        # request pattern
        prefill(req)
        for step in range(8):
            decode(req)
            coord.maybe_pump()
    s = coord.stats()
    assert s["swaps"] >= 2                       # both kernels improved
    assert s["gen_stall_s"] == 0.0               # nothing ever stalled
    assert s["gen_spent_s"] > 0                  # ...but the budget paid
    assert s["budget_spent_s"] >= s["gen_spent_s"]
    assert s["generation"]["mode"] == "manual"
    # component split is coherent: gen + eval ≈ total tuning time
    assert s["gen_spent_s"] + s["eval_spent_s"] == pytest.approx(
        s["tuning_spent_s"])


# -------------------------------------------------------- latency EWMA
def make_outlier_compilette(clock, cost_box):
    """Kernels whose calls advance the clock by a MUTABLE cost, so a test
    can inject one slow outlier call."""
    sp = space()

    def gen(point, **spec):
        def fn(*args):
            clock.advance(cost_box["c"] / point["unroll"])
            return args[0] if args else None
        fn.score_s = cost_box["c"] / point["unroll"]
        return fn

    from repro.core import Compilette
    return Compilette("k", sp, gen)


def mutable_kernel(clock, cost_box):
    """Reference function reading the same mutable cost."""

    def fn(*args):
        clock.advance(cost_box["c"])
        return args[0] if args else None

    fn.score_s = cost_box["c"]
    return fn


def test_one_outlier_call_cannot_freeze_headroom_gate():
    """The gate reads an EWMA of real per-call latencies recorded by
    ManagedTuner, not the last raw observation: a single 100x outlier
    call must not freeze tuning (and a single fast call must not unfreeze
    a genuinely slow kernel)."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(
            1.0, 0.5, headroom=LatencyHeadroomGate(slo_s=0.010,
                                                   min_headroom_frac=0.5)),
        device="test:v", clock=clock)
    cost_box = {"c": 0.002}
    m = coord.register("k", make_outlier_compilette(clock, cost_box), ev,
                       reference_fn=mutable_kernel(clock, cost_box))
    for i in range(20):
        m(i)
    coord.pump()
    regens_before = m.tuner.accounts.regenerations
    assert regens_before > 0                     # fast kernel tunes freely
    # ONE outlier call (7.5x the norm, eating the whole SLO headroom if
    # read raw) then back to normal
    cost_box["c"] = 0.015
    m(0)
    cost_box["c"] = 0.002
    # the EWMA absorbed the spike: the gate stays open — a raw last-call
    # reading of 0.015 s against the 0.010 s SLO would have frozen it
    assert m.tuner.accounts.observed_call_s < 0.005
    assert coord.policy.headroom_allows(m.tuner.accounts, 0.0)
    gate = coord.policy.headroom
    assert not gate.allows(0.015, 0.0)           # the raw reading would
    for i in range(60):
        m(i)
        coord.pump()
    assert m.tuner.accounts.regenerations > regens_before   # not frozen


def test_ewma_tracks_sustained_latency_shift():
    """A SUSTAINED regression (not an outlier) must still freeze tuning:
    the EWMA converges to the new level and the gate closes."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(
            1.0, 0.5, headroom=LatencyHeadroomGate(slo_s=0.010,
                                                   min_headroom_frac=0.5)),
        device="test:v", clock=clock)
    cost_box = {"c": 0.002}
    m = coord.register("k", make_outlier_compilette(clock, cost_box), ev,
                       reference_fn=mutable_kernel(clock, cost_box))
    for i in range(40):
        m(i)
    assert coord.policy.headroom_allows(m.tuner.accounts, 0.0)
    cost_box["c"] = 0.2                          # sustained: every call slow
    for i in range(40):
        m(i)
    assert m.tuner.accounts.observed_call_s > 0.010
    assert not coord.policy.headroom_allows(m.tuner.accounts, 0.0)
    regens_before = m.tuner.accounts.regenerations
    for _ in range(40):
        coord.pump()
    assert m.tuner.accounts.regenerations == regens_before  # frozen


# ------------------------------------------------- tail-aware (p99) gate
def test_latency_histogram_quantiles():
    from repro.core import LatencyHistogram

    h = LatencyHistogram()
    assert h.quantile(0.99) == 0.0               # no samples yet
    for _ in range(98):
        h.observe(0.001)
    for _ in range(2):
        h.observe(0.1)
    assert h.count == 100
    # bucket resolution is ~15% relative at 16 buckets/decade
    assert h.quantile(0.5) == pytest.approx(0.001, rel=0.2)
    assert h.quantile(0.9) == pytest.approx(0.001, rel=0.2)
    assert h.quantile(0.99) == pytest.approx(0.1, rel=0.2)
    assert h.quantile(1.0) == pytest.approx(0.1, rel=0.2)
    with pytest.raises(ValueError):
        h.quantile(0.0)


def test_p99_gate_freezes_on_tail_the_ewma_misses():
    """Satellite: with ``slo_quantile=0.99`` the headroom gate reads the
    log-histogram tail — a kernel whose mean is comfortable but whose
    p99 already exceeds the SLO is frozen, even though the EWMA (and
    thus the PR-3 gate) would keep tuning."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    gate = LatencyHeadroomGate(slo_s=0.010, min_headroom_frac=0.25,
                               slo_quantile=0.99)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5, headroom=gate),
        device="test:v", clock=clock)
    cost_box = {"c": 0.002}
    m = coord.register("k", make_outlier_compilette(clock, cost_box), ev,
                       reference_fn=mutable_kernel(clock, cost_box))
    # a 3% tail of SLO-busting calls spread through otherwise-fast
    # traffic (the run ends fast, so the EWMA has decayed back down)
    for i in range(100):
        cost_box["c"] = 0.02 if i % 34 == 0 else 0.002
        m(i)
    cost_box["c"] = 0.002
    assert m.tuner.accounts.observed_call_s < 0.005     # mean looks fine
    assert m.tuner.accounts.observed_tail_s > 0.010     # p99 does not
    # the PR-3 EWMA gate would allow; the tail-aware gate freezes
    assert gate.allows(m.tuner.accounts.observed_call_s, 0.0)
    assert not coord.policy.headroom_allows(m.tuner.accounts, 0.0)
    regens_before = m.tuner.accounts.regenerations
    for _ in range(40):
        coord.pump()
    assert m.tuner.accounts.regenerations == regens_before  # frozen


def test_p99_gate_opens_when_tail_is_tight():
    """Uniformly fast traffic: the p99 estimate sits at the mean and the
    tail-aware gate behaves exactly like the EWMA gate."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    gate = LatencyHeadroomGate(slo_s=0.010, min_headroom_frac=0.25,
                               slo_quantile=0.99)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5, headroom=gate),
        device="test:v", clock=clock)
    cost_box = {"c": 0.002}
    m = coord.register("k", make_outlier_compilette(clock, cost_box), ev,
                       reference_fn=mutable_kernel(clock, cost_box))
    for i in range(100):
        m(i)
        coord.pump()
    assert m.tuner.accounts.observed_tail_s == pytest.approx(0.002,
                                                             rel=0.2)
    assert coord.policy.headroom_allows(m.tuner.accounts, 0.0)
    assert m.tuner.accounts.regenerations > 0


# ------------------------------------------------------ component split
def test_gen_spent_split_in_sync_mode():
    """Satellite: stats() reports cumulative generation time separately
    from measurement time, in the synchronous paper cycle too."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = make_coord(clock)
    m = coord.register("k", counted_compilette(clock), ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    drive(coord, m, 300)
    s = coord.stats()
    assert s["gen_spent_s"] == pytest.approx(4 * GEN_COST)
    assert s["gen_stall_s"] == pytest.approx(4 * GEN_COST)   # all inline
    expected_eval = sum(cost({"unroll": u}) for u in (1, 2, 4, 8))
    assert s["eval_spent_s"] == pytest.approx(expected_eval)
    assert s["tuning_spent_s"] == pytest.approx(
        s["gen_spent_s"] + s["eval_spent_s"])
    per_kernel = s["kernels"]["k"]
    assert per_kernel["gen_spent_s"] == pytest.approx(4 * GEN_COST)
