"""Compile farm: multi-worker generation, gain-priority queue, caps.

Determinism tests run the ``"manual"`` farm on the ``VirtualClock`` with
declared compile costs — one ``run_pending()`` completes one *batch* of
up to ``workers`` jobs in priority order (max-overlap semantics: the
batch's wall time hides inside the serving interval, the budget is
billed the full sum, ``gen_stall_s`` stays exactly 0). Thread/process
backends get targeted concurrency and lifecycle tests.
"""

import json
import os
import threading

import pytest

from repro.core import (
    CompileFarm,
    Param,
    RegenerationPolicy,
    VirtualClock,
    VirtualClockEvaluator,
    product_space,
    virtual_compilette,
    virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.lifecycle import TunerLifecycle

GEN_COST = 0.010


def space(n=4):
    return product_space([Param("unroll", (1, 2, 4, 8)[:n], phase=1)])


def cost(p):
    return 0.008 / p["unroll"]


def tracked_compilette(clock, name="k", order=None, gen_cost_s=GEN_COST):
    """virtual_compilette recording generation ORDER into ``order``."""
    comp = virtual_compilette(clock, name, space(), cost,
                              gen_cost_s=gen_cost_s)
    if order is not None:
        inner = comp._generate

        def tracking(point, **spec):
            order.append((name, dict(point)))
            return inner(point, **spec)

        comp._generate = tracking
    return comp


# --------------------------------------------------------- batch semantics
def test_run_pending_completes_one_batch_of_workers():
    """Manual mode: one run_pending = up to ``workers`` completions (the
    M-workers-one-pump-interval overlap model), drain() flushes all."""
    clock = VirtualClock()
    farm = CompileFarm("manual", workers=2)
    comp = tracked_compilette(clock)
    tickets = [farm.submit(comp, {"unroll": u}, {}) for u in (1, 2, 4, 8)]
    assert farm.in_flight == 4
    assert farm.run_pending() == 2           # one batch of 2
    assert [t.done for t in tickets] == [True, True, False, False]
    assert farm.run_pending() == 2
    assert all(t.done for t in tickets)
    assert farm.run_pending() == 0           # queue empty
    # virtual clock never advanced: the batch overlapped with serving
    assert clock() == 0.0
    # ...but every job's cost is billed on its ticket
    assert all(t.gen_charge_s == GEN_COST for t in tickets)


def test_drain_flushes_whole_queue_regardless_of_workers():
    clock = VirtualClock()
    farm = CompileFarm("manual", workers=2)
    comp = tracked_compilette(clock)
    for u in (1, 2, 4, 8):
        farm.submit(comp, {"unroll": u}, {})
    assert farm.drain() == 4
    assert farm.in_flight == 0


# --------------------------------------------------------- priority order
def test_priority_queue_pops_highest_gain_first():
    clock = VirtualClock()
    order = []
    farm = CompileFarm("manual", workers=1)
    a = tracked_compilette(clock, "a", order)
    b = tracked_compilette(clock, "b", order)
    c = tracked_compilette(clock, "c", order)
    farm.submit(a, {"unroll": 1}, {}, priority=0.5)
    farm.submit(b, {"unroll": 1}, {}, priority=2.0)
    farm.submit(c, {"unroll": 1}, {}, priority=1.0)
    farm.drain()
    assert [n for n, _ in order] == ["b", "c", "a"]


def test_requests_preempt_speculation_at_equal_priority():
    clock = VirtualClock()
    order = []
    farm = CompileFarm("manual", workers=1)
    a = tracked_compilette(clock, "a", order)
    b = tracked_compilette(clock, "b", order)
    billed = []
    farm.submit(a, {"unroll": 1}, {}, speculative=True, priority=1.0,
                charge_cb=lambda t, s: billed.append(s))
    farm.submit(b, {"unroll": 1}, {}, priority=1.0)
    farm.drain()
    # b submitted LATER but non-speculative: it wins the tie
    assert [n for n, _ in order] == ["b", "a"]
    assert billed == [GEN_COST]              # prefetch billed via callback


def test_equal_priority_requests_keep_submission_order():
    clock = VirtualClock()
    order = []
    farm = CompileFarm("manual", workers=1)
    comps = [tracked_compilette(clock, n, order) for n in ("x", "y", "z")]
    for comp in comps:
        farm.submit(comp, {"unroll": 1}, {}, priority=1.0)
    farm.drain()
    assert [n for n, _ in order] == ["x", "y", "z"]


# ------------------------------------------------------- per-kernel caps
def test_per_kernel_cap_rejects_only_speculation():
    clock = VirtualClock()
    farm = CompileFarm("manual", workers=4, per_kernel_cap=2)
    a = tracked_compilette(clock, "a")
    b = tracked_compilette(clock, "b")
    # the tuner's own request + one prefetch fill kernel a's quota
    assert farm.submit(a, {"unroll": 1}, {}) is not None
    assert farm.submit(a, {"unroll": 2}, {}, speculative=True) is not None
    assert farm.kernel_in_flight("a") == 2
    # further speculation for a is REJECTED...
    assert farm.submit(a, {"unroll": 4}, {}, speculative=True) is None
    assert farm.stats()["rejected_speculative"] == 1
    # ...but another kernel's jobs keep flowing
    assert farm.submit(b, {"unroll": 1}, {}, speculative=True) is not None
    # and a non-speculative request is ALWAYS admitted (one per tuner)
    assert farm.submit(a, {"unroll": 4}, {}) is not None
    assert farm.kernel_in_flight("a") == 3
    farm.drain()
    assert farm.kernel_in_flight("a") == 0
    assert farm.in_flight == 0


def test_saturated_kernel_cannot_starve_the_farm():
    """With the cap, a wide-space kernel's speculation leaves slots for
    every other kernel even under saturation."""
    clock = VirtualClock()
    farm = CompileFarm("manual", workers=2, per_kernel_cap=2)
    wide = tracked_compilette(clock, "wide")
    admitted = sum(
        farm.submit(wide, {"unroll": u}, {}, speculative=True) is not None
        for u in (1, 2, 4, 8))
    assert admitted == 2                       # quota, not queue length
    order = []
    other = tracked_compilette(clock, "other", order)
    farm.submit(other, {"unroll": 1}, {}, priority=5.0)
    assert farm.run_pending() == 2             # first batch
    assert order and order[0][0] == "other"    # gain-priority: other first


# ------------------------------------------------ determinism across M
def _scripted_coordinator(clock, workers):
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock,
        async_generation=True, prefetch=1, compile_workers=workers,
        lifecycle=TunerLifecycle(seq_buckets=True, idle_evict_s=None))
    ev = VirtualClockEvaluator(clock)
    handles = []
    for i, name in enumerate(("k0", "k1", "k2", "k3")):
        comp = virtual_compilette(
            clock, name, space(), cost, gen_cost_s=GEN_COST * (i + 1))
        handles.append(coord.register(
            name, comp, ev,
            reference_fn=virtual_kernel(clock, 0.008)))
    return coord, handles


def _drive_scripted(workers, steps=400):
    clock = VirtualClock()
    coord, handles = _scripted_coordinator(clock, workers)
    for i in range(steps):
        for h in handles:
            h(i)
        clock.advance(0.0005)
        coord.pump()
    stats = coord.stats()
    stats["farm"] = coord.generator.stats()
    return stats


@pytest.mark.parametrize("workers", [1, 2, 4])
def test_same_seed_same_costs_byte_identical_stats(workers):
    """Acceptance: two identical runs at every M produce byte-identical
    stats — scheduling order, billing and farm counters are all
    deterministic functions of (seed, scripted costs, M)."""
    a = _drive_scripted(workers)
    b = _drive_scripted(workers)
    assert json.dumps(a, sort_keys=True) == json.dumps(b, sort_keys=True)
    assert a["farm"]["workers"] == workers
    assert a["gen_stall_s"] == 0.0
    assert a["gen_spent_s"] > 0
    # rollup reconciliation: per-kernel accounts + tombstone == aggregate
    for f in ("gen_spent_s", "gen_stall_s", "eval_spent_s"):
        rollup = (sum(k[f] for k in a["kernels"].values())
                  + a["retired_accounts"][f])
        assert rollup == pytest.approx(a[f], abs=1e-12), f


def test_more_workers_never_slow_time_to_best():
    """Cold-start time-to-best (virtual clock time at which the LAST
    kernel finishes exploring) shrinks monotonically with M."""

    def time_to_best(workers):
        clock = VirtualClock()
        coord, handles = _scripted_coordinator(clock, workers)
        for i in range(4000):
            for h in handles:
                h(i)
            clock.advance(0.0005)
            coord.pump()
            if all(h.tuner.explorer.finished for h in handles):
                return clock()
        raise AssertionError("never converged")

    t1, t2, t4 = (time_to_best(w) for w in (1, 2, 4))
    assert t4 <= t2 <= t1
    assert t4 < t1                      # strictly better at M=4


# -------------------------------------------------------- thread backend
def test_thread_workers_compile_concurrently():
    """workers=2 must run two generates at the same time: each generate
    blocks on a 2-party barrier, so a serialized farm would deadlock."""
    clock = VirtualClock()
    barrier = threading.Barrier(2, timeout=10.0)
    farm = CompileFarm("thread", workers=2)
    comp = virtual_compilette(clock, "k", space(), cost, gen_cost_s=GEN_COST)
    inner = comp._generate

    def rendezvous(point, **spec):
        barrier.wait()                  # passes only if both run at once
        return inner(point, **spec)

    comp._generate = rendezvous
    t1 = farm.submit(comp, {"unroll": 1}, {})
    t2 = farm.submit(comp, {"unroll": 2}, {})
    for _ in range(2000):
        if t1.done and t2.done:
            break
        threading.Event().wait(0.005)
    assert t1.done and t2.done               # both completed, no deadlock
    farm.shutdown()


def test_idle_retirement_never_loses_a_submission():
    """Regression (satellite): a job enqueued while the worker is timing
    out idle must still be served — retire-check and deregistration are
    one critical section under the submit mutex."""
    clock = VirtualClock()
    # timeout so small every submit races the retirement path
    farm = CompileFarm("thread", workers=1, worker_idle_timeout_s=0.001)
    comp = virtual_compilette(clock, "k", space(), cost, gen_cost_s=0.0)
    for i in range(200):
        # fresh key every time (cycle the space, vary specialization)
        ticket = farm.submit(comp, {"unroll": (1, 2, 4, 8)[i % 4]},
                             {"rep": i // 4})
        for _ in range(2000):
            if ticket.done:
                break
            threading.Event().wait(0.001)
        assert ticket.done, f"submission {i} lost to idle retirement"
    assert farm.completed == 200
    farm.shutdown()


def test_shutdown_leaves_farm_reusable():
    clock = VirtualClock()
    farm = CompileFarm("thread", workers=2)
    comp = virtual_compilette(clock, "k", space(), cost, gen_cost_s=0.0)
    t = farm.submit(comp, {"unroll": 1}, {})
    for _ in range(2000):
        if t.done:
            break
        threading.Event().wait(0.001)
    farm.shutdown()
    assert not farm._threads
    t2 = farm.submit(comp, {"unroll": 2}, {})     # respawns workers
    for _ in range(2000):
        if t2.done:
            break
        threading.Event().wait(0.001)
    assert t2.done and t2.error is None
    farm.shutdown()


# ------------------------------------------------------- process backend
def _child_compile(seconds: float) -> float:
    """Module-level child target (picklable-by-name) for payload tests."""
    return seconds


def test_process_backend_falls_back_without_payload():
    """A compilette with no process_payload protocol compiles in-thread;
    the fallback is transparent and counted."""
    clock = VirtualClock()
    farm = CompileFarm("process", workers=1)
    comp = virtual_compilette(clock, "k", space(), cost, gen_cost_s=GEN_COST)
    t = farm.submit(comp, {"unroll": 1}, {})
    for _ in range(2000):
        if t.done:
            break
        threading.Event().wait(0.001)
    assert t.done and t.error is None
    assert farm.stats()["process_fallbacks"] == 1
    assert farm.stats()["process_offloaded"] == 0
    farm.shutdown()


@pytest.mark.slow
def test_process_backend_offloads_to_child_process():
    """The payload runs in a REAL child (different pid) and its seconds
    are added to the generation charge."""
    clock = VirtualClock()
    farm = CompileFarm("process", workers=1)
    comp = virtual_compilette(clock, "k", space(), cost, gen_cost_s=GEN_COST)
    comp.process_payload = lambda point, spec: (
        "test_compile_farm", "_child_compile", {"seconds": 0.125})
    t = farm.submit(comp, {"unroll": 1}, {})
    for _ in range(30000):
        if t.done:
            break
        threading.Event().wait(0.005)
    assert t.done and t.error is None
    assert farm.stats()["process_offloaded"] == 1
    assert t.kern.meta["process_pid"] != os.getpid()
    assert t.kern.meta["process_compile_s"] == 0.125
    # declared virtual cost + the child's measured seconds, billed once
    assert t.gen_charge_s == pytest.approx(GEN_COST + 0.125)
    farm.shutdown()


# --------------------------------------------------------- adaptive sizing
def test_auto_farm_grows_under_sustained_backlog():
    clock = VirtualClock()
    farm = CompileFarm("manual", workers="auto", max_workers=4)
    assert farm.auto_sized and farm.workers == 1
    comp = tracked_compilette(clock)
    # every submit sees more queued work than workers: backlog pressure
    for i, u in enumerate((1, 2, 4, 8)):
        farm.submit(comp, {"unroll": u}, {})
    assert farm.workers > 1, "sustained backlog must grow the pool"
    assert farm.stats()["grown"] == farm.workers - 1
    assert farm.workers <= farm.max_workers
    farm.drain()


def test_auto_farm_never_exceeds_max_workers():
    clock = VirtualClock()
    farm = CompileFarm("manual", workers="auto", max_workers=2)
    # distinct compilettes so every submit is a fresh (uncached) job
    for wave in range(5):
        comp = tracked_compilette(clock, f"k{wave}", gen_cost_s=0.001)
        for u in (1, 2, 4, 8):
            farm.submit(comp, {"unroll": u}, {})
        farm.drain()
        assert farm.workers <= 2
    assert farm.stats()["max_workers"] == 2


def test_auto_farm_shrinks_when_observed_idle():
    clock = VirtualClock()
    farm = CompileFarm("manual", workers="auto", max_workers=4)
    comp = tracked_compilette(clock)
    for u in (1, 2, 4, 8):
        farm.submit(comp, {"unroll": u}, {})
    farm.drain()
    grown_to = farm.workers
    assert grown_to > 1
    # idle pumps: the pool cools back down one worker at a time
    for _ in range(farm.AUTO_SHRINK_AFTER * (grown_to - 1)):
        farm.run_pending()
    assert farm.workers == 1
    assert farm.stats()["shrunk"] == grown_to - 1


def test_auto_farm_manual_mode_is_deterministic():
    """Two same-seed runs through an auto-sized manual farm complete the
    same batches in the same order: resize decisions are queue-state
    functions, never wall-clock ones."""

    def one_run():
        clock = VirtualClock()
        order = []
        farm = CompileFarm("manual", workers="auto", max_workers=4)
        comps = [tracked_compilette(clock, n, order)
                 for n in ("a", "b", "c")]
        log = []
        for wave in range(4):
            for j, comp in enumerate(comps):
                farm.submit(comp, {"unroll": (1, 2, 4, 8)[wave]}, {},
                            priority=float(j))
            done = farm.run_pending()
            log.append((done, farm.workers))
        farm.drain()
        s = farm.stats()
        return order, log, (s["grown"], s["shrunk"], s["workers"])

    assert one_run() == one_run()


def test_fixed_farm_ignores_adaptive_signals():
    clock = VirtualClock()
    farm = CompileFarm("manual", workers=2)
    assert not farm.auto_sized
    comp = tracked_compilette(clock)
    for u in (1, 2, 4, 8):
        farm.submit(comp, {"unroll": u}, {})
    for _ in range(farm.AUTO_SHRINK_AFTER * 2):
        farm.run_pending()
    s = farm.stats()
    assert (farm.workers, s["grown"], s["shrunk"]) == (2, 0, 0)
    assert s["max_workers"] == 2


def test_auto_workers_validated_through_config():
    from repro.api import TuningConfig

    cfg = TuningConfig(compile_workers="auto")
    assert cfg.compile_workers == "auto"
    with pytest.raises(ValueError):
        TuningConfig(compile_workers="fast")
    coord = TuningCoordinator(device="test:v", clock=VirtualClock(),
                              async_generation=True, compile_workers="auto")
    assert coord.generator.auto_sized
    assert coord.generator.stats()["auto_sized"]
