"""Fleet fabric: registry backends, snapshot merge, partitioned replicas.

Everything deterministic on the VirtualClock + in-memory FleetBus; the
filesystem paths go through tmp_path with a SharedFileBackend per
"process". The invariants under test are the fleet contract:

  * ``merge_snapshots`` is a commutative, idempotent join (lower score
    wins, quarantine and evaluation ledgers union, condemned bests drop);
  * a point condemned by replica A is never proposed, warm-started or
    canaried by replica B after one sync — including after a restart
    from the merged registry;
  * peers' published evaluations count as seen: no point is compiled
    twice per fleet;
  * a peer's published best enters as a CANDIDATE through the gate, not
    as a blind incumbent.
"""

import argparse
import json
import os
import threading
import time

import pytest

from repro.api import TuningConfig, TuningSession, _resolve_backend
from repro.core import (
    Compilette, FleetBus, LocalBackend, OnlineAutotuner, Param,
    RegenerationPolicy, SharedFileBackend, TunedRegistry, VariantGate,
    VirtualClock, VirtualClockEvaluator, merge_snapshots, product_space,
    virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator

DEV = "test:v"


def snap(*, best=None, quarantine=None, evaluations=None, generation=0,
         traits=None):
    """Build a registry snapshot literal for one key ``k``."""
    reg = TunedRegistry()
    if best is not None:
        point, score = best
        reg.put("k", {}, DEV, point, score, traits=traits)
    if quarantine:
        for point, reason in quarantine:
            reg.quarantine("k", {}, DEV, point, reason)
    if evaluations:
        for point, score in evaluations:
            reg.record_evaluation("k", {}, DEV, point, score)
    reg._generation = generation
    return reg.snapshot()


def make_comp(clock, name="k", cost=lambda p: 0.010 / p["unroll"]):
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1,
                              switch_rank=0)])

    def gen(point, **spec):
        return virtual_kernel(clock, cost(point), tag=dict(point))

    return Compilette(name, sp, gen)


def make_coordinator(clock, registry, backend, rid, count, **kw):
    kw.setdefault("policy", RegenerationPolicy(
        max_overhead_frac=1.0, invest_frac=1.0))
    return TuningCoordinator(
        device=DEV, clock=clock, registry=registry,
        replica_id=rid, replica_count=count,
        registry_backend=backend, sync_every_s=None, **kw)


# -------------------------------------------------------- merge_snapshots
def test_merge_lower_score_wins_and_is_commutative():
    a = snap(best=({"unroll": 2}, 0.005))
    b = snap(best=({"unroll": 8}, 0.00125))
    ab, ba = merge_snapshots(a, b), merge_snapshots(b, a)
    assert ab == ba
    (key,) = [k for k in ab if not k.startswith("__")]
    assert ab[key]["point"] == {"unroll": 8}
    assert ab[key]["score_s"] == 0.00125
    # idempotent: merging the merge changes nothing
    assert merge_snapshots(ab, a) == ab


def test_merge_quarantine_union_drops_condemned_best():
    a = snap(best=({"unroll": 8}, 0.00125))
    b = snap(quarantine=[({"unroll": 8}, "wrong output")])
    for merged in (merge_snapshots(a, b), merge_snapshots(b, a)):
        assert all(k.startswith("__") for k in merged), (
            "a best condemned by any replica must not survive the merge")
        quar = merged["__registry_meta__"]["quarantine"]
        assert any("wrong output" in r
                   for v in quar.values() for r in v.values())


def test_merge_evaluations_union_keeps_min_score():
    a = snap(evaluations=[({"unroll": 2}, 0.006)])
    b = snap(evaluations=[({"unroll": 2}, 0.005), ({"unroll": 4}, 0.0025)])
    ab, ba = merge_snapshots(a, b), merge_snapshots(b, a)
    assert ab == ba
    evals = next(iter(ab["__registry_meta__"]["evaluations"].values()))
    assert sorted(evals.values()) == [0.0025, 0.005]


def test_merge_generation_is_max():
    a = snap(generation=3)
    b = snap(generation=7)
    assert merge_snapshots(a, b)["__registry_meta__"]["generation"] == 7


TRAITS = {"flops": 1.52e13, "bandwidth_gbps": 410.0, "vmem_kb": 1024.0,
          "issue": 3.0, "overlap": 0.0}


def test_merge_traits_union_is_commutative_and_idempotent():
    """A side that has not yet learned its device traits must not strip
    them from the merge — regardless of sync order or repetition."""
    a = snap(best=({"unroll": 8}, 0.00125))                  # no traits
    b = snap(best=({"unroll": 8}, 0.00125), traits=TRAITS)   # with traits
    ab, ba = merge_snapshots(a, b), merge_snapshots(b, a)
    assert ab == ba
    (key,) = [k for k in ab if not k.startswith("__")]
    assert ab[key]["traits"] == TRAITS
    # idempotent: re-merging either original side changes nothing
    assert merge_snapshots(ab, a) == ab
    assert merge_snapshots(ab, b) == ab


def test_merge_traits_survive_winner_without_them():
    """Traits describe the key's DEVICE, not the point: a traits-less
    winner adopts the losing candidate's trait vector."""
    a = snap(best=({"unroll": 8}, 0.00125))                  # wins on score
    b = snap(best=({"unroll": 2}, 0.005), traits=TRAITS)     # loses, knows
    for merged in (merge_snapshots(a, b), merge_snapshots(b, a)):
        (key,) = [k for k in merged if not k.startswith("__")]
        assert merged[key]["point"] == {"unroll": 8}
        assert merged[key]["score_s"] == 0.00125
        assert merged[key]["traits"] == TRAITS


def test_merge_snapshot_instance_grafts_missing_traits():
    reg = TunedRegistry()
    reg.put("k", {}, DEV, {"unroll": 8}, 0.00125)            # no traits yet
    reg.merge_snapshot(snap(best=({"unroll": 8}, 0.00125), traits=TRAITS))
    snapshot = reg.snapshot()
    (key,) = [k for k in snapshot if not k.startswith("__")]
    assert snapshot[key]["traits"] == TRAITS


def test_registry_merge_snapshot_round_trips_through_save_load(tmp_path):
    reg = TunedRegistry()
    reg.merge_snapshot(snap(
        best=({"unroll": 4}, 0.0025),
        quarantine=[({"unroll": 8}, "tail")],
        evaluations=[({"unroll": 2}, 0.005)]))
    path = str(tmp_path / "tuned.json")
    reg.save(path)
    back = TunedRegistry.load(path)
    assert back.get("k", {}, DEV) == {"unroll": 4}
    assert back.is_quarantined("k", {}, DEV, {"unroll": 8})
    assert back.evaluated_points("k", {}, DEV) == [{"unroll": 2}]


# ---------------------------------------------------------------- backends
def test_local_backend_atomic_write_and_corrupt_read(tmp_path):
    path = str(tmp_path / "r.json")
    be = LocalBackend(path)
    assert be.read() is None           # missing -> cold start
    be.write({"x": {"point": {}, "score_s": 1.0}})
    assert be.read() == {"x": {"point": {}, "score_s": 1.0}}
    assert [f for f in os.listdir(tmp_path)] == ["r.json"], (
        "write must not leak temp files")
    with open(path, "w") as f:
        f.write("{ torn")
    assert be.read() is None           # corrupt -> cold start, no raise


def test_shared_file_backend_merges_across_instances(tmp_path):
    path = str(tmp_path / "fleet.json")
    a, b = SharedFileBackend(path), SharedFileBackend(path)
    a.sync(snap(best=({"unroll": 2}, 0.005)))
    merged = b.sync(snap(best=({"unroll": 8}, 0.00125),
                         quarantine=[({"unroll": 1}, "bad")]))
    (key,) = [k for k in merged if not k.startswith("__")]
    assert merged[key]["score_s"] == 0.00125
    # and A observes B's quarantine on its next sync
    merged_a = a.sync(snap())
    assert merged_a["__registry_meta__"]["quarantine"]
    assert not os.path.exists(path + ".lock"), "lock must be released"


def test_shared_file_backend_stale_lock_takeover(tmp_path):
    path = str(tmp_path / "fleet.json")
    be = SharedFileBackend(path, stale_lock_s=5.0)
    with open(be.lock_path, "w") as f:
        f.write("99999")   # a holder that died mid-sync
    old = time.time() - 60.0
    os.utime(be.lock_path, (old, old))
    merged = be.sync(snap(best=({"unroll": 2}, 0.005)))
    assert be.stale_takeovers == 1
    assert any(not k.startswith("__") for k in merged)
    assert not os.path.exists(be.lock_path)


def test_shared_file_backend_times_out_on_live_lock(tmp_path):
    path = str(tmp_path / "fleet.json")
    be = SharedFileBackend(path, lock_timeout_s=0.05, stale_lock_s=60.0,
                           poll_s=0.001)
    with open(be.lock_path, "w") as f:
        f.write("1")       # fresh lock, legitimately held
    with pytest.raises(TimeoutError):
        be.sync(snap())
    os.unlink(be.lock_path)
    # after release the same backend syncs fine
    assert be.sync(snap()) is not None


def test_shared_file_backend_concurrent_syncs_lose_nothing(tmp_path):
    path = str(tmp_path / "fleet.json")
    errors = []

    def publish(rid):
        be = SharedFileBackend(path, lock_timeout_s=30.0)
        try:
            for j in range(5):
                be.sync(snap(evaluations=[({"unroll": rid}, 0.001 * (j + 1))]))
        except Exception as e:          # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=publish, args=(rid,))
               for rid in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    final = SharedFileBackend(path).sync(snap())
    evals = next(iter(final["__registry_meta__"]["evaluations"].values()))
    # every replica's ledger survived, at the min score each
    assert len(evals) == 4 and set(evals.values()) == {0.001}


def test_fleet_bus_merges_and_isolates_state():
    bus = FleetBus()
    bus.sync(snap(best=({"unroll": 2}, 0.005)))
    merged = bus.sync(snap(best=({"unroll": 8}, 0.00125)))
    (key,) = [k for k in merged if not k.startswith("__")]
    assert merged[key]["point"] == {"unroll": 8}
    merged[key]["point"]["unroll"] = 999   # mutating the copy is harmless
    assert bus.peek()[key]["point"] == {"unroll": 8}
    assert bus.syncs == 2


def test_resolve_backend_specs(tmp_path):
    assert _resolve_backend(None) is None
    assert _resolve_backend("") is None
    bus = FleetBus()
    assert _resolve_backend(bus) is bus    # objects pass through
    be = _resolve_backend(f"shared:{tmp_path}/r.json")
    assert isinstance(be, SharedFileBackend)
    assert be.path == f"{tmp_path}/r.json"
    bare = _resolve_backend(f"{tmp_path}/r2.json")
    assert isinstance(bare, SharedFileBackend)


# ----------------------------------------------------- fleet coordination
def test_fleet_quarantine_reaches_peer_after_one_sync():
    """Replica A condemns its gate-failing point; after one sync replica
    B must treat it as condemned: never proposed, never served."""
    bus = FleetBus()
    bad = {"unroll": 8}
    fleets = []
    for rid in range(2):
        clock = VirtualClock()
        coord = make_coordinator(clock, TunedRegistry(), bus, rid, 2,
                                 gate_mode="check")
        comp = make_comp(clock)
        comp.gate_script = lambda point: dict(point) != bad
        m = coord.register("k", comp, VirtualClockEvaluator(clock),
                           reference_fn=virtual_kernel(clock, 0.010))
        fleets.append((coord, m, clock))

    for i in range(300):
        for coord, m, clock in fleets:
            m(i)
            clock.advance(0.010)
            coord.observe_busy(0.010)
            coord.pump()

    for rid, (coord, m, clock) in enumerate(fleets):
        assert m.tuner.explorer.is_quarantined(bad), rid
        assert m.tuner.stats()["active_point"] != bad, rid
        assert all(life.point != bad or life.calls == 0
                   for life in m.tuner._lives), rid
    # exactly ONE replica paid the oracle check for the bad point
    failures = [m.tuner.stats()["gate_failures"] for _, m, _ in fleets]
    assert sum(failures) == 1, failures


def test_fleet_quarantine_survives_restart_from_merged_registry(tmp_path):
    path = str(tmp_path / "fleet.json")
    bad = {"unroll": 8}
    clock = VirtualClock()
    coord = make_coordinator(clock, TunedRegistry(), SharedFileBackend(path),
                             0, 2, gate_mode="check")
    comp = make_comp(clock)
    comp.gate_script = lambda point: dict(point) != bad
    m = coord.register("k", comp, VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    for i in range(200):
        m(i)
        clock.advance(0.010)
        coord.observe_busy(0.010)
        coord.pump()
    assert m.tuner.explorer.is_quarantined(bad)
    coord.close()

    # a NEW process (fresh registry object) on the same backend: the
    # initial sync merges the condemned state before register()
    clock2 = VirtualClock()
    coord2 = make_coordinator(clock2, TunedRegistry(),
                              SharedFileBackend(path), 1, 2,
                              gate_mode="check")
    m2 = coord2.register("k", make_comp(clock2),
                         VirtualClockEvaluator(clock2),
                         reference_fn=virtual_kernel(clock2, 0.010))
    assert m2.tuner.explorer.is_quarantined(bad)
    assert not m2.warm_started or m2.tuner.explorer.best_point != bad
    m2.tuner.exhaust()
    assert bad not in [dict(p) for p, _ in m2.tuner.explorer.history]


def test_fleet_peer_evaluations_never_compiled_twice():
    """After replica A explored everything, a late-joining replica B must
    re-compile nothing but the warm-start re-validation."""
    bus = FleetBus()
    clock = VirtualClock()
    coord = make_coordinator(clock, TunedRegistry(), bus, 0, 2)
    m = coord.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    for i in range(200):
        m(i)
        clock.advance(0.010)
        coord.observe_busy(0.010)
        coord.pump()
    assert m.tuner.explorer.finished
    coord.sync_fleet()

    clock2 = VirtualClock()
    coord2 = make_coordinator(clock2, TunedRegistry(), bus, 1, 2)
    m2 = coord2.register("k", make_comp(clock2),
                         VirtualClockEvaluator(clock2),
                         reference_fn=virtual_kernel(clock2, 0.010))
    assert m2.warm_started   # fleet best seeds the warm start
    for i in range(200):
        m2(i)
        clock2.advance(0.010)
        coord2.observe_busy(0.010)
        coord2.pump()
    # only the warm re-validation regenerated; every other point was a
    # peer evaluation and counted as seen
    assert m2.tuner.accounts.regenerations == 1
    assert [dict(p) for p, _ in m2.tuner.explorer.history] == [
        m2.tuner.explorer.best_point]


def test_fleet_peer_best_enters_as_candidate_through_gate():
    """A peer-published best that FAILS this replica's local gate must be
    rejected here (quarantined), not blindly trusted as incumbent."""
    bus = FleetBus()
    best = {"unroll": 8}
    # replica 0: clean, finds and publishes `best`
    clock = VirtualClock()
    coord = make_coordinator(clock, TunedRegistry(), bus, 0, 2,
                             gate_mode="check")
    m = coord.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    for i in range(200):
        m(i)
        clock.advance(0.010)
        coord.observe_busy(0.010)
        coord.pump()
    assert m.tuner.explorer.best_point == best
    coord.sync_fleet()

    # replica 1: same point fails ITS oracle (e.g. divergent hardware)
    clock2 = VirtualClock()
    coord2 = make_coordinator(clock2, TunedRegistry(), bus, 1, 2,
                              gate_mode="check")
    comp2 = make_comp(clock2)
    comp2.gate_script = lambda point: dict(point) != best
    m2 = coord2.register("k", comp2, VirtualClockEvaluator(clock2),
                         reference_fn=virtual_kernel(clock2, 0.010))
    for i in range(200):
        m2(i)
        clock2.advance(0.010)
        coord2.observe_busy(0.010)
        coord2.pump()
    s2 = m2.tuner.stats()
    assert s2["gate_failures"] >= 1
    assert m2.tuner.explorer.is_quarantined(best)
    assert s2["active_point"] != best
    assert all(life.point != best or life.calls == 0
               for life in m2.tuner._lives)


def test_adopt_quarantine_aborts_canary_and_demotes_incumbent():
    clock = VirtualClock()
    comp = make_comp(clock)
    tuner = OnlineAutotuner(
        comp, VirtualClockEvaluator(clock),
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        clock=clock, wake_every=1, gate=VariantGate(comp),
        gate_mode="canary", canary_fraction=1.0, canary_calls=10_000)
    # run until some candidate is in canary probation
    for i in range(100):
        tuner(i)
        if tuner._canary is not None:
            break
    assert tuner._canary is not None
    canaried = dict(tuner._canary.life.point)
    assert tuner.adopt_quarantine(canaried, "fleet quarantine")
    assert tuner._canary is None, "peer verdict must abort the canary"
    assert tuner.explorer.is_quarantined(canaried)
    # no rollback charged: this was an external verdict, not a local one
    assert tuner.accounts.rollbacks == 0

    # now demote an ACTIVE incumbent
    tuner2 = OnlineAutotuner(
        comp if False else make_comp(VirtualClock()),
        VirtualClockEvaluator(clock), clock=clock, wake_every=1,
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0))
    for i in range(400):
        tuner2(i)
    active = dict(tuner2.stats()["active_point"])
    assert active != {}
    assert tuner2.adopt_quarantine(active, "fleet quarantine")
    assert tuner2.stats()["active_point"] != active
    # idempotent: adopting again changes nothing
    assert not tuner2.adopt_quarantine(active, "fleet quarantine")


def test_converged_tuner_reactivates_on_peer_best():
    """A CONVERGED replica must wake up when a peer publishes a strictly
    better variant, re-validate it and serve it."""
    bus = FleetBus()
    # replica 1 of 2: every point of this 4-point space happens to hash
    # to stripe 0, so this replica owns nothing, proposes nothing and
    # converges almost immediately
    from repro.core import point_stripe
    assert all(point_stripe({"unroll": u}, 2) == 0 for u in (1, 2, 4, 8))
    clock = VirtualClock()
    coord = make_coordinator(clock, TunedRegistry(), bus, 1, 2)
    m = coord.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    for i in range(300):
        m(i)
        clock.advance(0.010)
        coord.observe_busy(0.010)
        coord.pump()
    assert m.tuner.explorer.finished
    from repro.runtime.lifecycle import TunerState
    assert m.state is TunerState.CONVERGED
    old_best = m.tuner.explorer.best_score

    # a peer publishes a strictly better best for the same key
    peer = TunedRegistry()
    peer.put("k", {}, DEV, {"unroll": 8}, 0.00125)
    peer.record_evaluation("k", {}, DEV, {"unroll": 8}, 0.00125)
    bus.sync(peer.snapshot())

    for i in range(300):
        m(i)
        clock.advance(0.010)
        coord.observe_busy(0.010)
        coord.pump()
    assert m.tuner.explorer.best_score < old_best
    assert m.tuner.explorer.best_point == {"unroll": 8}
    assert m.tuner.stats()["active_point"] == {"unroll": 8}


def test_coordinator_validates_replica_knobs():
    with pytest.raises(ValueError):
        TuningCoordinator(device=DEV, replica_id=2, replica_count=2)
    with pytest.raises(ValueError):
        TuningCoordinator(device=DEV, replica_id=-1, replica_count=2)
    coord = TuningCoordinator(device=DEV, replica_id=3, replica_count=4)
    assert coord.stats()["fleet"] == {
        "replica_id": 3, "replica_count": 4, "backend": None, "syncs": 0}


# ------------------------------------------------------------ config knobs
def test_fleet_config_env_flags_programmatic_identical(tmp_path):
    base = TuningConfig(enabled=False)
    env = {
        "REPRO_TUNE_REPLICA_ID": "1",
        "REPRO_TUNE_REPLICA_COUNT": "4",
        "REPRO_TUNE_REGISTRY_BACKEND": f"shared:{tmp_path}/fleet.json",
        "REPRO_TUNE_SYNC_EVERY": "2.5",
        "REPRO_TUNE_COMPILE_WORKERS": "auto",
    }
    cfg_env = TuningConfig.from_env(env, base=base)
    parser = argparse.ArgumentParser()
    TuningConfig.add_flags(parser, base=base)
    cfg_flags = TuningConfig.from_flags(parser.parse_args([
        "--replica-id", "1", "--replica-count", "4",
        "--registry-backend", f"shared:{tmp_path}/fleet.json",
        "--sync-every", "2.5", "--compile-workers", "auto",
    ]), base=base)
    cfg_prog = TuningConfig(
        enabled=False, replica_id=1, replica_count=4,
        registry_backend=f"shared:{tmp_path}/fleet.json",
        sync_every_s=2.5, compile_workers="auto")
    assert cfg_env == cfg_flags == cfg_prog


def test_fleet_config_validation():
    with pytest.raises(ValueError):
        TuningConfig(replica_count=0)
    with pytest.raises(ValueError):
        TuningConfig(replica_id=2, replica_count=2)
    with pytest.raises(ValueError):
        TuningConfig(sync_every_s=-1.0)
    with pytest.raises(ValueError):
        TuningConfig(compile_workers="turbo")
    with pytest.raises(ValueError):
        TuningConfig(compile_workers=0)
    TuningConfig(compile_workers="auto", sync_every_s=None)   # both legal


def test_session_wires_backend_through_config_and_kwarg(tmp_path):
    cfg = TuningConfig(
        enabled=True, registry_backend=f"shared:{tmp_path}/fleet.json",
        replica_id=0, replica_count=2, sync_every_s=None)
    s = TuningSession(cfg, clock=VirtualClock(), device=DEV)
    try:
        be = s.coordinator.registry_backend
        assert isinstance(be, SharedFileBackend)
        assert s.coordinator.replica_count == 2
        assert s.coordinator.fleet_syncs >= 1   # initial sync ran
    finally:
        s.close()
    # a backend OBJECT passed to the session wins over the config string
    bus = FleetBus()
    s2 = TuningSession(TuningConfig(enabled=True), clock=VirtualClock(),
                       device=DEV, registry_backend=bus)
    try:
        assert s2.coordinator.registry_backend is bus
    finally:
        s2.close()


def test_fleet_sync_counts_surface_in_stats(tmp_path):
    bus = FleetBus()
    clock = VirtualClock()
    coord = make_coordinator(clock, TunedRegistry(), bus, 0, 1)
    m = coord.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    for i in range(50):
        m(i)
        clock.advance(0.010)
        coord.observe_busy(0.010)
        coord.pump()
    s = coord.stats()
    assert s["fleet"]["backend"] == "FleetBus"
    assert s["fleet"]["syncs"] == coord.fleet_syncs >= 2
    # evaluations flushed to the shared ledger as they landed
    state = bus.peek()
    evals = state["__registry_meta__"]["evaluations"]
    assert sum(len(v) for v in evals.values()) == len(
        m.tuner.explorer.history)
