"""Substrate tests: optimizer, data, checkpointing, fault tolerance,
gradient compression, train/serve loops."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import REGISTRY
from repro.configs.base import ShapeSpec
from repro.data.pipeline import DataConfig, SyntheticLM, batches_for
from repro.distributed.compression import (
    ErrorFeedback, dequantize_int8, quantize_int8)
from repro.optim.adamw import AdamW, OptimizerConfig, schedule
from repro.runtime.train_loop import FaultInjected, TrainLoopConfig, train

SMOKE_SHAPE = ShapeSpec("smoke", "train", 64, 4)


# ---------------------------------------------------------------- optimizer
def test_adamw_converges_on_quadratic():
    opt = AdamW(OptimizerConfig(lr=0.1, warmup_steps=5, total_steps=200,
                                weight_decay=0.0, clip_norm=10.0))
    params = {"w": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(150):
        grads = {"w": 2 * params["w"]}
        params, state, gnorm = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_schedule_warmup_and_decay():
    cfg = OptimizerConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(schedule(cfg, jnp.int32(5))) == pytest.approx(0.5)
    assert float(schedule(cfg, jnp.int32(10))) <= 1.0
    assert float(schedule(cfg, jnp.int32(100))) == pytest.approx(
        cfg.min_lr_frac, rel=1e-3)


def test_grad_clip_bounds_update():
    opt = AdamW(OptimizerConfig(lr=0.1, clip_norm=1.0, warmup_steps=0,
                                total_steps=10))
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update({"w": jnp.full(3, 1e6)}, state, params)
    assert float(gnorm) > 1e5  # reported raw norm


# --------------------------------------------------------------------- data
def test_data_determinism_and_restart():
    lm = SyntheticLM(DataConfig(seed=7, vocab=100, batch=4, seq_len=16))
    b5 = lm.batch_at(5)
    b5_again = lm.batch_at(5)
    np.testing.assert_array_equal(b5["tokens"], b5_again["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b5["tokens"][:, 1:], b5["labels"][:, :-1])


def test_batches_for_adds_modality_stubs():
    cfg = REGISTRY["whisper-tiny"].reduced()
    b = next(batches_for(cfg, SMOKE_SHAPE))
    assert b["audio_embeds"].shape == (4, cfg.enc_frames, cfg.d_model)
    cfg = REGISTRY["qwen2-vl-7b"].reduced()
    b = next(batches_for(cfg, SMOKE_SHAPE))
    assert b["vision"].shape == (4, cfg.vision_patches, cfg.d_model)
    assert b["tokens"].shape[1] == SMOKE_SHAPE.seq_len - cfg.vision_patches


# -------------------------------------------------------------- checkpoints
def test_checkpointer_roundtrip_retention_latest():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=2)
        state = {"a": jnp.arange(4.0), "nested": {"b": jnp.ones((2, 2))},
                 "t": (jnp.zeros(1), jnp.ones(1))}
        for step in (1, 2, 3):
            ck.save(step, state)
        assert ck.all_steps() == [2, 3]       # retention
        assert ck.latest_step() == 3
        restored, manifest = ck.restore(state)
        np.testing.assert_array_equal(restored["a"], state["a"])
        np.testing.assert_array_equal(restored["t"][1], state["t"][1])
        assert manifest["step"] == 3


def test_checkpointer_atomicity_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        ck = Checkpointer(d, keep=3)
        ck.save(1, {"x": jnp.ones(8)})
        names = set(os.listdir(d))
        assert not any(n.startswith("tmp.") for n in names)


# -------------------------------------------------------------- compression
def test_int8_quant_roundtrip_error_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,))
    q, s = quantize_int8(g)
    err = jnp.abs(dequantize_int8(q, s) - g)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_preserves_signal():
    """Sum of applied (compressed) grads + residual == sum of true grads."""
    ef = ErrorFeedback()
    params = {"w": jnp.zeros(64)}
    errors = ef.init(params)
    true_sum = jnp.zeros(64)
    applied_sum = jnp.zeros(64)
    for i in range(20):
        g = {"w": jax.random.normal(jax.random.PRNGKey(i), (64,)) * 0.1}
        true_sum = true_sum + g["w"]
        gq, errors = ef.apply(g, errors)
        applied_sum = applied_sum + gq["w"]
    drift = applied_sum + errors["w"] - true_sum
    np.testing.assert_allclose(np.asarray(drift), 0.0, atol=1e-4)


# --------------------------------------------------------------- train loop
def test_train_loss_decreases():
    with tempfile.TemporaryDirectory() as d:
        cfg = REGISTRY["deepseek-7b"].reduced()
        out = train(cfg, SMOKE_SHAPE, TrainLoopConfig(
            steps=15, ckpt_every=50, ckpt_dir=d))
        assert out["final_loss"] < out["first_loss"]


def test_train_fault_injection_and_recovery():
    with tempfile.TemporaryDirectory() as d:
        cfg = REGISTRY["deepseek-7b"].reduced()
        loop = TrainLoopConfig(steps=12, ckpt_every=4, ckpt_dir=d,
                               fail_at_step=9)
        with pytest.raises(FaultInjected):
            train(cfg, SMOKE_SHAPE, loop)
        # auto-resume from the last checkpoint (step 8) and finish
        loop2 = TrainLoopConfig(steps=12, ckpt_every=4, ckpt_dir=d)
        out = train(cfg, SMOKE_SHAPE, loop2)
        assert out["start_step"] == 8
        assert out["steps"] == 12


def test_train_restart_is_deterministic():
    """Run 10 straight vs 5+resume(10): same final loss (same data path)."""
    cfg = REGISTRY["deepseek-7b"].reduced()
    with tempfile.TemporaryDirectory() as d1:
        full = train(cfg, SMOKE_SHAPE, TrainLoopConfig(
            steps=10, ckpt_every=100, ckpt_dir=d1, seed=3))
    with tempfile.TemporaryDirectory() as d2:
        train(cfg, SMOKE_SHAPE, TrainLoopConfig(
            steps=5, ckpt_every=5, ckpt_dir=d2, seed=3))
        resumed = train(cfg, SMOKE_SHAPE, TrainLoopConfig(
            steps=10, ckpt_every=5, ckpt_dir=d2, seed=3))
    assert resumed["final_loss"] == pytest.approx(full["final_loss"],
                                                  rel=1e-4)


def test_train_with_compression_converges():
    with tempfile.TemporaryDirectory() as d:
        cfg = REGISTRY["deepseek-7b"].reduced()
        out = train(cfg, SMOKE_SHAPE, TrainLoopConfig(
            steps=15, ckpt_every=50, ckpt_dir=d, compress_grads=True))
        assert out["final_loss"] < out["first_loss"]


def test_train_autotune_respects_budget_and_persists():
    with tempfile.TemporaryDirectory() as d:
        cfg = REGISTRY["deepseek-7b"].reduced()
        out = train(cfg, SMOKE_SHAPE, TrainLoopConfig(
            steps=20, ckpt_every=10, ckpt_dir=d, autotune=True,
            tune_max_overhead=0.5, tune_invest=0.5))
        stats = out["autotune"]
        assert stats["regenerations"] >= 1
        assert os.path.exists(os.path.join(d, "tuned.json"))
        from repro.core import TunedRegistry
        reg = TunedRegistry.load(os.path.join(d, "tuned.json"))
        assert len(reg) >= 1


# --------------------------------------------------------------- serve loop
def test_serve_generates_tokens():
    from repro.runtime.serve_loop import ServeConfig, generate
    cfg = REGISTRY["deepseek-7b"].reduced()
    batch = {"tokens": jnp.ones((2, 12), jnp.int32)}
    out = generate(cfg, batch, ServeConfig(max_new_tokens=6))
    assert out["tokens"].shape == (2, 6)
    assert out["decode_tokens_per_s"] > 0


def test_serve_rwkv_state_decode():
    from repro.runtime.serve_loop import ServeConfig, generate
    cfg = REGISTRY["rwkv6-1.6b"].reduced()
    batch = {"tokens": jnp.ones((2, 12), jnp.int32)}
    out = generate(cfg, batch, ServeConfig(max_new_tokens=5))
    assert out["tokens"].shape == (2, 5)
