"""Distributed tests: run in subprocesses with 8 fake host devices.

Sharding decisions, pjit lowering of reduced configs per family, GPipe
pipeline, and elastic (re-mesh) checkpoint restore.
"""

import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run8(code: str, timeout=420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout, env=env)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


# ------------------------------------------------------------ sharding unit
def test_fit_spec_drops_nondividing_axes():
    from jax.sharding import PartitionSpec as P
    import jax
    from repro.launch.mesh import make_mesh_for
    from repro.launch.shapes import _fit_spec
    # single-device host: build an abstract mesh via make_mesh_for(1)
    mesh = make_mesh_for(1, model_axis=1)

    class FakeMesh:
        shape = {"data": 16, "model": 16, "pod": 2}

    s = _fit_spec(P("data", "model"), (32, 40), FakeMesh())
    assert s == P("data", None)
    s = _fit_spec(P(("pod", "data"), None), (64, 10), FakeMesh())
    assert s == P(("pod", "data"), None)
    s = _fit_spec(P(("pod", "data"), None), (16, 10), FakeMesh())
    assert s == P(None, None)


def test_shard_translates_embed_for_activations():
    from repro.distributed import sharding as sh
    rules = sh.default_rules()
    with sh.use_rules(rules):
        # no mesh: shard() is a no-op but must not raise
        import jax.numpy as jnp
        x = jnp.ones((2, 3, 4))
        y = sh.shard(x, "batch", "seq", "embed")
        assert y.shape == x.shape


def test_default_rules_multi_pod():
    from repro.distributed import sharding as sh
    r = sh.default_rules(multi_pod=True)
    assert r["batch"] == ("pod", "data")
    assert r["embed"] == ("pod", "data")
    assert r["heads"] == "model"


# ------------------------------------------------------- 8-device lowering
@pytest.mark.parametrize("arch,kind", [
    ("deepseek-7b", "train"),
    ("qwen3-moe-30b-a3b", "train"),
    ("rwkv6-1.6b", "decode"),
    ("hymba-1.5b", "prefill"),
    ("whisper-tiny", "train"),
    ("qwen2-vl-7b", "decode"),
])
def test_family_lowers_on_8dev_mesh(arch, kind):
    run8(f"""
    import dataclasses
    import jax, jax.numpy as jnp
    from repro.configs import REGISTRY
    from repro.configs.base import ShapeSpec
    from repro.distributed.hlo_analysis import compiled_cost_analysis
    from repro.launch.mesh import make_mesh_for, set_mesh
    from repro.launch.shapes import build_cell
    cfg = REGISTRY['{arch}'].reduced(n_layers=2, vocab=512)
    cfg = dataclasses.replace(cfg, compute_dtype=jnp.bfloat16)
    shape = ShapeSpec('t', '{kind}', 128, 16)
    mesh = make_mesh_for(8, model_axis=2)
    cell = build_cell(cfg, shape, mesh)
    with set_mesh(mesh):
        compiled = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                           out_shardings=cell.out_shardings,
                           donate_argnums=cell.donate_argnums
                           ).lower(*cell.args).compile()
    assert compiled_cost_analysis(compiled)['flops'] > 0
    print('ok')
    """)


def test_train_step_executes_on_8dev_mesh():
    """Not just lowering: run 2 real sharded steps, loss decreases-ish."""
    run8("""
    import dataclasses
    import numpy as np
    import jax, jax.numpy as jnp
    from repro.configs import REGISTRY
    from repro.configs.base import ShapeSpec
    from repro.launch.mesh import make_mesh_for, set_mesh
    from repro.launch.shapes import build_cell
    from repro.models.model import build_model
    from repro.models.params import init_tree
    from repro.optim.adamw import AdamW
    from repro.data.pipeline import batches_for

    cfg = REGISTRY['deepseek-7b'].reduced(n_layers=2, vocab=512)
    shape = ShapeSpec('t', 'train', 64, 16)
    mesh = make_mesh_for(8, model_axis=2)
    cell = build_cell(cfg, shape, mesh)
    model = build_model(cfg)
    opt = AdamW()
    with set_mesh(mesh):
        params = jax.device_put(
            init_tree(model.param_defs(), jax.random.PRNGKey(0)),
            cell.in_shardings[0])
        opt_state = jax.device_put(opt.init(params), cell.in_shardings[1])
        step = jax.jit(cell.fn, in_shardings=cell.in_shardings,
                       out_shardings=cell.out_shardings)
        stream = batches_for(cfg, shape)
        losses = []
        for i in range(3):
            batch = {k: jax.device_put(v, cell.in_shardings[2][k])
                     for k, v in next(stream).items()}
            loss, params, opt_state = step(params, opt_state, batch)
            losses.append(float(loss))
    assert all(np.isfinite(losses)), losses
    # 3 steps with warmup LR: executability + stability, not convergence
    assert abs(losses[-1] - losses[0]) < 0.5, losses
    print('losses', losses)
    """)


def test_pipeline_parallel_matches_sequential():
    run8("""
    import jax, jax.numpy as jnp, numpy as np
    from repro.launch.mesh import _mk
    from repro.distributed.pipeline import pipeline_apply
    mesh = _mk((8,), ('pipe',))
    S, M, mb, d = 8, 4, 16, 32
    Ws = jax.random.normal(jax.random.PRNGKey(0), (S, d, d)) * 0.1
    x = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))
    out = pipeline_apply(Ws, x, lambda W, h: jnp.tanh(h @ W), mesh, axis='pipe')
    ref = x
    for i in range(S):
        ref = jnp.tanh(ref @ Ws[i])
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)
    print('ok')
    """)


def test_elastic_restore_across_mesh_shapes():
    """Save sharded on a 4×2 mesh, restore onto 2×4 — logical layout."""
    run8("""
    import tempfile
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.checkpoint.checkpointer import Checkpointer
    from repro.launch.mesh import _mk

    state = {'w': jnp.arange(64.0).reshape(8, 8)}
    with tempfile.TemporaryDirectory() as d:
        mesh1 = _mk((4, 2), ('data', 'model'))
        s1 = NamedSharding(mesh1, P('data', 'model'))
        sharded = jax.device_put(state['w'], s1)
        ck = Checkpointer(d)
        ck.save(5, {'w': sharded})
        mesh2 = _mk((2, 4), ('data', 'model'))
        s2 = NamedSharding(mesh2, P('data', 'model'))
        restored, manifest = ck.restore({'w': state['w']},
                                        shardings={'w': s2})
        assert manifest['step'] == 5
        np.testing.assert_array_equal(np.asarray(restored['w']), state['w'])
        assert restored['w'].sharding == s2
    print('ok')
    """)


def test_multipod_mesh_builders():
    run8("""
    # 8 host devices cannot build the 512-chip mesh, but the builder's
    # shape logic is checked via the abstract mesh (no device commit).
    from repro.launch.mesh import make_mesh_for
    m = make_mesh_for(8, model_axis=2)
    assert m.shape == {'data': 4, 'model': 2}
    print('ok')
    """)
