"""Process-wide TuningCoordinator: budget sharing, warm starts, swaps.

Everything except the two explicitly-threaded tests runs on the
``VirtualClockEvaluator``: simulated time is injected into the autotuner
and coordinator, so budget decisions and time-to-best are deterministic —
no wall-clock sleeps, no flakes on loaded CI hosts.
"""

import os
import tempfile
import threading

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Compilette, OnlineAutotuner, Param, RegenerationPolicy, TunedRegistry,
    VirtualClock, VirtualClockEvaluator, product_space, virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator


def make_virtual_compilette(clock, name, cost_fn, *, with_phase2=False):
    params = [Param("unroll", (1, 2, 4, 8), phase=1, switch_rank=0)]
    if with_phase2:
        params.append(Param("sched", (0, 1), phase=2))
    sp = product_space(params)

    def gen(point, **spec):
        return virtual_kernel(clock, cost_fn(point), tag=dict(point))

    return Compilette(name, sp, gen)


# ---------------------------------------------------------- virtual clock
def test_virtual_clock_evaluator_advances_simulated_time_only():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock, runs=3, fixed_eval_cost_s=0.5)
    fn = virtual_kernel(clock, 2.0)
    m = ev.evaluate(fn)
    assert m.score_s == 2.0
    assert m.eval_time_s == 3 * 2.0 + 0.5
    assert clock() == 6.5
    # calling the kernel itself also advances the clock by its cost
    fn()
    assert clock() == 8.5


def test_virtual_clock_rejects_backwards_time():
    clock = VirtualClock(10.0)
    with pytest.raises(ValueError):
        clock.advance(-1.0)


# ------------------------------------------------------------ scheduling
def test_budget_sharing_across_kernels():
    """One RegenerationPolicy bounds the SUM of tuning spent across all
    managed kernels, and slots flow to the kernel with estimated gain."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    policy = RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.2)
    coord = TuningCoordinator(policy=policy, device="test:v", clock=clock)

    # A has real speedup headroom; B's variants are all identical to its
    # reference, so its estimated gain collapses to zero after bootstrap.
    a = coord.register("hot", make_virtual_compilette(
        clock, "hot", lambda p: 0.008 / p["unroll"]), ev,
        reference_fn=virtual_kernel(clock, 0.008))
    b = coord.register("flat", make_virtual_compilette(
        clock, "flat", lambda p: 0.002), ev,
        reference_fn=virtual_kernel(clock, 0.002))

    while not a.tuner.explorer.finished:
        a(1)
        b(1)
        coord.pump()

    a_regens = a.tuner.accounts.regenerations
    b_regens = b.tuner.accounts.regenerations
    # bootstrap gives each kernel one slot; after that every slot goes to
    # the kernel whose estimated gain is positive
    assert b_regens == 1
    assert a_regens > b_regens

    # the global cap bounds the aggregate, not each kernel separately
    agg = coord._aggregate_accounts()
    spent = agg.tuning_spent_s
    budget = policy.budget_s(agg, clock())
    max_single_eval = 0.008  # costliest variant evaluation
    assert spent <= budget + max_single_eval


def test_coordinator_stats_aggregate():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock)
    m = coord.register("k", make_virtual_compilette(
        clock, "k", lambda p: 0.004 / p["unroll"]), ev,
        reference_fn=virtual_kernel(clock, 0.004))
    for i in range(200):
        m(i)
        coord.pump()
    s = coord.stats()
    assert s["n_kernels"] == 1
    assert s["regenerations"] == m.tuner.accounts.regenerations > 0
    assert s["kernels"]["k"]["best_point"] == {"unroll": 8}
    assert 0 < s["overhead_frac"] < 1


# ------------------------------------------------------------ warm start
def _run_process(registry_path, *, calls=4000):
    """One simulated process lifetime; returns (regens_to_best, total)."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=0.5, invest_frac=0.5),
        registry_path=registry_path, device="test:v", clock=clock)
    comp = make_virtual_compilette(
        clock, "k",
        lambda p: 0.008 / p["unroll"] + (0 if p.get("sched") else 0.001),
        with_phase2=True)
    m = coord.register("k", comp, ev,
                       reference_fn=virtual_kernel(clock, 0.008))
    best = {"unroll": 8, "sched": 1}
    regens_to_best = None
    for i in range(calls):
        m(i)
        coord.pump()
        if regens_to_best is None and m.tuner._active_life.point == best:
            regens_to_best = m.tuner.accounts.regenerations
    coord.save_registry()
    assert regens_to_best is not None, "never reached the known best point"
    return regens_to_best, m.tuner.accounts.regenerations, m.warm_started


def test_warm_start_reaches_best_with_strictly_fewer_regenerations():
    """Acceptance: a warm-started process (same registry, fresh process
    state) reaches its best point with strictly fewer regenerations than
    the cold start — pure VirtualClock, no wall-clock sleeps."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        cold_to_best, _, cold_warm = _run_process(path)
        warm_to_best, _, warm_warm = _run_process(path)
    assert cold_warm is False and warm_warm is True
    # the registry seed is proposed first: ONE regeneration re-validates it
    assert warm_to_best == 1
    assert warm_to_best < cold_to_best


def test_warm_start_survives_registry_reload_from_disk():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        reg = TunedRegistry()
        reg.put("k", {}, "test:v", {"unroll": 8}, 0.001)
        reg.save(path)
        coord = TuningCoordinator(registry_path=path, device="test:v",
                                  clock=clock,
                                  policy=RegenerationPolicy(1.0, 0.5))
        m = coord.register("k", make_virtual_compilette(
            clock, "k", lambda p: 0.008 / p["unroll"]), ev,
            reference_fn=virtual_kernel(clock, 0.008))
        assert m.warm_started
        m(1)
        coord.pump()   # first slot re-validates the persisted best
        assert m.tuner._active_life.point == {"unroll": 8}
        assert m.tuner.accounts.regenerations == 1


# ---------------------------------------------------------- swap ordering
def test_swaps_only_to_strictly_better_and_never_regress():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    comp = make_virtual_compilette(clock, "k", lambda p: 0.008 / p["unroll"])
    at = OnlineAutotuner(
        comp, ev, policy=RegenerationPolicy(1.0, 0.5),
        reference_fn=virtual_kernel(clock, 0.008), wake_every=None,
        clock=clock)
    scores = [at._active_life.score_s]
    while not at.explorer.finished:
        at(1)
        at.wake()
        scores.append(at._active_life.score_s)
    # active score is monotonically non-increasing over the whole run
    assert all(b <= a for a, b in zip(scores, scores[1:]))
    # unroll=1 ties the reference (0.008, not strictly better): no swap;
    # 2, 4, 8 are each strictly better: exactly three swaps
    assert at.accounts.swaps == 3
    assert at._active_life.point == {"unroll": 8}


# ---------------------------------------------------------- thread safety
def test_active_fn_pointer_swap_safe_under_reader_thread():
    """Hammer the active-function pointer from a reader thread while the
    tuning side swaps it: every call must hit a coherent, valid kernel."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    comp = make_virtual_compilette(clock, "k", lambda p: 1e-6 / p["unroll"],
                                   with_phase2=True)
    at = OnlineAutotuner(
        comp, ev, policy=RegenerationPolicy(1e9, 1.0),
        reference_fn=virtual_kernel(clock, 1e-6), wake_every=None,
        clock=clock)

    errors: list[BaseException] = []
    stop = threading.Event()

    def reader():
        try:
            while not stop.is_set():
                # the pointer must always be callable and return its arg
                assert at("payload") == "payload"
                fn = at.active_fn
                assert callable(fn)
        except BaseException as e:  # surfaced in the main thread
            errors.append(e)

    t = threading.Thread(target=reader, daemon=True)
    t.start()
    try:
        # drive wakes as fast as possible: every wake may swap the pointer
        for _ in range(2000):
            if at.explorer.finished:
                # restart exploration pressure by re-running over a fresh
                # autotuner sharing the same clock — keeps swaps coming
                break
            at.wake()
    finally:
        stop.set()
        t.join(timeout=10.0)
    assert not errors, errors[:1]
    assert at.accounts.swaps >= 1
    assert at._active_life.score_s <= at.reference_score_s


def test_single_coordinator_thread_drives_many_kernels():
    """Threaded mode: ONE coordinator thread (not one per kernel)."""
    import time as _time

    def busy(seconds):
        t0 = _time.perf_counter()
        while _time.perf_counter() - t0 < seconds:
            pass

    def make_real_compilette(name, base):
        sp = product_space([Param("unroll", (1, 2, 4), phase=1)])

        def gen(point, **spec):
            c = base / point["unroll"]

            def fn(x):
                busy(c)
                return x
            return fn

        return Compilette(name, sp, gen)

    from repro.core import Evaluator
    ev = Evaluator(mode="training", groups=1, group_size=2,
                   make_args=lambda: (1,))
    coord = TuningCoordinator(policy=RegenerationPolicy(0.9, 0.9),
                              device="test:host")
    a = coord.register("a", make_real_compilette("a", 2e-4), ev,
                       reference_fn=lambda x: (busy(2e-4), x)[1])
    b = coord.register("b", make_real_compilette("b", 1e-4), ev,
                       reference_fn=lambda x: (busy(1e-4), x)[1])
    coord.start_thread(wake_period_s=0.0005)
    try:
        n_threads = len([t for t in threading.enumerate()
                         if t.name == "tuning-coordinator"])
        assert n_threads == 1
        for i in range(400):
            a(i)
            b(i)
    finally:
        coord.stop_thread()
    total = (a.tuner.accounts.regenerations
             + b.tuner.accounts.regenerations)
    assert total > 0


# ---------------------------------------------------- registry round-trip
@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31),
    score=st.floats(1e-6, 10.0),
)
def test_registry_canonical_key_stable_under_dict_reordering(seed, score):
    """(kernel, specialization, device) keys must not depend on dict
    insertion order, and save/load must round-trip exactly."""
    import random

    rng = random.Random(seed)
    items = [("seq", 128), ("batch", 8), ("heads", 4), ("dtype", "bf16")]
    spec_a = dict(items)
    shuffled = items[:]
    rng.shuffle(shuffled)
    spec_b = dict(shuffled)

    assert TunedRegistry.key("k", spec_a, "d") == \
        TunedRegistry.key("k", spec_b, "d")

    reg = TunedRegistry()
    point = {"unroll": rng.choice([1, 2, 4, 8]), "sched": rng.choice([0, 1])}
    reg.put("k", spec_a, "dev", point, score)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        reg.save(path)
        loaded = TunedRegistry.load(path)
    # lookup through the *reordered* spec must hit the same entry
    assert loaded.get("k", spec_b, "dev") == point
    assert len(loaded) == len(reg) == 1


def test_stale_registry_point_from_older_space_is_a_cache_miss():
    """A persisted best from an older space definition (parameter added
    or renamed since) must degrade to a cold start, not crash wake()."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    reg = TunedRegistry()
    # persisted before the space gained its 'sched' phase-2 parameter
    reg.put("k", {}, "test:v", {"unroll": 8}, 0.001)
    coord = TuningCoordinator(registry=reg, device="test:v", clock=clock,
                              policy=RegenerationPolicy(1.0, 0.5))
    m = coord.register("k", make_virtual_compilette(
        clock, "k", lambda p: 0.008 / p["unroll"], with_phase2=True), ev,
        reference_fn=virtual_kernel(clock, 0.008))
    assert not m.warm_started
    for i in range(200):
        m(i)
        coord.pump()   # must not raise
    assert m.tuner.accounts.regenerations > 0


def test_legacy_device_kind_registry_entries_still_warm_start():
    """Pre-coordinator registries were keyed by bare device_kind; the
    platform-qualified fingerprint must fall back to them."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    reg = TunedRegistry()
    reg.put("k", {}, "v", {"unroll": 8}, 0.001)   # legacy key: bare kind
    coord = TuningCoordinator(registry=reg, device="test:v", clock=clock)
    m = coord.register("k", make_virtual_compilette(
        clock, "k", lambda p: 0.008 / p["unroll"]), ev,
        reference_fn=virtual_kernel(clock, 0.008))
    assert m.warm_started


def test_budget_denied_slot_keeps_hotness_signal():
    """A pump() that the budget gate denies must not reset the picked
    kernel's calls-since-last-wake fairness signal."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    # zero budget after the cold-start freebie: wakes get denied
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=0.0, invest_frac=0.0),
        device="test:v", clock=clock)
    m = coord.register("k", make_virtual_compilette(
        clock, "k", lambda p: 0.008 / p["unroll"]), ev,
        reference_fn=virtual_kernel(clock, 0.008))
    for i in range(50):
        m(i)
    coord.pump()          # cold-start regeneration is admitted
    for i in range(50):
        m(i)
    before = m.calls_at_last_wake
    assert not coord.pump()   # denied: zero budget
    assert m.calls_at_last_wake == before


def test_registry_corrupt_file_degrades_to_cold_start():
    """A warm-start cache must never crash the process it warms."""
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        with open(path, "w") as f:
            f.write("{not json")
        reg = TunedRegistry.load(path)
        assert len(reg) == 0
        # well-formed JSON with malformed entries is equally a cache miss
        with open(path, "w") as f:
            f.write('{"k": {}, "k2": {"point": 3}, "k3": "x"}')
        reg = TunedRegistry.load(path)
        assert len(reg) == 0
        clock = VirtualClock()
        coord = TuningCoordinator(registry_path=path, device="test:v",
                                  clock=clock)
        m = coord.register("k", make_virtual_compilette(
            clock, "k", lambda p: 0.004 / p["unroll"]),
            VirtualClockEvaluator(clock),
            reference_fn=virtual_kernel(clock, 0.004))
        assert not m.warm_started
        coord.save_registry()   # overwrites the corrupt file atomically
        assert isinstance(TunedRegistry.load(path)._table, dict)


def test_registry_save_is_safe_under_concurrent_puts():
    """The tuning thread puts while the app thread saves (request end /
    checkpoint): serialization must never see a mid-mutation table."""
    reg = TunedRegistry()
    errors: list[BaseException] = []

    def writer():
        try:
            for i in range(5000):
                reg.put(f"k{i % 50}", {"s": i % 7}, "d",
                        {"u": i}, 1.0 / (i + 1))
        except BaseException as e:
            errors.append(e)

    t = threading.Thread(target=writer, daemon=True)
    t.start()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        while t.is_alive():
            reg.save(path)          # must not raise mid-iteration
        t.join(timeout=10.0)
        reg.save(path)
        loaded = TunedRegistry.load(path)
        assert len(loaded) == len(reg) >= 1
    assert not errors, errors[:1]


def test_registry_keeps_best_score_on_repeated_put():
    reg = TunedRegistry()
    reg.put("k", {"s": 1}, "d", {"u": 2}, 0.5)
    reg.put("k", {"s": 1}, "d", {"u": 8}, 0.1)   # better: replaces
    reg.put("k", {"s": 1}, "d", {"u": 4}, 0.3)   # worse: ignored
    assert reg.get("k", {"s": 1}, "d") == {"u": 8}
