"""Strategy-parity suite: every registered search strategy, one contract.

Each strategy in the ``repro.core.explorer`` registry must (a) converge to
the known optimum of a small exhaustive space, (b) respect the budget
gate, and (c) never re-propose a seen point. All tuning-control tests run
under the ``VirtualClock`` — no sleeps, deterministic on any host.
``hypothesis`` drives the property tests where installed; the conftest
stub degrades them to deterministic examples otherwise.
"""

import pytest
from hypothesis import given, settings, strategies as st

from repro.core import (
    Compilette,
    GreedyNeighborhood,
    LatencyHeadroomGate,
    OnlineAutotuner,
    Param,
    RandomSearch,
    RegenerationPolicy,
    TuningAccounts,
    TwoPhaseExplorer,
    VirtualClock,
    VirtualClockEvaluator,
    available_strategies,
    make_strategy,
    product_space,
    static_autotune,
    virtual_kernel,
)

ALL_STRATEGIES = available_strategies()


def small_space(with_phase2=True, validator=None):
    params = [Param("unroll", (1, 2, 4, 8), phase=1, switch_rank=0)]
    if with_phase2:
        params.append(Param("sched", (0, 1), phase=2))
    kwargs = {"validator": validator} if validator else {}
    return product_space(params, **kwargs)


def cost(p):
    # unique global optimum at {"unroll": 8, "sched": 1}
    return 0.008 / p["unroll"] + (0.0 if p.get("sched", 1) else 0.001)


def make_compilette(clock, space=None):
    sp = space or small_space()

    def gen(point, **spec):
        return virtual_kernel(clock, cost(point))

    return Compilette("k", sp, gen)


# ------------------------------------------------------------ registry
def test_registry_contents():
    assert {"two_phase", "random", "greedy",
            "cost_model"} <= set(ALL_STRATEGIES)
    assert make_strategy("two_phase", small_space()).name == "two_phase"
    assert isinstance(make_strategy("random", small_space()), RandomSearch)
    assert isinstance(make_strategy("greedy", small_space()),
                      GreedyNeighborhood)
    from repro.core import CostModelSearch
    assert isinstance(make_strategy("cost_model", small_space()),
                      CostModelSearch)


def test_unknown_strategy_is_a_value_error():
    with pytest.raises(ValueError, match="unknown search strategy"):
        make_strategy("simulated_annealing", small_space())
    with pytest.raises(ValueError, match="unknown search strategy"):
        OnlineAutotuner(
            make_compilette(VirtualClock()), None, strategy="nope")


def test_instance_passthrough():
    sp = small_space()
    inst = TwoPhaseExplorer(sp)
    assert make_strategy(inst, sp) is inst


# ------------------------------------------------- parity: finds optimum
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_converges_to_known_optimum(strategy):
    strat = make_strategy(strategy, small_space())
    best, score = strat.run_to_completion(cost)
    assert best == {"unroll": 8, "sched": 1}
    assert score == cost(best)


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_optimum_through_online_autotuner(strategy):
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    at = OnlineAutotuner(
        make_compilette(clock), ev,
        policy=RegenerationPolicy(1.0, 0.5),
        reference_fn=virtual_kernel(clock, 0.008),
        wake_every=None, clock=clock, strategy=strategy)
    while not at.explorer.finished:
        at(1)
        at.wake()
    s = at.stats()
    assert s["strategy"] == strategy
    assert s["best_point"] == {"unroll": 8, "sched": 1}
    assert s["active_score_s"] <= s["reference_score_s"]


# ------------------------------------------------- parity: dedup property
@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 2**31))
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_never_reproposes_and_covers_space(strategy, seed):
    """Full-space exhaustion proposes every valid point exactly once —
    even with holes, warm-start seeds, and adversarial report scores."""
    import random

    rng = random.Random(seed)
    banned = {(a, b) for a in (1, 2, 4, 8) for b in (0, 1)
              if rng.random() < 0.3}
    if len(banned) == 8:
        banned.pop()
    sp = small_space(
        validator=lambda p: (p["unroll"], p.get("sched", 1)) not in banned)
    valid = [sp.key(p) for p in sp.iter_valid()]
    seed_pt = rng.choice(list(sp.iter_valid()))
    strat = make_strategy(strategy, sp, seed_points=[seed_pt])
    seen = []
    while True:
        pt = strat.next_point()
        if pt is None:
            break
        key = sp.key(pt)
        assert key not in seen, (strategy, pt)
        assert key in valid, (strategy, "proposed a hole", pt)
        seen.append(key)
        strat.report(pt, rng.random())
    assert strat.finished
    # random + greedy are exhaustive by construction; two_phase is
    # exhaustive here because the space has a single phase-2 dimension
    # and phase 2 re-scans it around the winner
    best_reported = min(strat.history, key=lambda h: h[1])
    assert strat.best_score == best_reported[1]
    if strategy in ("random", "greedy"):
        assert set(seen) == set(valid)


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_warm_start_seed_is_proposed_first(strategy):
    seed_pt = {"unroll": 4, "sched": 0}
    strat = make_strategy(strategy, small_space(), seed_points=[seed_pt])
    assert strat.next_point() == seed_pt


# ------------------------------------------------- parity: peek / propose
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_peek_matches_subsequent_proposals(strategy):
    """peek(n) is idempotent and, absent intervening reports, returns
    exactly the points next_point() will yield, in order."""
    strat = make_strategy(strategy, small_space())
    ahead = strat.peek(3)
    assert len(ahead) == 3
    assert strat.peek(3) == ahead                 # idempotent
    assert strat.peek(2) == ahead[:2]             # prefix-consistent
    assert [strat.next_point() for _ in range(3)] == ahead
    # peeking never double-counts proposals
    assert strat.state.n_proposed == 3


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2**31))
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_peek_preserves_dedup_and_coverage(strategy, seed):
    """Randomly interleaving peeks with propose/report cycles must not
    break the core contract: no point proposed twice, no hole proposed,
    and exhaustive strategies still cover the space."""
    import random

    rng = random.Random(seed)
    sp = small_space()
    valid = {sp.key(p) for p in sp.iter_valid()}
    strat = make_strategy(strategy, sp)
    seen = []
    while True:
        if rng.random() < 0.5:
            strat.peek(rng.randint(1, 4))
        pt = strat.next_point()
        if pt is None:
            break
        key = sp.key(pt)
        assert key not in seen, (strategy, pt)
        assert key in valid
        seen.append(key)
        strat.report(pt, rng.random())
    assert strat.finished
    if strategy in ("random", "greedy"):
        assert set(seen) == valid


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_peek_past_exhaustion_does_not_finish_strategy(strategy):
    """Peeking beyond the end returns what is left WITHOUT marking the
    strategy finished: buffered points are still pending proposal."""
    sp = small_space(with_phase2=False)           # 4 valid points
    strat = make_strategy(strategy, sp)
    ahead = strat.peek(100)
    assert 1 <= len(ahead) <= 4
    assert not strat.finished
    served = []
    while True:
        pt = strat.next_point()
        if pt is None:
            break
        served.append(pt)
        strat.report(pt, 1.0)
    # every peeked point was eventually proposed (two_phase may re-scan
    # more after reports; the peeked prefix must be served regardless)
    for p in ahead:
        assert p in served
    assert strat.finished
    assert strat.peek(2) == []                    # finished: nothing ahead


# ------------------------------------------------- parity: budget respect
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_respects_budget_gate(strategy):
    """Zero budget after the cold-start freebie: no strategy may keep
    regenerating once the gate denies."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    at = OnlineAutotuner(
        make_compilette(clock), ev,
        policy=RegenerationPolicy(max_overhead_frac=0.0, invest_frac=0.0),
        reference_fn=virtual_kernel(clock, 0.008),
        wake_every=None, clock=clock, strategy=strategy)
    for _ in range(200):
        at(1)
        at.wake()
    # tuning_spent_s 0 <= budget 0 admits exactly the first regeneration
    assert at.accounts.regenerations <= 1


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_spent_stays_within_budget(strategy):
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    pol = RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.2)
    at = OnlineAutotuner(
        make_compilette(clock), ev, policy=pol,
        reference_fn=virtual_kernel(clock, 0.008),
        wake_every=None, clock=clock, strategy=strategy)
    for _ in range(3000):
        at(1)
        at.wake()
        if at.explorer.finished:
            break
    spent = at.accounts.tuning_spent_s
    budget = pol.budget_s(at.accounts, clock())
    # one in-flight regeneration of the costliest variant may overshoot
    assert spent <= budget + 0.008


# ------------------------------------------------------- busy-time budget
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_busy_budget_ignores_idle_time(strategy):
    """budget_from='busy': a long-idle process accrues NO budget, so the
    wakes after an idle gap cannot burst regenerations onto one request
    (only the zero-spent cold-start freebie is ever admitted)."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    pol = RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.0,
                             budget_from="busy")
    at = OnlineAutotuner(
        make_compilette(clock), ev, policy=pol,
        reference_fn=virtual_kernel(clock, 0.008),
        wake_every=None, clock=clock, strategy=strategy)
    clock.advance(3600.0)            # one idle hour, zero kernel calls
    for _ in range(50):
        at.wake()
    assert at.accounts.regenerations <= 1
    # the equivalent wall-budget policy would have bankrolled the LOT:
    # 5 % of an idle hour covers the whole space many times over
    wall = RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.0)
    at._update_gains()
    assert wall.budget_s(at.accounts, clock()) > 100 * 0.008
    # busy time from real calls does accrue budget
    for _ in range(500):
        at(1)
        at.wake()
    assert at.accounts.regenerations > 1


def test_busy_budget_bounds_spend_by_busy_fraction():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    pol = RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.0,
                             budget_from="busy")
    at = OnlineAutotuner(
        make_compilette(clock), ev, policy=pol,
        reference_fn=virtual_kernel(clock, 0.008),
        wake_every=None, clock=clock)
    for _ in range(2000):
        at(1)
        at.wake()
    at._update_gains()
    assert at.accounts.tuning_spent_s <= \
        0.05 * at.accounts.busy_s + 0.008


# ------------------------------------------------------ headroom gate
def test_headroom_gate_blocks_thin_headroom():
    gate = LatencyHeadroomGate(slo_s=0.010, min_headroom_frac=0.5)
    assert gate.allows(0.002, 0.001)            # 80 % headroom
    assert not gate.allows(0.008, 0.0)          # 20 % headroom: blocked
    assert not gate.allows(0.002, 0.009)        # cycle exceeds headroom
    pol = RegenerationPolicy(1.0, 0.0, headroom=gate)
    acc = TuningAccounts(observed_call_s=0.008)
    assert not pol.should_regenerate(acc, 1.0, 0.0)
    acc.observed_call_s = 0.002
    assert pol.should_regenerate(acc, 1.0, 0.001)


def test_headroom_gate_in_autotuner_loop():
    """An active kernel too close to the SLO freezes regeneration; a fast
    one tunes freely."""
    for ref_cost, expect_tuning in ((0.009, False), (0.001, True)):
        clock = VirtualClock()
        ev = VirtualClockEvaluator(clock)
        sp = small_space()

        def gen(point, _c=clock, _r=ref_cost, **spec):
            return virtual_kernel(_c, _r / point["unroll"])

        at = OnlineAutotuner(
            Compilette("k", sp, gen), ev,
            policy=RegenerationPolicy(
                1.0, 0.5,
                headroom=LatencyHeadroomGate(slo_s=0.010,
                                             min_headroom_frac=0.5)),
            reference_fn=virtual_kernel(clock, ref_cost),
            wake_every=None, clock=clock)
        for _ in range(100):
            at(1)
            at.wake()
        assert (at.accounts.regenerations > 0) == expect_tuning, ref_cost


def test_headroom_gate_is_per_kernel_under_coordinator():
    """A slow prefill-like kernel far over the SLO must not veto tuning
    of a fast decode-like kernel under the shared budget gate."""
    from repro.runtime.coordinator import TuningCoordinator

    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    pol = RegenerationPolicy(
        1.0, 0.5,
        headroom=LatencyHeadroomGate(slo_s=0.010, min_headroom_frac=0.5))
    coord = TuningCoordinator(policy=pol, device="test:v", clock=clock)

    def comp(name, base):
        sp = small_space(with_phase2=False)

        def gen(point, **spec):
            return virtual_kernel(clock, base / point["unroll"])

        return Compilette(name, sp, gen)

    slow = coord.register("prefill", comp("prefill", 0.100), ev,
                          reference_fn=virtual_kernel(clock, 0.100))
    fast = coord.register("decode", comp("decode", 0.001), ev,
                          reference_fn=virtual_kernel(clock, 0.001))
    for i in range(500):
        slow(i)
        fast(i)
        coord.pump()
    # the slow kernel (100 ms/call vs a 10 ms SLO) is frozen by headroom;
    # the fast one (1 ms/call) tunes normally
    assert slow.tuner.accounts.regenerations == 0
    assert fast.tuner.accounts.regenerations > 0
    assert fast.tuner.explorer.best_point == {"unroll": 8}


# ------------------------------------------------------ init charging
def test_charge_init_counts_reference_measurement_against_budget():
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    pol = RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.0,
                             budget_from="busy", charge_init=True)
    at = OnlineAutotuner(
        make_compilette(clock), ev, policy=pol,
        reference_fn=virtual_kernel(clock, 0.008),  # fn given, score not:
        wake_every=None, clock=clock)               # init eval is charged
    assert at.accounts.init_spent_s > 0
    assert pol.spent_s(at.accounts) == at.accounts.init_spent_s
    # the uncharged policy admits immediately; the charged one must first
    # observe enough busy time to cover the init debt
    uncharged = RegenerationPolicy(0.05, 0.0, budget_from="busy")
    at._update_gains()
    assert uncharged.should_regenerate(at.accounts, clock(), 0.0)
    assert not pol.should_regenerate(at.accounts, clock(), 0.0)
    for _ in range(500):
        at(1)
        at.wake()
    assert at.accounts.regenerations > 0   # debt amortized by busy time


# ------------------------------------------------------ static + registry
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_static_autotune_accepts_strategy(strategy):
    comp = Compilette("s", small_space(), lambda point, **spec: None)
    best, score, hist = static_autotune(
        comp, None, strategy=strategy, score_fn=cost)
    assert best == {"unroll": 8, "sched": 1}
    assert len(hist) >= 1


def test_random_search_is_deterministic_per_seed():
    sp = small_space()
    order_a = [sp.key(p) for p in iter(
        RandomSearch(sp, rng_seed=7).next_point, None)]
    order_b = [sp.key(p) for p in iter(
        RandomSearch(sp, rng_seed=7).next_point, None)]
    order_c = [sp.key(p) for p in iter(
        RandomSearch(sp, rng_seed=8).next_point, None)]
    assert order_a == order_b
    assert sorted(order_a) == sorted(order_c)


def test_cost_model_proposes_in_predicted_order():
    """With a trustworthy model the predicted-fastest point comes first;
    with none, enumeration order is served (still exhaustive)."""
    sp = small_space()
    strat = make_strategy("cost_model", sp, cost_fn=cost)
    assert strat.next_point() == {"unroll": 8, "sched": 1}
    # model-free: plain enumeration, same coverage
    bare = make_strategy("cost_model", sp)
    order = [sp.key(p) for p in iter(bare.next_point, None)]
    assert len(order) == len(set(order)) == len(list(sp.iter_valid()))


def test_cost_model_survives_a_misleading_model():
    """A model that inverts reality must only cost ORDER, not coverage or
    the final verdict: measurements, not predictions, pick the best."""
    sp = small_space()
    strat = make_strategy("cost_model", sp, cost_fn=lambda p: -cost(p))
    best, score = strat.run_to_completion(cost)
    assert best == {"unroll": 8, "sched": 1}
    assert score == cost(best)


def test_cost_model_calibrates_ranking_from_observations():
    """Observed scores correct a biased model: after reports showing the
    model is wrong about ``sched``, later proposals re-rank."""
    sp = small_space()
    # model claims sched is free and unroll barely matters
    strat = make_strategy("cost_model", sp,
                          cost_fn=lambda p: 0.001 / p["unroll"])
    seen = []
    for _ in range(len(list(sp.iter_valid()))):
        p = strat.next_point()
        seen.append(dict(p))
        strat.report(p, cost(p))
    assert strat.next_point() is None and strat.finished
    assert strat.best_point == {"unroll": 8, "sched": 1}


def test_cost_model_autotuner_wires_compilette_model_as_cost_fn():
    """OnlineAutotuner(strategy="cost_model") feeds the compilette's own
    analytic cost model into the strategy: the first non-base proposal is
    the model's argmin, not enumeration order."""
    clock = VirtualClock()
    sp = small_space()

    def gen(point, **spec):
        return virtual_kernel(clock, cost(point))

    comp = Compilette("k", sp, gen,
                      cost_model=lambda point, spec, profile: cost(point))
    tuner = OnlineAutotuner(comp, VirtualClockEvaluator(clock),
                            clock=clock, wake_every=1,
                            strategy="cost_model")
    assert tuner.explorer.peek(1)[0] == {"unroll": 8, "sched": 1}
    # a model-less compilette degrades to the model-free strategy
    tuner2 = OnlineAutotuner(Compilette("k2", small_space(), gen),
                             VirtualClockEvaluator(clock),
                             clock=clock, wake_every=1,
                             strategy="cost_model")
    assert tuner2.explorer.peek(1)[0] is not None


def test_cost_model_seeded_determinism_with_model_and_seeds():
    """Satellite row: same seed points + same cost_fn + same peek(n)
    interleaving => byte-identical proposal/peek/best logs."""
    sp = small_space()

    def run():
        strat = make_strategy(
            "cost_model", sp,
            seed_points=[{"unroll": 4, "sched": 0}],
            cost_fn=lambda p: 0.008 / p["unroll"])
        log = []
        while True:
            log.append(("peek", [sp.key(p) for p in strat.peek(2)]))
            p = strat.next_point()
            if p is None:
                break
            strat.report(p, cost(p))
            log.append(("propose", sp.key(p)))
        log.append(("best", sp.key(strat.best_point), strat.best_score))
        return log

    a, b = run(), run()
    assert a == b
    # the warm seed is proposed first, then model-ranked order
    proposes = [e for e in a if e[0] == "propose"]
    assert proposes[0][1] == sp.key({"unroll": 4, "sched": 0})
    assert a[-1] == ("best", sp.key({"unroll": 8, "sched": 1}),
                     cost({"unroll": 8, "sched": 1}))


def test_greedy_recenters_on_improvement():
    """After an improving report, the next proposals are one-parameter
    variations of the new incumbent."""
    sp = small_space()
    strat = GreedyNeighborhood(sp)
    first = strat.next_point()                    # the base/default point
    assert first == {"unroll": 1, "sched": 0}
    strat.report(first, 1.0)
    nxt = strat.next_point()
    diffs = sum(1 for k in first if first[k] != nxt[k])
    assert diffs == 1


# ------------------------------------------------- seeded determinism
def _drive_seeded(strategy: str, peek_n: int) -> list:
    """Propose/report the whole space, interleaving peek(n) calls, and
    return everything observable: proposals, peeks, final best."""
    import inspect

    sp = small_space()
    kwargs = {}
    from repro.core.explorer import STRATEGIES
    if "rng_seed" in inspect.signature(
            STRATEGIES[strategy]).parameters:
        kwargs["rng_seed"] = 7
    strat = make_strategy(strategy, sp, **kwargs)
    log = []
    while True:
        if peek_n:
            log.append(("peek", [sp.key(p) for p in strat.peek(peek_n)]))
        p = strat.next_point()
        if p is None:
            break
        strat.report(p, cost(p))
        log.append(("propose", sp.key(p)))
    log.append(("best", sp.key(strat.best_point), strat.best_score))
    return log


@pytest.mark.parametrize("peek_n", [0, 2], ids=["plain", "through_peek"])
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_every_strategy_is_deterministic_per_seed(strategy, peek_n):
    """Satellite acceptance: same seed => identical proposal sequence for
    EVERY registered strategy, including when peek(n) interleaves — the
    replay fleet's byte-identical artifacts depend on exactly this."""
    a = _drive_seeded(strategy, peek_n)
    b = _drive_seeded(strategy, peek_n)
    assert a == b
    # and peeking never changes WHAT gets explored or found — only the
    # serving order may shift (greedy re-centers around a new incumbent
    # while previously peeked points drain from the buffer)
    proposed = [e[1] for e in a if e[0] == "propose"]
    plain_run = _drive_seeded(strategy, 0)
    plain = [e[1] for e in plain_run if e[0] == "propose"]
    assert sorted(proposed) == sorted(plain)
    assert a[-1] == plain_run[-1]         # same best point, same score


# ------------------------------------------------- parity: compile farm
@pytest.mark.parametrize("workers", [1, 4], ids=["one_worker", "farm"])
@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_strategy_converges_with_prefetch_through_farm(strategy, workers):
    """Every strategy still covers its space and finds the optimum when
    its proposals AND peek(n) prefetches drain through a multi-worker
    compile farm — speculation must never consume, reorder or duplicate
    the proposal stream, at any M."""
    from repro.core import virtual_compilette
    from repro.runtime.coordinator import TuningCoordinator

    clock = VirtualClock()
    coord = TuningCoordinator(
        policy=RegenerationPolicy(1.0, 0.5), device="test:v", clock=clock,
        async_generation=True, prefetch=2, compile_workers=workers,
        strategy=strategy)
    comp = virtual_compilette(clock, "k", small_space(), cost,
                              gen_cost_s=0.010)
    m = coord.register("k", comp, VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.009))
    for i in range(2000):
        m(i)
        clock.advance(0.0005)
        coord.pump()
        if m.tuner.explorer.finished:
            break
    strat = m.tuner.explorer
    assert strat.finished
    assert strat.best_point == {"unroll": 8, "sched": 1}
    assert strat.best_score == pytest.approx(cost(strat.best_point))
    # prefetch really flowed through the farm and stayed off the hot path
    farm = coord.generator.stats()
    assert farm["speculative_submitted"] > 0
    assert farm["workers"] == workers
    assert m.tuner.accounts.gen_stall_s == 0.0
    # every measured point was compiled exactly once and cached (joins
    # dedup concurrent request/prefetch submissions by key; prefetched-
    # but-never-proposed points may add a few more entries on top)
    assert (coord.stats()["generation_cache"]["entries"]
            >= strat.state.n_reported)


# ------------------------------------------------- parity: fleet partition
def test_point_stripe_is_deterministic_and_validates():
    from repro.core import point_stripe

    p = {"unroll": 4, "sched": 1}
    assert point_stripe(p, 4) == point_stripe(dict(p), 4)
    assert point_stripe(p, 1) == 0
    with pytest.raises(ValueError):
        point_stripe(p, 0)
    # stripes partition by construction: one owner per point at every N
    sp = small_space()
    for n in (2, 3, 4):
        owners = {sp.key(q): point_stripe(q, n) for q in sp.iter_valid()}
        assert all(0 <= o < n for o in owners.values())


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_partition_proposals_stay_inside_the_stripe(strategy):
    """Satellite acceptance: under partition(i, n) every strategy proposes
    only points of stripe i, and peek(n) never leaks a foreign point."""
    from repro.core import point_stripe

    sp = small_space()
    n = 2
    for rid in range(n):
        strat = make_strategy(strategy, sp)
        strat.partition(rid, n)
        while True:
            for q in strat.peek(3):
                assert point_stripe(q, n) == rid, (strategy, rid, q)
            p = strat.next_point()
            if p is None:
                break
            assert point_stripe(p, n) == rid, (strategy, rid, p)
            strat.report(p, cost(p))
        assert strat.finished


@pytest.mark.parametrize("strategy", ["random", "greedy"])
def test_partition_stripes_are_disjoint_and_jointly_exhaustive(strategy):
    """For the exhaustive strategies the stripes cover the whole space
    with no overlap: the fleet pays for every point exactly once.
    (two_phase is deliberately excluded: its phase 2 enumerates around
    the stripe-local phase-1 winner, so per-stripe coverage is a subset.)
    """
    sp = small_space()
    valid = {sp.key(p) for p in sp.iter_valid()}
    n = 2
    per_stripe = []
    for rid in range(n):
        strat = make_strategy(strategy, sp)
        strat.partition(rid, n)
        seen = set()
        while True:
            p = strat.next_point()
            if p is None:
                break
            key = sp.key(p)
            assert key not in seen, (strategy, rid, p)
            seen.add(key)
            strat.report(p, cost(p))
        per_stripe.append(seen)
    union = set().union(*per_stripe)
    assert union == valid, strategy
    for a in range(n):
        for b in range(a + 1, n):
            assert not per_stripe[a] & per_stripe[b], (strategy, a, b)


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_partition_exempts_warm_start_seeds(strategy):
    """A warm-start seed is proposed on EVERY replica regardless of its
    stripe: the fleet best must stay locally re-validatable."""
    from repro.core import point_stripe

    sp = small_space()
    seed_pt = {"unroll": 4, "sched": 0}
    n = 4
    for rid in range(n):
        strat = make_strategy(strategy, sp, seed_points=[seed_pt])
        strat.partition(rid, n)
        assert strat.next_point() == seed_pt, (strategy, rid)
    # sanity: the seed is NOT owned by every stripe
    assert len({point_stripe(seed_pt, n)}) == 1


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_inject_candidate_bypasses_stripe_but_not_gatekeeping(strategy):
    """An injected peer best is proposed exactly once on a foreign
    replica; quarantined or already-measured points are refused."""
    from repro.core import point_stripe

    sp = small_space()
    peer_best = {"unroll": 8, "sched": 1}
    n = 3
    foreign = next(r for r in range(n)
                   if r != point_stripe(peer_best, n))
    strat = make_strategy(strategy, sp)
    strat.partition(foreign, n)
    assert strat.inject_candidate(peer_best)
    assert strat.next_point() == peer_best
    strat.report(peer_best, cost(peer_best))
    # idempotent: re-injection after local measurement is refused
    assert not strat.inject_candidate(peer_best)
    # quarantined points are refused outright
    bad = {"unroll": 1, "sched": 0}
    strat.quarantine(bad)
    assert not strat.inject_candidate(bad)
    # and holes are refused
    assert not strat.inject_candidate({"unroll": 3, "sched": 1})


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_partition_validates_and_single_replica_is_identity(strategy):
    sp = small_space()
    strat = make_strategy(strategy, sp)
    with pytest.raises(ValueError):
        strat.partition(2, 2)
    with pytest.raises(ValueError):
        strat.partition(-1, 2)
    with pytest.raises(ValueError):
        strat.partition(0, 0)
    # partition(0, 1) is the identity: full coverage
    strat.partition(0, 1)
    seen = []
    while True:
        p = strat.next_point()
        if p is None:
            break
        seen.append(sp.key(p))
        strat.report(p, cost(p))
    if strategy in ("random", "greedy"):
        assert set(seen) == {sp.key(p) for p in sp.iter_valid()}


@pytest.mark.parametrize("strategy", ALL_STRATEGIES)
def test_mark_seen_purges_pending_peeks(strategy):
    """A peer's published evaluation retires a locally prefetched point:
    the pending compile must never be served to the proposal stream."""
    sp = small_space()
    strat = make_strategy(strategy, sp)
    ahead = strat.peek(3)
    assert len(ahead) == 3
    victim = ahead[1]
    assert strat.mark_seen(victim)              # purged from the buffer
    assert not strat.mark_seen(victim)          # already seen AND purged
    nxt = [strat.next_point() for _ in range(2)]
    assert victim not in nxt


def test_mark_seen_never_cancels_a_pending_injected_candidate():
    """The fleet best travels with its own evaluation record: a repeat
    sync marks it seen again while it is still queued, which must not
    purge it (inject_candidate's dedup would refuse to re-queue it and
    the adoption would be silently lost)."""
    sp = small_space(with_phase2=False)
    ex = make_strategy("random", sp)
    ex.partition(1, 2)
    peer_best = {"unroll": 4}
    assert ex.inject_candidate(peer_best)
    # the same sync (and every later one) also publishes the evaluation
    assert ex.mark_seen(peer_best) is False
    assert ex.mark_seen(peer_best) is False
    got = ex.next_point()
    assert got == peer_best
    # once locally measured, further mark_seen calls stay no-ops
    ex.report(got, 0.001)
    assert ex.mark_seen(peer_best) is False
