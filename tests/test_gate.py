"""Trusted swaps: oracle gate, canaried promotion, quarantine persistence.

Everything deterministic runs on the ``VirtualClock`` + scripted gate
verdicts (virtual variants carry no numerics); the catalog-oracle checks
run the real kernels once on tiny shapes.
"""

import dataclasses

import pytest

from repro.core import (
    Compilette, FleetBus, OnlineAutotuner, Param, RegenerationPolicy,
    TunedRegistry, VariantGate, VirtualClock, VirtualClockEvaluator,
    product_space, virtual_kernel,
)
from repro.core.gate import GATE_MODES
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.lifecycle import TunerLifecycle


def make_virtual_compilette(clock, name, cost_fn):
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1,
                              switch_rank=0)])

    def gen(point, **spec):
        return virtual_kernel(clock, cost_fn(point), tag=dict(point))

    return Compilette(name, sp, gen)


def make_lying_compilette(clock, name, *, honest_s, lie_point,
                          lie_score_s, lie_serve_s):
    """Variants measure honestly except ``lie_point``, which reports
    ``lie_score_s`` to the evaluator but burns ``lie_serve_s`` per
    production call — the injected tail regression."""
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1,
                              switch_rank=0)])

    def gen(point, **spec):
        if dict(point) == lie_point:
            fn = virtual_kernel(clock, lie_serve_s, tag=dict(point))
            fn.score_s = lie_score_s
            return fn
        return virtual_kernel(clock, honest_s(point), tag=dict(point))

    return Compilette(name, sp, gen)


def run_tuner(tuner, calls=400):
    for i in range(calls):
        tuner(i)


# ----------------------------------------------------------------- gate
def test_gate_mode_validated():
    clock = VirtualClock()
    comp = make_virtual_compilette(clock, "k", lambda p: 0.01)
    with pytest.raises(ValueError):
        OnlineAutotuner(comp, VirtualClockEvaluator(clock),
                        gate_mode="sometimes")
    with pytest.raises(ValueError):
        TuningCoordinator(device="test:v", gate_mode="yes")
    assert GATE_MODES == ("off", "check", "canary")


def test_check_mode_blocks_wrong_variant_and_quarantines():
    """A scripted oracle failure on the best-measuring point: the point
    must never serve, be quarantined in the strategy (never re-proposed)
    and reported through the quarantine callback."""
    clock = VirtualClock()
    bad = {"unroll": 8}   # also the fastest — the dangerous case
    comp = make_virtual_compilette(
        clock, "k", lambda p: 0.010 / p["unroll"])
    comp.gate_script = lambda point: dict(point) != bad
    condemned = []
    tuner = OnlineAutotuner(
        comp, VirtualClockEvaluator(clock),
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        clock=clock, wake_every=1, gate=VariantGate(comp), gate_mode="check",
        quarantine_cb=lambda p, reason: condemned.append((p, reason)))
    run_tuner(tuner)
    s = tuner.stats()
    assert s["gate_checks"] >= 3
    assert s["gate_failures"] == 1
    assert s["quarantined"] == 1
    assert condemned and condemned[0][0] == bad
    assert "oracle" in condemned[0][1]
    assert tuner.explorer.is_quarantined(bad)
    # the gate caught it before it could serve: active is the best of
    # the variants that PASSED, and the bad point never served a call
    assert s["active_point"] == {"unroll": 4}
    assert s["swaps"] >= 1
    assert all(life.point != bad or life.calls == 0
               for life in tuner._lives)


def test_check_mode_passes_clean_variants_unchanged():
    clock = VirtualClock()
    comp = make_virtual_compilette(
        clock, "k", lambda p: 0.010 / p["unroll"])
    # virtual marker: the gate bills its natural cost (one simulated
    # execution of the variant) to the virtual clock
    comp.virtual = (clock, None)
    tuner = OnlineAutotuner(
        comp, VirtualClockEvaluator(clock),
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        clock=clock, wake_every=1, gate=VariantGate(comp), gate_mode="check")
    run_tuner(tuner)
    s = tuner.stats()
    assert s["gate_failures"] == 0
    assert s["quarantined"] == 0
    assert s["active_point"] == {"unroll": 8}
    # the checks billed their cost: one simulated execution each
    assert s["gate_spent_s"] > 0.0
    assert s["tuning_spent_s"] >= s["gate_spent_s"]


# --------------------------------------------------------------- canary
def test_canary_promotes_clean_variant_after_probation():
    clock = VirtualClock()
    comp = make_virtual_compilette(
        clock, "k", lambda p: 0.010 / p["unroll"])
    tuner = OnlineAutotuner(
        comp, VirtualClockEvaluator(clock),
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        clock=clock, wake_every=1, gate=VariantGate(comp), gate_mode="canary",
        canary_fraction=0.5, canary_calls=4)
    run_tuner(tuner)
    s = tuner.stats()
    assert s["canary_promotions"] >= 1
    assert s["swaps"] == s["canary_promotions"]   # canary mode: no direct swaps
    assert s["rollbacks"] == 0
    assert s["canary_calls"] >= 4
    assert s["active_point"] == {"unroll": 8}
    assert not s["canary_in_flight"]


def test_canary_tail_regression_rolls_back_and_quarantines():
    """The variant measures 2x faster than the incumbent but serves 4x
    slower: the canary's observed mean latency trips the regression
    limit, the incumbent takes back every call, the point is condemned."""
    clock = VirtualClock()
    lie = {"unroll": 8}
    comp = make_lying_compilette(
        clock, "k", honest_s=lambda p: 0.010, lie_point=lie,
        lie_score_s=0.005, lie_serve_s=0.040)
    condemned = []
    tuner = OnlineAutotuner(
        comp, VirtualClockEvaluator(clock),
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        clock=clock, wake_every=1, gate=VariantGate(comp), gate_mode="canary",
        canary_fraction=0.5, canary_calls=4,
        quarantine_cb=lambda p, reason: condemned.append((p, reason)))
    run_tuner(tuner)
    s = tuner.stats()
    assert s["rollbacks"] == 1
    assert s["quarantined"] == 1
    assert s["canary_promotions"] == 0
    assert s["swaps"] == 0
    assert tuner.explorer.is_quarantined(lie)
    assert condemned and condemned[0][0] == lie
    assert "tail regression" in condemned[0][1]
    # the incumbent (reference) still serves
    assert s["active_point"] is None
    assert tuner.last_served_point is None


def test_canary_raising_variant_rolls_back_and_caller_never_sees_it():
    clock = VirtualClock()
    bad = {"unroll": 8}
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1,
                              switch_rank=0)])

    def gen(point, **spec):
        if dict(point) == bad:
            fn = virtual_kernel(clock, 0.004, tag=dict(point))

            def raising(*args):
                raise RuntimeError("bad codegen")
            raising.score_s = fn.score_s
            raising.tag = fn.tag
            return raising
        return virtual_kernel(clock, 0.010, tag=dict(point))

    comp = Compilette("k", sp, gen)
    # the gate's virtual path would catch the raise at check time; give
    # this compilette no virtual marker so the raise surfaces in canary
    tuner = OnlineAutotuner(
        comp, VirtualClockEvaluator(clock),
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        clock=clock, wake_every=1, gate=VariantGate(comp), gate_mode="canary",
        canary_fraction=0.5, canary_calls=4)
    outs = [tuner(i) for i in range(400)]
    s = tuner.stats()
    assert s["rollbacks"] == 1
    assert tuner.explorer.is_quarantined(bad)
    # every production call got a real answer (incumbent covered the raise)
    assert all(out is not None for out in outs)


def test_better_candidate_supersedes_canary_without_quarantine():
    """A newer, faster candidate replaces an unfinished canary: the old
    canary lost the race but did nothing wrong — no quarantine."""
    clock = VirtualClock()
    comp = make_virtual_compilette(
        clock, "k", lambda p: 0.010 / p["unroll"])
    tuner = OnlineAutotuner(
        comp, VirtualClockEvaluator(clock),
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        clock=clock, wake_every=1, gate=VariantGate(comp), gate_mode="canary",
        canary_fraction=0.25, canary_calls=1000)   # probation never ends
    run_tuner(tuner)
    s = tuner.stats()
    assert s["quarantined"] == 0
    assert s["rollbacks"] == 0
    assert s["canary_promotions"] == 0
    assert s["canary_in_flight"]          # the last best still on probation
    assert s["active_point"] is None      # reference never displaced
    assert tuner._canary.life.point == {"unroll": 8}


# ---------------------------------------------------- quarantine persistence
def test_registry_quarantine_survives_save_load(tmp_path):
    reg = TunedRegistry()
    spec, dev, point = {"N": 64}, "test:v", {"unroll": 8}
    reg.put("k", spec, dev, point, 0.001)
    assert reg.get("k", spec, dev) == point
    reg.quarantine("k", spec, dev, point, "oracle mismatch")
    # quarantine drops the matching best immediately
    assert reg.get("k", spec, dev) is None
    assert reg.is_quarantined("k", spec, dev, point)

    path = str(tmp_path / "tuned.json")
    reg.save(path)
    back = TunedRegistry.load(path)
    assert back.is_quarantined("k", spec, dev, point)
    assert back.n_quarantined == 1
    assert back.get_warm("k", spec, dev) is None
    assert back.quarantined_points("k", spec, dev) == [point]


def test_coordinator_never_re_trusts_quarantined_point_after_restart():
    """Warm-start path: a condemned point must neither seed the tuner nor
    ever be proposed again by its strategy."""
    clock = VirtualClock()
    reg = TunedRegistry()
    coord = TuningCoordinator(device="test:v", clock=clock, registry=reg,
                              gate_mode="check")
    comp = make_virtual_compilette(clock, "k", lambda p: 0.010)
    bad = {"unroll": 8}
    # a previous process found `bad` best, then condemned it
    reg.put("k", {}, coord.device, bad, 0.001)
    reg.quarantine("k", {}, coord.device, bad, "tail regression")
    m = coord.register("k", comp, VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    assert not m.warm_started
    assert m.tuner.explorer.is_quarantined(bad)
    m.tuner.exhaust()
    assert m.tuner.explorer.best_point != bad
    assert bad not in [life.point for life in m.tuner._lives]


def test_autotuner_quarantine_writes_through_to_registry():
    clock = VirtualClock()
    reg = TunedRegistry()
    coord = TuningCoordinator(
        device="test:v", clock=clock, registry=reg, gate_mode="check",
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0))
    comp = make_virtual_compilette(clock, "k", lambda p: 0.010 / p["unroll"])
    bad = {"unroll": 8}
    comp.gate_script = lambda point: dict(point) != bad
    m = coord.register("k", comp, VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    while not m.tuner.explorer.finished:
        m(1)
        coord.pump()
    assert reg.is_quarantined("k", {}, m.registry_device, bad)
    # and a later process seeded from this registry skips it outright
    coord2 = TuningCoordinator(device="test:v", clock=clock, registry=reg,
                               gate_mode="check")
    comp2 = make_virtual_compilette(clock, "k", lambda p: 0.010)
    m2 = coord2.register("k", comp2, VirtualClockEvaluator(clock),
                         reference_fn=virtual_kernel(clock, 0.010))
    assert m2.tuner.explorer.is_quarantined(bad)


# ------------------------------------------------------------ stats rollup
def test_coordinator_stats_reconcile_gate_and_canary_counters():
    """Top-level aggregates == sum(per-kernel) + retired tombstone for
    every trusted-swaps counter, including after a tuner retires."""
    clock = VirtualClock()
    coord = TuningCoordinator(
        device="test:v", clock=clock, gate_mode="canary",
        canary_fraction=0.5, canary_calls=2,
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        lifecycle=TunerLifecycle(idle_evict_s=50.0))
    ev = VirtualClockEvaluator(clock)
    bad = {"unroll": 4}
    comp_a = make_virtual_compilette(clock, "a", lambda p: 0.010 / p["unroll"])
    comp_a.gate_script = lambda point: dict(point) != bad
    comp_b = make_virtual_compilette(clock, "b", lambda p: 0.020 / p["unroll"])
    a = coord.register("a", comp_a, ev,
                       reference_fn=virtual_kernel(clock, 0.010))
    b = coord.register("b", comp_b, ev,
                       reference_fn=virtual_kernel(clock, 0.020))
    for i in range(300):
        a(i)
        b(i)
        coord.pump()
    fields = ("gate_spent_s", "gate_checks", "gate_failures",
              "canary_calls", "canary_promotions", "rollbacks",
              "quarantined", "swaps")

    def assert_reconciles():
        s = coord.stats()
        for f in fields:
            parts = (sum(k[f] for k in s["kernels"].values())
                     + s["retired_accounts"][f])
            assert parts == pytest.approx(s[f]), f
        return s

    s = assert_reconciles()
    assert s["gate_mode"] == "canary"
    assert s["gate_checks"] >= 6
    assert s["gate_failures"] >= 1
    assert s["quarantined"] >= 1
    assert s["canary_promotions"] >= 1

    # retire kernel "a" (idle past the eviction horizon): its counters
    # move to the tombstone and the aggregates must not change
    before = {f: coord.stats()[f] for f in fields}
    for i in range(300):
        b(i)
        clock.advance(1.0)
        coord.pump()
    s = assert_reconciles()
    assert s["lifecycle"]["retired"] >= 1
    for f in ("gate_checks", "gate_failures", "quarantined"):
        assert s[f] >= before[f]
    assert s["retired_accounts"]["gate_checks"] >= 1


# --------------------------------------------------------- catalog oracles
def test_every_catalog_kernel_declares_an_oracle():
    from repro.kernels.catalog import get_catalog

    catalog = get_catalog()
    assert len(catalog.names()) >= 5
    for name in catalog.names():
        defn = catalog.get(name)
        assert defn.oracle is not None, f"{name} has no ref.py oracle"
        tol = dict(defn.tolerance or {})
        assert 0 < tol.get("rtol", 0) <= 1e-2, f"{name} tolerance {tol}"


def test_decode_attention_matches_its_oracle():
    import jax
    import jax.numpy as jnp

    from repro.kernels.attention.ops import decode_attention
    from repro.kernels.decode_attention.ref import decode_attention_ref

    B, S, H, Hk, Dh = 2, 64, 4, 2, 16
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, 1, H, Dh), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, Hk, Dh), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, Hk, Dh), jnp.float32)
    length = jnp.array([40, 64])
    got = decode_attention(q, k, v, length=length, k_chunk=16)
    want = decode_attention_ref(q, k, v, length)
    assert got.shape == want.shape
    assert float(jnp.max(jnp.abs(got - want))) < 2e-3


def test_example_args_are_never_constant():
    """Constant example fills make the oracle gate vacuous — euclid's
    distance between identical all-ones rows is exactly 0, so any
    multiplicative corruption compares equal to the reference. Every
    kernel must feed the gate varied data."""
    import numpy as np

    from repro.kernels.catalog import get_catalog

    specs = {
        "matmul": {"M": 64, "N": 128, "K": 128, "dtype": "float32"},
        "attention": {"B": 1, "Tq": 16, "Tkv": 16, "H": 2, "Hk": 1,
                      "Dh": 8, "causal": True, "dtype": "float32"},
        "decode_attention": {"B": 2, "S": 64, "H": 4, "Hk": 2, "Dh": 16,
                             "dtype": "float32"},
        "rmsnorm": {"N": 16, "d": 8, "dtype": "float32"},
        "lintra": {"H": 8, "W": 16, "bands": 3, "dtype": "float32"},
        "euclid": {"N": 128, "M": 64, "D": 32, "dtype": "float32"},
    }
    cat = get_catalog()
    assert set(specs) == set(cat.names())
    for name, spec in specs.items():
        for arr in cat.get(name).example_args(spec):
            a = np.asarray(arr)
            if a.ndim == 0:
                continue             # scalars (decode_attention length)
            assert a.std() > 0, f"{name}: constant example array"


def test_gate_rejects_corrupted_variant_on_real_numerics():
    """End to end on the real XLA backend: a genuinely generated euclid
    variant passes the oracle gate, the same variant scaled by 1.5x is
    rejected with the kernel's own tolerance in the reason."""
    from repro.kernels.catalog import get_catalog

    comp = get_catalog().compilette(
        "euclid", {"N": 128, "M": 64, "D": 32, "dtype": "float32"})
    point = next(iter(comp.space.iter_valid()))
    kern = comp.generate(point)
    gate = VariantGate(comp)
    ok, reason = gate.check(point, kern.fn)
    assert ok, reason
    ok, reason = gate.check(point, lambda *a: kern.fn(*a) * 1.5)
    assert not ok and "err" in reason
    assert gate.checks == 2 and gate.failures == 1


def test_variant_gate_uses_catalog_oracle_and_tolerance():
    """Real-numerics path: the gate passes the kernel's own reference and
    fails a deliberately wrong function, using KernelDef tolerances."""
    from repro.kernels.catalog import get_catalog

    defn = get_catalog().get("euclid")
    spec = {"N": 16, "M": 8, "D": 8, "dtype": "float32"}
    comp = get_catalog().compilette("euclid", spec)
    gate = VariantGate(comp)
    assert gate.rtol == dict(defn.tolerance)["rtol"]
    ok, _ = gate.check({"p": 1}, defn.oracle)
    assert ok
    ok, reason = gate.check({"p": 2}, lambda x, c: defn.oracle(x, c) + 1.0)
    assert not ok and "err" in reason
    assert gate.checks == 2 and gate.failures == 1


# ------------------------------------------------------------ compile farm
def test_compile_farm_workers_survive_failures():
    """A raising generate and a raising charge callback each produce a
    failed ticket (billed, quarantinable) — never a dead worker slot."""
    from repro.core.compile_farm import CompileFarm

    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1)])
    calls = {"n": 0}

    def gen(point, **spec):
        calls["n"] += 1
        if point["unroll"] == 2:
            raise RuntimeError("codegen exploded")
        return lambda x: x

    comp = Compilette("k", sp, gen)
    farm = CompileFarm(mode="thread", workers=2)
    try:
        t_bad = farm.submit(comp, {"unroll": 2}, {})
        t_good = farm.submit(comp, {"unroll": 4}, {})
        charges = []

        def bad_cb(ticket, seconds):
            charges.append(seconds)
            raise RuntimeError("account gone")

        t_spec = farm.submit(comp, {"unroll": 8}, {},
                             speculative=True, charge_cb=bad_cb)

        def wait(*tickets):
            import threading
            for _ in range(2000):
                if all(t.done for t in tickets):
                    return
                threading.Event().wait(0.005)
            raise AssertionError("farm tickets never completed")

        wait(t_bad, t_good, t_spec)
        assert t_bad.error is not None and t_bad.kern is None
        assert t_good.error is None and t_good.kern is not None
        assert t_spec.done
        assert charges                       # the farm did try to bill
        assert farm.worker_errors >= 1       # ...and logged the escape
        # the pool is intact: a fresh job still completes
        t_again = farm.submit(comp, {"unroll": 1}, {})
        wait(t_again)
        assert t_again.error is None
        s = farm.stats()
        assert s["completed"] >= 3 and s["failed"] >= 1
    finally:
        farm.shutdown()


# ------------------------------------------------------------ config knobs
def test_tuning_config_gate_knobs_env_flags_alias():
    import argparse

    from repro.api import TuningConfig

    cfg = TuningConfig.from_env({
        "REPRO_TUNE_GATE": "canary",              # alias -> gate_mode
        "REPRO_TUNE_CANARY_FRACTION": "0.5",
        "REPRO_TUNE_CANARY_CALLS": "16",
        "REPRO_TUNE_GATE_RTOL": "1e-2",
    })
    assert cfg.gate_mode == "canary"
    assert cfg.canary_fraction == 0.5
    assert cfg.canary_calls == 16
    assert cfg.gate_rtol == 1e-2
    assert cfg.gate_atol is None

    ap = argparse.ArgumentParser()
    TuningConfig.add_flags(ap)
    args = ap.parse_args(["--gate-mode", "check", "--canary-calls", "3",
                          "--gate-atol", "1e-6"])
    cfg = TuningConfig.from_flags(args)
    assert cfg.gate_mode == "check"
    assert cfg.canary_calls == 3
    assert cfg.gate_atol == 1e-6

    with pytest.raises(ValueError):
        TuningConfig(gate_mode="nope")
    with pytest.raises(ValueError):
        TuningConfig(canary_fraction=0.0)
    with pytest.raises(ValueError):
        TuningConfig(canary_calls=0)


# ------------------------------------------------------ fault-injection replay
def test_fault_replay_wrong_output_serves_zero_calls():
    from repro.api import TuningConfig
    from repro.bench.replay import (
        fault_scenarios, replay_scenario, replay_tuning_defaults)
    from repro.configs import REGISTRY

    gated = dataclasses.replace(replay_tuning_defaults(),
                                gate_mode="canary")
    configs = {"deepseek-7b": REGISTRY["deepseek-7b"]}
    by_name = {sc.name: sc for sc in fault_scenarios(320)}

    r = replay_scenario(by_name["wrong_output_variant"], configs,
                        seed=0, config=gated)
    t = r["tuning"]
    assert t["gate_mode"] == "canary"
    assert t["gate_failures"] >= 1
    assert t["quarantined"] >= t["gate_failures"]
    assert t["served_wrong_calls"] == 0
    assert t["overhead_pct"] <= 5.0


def test_fault_replay_tail_regression_rolls_back():
    from repro.bench.replay import (
        fault_scenarios, replay_scenario, replay_tuning_defaults)
    from repro.configs import REGISTRY

    gated = dataclasses.replace(replay_tuning_defaults(),
                                gate_mode="canary")
    configs = {"deepseek-7b": REGISTRY["deepseek-7b"]}
    by_name = {sc.name: sc for sc in fault_scenarios(320)}

    r = replay_scenario(by_name["tail_regression"], configs,
                        seed=0, config=gated)
    t = r["tuning"]
    assert t["rollbacks"] >= 1
    assert t["quarantined"] >= t["rollbacks"]
    assert t["overhead_pct"] <= 5.0
    # the rollback restored service: still at least as fast as reference
    assert all(pt["speedup_vs_ref"] >= 1.0
               for pt in r["per_tenant"].values())


def test_fault_replay_compile_failures_quarantine_without_stall():
    from repro.bench.replay import (
        fault_scenarios, replay_scenario, replay_tuning_defaults)
    from repro.configs import REGISTRY

    gated = dataclasses.replace(replay_tuning_defaults(),
                                gate_mode="canary")
    configs = {"deepseek-7b": REGISTRY["deepseek-7b"]}
    by_name = {sc.name: sc for sc in fault_scenarios(320)}

    r = replay_scenario(by_name["faulty_compiles_burst"], configs,
                        seed=0, config=gated)
    t = r["tuning"]
    assert t["quarantined"] >= 1
    assert t["served_wrong_calls"] == 0
    assert t["overhead_pct"] <= 5.0


# ------------------------------------------------------------ fleet gate
def _fleet_canary_coordinator(clock, *, rid, bus):
    return TuningCoordinator(
        device="test:v", clock=clock, registry=TunedRegistry(),
        gate_mode="canary", canary_fraction=0.5, canary_calls=4,
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        replica_id=rid, replica_count=2, registry_backend=bus,
        sync_every_s=None)


def test_canary_rollback_quarantines_fleet_wide():
    """A tail regression caught by replica 0's canary condemns the point
    for the whole fleet: after one sync, replica 1 holds the quarantine,
    never serves the lying point, and adopts replica 0's honest best as a
    CANDIDATE through its own canary — one rollback per fleet, not one
    per replica."""
    bus = FleetBus()
    lie = {"unroll": 8}
    clock_a, clock_b = VirtualClock(), VirtualClock()
    coord_a = _fleet_canary_coordinator(clock_a, rid=0, bus=bus)
    coord_b = _fleet_canary_coordinator(clock_b, rid=1, bus=bus)

    def lying(clock):
        return make_lying_compilette(
            clock, "k", honest_s=lambda p: 0.010 / p["unroll"],
            lie_point=lie, lie_score_s=0.001, lie_serve_s=0.040)

    m_a = coord_a.register("k", lying(clock_a), VirtualClockEvaluator(clock_a),
                           reference_fn=virtual_kernel(clock_a, 0.010))
    m_b = coord_b.register("k", lying(clock_b), VirtualClockEvaluator(clock_b),
                           reference_fn=virtual_kernel(clock_b, 0.010))
    # all unroll points stripe to replica 0: replica 1 owns nothing and
    # can only ever receive work through the fleet adoption path
    for i in range(50):
        m_b(i)
        clock_b.advance(0.010)
        coord_b.observe_busy(0.010)
        coord_b.pump()
    assert m_b.tuner.explorer.finished

    for i in range(400):
        m_a(i)
        clock_a.advance(0.010)
        coord_a.observe_busy(0.010)
        coord_a.pump()
    s_a = m_a.tuner.stats()
    assert s_a["rollbacks"] == 1
    assert m_a.tuner.explorer.is_quarantined(lie)
    coord_a.sync_fleet()

    coord_b.sync_fleet()
    assert m_b.tuner.explorer.is_quarantined(lie)
    for i in range(400):
        m_b(i)
        clock_b.advance(0.010)
        coord_b.observe_busy(0.010)
        coord_b.pump()
    s_b = m_b.tuner.stats()
    # the fleet paid for exactly one rollback; the peer adopted the
    # verdict instead of re-learning it in production
    assert s_b["rollbacks"] == 0
    assert s_b["gate_failures"] == 0
    assert all(life.point != lie or life.calls == 0
               for life in m_b.tuner._lives)
    # peer best arrived as a canaried CANDIDATE, never a blind incumbent
    assert s_b["canary_promotions"] >= 1
    assert s_b["swaps"] == s_b["canary_promotions"]
    assert s_b["active_point"] == {"unroll": 4}


def test_fleet_quarantine_blocks_warm_start_after_restart():
    """Replica 1 restarts from the merged fleet state: the condemned
    point neither warm-starts nor re-enters its strategy even though the
    registry file never saw replica 1 condemn anything itself."""
    bus = FleetBus()
    lie = {"unroll": 8}
    clock_a = VirtualClock()
    coord_a = _fleet_canary_coordinator(clock_a, rid=0, bus=bus)
    comp_a = make_lying_compilette(
        clock_a, "k", honest_s=lambda p: 0.010 / p["unroll"],
        lie_point=lie, lie_score_s=0.001, lie_serve_s=0.040)
    m_a = coord_a.register("k", comp_a, VirtualClockEvaluator(clock_a),
                           reference_fn=virtual_kernel(clock_a, 0.010))
    for i in range(400):
        m_a(i)
        clock_a.advance(0.010)
        coord_a.observe_busy(0.010)
        coord_a.pump()
    coord_a.sync_fleet()

    # a fresh replica-1 process joining the fleet after the fact
    clock_b = VirtualClock()
    coord_b = _fleet_canary_coordinator(clock_b, rid=1, bus=bus)
    comp_b = make_virtual_compilette(clock_b, "k",
                                     lambda p: 0.010 / p["unroll"])
    m_b = coord_b.register("k", comp_b, VirtualClockEvaluator(clock_b),
                           reference_fn=virtual_kernel(clock_b, 0.010))
    coord_b.sync_fleet()
    assert m_b.tuner.explorer.is_quarantined(lie)
    assert not m_b.warm_started or m_b.tuner.stats()["active_point"] != lie
    for i in range(200):
        m_b(i)
        clock_b.advance(0.010)
        coord_b.observe_busy(0.010)
        coord_b.pump()
    assert all(life.point != lie or life.calls == 0
               for life in m_b.tuner._lives)
    assert m_b.tuner.stats()["rollbacks"] == 0


# ---------------------------------------------------------- transfer gate
def test_transfer_seed_faulted_oracle_quarantines_fleet_wide():
    """Transfer fault row: a trait-similar device receives a foreign best
    as a transfer seed, its (fault-injected) oracle rejects it — the
    point must quarantine fleet-wide and never be re-seeded on ANY
    similar device, which must still converge to an honest best."""
    from repro.bench.replay import fault_injection_hook
    from repro.core.profiles import TI_L3, scaled_profile

    def comp_on(clock, profile):
        comp = make_virtual_compilette(clock, "k",
                                       lambda p: 0.010 / p["unroll"])
        comp.virtual = (clock, profile)
        return comp

    def coordinator(clock, device):
        return TuningCoordinator(
            device=device, clock=clock, registry=reg, transfer=True,
            gate_mode="check",
            policy=RegenerationPolicy(max_overhead_frac=1.0,
                                      invest_frac=1.0))

    def drive(coord, m, clock, n=300):
        for i in range(n):
            m(i)
            clock.advance(0.010)
            coord.observe_busy(0.010)
            coord.pump()

    reg = TunedRegistry()
    # donor: clean device publishes its best (with traits)
    clock_a = VirtualClock()
    coord_a = coordinator(clock_a, "bench:donor")
    m_a = coord_a.register("k", comp_on(clock_a, TI_L3),
                           VirtualClockEvaluator(clock_a),
                           reference_fn=virtual_kernel(clock_a, 0.010))
    drive(coord_a, m_a, clock_a)
    best = {"unroll": 8}
    assert m_a.tuner.explorer.best_point == best

    # device B (similar profile): EVERY non-base variant is miscompiled —
    # the transferred best must fail B's oracle, not serve, and condemn
    clock_b = VirtualClock()
    coord_b = coordinator(clock_b, "bench:b")
    comp_b = comp_on(clock_b, scaled_profile(TI_L3, "TI-L3~", flops=1.2))
    fault_injection_hook({"wrong_output_rate": 1.0}, seed=0,
                         clock=clock_b)(comp_b)
    m_b = coord_b.register("k", comp_b, VirtualClockEvaluator(clock_b),
                           reference_fn=virtual_kernel(clock_b, 0.010))
    assert m_b.transfer_seed_keys, "similar device must receive the seed"
    drive(coord_b, m_b, clock_b)
    s_b = m_b.tuner.stats()
    assert s_b["gate_failures"] >= 1
    assert m_b.tuner.explorer.is_quarantined(best)
    assert reg.is_quarantined("k", {}, "bench:b", best)
    assert all(life.point != best or life.calls == 0
               for life in m_b.tuner._lives), (
        "a faulted transfer seed must never serve a production call")
    assert coord_b.stats()["transfer_adopted"] == 0

    # device C (similar to both): the condemned point never travels again
    clock_c = VirtualClock()
    coord_c = coordinator(clock_c, "bench:c")
    comp_c = comp_on(clock_c, scaled_profile(TI_L3, "TI-L3≈",
                                             bandwidth=1.1))
    m_c = coord_c.register("k", comp_c, VirtualClockEvaluator(clock_c),
                           reference_fn=virtual_kernel(clock_c, 0.010))
    bad_key = comp_c.space.key(best)
    assert bad_key not in m_c.transfer_seed_keys, (
        "a seed condemned anywhere in the fleet must not be re-seeded "
        "on any similar device")
    drive(coord_c, m_c, clock_c)
    # C still converges honestly (its own gate is clean)
    assert m_c.tuner.explorer.best_point == best
    assert m_c.tuner.stats()["gate_failures"] == 0
