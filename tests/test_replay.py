"""Traffic-replay harness: deterministic scenario fleet across configs.

Acceptance suite for ``repro.bench.replay`` + ``benchmarks/scenario_fleet``:
seeded arrival processes and mixes, trace synthesis and multi-tenant
merging, the virtual-clock replay engine, and the fleet-level CI gates
(two same-seed runs byte-identical; tuning overhead <= 5%; speedup vs
reference >= 1.0 on every scenario x config row).
"""

import json
import os
import random
import sys

import pytest

from repro.bench import (
    Request, Trace, bursty_arrivals, choice_mix, fixed_mix,
    fleet_scenarios, longtail_mix, make_trace, merge_traces, phase_arrivals,
    phase_mix, poisson_arrivals, ramp_arrivals, replay_scenario,
)
from repro.configs import REGISTRY

ARRIVALS = [poisson_arrivals, bursty_arrivals, ramp_arrivals, phase_arrivals]


def _fleet_module():
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..",
                                    "benchmarks"))
    import scenario_fleet
    return scenario_fleet


# ------------------------------------------------------------- arrivals
@pytest.mark.parametrize("arrival", ARRIVALS, ids=lambda a: a.__name__)
def test_arrival_processes_are_seeded_and_bounded(arrival):
    a1 = arrival(random.Random("s"), rate_hz=50.0, duration_s=4.0)
    a2 = arrival(random.Random("s"), rate_hz=50.0, duration_s=4.0)
    assert a1 == a2                       # same seed, same arrivals
    assert a1 == sorted(a1)
    assert all(0.0 <= t < 4.0 for t in a1)
    # the processes are average-rate-preserving: ~rate*duration events
    assert 0.4 * 200 <= len(a1) <= 2.0 * 200
    a3 = arrival(random.Random("other"), rate_hz=50.0, duration_s=4.0)
    assert a1 != a3                       # seed actually matters


def test_bursty_arrivals_cluster_into_bursts():
    rng = random.Random(3)
    times = bursty_arrivals(rng, rate_hz=100.0, duration_s=8.0,
                            burst_factor=8.0)
    gaps = sorted(b - a for a, b in zip(times, times[1:]))
    # on/off traffic: tight in-burst gaps plus long inter-burst silences
    assert gaps[len(gaps) // 2] < 1.0 / 100.0
    assert gaps[-1] > 4.0 / 100.0


# ----------------------------------------------------------------- mixes
def test_mixes_are_seeded_and_in_range():
    lt = longtail_mix(64, 4096, sigma=1.0)
    draws = [lt(random.Random(9), i / 100.0) for i in range(100)]
    assert draws == [lt(random.Random(9), i / 100.0) for i in range(100)]
    assert all(64 <= d <= 4096 for d in draws)
    assert fixed_mix(7)(random.Random(0), 0.3) == 7
    ch = choice_mix((1, 2), (1.0, 0.0))
    assert ch(random.Random(0), 0.5) == 1
    pm = phase_mix(fixed_mix(1), fixed_mix(2), switch_at=0.5)
    assert pm(random.Random(0), 0.2) == 1
    assert pm(random.Random(0), 0.8) == 2


# ---------------------------------------------------------------- traces
def test_make_trace_is_deterministic_and_sorted():
    sc = fleet_scenarios(64)[1]           # bursty_longtail
    t1 = make_trace(sc, "tenant-a", 200.0, seed=5)
    t2 = make_trace(sc, "tenant-a", 200.0, seed=5)
    assert t1 == t2
    assert t1 != make_trace(sc, "tenant-a", 200.0, seed=6)
    # a different tenant name reseeds the stream, not just relabels it
    assert ([r.prompt_len for r in t1.requests]
            != [r.prompt_len
                for r in make_trace(sc, "tenant-b", 200.0, seed=5).requests])
    ts = [r.t_arrival_s for r in t1.requests]
    assert ts == sorted(ts)
    assert all(r.tenant == "tenant-a" for r in t1.requests)


def test_merge_traces_interleaves_tenants_in_time_order():
    sc = fleet_scenarios(48)[0]
    ta = make_trace(sc, "a", 150.0, seed=1)
    tb = make_trace(sc, "b", 150.0, seed=1)
    merged = merge_traces("pair", [ta, tb])
    assert merged.tenants == ("a", "b")
    assert len(merged.requests) == len(ta.requests) + len(tb.requests)
    keys = [(r.t_arrival_s, r.tenant) for r in merged.requests]
    assert keys == sorted(keys)


# ----------------------------------------------------------------- engine
def test_replay_requires_a_virtual_clock():
    from repro.api import TuningSession

    trace = Trace("t", 0, 1.0, ("deepseek-7b",),
                  (Request(0.0, "deepseek-7b", 128, 0),))
    session = TuningSession()             # wall clock: no .advance
    try:
        with pytest.raises(TypeError):
            session.replay(trace)
    finally:
        session.close()


def test_single_config_replay_converges_and_reports():
    sc = fleet_scenarios(160)[0]          # steady_poisson
    rep = replay_scenario(sc, {"deepseek-7b": REGISTRY["deepseek-7b"]},
                          seed=0)
    pt = rep["per_tenant"]["deepseek-7b"]
    t = rep["tuning"]
    assert rep["trace"]["tenants"] == ["deepseek-7b"]
    assert pt["n_requests"] > 100
    assert pt["p99_s"] >= pt["p50_s"] > 0.0
    assert pt["n_handles"] >= 3           # rmsnorm + matmul + attention
    assert t["swaps"] > 0                 # tuning actually found wins
    assert pt["speedup_vs_ref"] > 1.0
    assert t["time_to_best_s"] is not None
    assert 0.0 < t["time_to_best_s"] <= rep["trace"]["duration_s"] * 2
    assert 0.0 < t["overhead_pct"] <= 5.0
    assert 0.0 <= t["cache_hit_rate"] <= 1.0
    # identical seed -> byte-identical report
    rep2 = replay_scenario(sc, {"deepseek-7b": REGISTRY["deepseek-7b"]},
                           seed=0)
    assert json.dumps(rep, sort_keys=True, default=str) \
        == json.dumps(rep2, sort_keys=True, default=str)


def test_bursty_traffic_builds_a_queueing_tail():
    sc = fleet_scenarios(160)[1]          # bursty_longtail
    rep = replay_scenario(sc, {"qwen2.5-32b": REGISTRY["qwen2.5-32b"]},
                          seed=0)
    pt = rep["per_tenant"]["qwen2.5-32b"]
    # bursts overrun the server: the p99 sits well above the median
    assert pt["p99_s"] > 2.0 * pt["p50_s"]


def test_multi_tenant_replay_shares_one_session():
    sc = fleet_scenarios(48)[0]
    names = ["deepseek-7b", "whisper-tiny", "rwkv6-1.6b"]
    rep = replay_scenario(sc, {n: REGISTRY[n] for n in names}, seed=0)
    assert sorted(rep["per_tenant"]) == sorted(names)
    for name in names:
        pt = rep["per_tenant"][name]
        assert pt["n_requests"] > 0
        assert pt["speedup_vs_ref"] >= 1.0
    assert rep["tuning"]["overhead_pct"] <= 5.0


def test_session_replay_delegates_to_bench_replay():
    from repro.api import TuningSession
    from repro.bench import replay as bench_replay

    assert TuningSession.replay.__doc__
    sc = fleet_scenarios(32)[0]
    trace = make_trace(sc, "whisper-tiny", 400.0, seed=2)
    from repro.bench.replay import replay_session
    from repro.core import VirtualClock

    clock = VirtualClock()
    session = replay_session(clock)
    try:
        rep = session.replay(trace,
                             {"whisper-tiny": REGISTRY["whisper-tiny"]})
    finally:
        session.close()
    clock2 = VirtualClock()
    session2 = replay_session(clock2)
    try:
        rep2 = bench_replay(session2, trace,
                            {"whisper-tiny": REGISTRY["whisper-tiny"]})
    finally:
        session2.close()
    assert json.dumps(rep, sort_keys=True, default=str) \
        == json.dumps(rep2, sort_keys=True, default=str)


# ------------------------------------------------------------ fleet gates
def test_scenario_fleet_quick_is_deterministic_and_gated():
    """The CI acceptance: >= 10 configs x >= 4 scenarios (+ multi-tenant),
    two same-seed runs byte-identical, overhead <= 5% and speedup >= 1.0
    on every row."""
    fleet = _fleet_module()
    p1 = fleet.run(quick=True, seed=0, write=False)
    p2 = fleet.run(quick=True, seed=0, write=False)
    assert json.dumps(p1, sort_keys=True, default=str) \
        == json.dumps(p2, sort_keys=True, default=str)

    assert p1["n_configs"] >= 10
    assert p1["n_scenarios"] >= 4
    scenario_names = {r["scenario"] for r in p1["rows"]}
    assert len(scenario_names) >= 5       # 4 traffic shapes + multi_tenant
    assert "multi_tenant" in scenario_names
    assert len(p1["rows"]) >= 10 * 4

    assert p1["violations"] == []
    for r in p1["rows"]:
        assert r["overhead_pct"] <= fleet.MAX_OVERHEAD_PCT, r
        assert r["speedup_vs_ref"] >= fleet.MIN_SPEEDUP, r
    # tuning is live across the fleet, not vacuously gated
    assert sum(1 for r in p1["rows"] if r["swaps"]) >= len(p1["rows"]) // 2


def test_scenario_fleet_check_rows_flags_violations():
    fleet = _fleet_module()
    bad = [{"scenario": "s", "config": "c",
            "overhead_pct": 7.5, "speedup_vs_ref": 0.9}]
    msgs = fleet.check_rows(bad)
    assert len(msgs) == 2
    assert "overhead" in msgs[0] and "speedup" in msgs[1]
    good = [{"scenario": "s", "config": "c",
             "overhead_pct": 0.5, "speedup_vs_ref": 1.2}]
    assert fleet.check_rows(good) == []
