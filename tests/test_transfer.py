"""Transfer plane: trait vectors, similarity ranking, cross-device seeds.

Deterministic on the VirtualClock; virtual compilettes carry a
``virtual = (clock, profile)`` marker so traits derive from the exact
:class:`~repro.core.DeviceProfile`. The contract under test:

  * every registry best carries the device's trait vector and it
    round-trips through save/load;
  * on a fingerprint miss, ``transfer_seeds`` ranks foreign bests by
    trait similarity, floors, dedups, and never proposes a point
    condemned anywhere in the fleet;
  * a coordinator with ``transfer=True`` injects the seeds as gated
    CANDIDATEs and reaches the known best in <= 2 regenerations where a
    cold search pays the whole enumeration;
  * the knobs parse identically from env, flags and code.
"""

import argparse

import pytest

from repro.api import TuningConfig, TuningSession
from repro.core import (
    Compilette, Param, RegenerationPolicy, TunedRegistry, VirtualClock,
    VirtualClockEvaluator, product_space, scaled_profile, virtual_kernel,
)
from repro.core.profiles import ALL_PROFILES, SI_L1, TI_F3, TI_L3, TPU_V5E
from repro.core.transfer import (
    DeviceTraits,
    calibrated_traits,
    device_traits,
    similarity,
    traits_from_fingerprint,
    transfer_seeds,
)
from repro.runtime.coordinator import TuningCoordinator


def make_comp(clock, name="k", profile=TI_L3,
              cost=lambda p: 0.010 / p["unroll"]):
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1,
                              switch_rank=0)])

    def gen(point, **spec):
        return virtual_kernel(clock, cost(point), tag=dict(point))

    comp = Compilette(name, sp, gen)
    comp.virtual = (clock, profile)
    return comp


def make_coordinator(clock, registry, device, **kw):
    kw.setdefault("policy", RegenerationPolicy(
        max_overhead_frac=1.0, invest_frac=1.0))
    return TuningCoordinator(device=device, clock=clock,
                             registry=registry, **kw)


def drive(coord, m, clock, n=200):
    for i in range(n):
        m(i)
        clock.advance(0.010)
        coord.observe_busy(0.010)
        coord.pump()


TRAITS_A = DeviceTraits.from_profile(TI_L3)


# ----------------------------------------------------------------- traits
def test_traits_from_profile_and_roundtrip():
    t = DeviceTraits.from_profile(TI_L3)
    assert t.flops == TI_L3.peak_flops
    assert t.bandwidth_gbps == TI_L3.hbm_gbps
    assert t.vmem_kb == TI_L3.vmem_kb
    assert t.issue == TI_L3.issue
    assert t.overlap == 0.0       # lean core
    assert DeviceTraits.from_profile(TI_F3).overlap == 1.0
    assert DeviceTraits.from_dict(t.to_dict()) == t


def test_traits_from_dict_is_tolerant():
    good = TRAITS_A.to_dict()
    assert DeviceTraits.from_dict(None) is None
    assert DeviceTraits.from_dict("not a dict") is None
    for axis in good:
        broken = dict(good)
        del broken[axis]
        assert DeviceTraits.from_dict(broken) is None
        broken[axis] = float("nan")
        assert DeviceTraits.from_dict(broken) is None
        broken[axis] = "fast"
        assert DeviceTraits.from_dict(broken) is None


def test_similarity_identity_symmetry_monotonicity():
    a = DeviceTraits.from_profile(TI_L3)
    near = DeviceTraits.from_profile(
        scaled_profile(TI_L3, "TI-L3+", flops=1.2, bandwidth=1.1))
    far = DeviceTraits.from_profile(SI_L1)
    assert similarity(a, a) == pytest.approx(1.0)
    assert similarity(a, near) == pytest.approx(similarity(near, a))
    assert similarity(a, far) < similarity(a, near) < 1.0
    # the overlap axis is categorical: a lean/fat flip costs similarity
    # even with every quantitative axis identical
    fat = DeviceTraits.from_dict({**a.to_dict(), "overlap": 1.0})
    assert similarity(a, fat) < 1.0
    assert 0.0 < similarity(a, far) <= 1.0


def test_scaled_profile_moves_only_roofline_terms():
    p = scaled_profile(TI_L3, "TI-L3-x2", flops=2.0, bandwidth=0.5,
                       vmem=2.0)
    assert p.name == "TI-L3-x2"
    assert p.mxu_tflops == pytest.approx(TI_L3.mxu_tflops * 2.0)
    assert p.hbm_gbps == pytest.approx(TI_L3.hbm_gbps * 0.5)
    assert p.vmem_kb == TI_L3.vmem_kb * 2
    assert (p.issue, p.overlap, p.vpus, p.clock_ghz) == (
        TI_L3.issue, TI_L3.overlap, TI_L3.vpus, TI_L3.clock_ghz)
    with pytest.raises(ValueError):
        scaled_profile(TI_L3, "bad", flops=0.0)


def test_device_traits_precedence_and_fingerprints():
    clock = VirtualClock()
    comp = make_comp(clock, profile=TI_L3)
    # virtual marker wins when no explicit profile is passed
    assert device_traits(comp, device="cpu:x") == TRAITS_A
    assert device_traits(comp, profile=TI_F3) == DeviceTraits.from_profile(
        TI_F3)
    # real backends: platform prefix picks the nominal
    assert traits_from_fingerprint("tpu:v5e:xla-9") == (
        DeviceTraits.from_profile(TPU_V5E))
    assert traits_from_fingerprint("cpu:host") is not None
    assert traits_from_fingerprint("quantum:q1") is None
    assert traits_from_fingerprint(None) is None
    assert device_traits(object(), device="unknown:dev") is None


def test_calibrated_traits_scales_throughput_by_probe():
    sp = product_space([Param("unroll", (1, 2), phase=1, switch_rank=0)])
    comp = Compilette("k", sp, lambda point, **spec: (lambda *a: None),
                      cost_model=lambda point, spec, profile: 0.004)
    base = traits_from_fingerprint("cpu:host")
    # observed twice as slow as predicted -> throughput halves
    cal = calibrated_traits(base, comp, {}, 0.008, device="cpu:host")
    assert cal.flops == pytest.approx(base.flops * 0.5)
    assert cal.bandwidth_gbps == pytest.approx(base.bandwidth_gbps * 0.5)
    assert (cal.vmem_kb, cal.issue, cal.overlap) == (
        base.vmem_kb, base.issue, base.overlap)
    # the probe ratio is clamped to 8x either way
    assert calibrated_traits(base, comp, {}, 1e6, device="cpu:host"
                             ).flops == pytest.approx(base.flops / 8.0)
    # no model / bad observation / virtual marker: pass through unchanged
    assert calibrated_traits(base, object(), {}, 0.008,
                             device="cpu:host") == base
    assert calibrated_traits(base, comp, {}, float("nan"),
                             device="cpu:host") == base
    clock = VirtualClock()
    vcomp = make_comp(clock)
    vt = device_traits(vcomp)
    assert calibrated_traits(vt, vcomp, {}, 123.0) == vt


# ------------------------------------------------------------ registry IO
def test_put_persists_traits_and_round_trips(tmp_path):
    reg = TunedRegistry()
    td = TRAITS_A.to_dict()
    reg.put("k", {}, "bench:a", {"unroll": 8}, 0.00125, traits=td)
    path = str(tmp_path / "tuned.json")
    reg.save(path)
    back = TunedRegistry.load(path)
    (dev, entry), = back.cross_device_entries("k", {}, exclude_device=None)
    assert dev == "bench:a"
    assert entry["traits"] == td
    # a worse-score re-put grafts traits onto a pre-transfer entry
    reg2 = TunedRegistry()
    reg2.put("k", {}, "bench:a", {"unroll": 8}, 0.00125)
    reg2.put("k", {}, "bench:a", {"unroll": 8}, 0.00300, traits=td)
    (_, entry2), = reg2.cross_device_entries("k", {})
    assert entry2["score_s"] == 0.00125 and entry2["traits"] == td


def test_cross_device_entries_filters_and_sorts():
    reg = TunedRegistry()
    reg.put("k", {}, "bench:b", {"unroll": 4}, 0.0025)
    reg.put("k", {}, "bench:a", {"unroll": 8}, 0.00125)
    reg.put("k", {"n": 1}, "bench:c", {"unroll": 2}, 0.005)   # other spec
    reg.put("other", {}, "bench:d", {"unroll": 2}, 0.005)     # other kernel
    rows = reg.cross_device_entries("k", {}, exclude_device="bench:b")
    assert [dev for dev, _ in rows] == ["bench:a"]
    rows = reg.cross_device_entries("k", {})
    assert [dev for dev, _ in rows] == ["bench:a", "bench:b"]
    # an entry quarantined under its own key never surfaces
    reg.quarantine("k", {}, "bench:a", {"unroll": 8}, "wrong output")
    assert [dev for dev, _ in reg.cross_device_entries("k", {})] == [
        "bench:b"]


def test_fleet_quarantined_points_spans_devices():
    reg = TunedRegistry()
    reg.quarantine("k", {}, "bench:a", {"unroll": 8}, "wrong output")
    reg.quarantine("k", {}, "bench:b", {"unroll": 4}, "tail")
    reg.quarantine("other", {}, "bench:a", {"unroll": 2}, "tail")
    pts = reg.fleet_quarantined_points("k", {})
    assert sorted(p["unroll"] for p in pts) == [4, 8]
    assert reg.fleet_quarantined_points("missing", {}) == []


# --------------------------------------------------------- transfer_seeds
def seeded_registry():
    """Three donors: near (same family), scaled, and a far outlier."""
    reg = TunedRegistry()
    donors = (
        ("bench:near", TI_L3, {"unroll": 8}, 0.00125),
        ("bench:scaled", scaled_profile(TI_L3, "TI-L3~", flops=1.3,
                                        bandwidth=1.2),
         {"unroll": 4}, 0.0025),
        ("bench:far", SI_L1, {"unroll": 1}, 0.010),
    )
    for dev, prof, point, score in donors:
        reg.put("k", {}, dev, point, score,
                traits=DeviceTraits.from_profile(prof).to_dict())
    return reg


def test_transfer_seeds_ranks_floors_and_caps():
    reg = seeded_registry()
    local = DeviceTraits.from_profile(TI_L3)
    seeds = transfer_seeds(reg, "k", {}, "bench:me", local,
                           top_k=3, min_similarity=0.75)
    # the far outlier is floored away; most similar donor first
    assert [s.device for s in seeds] == ["bench:near", "bench:scaled"]
    assert seeds[0].point == {"unroll": 8}
    assert seeds[0].similarity == pytest.approx(1.0)
    assert seeds[1].similarity < seeds[0].similarity
    assert transfer_seeds(reg, "k", {}, "bench:me", local,
                          top_k=1, min_similarity=0.75)[0].device == (
        "bench:near")
    # no traits / zero k -> no seeds; the requesting device is excluded
    assert transfer_seeds(reg, "k", {}, "bench:me", None) == []
    assert transfer_seeds(reg, "k", {}, "bench:me", local, top_k=0) == []
    assert all(s.device != "bench:near" for s in transfer_seeds(
        reg, "k", {}, "bench:near", local, min_similarity=0.0))


def test_transfer_seeds_dedup_by_point_keeps_most_similar_donor():
    reg = seeded_registry()
    # a second donor holding the SAME point as bench:near, less similar
    reg.put("k", {}, "bench:twin", {"unroll": 8}, 0.002,
            traits=DeviceTraits.from_profile(
                scaled_profile(TI_L3, "TI-L3~~", flops=1.5)).to_dict())
    seeds = transfer_seeds(reg, "k", {}, "bench:me",
                           DeviceTraits.from_profile(TI_L3),
                           top_k=3, min_similarity=0.0)
    points = [s.point["unroll"] for s in seeds]
    assert points.count(8) == 1
    assert seeds[0].device == "bench:near"


def test_transfer_seeds_skip_fleet_quarantined_points():
    reg = seeded_registry()
    # the point was condemned on some OTHER device entirely: it must not
    # travel to anyone, even though the donor entry itself is clean
    reg.quarantine("k", {}, "bench:elsewhere", {"unroll": 8}, "wrong")
    seeds = transfer_seeds(reg, "k", {}, "bench:me",
                           DeviceTraits.from_profile(TI_L3),
                           min_similarity=0.0)
    assert all(s.point != {"unroll": 8} for s in seeds)


def test_transfer_seeds_ignore_traitless_entries():
    reg = TunedRegistry()
    reg.put("k", {}, "bench:old", {"unroll": 8}, 0.00125)   # pre-transfer
    assert transfer_seeds(reg, "k", {}, "bench:me",
                          DeviceTraits.from_profile(TI_L3),
                          min_similarity=0.0) == []


# --------------------------------------------------- coordinator seeding
def test_coordinator_attaches_traits_to_registry_bests():
    clock = VirtualClock()
    reg = TunedRegistry()
    coord = make_coordinator(clock, reg, "bench:donor")
    m = coord.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                       reference_fn=virtual_kernel(clock, 0.010))
    assert m.device_traits == TRAITS_A.to_dict()
    drive(coord, m, clock)
    (dev, entry), = reg.cross_device_entries("k", {})
    assert dev == "bench:donor"
    assert entry["point"] == {"unroll": 8}
    assert entry["traits"] == TRAITS_A.to_dict()


def test_transfer_seeded_tuner_reaches_best_in_two_regens():
    clock = VirtualClock()
    reg = TunedRegistry()
    donor = make_coordinator(clock, reg, "bench:donor")
    md = donor.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                        reference_fn=virtual_kernel(clock, 0.010))
    drive(donor, md, clock)
    assert md.tuner.explorer.best_point == {"unroll": 8}

    # unseen-but-similar device: fingerprint miss, transfer seeds the best
    clock2 = VirtualClock()
    recip = make_coordinator(
        clock2, reg, "bench:unseen", transfer=True, gate_mode="check")
    profile = scaled_profile(TI_L3, "TI-L3~", flops=1.2)
    m2 = recip.register("k", make_comp(clock2, profile=profile),
                        VirtualClockEvaluator(clock2),
                        reference_fn=virtual_kernel(clock2, 0.010))
    assert not m2.warm_started
    assert m2.transfer_seed_keys, "similar foreign best must be injected"
    drive(recip, m2, clock2, n=40)
    ex = m2.tuner.explorer
    assert ex.best_point == {"unroll": 8}
    first_best = next(i for i, (p, _) in enumerate(ex.history, 1)
                      if dict(p) == {"unroll": 8})
    assert first_best <= 2, (
        f"transfer seed must reach the optimum in <=2 regens, "
        f"took {first_best}")
    s = recip.stats()
    assert s["transfer_enabled"] and s["transfer_hits"] >= 1
    assert s["transfer_adopted"] == 1
    assert s["seeded_regens_to_best"] <= 2
    assert m2.stats()["transfer_seeds"] == len(m2.transfer_seed_keys)
    # the seed passed through the gate as a CANDIDATE, not a blind swap
    assert m2.tuner.stats()["gate_checks"] >= 1


def test_transfer_off_or_warm_hit_suppresses_seeding():
    clock = VirtualClock()
    reg = TunedRegistry()
    donor = make_coordinator(clock, reg, "bench:donor")
    md = donor.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                        reference_fn=virtual_kernel(clock, 0.010))
    drive(donor, md, clock)

    # transfer disabled (default): a fingerprint miss stays cold
    clock2 = VirtualClock()
    cold = make_coordinator(clock2, reg, "bench:unseen")
    m2 = cold.register("k", make_comp(clock2), VirtualClockEvaluator(clock2),
                       reference_fn=virtual_kernel(clock2, 0.010))
    assert not m2.transfer_seed_keys
    assert cold.stats()["transfer_hits"] == 0

    # exact-fingerprint hit: the warm start wins, transfer stays quiet
    clock3 = VirtualClock()
    warm = make_coordinator(clock3, reg, "bench:donor", transfer=True)
    m3 = warm.register("k", make_comp(clock3), VirtualClockEvaluator(clock3),
                       reference_fn=virtual_kernel(clock3, 0.010))
    assert m3.warm_started and not m3.transfer_seed_keys


def test_transfer_seed_failing_gate_quarantined_and_never_reseeded():
    clock = VirtualClock()
    reg = TunedRegistry()
    donor = make_coordinator(clock, reg, "bench:donor")
    md = donor.register("k", make_comp(clock), VirtualClockEvaluator(clock),
                        reference_fn=virtual_kernel(clock, 0.010))
    drive(donor, md, clock)
    bad = {"unroll": 8}

    # device B: the transferred best FAILS the local oracle
    clock2 = VirtualClock()
    recip = make_coordinator(clock2, reg, "bench:b", transfer=True,
                             gate_mode="check")
    comp2 = make_comp(clock2)
    comp2.gate_script = lambda point: dict(point) != bad
    m2 = recip.register("k", comp2, VirtualClockEvaluator(clock2),
                        reference_fn=virtual_kernel(clock2, 0.010))
    assert m2.transfer_seed_keys
    drive(recip, m2, clock2)
    assert m2.tuner.stats()["gate_failures"] >= 1
    assert m2.tuner.explorer.is_quarantined(bad)
    assert reg.is_quarantined("k", {}, "bench:b", bad)
    assert m2.tuner.stats()["active_point"] != bad

    # device C (similar to both): the condemned point must never be
    # proposed as a transfer seed again, anywhere in the fleet
    clock3 = VirtualClock()
    third = make_coordinator(clock3, reg, "bench:c", transfer=True,
                             gate_mode="check")
    m3 = third.register("k", make_comp(clock3), VirtualClockEvaluator(clock3),
                        reference_fn=virtual_kernel(clock3, 0.010))
    injected = [m3.tuner.compilette.space.key({"unroll": 8})]
    assert all(k not in injected for k in m3.transfer_seed_keys)
    assert third.stats()["transfer_adopted"] == 0


def test_coordinator_validates_transfer_knobs():
    with pytest.raises(ValueError):
        TuningCoordinator(device="d", transfer_top_k=0)
    with pytest.raises(ValueError):
        TuningCoordinator(device="d", min_similarity=0.0)
    with pytest.raises(ValueError):
        TuningCoordinator(device="d", min_similarity=1.5)


# ------------------------------------------------------------ config knobs
def test_transfer_config_env_flags_programmatic_identical():
    base = TuningConfig(enabled=False)
    env = {
        "REPRO_TUNE_TRANSFER": "1",
        "REPRO_TUNE_TRANSFER_K": "5",          # alias for transfer_top_k
        "REPRO_TUNE_MIN_SIMILARITY": "0.6",
        "REPRO_TUNE_STRATEGY": "cost_model",
    }
    cfg_env = TuningConfig.from_env(env, base=base)
    parser = argparse.ArgumentParser()
    TuningConfig.add_flags(parser, base=base)
    cfg_flags = TuningConfig.from_flags(parser.parse_args([
        "--transfer", "--transfer-top-k", "5",
        "--min-similarity", "0.6", "--strategy", "cost_model",
    ]), base=base)
    cfg_prog = TuningConfig(enabled=False, transfer=True, transfer_top_k=5,
                            min_similarity=0.6, strategy="cost_model")
    assert cfg_env == cfg_flags == cfg_prog


def test_transfer_config_validation():
    with pytest.raises(ValueError):
        TuningConfig(transfer_top_k=0)
    with pytest.raises(ValueError):
        TuningConfig(min_similarity=0.0)
    with pytest.raises(ValueError):
        TuningConfig(min_similarity=1.01)


def test_session_wires_transfer_knobs_through():
    cfg = TuningConfig(enabled=True, transfer=True, transfer_top_k=2,
                       min_similarity=0.5)
    s = TuningSession(cfg, clock=VirtualClock(), device="bench:x")
    try:
        assert s.coordinator.transfer is True
        assert s.coordinator.transfer_top_k == 2
        assert s.coordinator.min_similarity == 0.5
        assert s.coordinator.stats()["transfer_enabled"] is True
    finally:
        s.close()
