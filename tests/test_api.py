"""PR-5 front door: repro.tune / repro.tuned / repro.TuningSession.

Round-trip suite for the session API: @tuned convergence on the
VirtualClock (no sleeps), config parity across programmatic / env /
flags construction, stats parity between the session path and the
equivalent PR-4 coordinator wiring, the close()/scope() re-entrancy
regression, the decode_attention plane kernel, the generation-cache
byte bound, and the deprecated-constructor import lint.
"""

import argparse
import importlib.util
import os
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import repro
from repro.api import TuningConfig, TuningSession
from repro.configs import REGISTRY
from repro.core import (
    Compilette,
    DEFAULT_ENTRY_BYTES,
    GeneratedKernel,
    GenerationCache,
    Param,
    RegenerationPolicy,
    TPU_V5E,
    TunedRegistry,
    VirtualClock,
    VirtualClockEvaluator,
    product_space,
)
from repro.kernels import get_catalog
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.kernel_plane import active_plane
from repro.runtime.lifecycle import TunerState

GEN_COST = 0.002


def unroll_space():
    return product_space([Param("unroll", (1, 2, 4, 8), phase=1)])


def unroll_cost(point) -> float:
    return 0.010 / point["unroll"]


def make_session(clock, **cfg_overrides) -> TuningSession:
    cfg = TuningConfig(max_overhead=1.0, invest=0.5, pump_every=1,
                       **cfg_overrides)
    return TuningSession(cfg, clock=clock, device="test:v")


def virtual_tuned(session, clock, **kwargs):
    """A @tuned virtual kernel: calls burn simulated time by point."""

    @session.tune(space=unroll_space(), jit=False, gen_cost_s=GEN_COST,
                  evaluator=VirtualClockEvaluator(
                      clock, score_fn=lambda f: unroll_cost(f.point)),
                  **kwargs)
    def k(step, *, unroll):
        clock.advance(0.010 / unroll)
        return step

    return k


# ------------------------------------------------------------- @tuned core
def test_tuned_function_converges_under_virtual_clock():
    """Acceptance: the decorator wraps a callable into a managed handle
    that reaches the known optimum deterministically — the application
    only ever calls its own function."""
    clock = VirtualClock()
    session = make_session(clock)
    k = virtual_tuned(session, clock)
    for step in range(300):
        k(step)
        if k.handle is not None and k.handle.tuner.explorer.finished:
            break
    assert k.best_point == {"unroll": 8}
    s = k.stats()
    assert s["n_explored"] == 4
    assert s["swaps"] >= 1
    # double-buffered by default: the budget paid, the hot path never did
    assert s["gen_spent_s"] > 0 and s["gen_stall_s"] == 0.0
    # the swapped-in active function serves the best variant
    assert k.active_fn is k.handle.active_fn
    session.close()


def test_tuned_stats_identical_to_pr4_wiring():
    """Acceptance: @tuned through the session produces bit-identical
    stats() accounting to the equivalent explicit PR-4 wiring
    (TuningCoordinator.register of a hand-built compilette)."""
    calls = 60

    # --- session front door ---------------------------------------------
    clock_a = VirtualClock()
    session = make_session(clock_a)
    ka = virtual_tuned(session, clock_a, name="k")
    for step in range(calls):
        ka(step)

    # --- PR-4 wiring ------------------------------------------------------
    clock_b = VirtualClock()
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=0.5),
        device="test:v", clock=clock_b, pump_every=1,
        async_generation=True, prefetch=1)

    def gen(point, **sp):
        def fn(*args):
            clock_b.advance(unroll_cost(point))
            return args[0] if args else None
        fn.point = dict(point)
        return fn

    comp = Compilette("k", unroll_space(), gen, gen_cost_s=GEN_COST)
    kb = coord.register(
        "k", comp,
        VirtualClockEvaluator(clock_b,
                              score_fn=lambda f: unroll_cost(f.point)),
        specialization={})
    for step in range(calls):
        kb(step)
        coord.maybe_pump()

    sa, sb = ka.stats(), kb.stats()
    for key in ("strategy", "kernel_calls", "regenerations", "swaps",
                "gen_spent_s", "gen_stall_s", "eval_spent_s", "gained_s",
                "reference_score_s", "active_score_s", "best_point",
                "best_score_s", "n_explored", "exploration_finished"):
        assert sa[key] == sb[key], key
    # aggregate rollups agree too (same budget arithmetic on both paths)
    agg_a, agg_b = session.stats(), coord.stats()
    for key in ("regenerations", "swaps", "gen_spent_s", "gen_stall_s",
                "eval_spent_s", "budget_spent_s", "gained_s", "busy_s"):
        assert agg_a[key] == agg_b[key], key
    session.close()
    coord.close()


def test_tuned_spec_from_buckets_handles():
    """spec_from keys separate handles per run-time-constant cell, with
    shape-like keys pow2-bucketed exactly like the kernel plane."""
    clock = VirtualClock()
    session = make_session(clock)

    @session.tune(space=unroll_space(), jit=False, gen_cost_s=GEN_COST,
                  evaluator=VirtualClockEvaluator(
                      clock, score_fn=lambda f: unroll_cost(f.point)),
                  spec_from=lambda step, seq: {"seq": seq})
    def k(step, seq, *, unroll):
        clock.advance(unroll_cost({"unroll": unroll}))
        return step

    k(0, 120)
    k(0, 150)          # same 128 bucket: shares the first handle
    assert len(k.handles()) == 1
    assert k.handle.specialization == {"seq": 128}
    k(0, 40)           # 32 bucket: its own handle
    assert len(k.handles()) == 2
    session.close()


def test_module_level_front_door():
    """repro.tune/repro.tuned/default_session round-trip."""
    clock = VirtualClock()
    session = make_session(clock)
    old = repro.set_default_session(session)
    try:
        @repro.tuned(space=unroll_space(), jit=False, gen_cost_s=GEN_COST,
                     evaluator=VirtualClockEvaluator(
                         clock, score_fn=lambda f: unroll_cost(f.point)))
        def k(step, *, unroll):
            clock.advance(unroll_cost({"unroll": unroll}))
            return step

        k(0)
        assert k.session is session
        assert repro.default_session() is session
    finally:
        repro.set_default_session(old)
        session.close()


# ----------------------------------------------------------------- configs
def test_config_from_env_flags_programmatic_identical():
    """from_flags == from_env == programmatic for the full knob set."""
    base = TuningConfig(enabled=False)
    env = {
        "REPRO_TUNE_AUTOTUNE": "1",
        "REPRO_TUNE_STRATEGY": "greedy",
        "REPRO_TUNE_MAX_OVERHEAD": "0.5",
        "REPRO_TUNE_INVEST": "0.25",
        "REPRO_TUNE_KERNEL_TUNING": "both",
        "REPRO_TUNE_STRATEGIES": "matmul=greedy,attention=random",
        "REPRO_TUNE_REGISTRY_PATH": "/tmp/api_r.json",
        "REPRO_TUNE_SLO_S": "0.05",
        "REPRO_TUNE_SLO_QUANTILE": "0.99",
        "REPRO_TUNE_SEQ_BUCKETS": "0",
        "REPRO_TUNE_ASYNC_GENERATION": "false",
        "REPRO_TUNE_PREFETCH": "3",
    }
    cfg_env = TuningConfig.from_env(env, base=base)

    parser = argparse.ArgumentParser()
    TuningConfig.add_flags(parser, base=base)
    args = parser.parse_args([
        "--autotune", "--strategy", "greedy", "--tune-overhead", "0.5",
        "--tune-invest", "0.25", "--kernel-tuning", "both",
        "--kernel-strategy", "matmul=greedy",
        "--kernel-strategy", "attention=random",
        "--registry", "/tmp/api_r.json", "--slo", "0.05",
        "--slo-quantile", "0.99", "--no-seq-buckets", "--sync-generation",
        "--prefetch", "3",
    ])
    cfg_flags = TuningConfig.from_flags(args, base=base)

    cfg_prog = TuningConfig(
        enabled=True, strategy="greedy",
        strategies={"matmul": "greedy", "attention": "random"},
        max_overhead=0.5, invest=0.25, registry_path="/tmp/api_r.json",
        slo_s=0.05, slo_quantile=0.99, seq_buckets=False,
        async_generation=False, prefetch=3, kernel_tuning="both")
    assert cfg_env == cfg_flags == cfg_prog
    # the session classmethods accept the same inputs
    s = TuningSession.from_env(env, base=base, clock=VirtualClock())
    assert s.config == cfg_prog
    s.close()


def test_from_flags_inherits_base_strategies_when_flag_absent():
    """Review fix: no --kernel-strategy on the command line must keep the
    base config's per-kernel overrides, like every other flag default."""
    base = TuningConfig(enabled=False, strategies={"matmul": "greedy"})
    parser = argparse.ArgumentParser()
    TuningConfig.add_flags(parser, base=base)
    cfg = TuningConfig.from_flags(parser.parse_args([]), base=base)
    assert cfg.strategies == {"matmul": "greedy"}
    # an explicit flag still overrides the base
    cfg2 = TuningConfig.from_flags(
        parser.parse_args(["--kernel-strategy", "attention=random"]),
        base=base)
    assert cfg2.strategies == {"attention": "random"}


def test_from_env_bad_strategies_raise_value_error():
    """Review fix: env parsing must follow the env contract (ValueError),
    not the CLI parser's SystemExit."""
    with pytest.raises(ValueError, match="kernel strategies"):
        TuningConfig.from_env(
            {"REPRO_TUNE_STRATEGIES": "matmul=not_a_strategy"})
    with pytest.raises(ValueError, match="kernel strategies"):
        TuningConfig.from_env({"REPRO_TUNE_STRATEGIES": "typo_kernel=greedy"})


def test_config_validation_fails_fast():
    with pytest.raises(ValueError, match="kernel_tuning"):
        TuningConfig(kernel_tuning="bogus")
    with pytest.raises(ValueError, match="budget_from"):
        TuningConfig(budget_from="idle")
    with pytest.raises(ValueError, match="REPRO_TUNE_TYPO"):
        TuningConfig.from_env({"REPRO_TUNE_TYPO": "1"})
    parser = argparse.ArgumentParser()
    TuningConfig.add_flags(parser)
    args = parser.parse_args(["--slo-quantile", "0.99"])
    with pytest.raises(SystemExit):   # quantile gate needs an SLO
        TuningConfig.from_flags(args)


# -------------------------------------------- config round-trip properties
# one random assignment of every flag-covered knob; slo_quantile is
# normalized onto slo_s (from_flags rejects a quantile without an SLO)
_KNOB_ASSIGNMENTS = st.tuples(
    st.booleans(),                                          # enabled
    st.sampled_from(["two_phase", "random", "greedy"]),     # strategy
    st.sampled_from(["off", "program", "kernel", "both"]),  # kernel_tuning
    st.dictionaries(                                        # strategies
        st.sampled_from(["matmul", "attention", "rmsnorm"]),
        st.sampled_from(["two_phase", "random", "greedy"]),
        min_size=0, max_size=3),
    st.floats(min_value=0.0, max_value=1.0),                # max_overhead
    st.floats(min_value=0.0, max_value=1.0),                # invest
    st.sampled_from([None, "/tmp/api_prop_reg.json"]),      # registry_path
    st.sampled_from([None, 0.01, 0.25]),                    # slo_s
    st.sampled_from([None, 0.5, 0.99]),                     # slo_quantile
    st.booleans(),                                          # seq_buckets
    st.booleans(),                                          # async_generation
    st.integers(min_value=0, max_value=4),                  # prefetch
)


@settings(max_examples=25)
@given(_KNOB_ASSIGNMENTS)
def test_config_round_trips_for_random_knobs(knobs):
    """Property: programmatic == from_env == from_flags for ANY knob
    assignment, not just the single hand-picked example above."""
    (enabled, strategy, kernel_tuning, strategies, max_overhead, invest,
     registry_path, slo_s, slo_quantile, seq_buckets, async_generation,
     prefetch) = knobs
    if slo_s is None:
        slo_quantile = None
    strategies = strategies or None       # {} and None parse identically

    base = TuningConfig(enabled=False)
    cfg_prog = TuningConfig(
        enabled=enabled, strategy=strategy, kernel_tuning=kernel_tuning,
        strategies=strategies, max_overhead=max_overhead, invest=invest,
        registry_path=registry_path, slo_s=slo_s, slo_quantile=slo_quantile,
        seq_buckets=seq_buckets, async_generation=async_generation,
        prefetch=prefetch)

    env = {
        "REPRO_TUNE_AUTOTUNE": "1" if enabled else "0",
        "REPRO_TUNE_STRATEGY": strategy,
        "REPRO_TUNE_KERNEL_TUNING": kernel_tuning,
        "REPRO_TUNE_STRATEGIES": ",".join(
            f"{k}={v}" for k, v in (strategies or {}).items()),
        "REPRO_TUNE_MAX_OVERHEAD": repr(max_overhead),
        "REPRO_TUNE_INVEST": repr(invest),
        "REPRO_TUNE_REGISTRY_PATH": registry_path or "",
        "REPRO_TUNE_SLO_S": "" if slo_s is None else repr(slo_s),
        "REPRO_TUNE_SLO_QUANTILE": (
            "" if slo_quantile is None else repr(slo_quantile)),
        "REPRO_TUNE_SEQ_BUCKETS": "1" if seq_buckets else "0",
        "REPRO_TUNE_ASYNC_GENERATION": "true" if async_generation else "no",
        "REPRO_TUNE_PREFETCH": str(prefetch),
    }
    assert TuningConfig.from_env(env, base=base) == cfg_prog

    argv = []
    if enabled:
        argv.append("--autotune")
    argv += ["--strategy", strategy, "--kernel-tuning", kernel_tuning]
    for k, v in (strategies or {}).items():
        argv += ["--kernel-strategy", f"{k}={v}"]
    argv += ["--tune-overhead", repr(max_overhead),
             "--tune-invest", repr(invest),
             "--prefetch", str(prefetch)]
    if registry_path is not None:
        argv += ["--registry", registry_path]
    if slo_s is not None:
        argv += ["--slo", repr(slo_s)]
    if slo_quantile is not None:
        argv += ["--slo-quantile", repr(slo_quantile)]
    argv.append("--seq-buckets" if seq_buckets else "--no-seq-buckets")
    if not async_generation:
        argv.append("--sync-generation")
    parser = argparse.ArgumentParser()
    TuningConfig.add_flags(parser, base=base)
    assert TuningConfig.from_flags(parser.parse_args(argv), base=base) \
        == cfg_prog


@settings(max_examples=25)
@given(st.sampled_from(["BUDGET", "OVERHEAD", "MAX_OVERHED", "STRATGY",
                        "PUMP", "CACHE", "EVICT"]),
       st.integers(min_value=0, max_value=99))
def test_config_from_env_unknown_keys_always_raise(stem, suffix):
    """Property: a typo'd REPRO_TUNE_* knob never parses silently, even
    next to perfectly valid keys."""
    env = {
        "REPRO_TUNE_STRATEGY": "greedy",          # valid
        f"REPRO_TUNE_{stem}{suffix}": "1",        # never a field name
    }
    with pytest.raises(ValueError, match="unknown tuning variable"):
        TuningConfig.from_env(env)


# -------------------------------------------------------- close/scope fix
def test_session_close_exactly_once_under_reentrant_scopes():
    """Regression (PR-5 satellite): nested scope() exits and repeated
    close() calls flush the registry and stop the async generator ONCE."""
    clock = VirtualClock()
    cfg = TuningConfig(max_overhead=1.0, invest=0.5, pump_every=1)
    session = TuningSession(cfg, clock=clock, device="test:v",
                            close_on_scope_exit=True)
    counts = {"save": 0, "shutdown": 0}
    real_save = session.coordinator.save_registry
    real_shutdown = session.coordinator.generator.shutdown

    def save_spy(path=None):
        counts["save"] += 1
        real_save(path)

    def shutdown_spy():
        counts["shutdown"] += 1
        real_shutdown()

    session.coordinator.save_registry = save_spy
    session.coordinator.generator.shutdown = shutdown_spy

    with session.scope():
        with session.scope():      # re-entrant: a request inside a scope
            pass
        assert not session.closed  # inner exit must NOT close
    assert session.closed          # outermost exit closed...
    assert counts == {"save": 1, "shutdown": 1}
    session.close()                # ...and close() is now a no-op
    session.close()
    assert counts == {"save": 1, "shutdown": 1}
    with pytest.raises(RuntimeError):
        with session.scope():
            pass


def test_session_close_flushes_registry(tmp_path):
    path = str(tmp_path / "tuned.json")
    clock = VirtualClock()
    session = make_session(clock, registry_path=path)
    k = virtual_tuned(session, clock, name="flushk")
    for step in range(300):
        k(step)
        if k.handle is not None and k.handle.tuner.explorer.finished:
            break
    session.close()
    assert os.path.exists(path)
    loaded = TunedRegistry.load(path)
    assert loaded.get("flushk", {}, session.coordinator.device) == \
        {"unroll": 8}


# ------------------------------------------------------- deprecation shims
def test_legacy_config_fields_alias_into_tuning():
    from repro.runtime.serve_loop import ServeConfig
    from repro.runtime.train_loop import TrainLoopConfig

    serve = ServeConfig(autotune=True, tune_strategy="greedy",
                        kernel_strategies={"matmul": "greedy"},
                        tune_max_overhead=0.3)
    assert serve.tuning.enabled and serve.autotune
    assert serve.tuning.strategy == "greedy" == serve.tune_strategy
    assert serve.tuning.strategies == {"matmul": "greedy"}
    assert serve.tuning.max_overhead == 0.3
    serve.tune_slo_s = 0.05            # property writes reach the config
    assert serve.tuning.slo_s == 0.05
    # serving-grade defaults survive the collapse
    assert serve.tuning.budget_from == "busy" and serve.tuning.charge_init
    with pytest.raises(TypeError, match="unexpected"):
        ServeConfig(bogus_knob=1)

    loop = TrainLoopConfig(autotune=True, tune_async=False,
                           tune_prefetch=2)
    assert loop.tuning.enabled
    assert loop.tuning.async_generation is False
    assert loop.tuning.prefetch == 2 == loop.tune_prefetch
    assert loop.tuning.budget_from == "wall"
    assert loop.tuning.seq_buckets is False   # train-grade defaults
    with pytest.raises(TypeError, match="unexpected"):
        TrainLoopConfig(bogus_knob=1)


def test_make_serve_coordinator_shim_warns_and_matches_session_path():
    """The deprecated constructor warns, and a request through it rolls
    up stats identically in structure to the session front door."""
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = REGISTRY["deepseek-7b"].reduced()
    serve = ServeConfig(max_new_tokens=4, autotune=True,
                        tune_max_overhead=0.5, kernel_tuning="both",
                        kernel_strategies={"attention": "greedy"},
                        seq_buckets=True, idle_evict_s=None)
    with pytest.warns(DeprecationWarning, match="TuningSession"):
        coordinator = make_serve_coordinator(serve)
    # the shim's coordinator is itself session-owned (one front door)
    assert isinstance(getattr(coordinator, "_session", None), TuningSession)

    def batch():
        return {"tokens": jnp.ones((2, 24), jnp.int32)}

    out_shim = generate(cfg, batch(), serve, coordinator=coordinator)
    session = TuningSession(serve.tuning)
    try:
        out_sess = generate(cfg, batch(), serve, session=session)
        for out in (out_shim, out_sess):
            a = out["autotune"]
            # identical rollup arithmetic: per-kernel sums + tombstone
            # reconcile exactly with the aggregate on both paths
            for f in ("gen_spent_s", "gen_stall_s", "eval_spent_s"):
                rollup = (sum(k[f] for k in a["kernels"].values())
                          + a["retired_accounts"][f])
                assert rollup == pytest.approx(a[f]), f
        a, b = out_shim["autotune"], out_sess["autotune"]
        assert set(a["kernels"]) == set(b["kernels"])
        for name in a["kernels"]:
            assert (a["kernels"][name]["strategy"]
                    == b["kernels"][name]["strategy"]), name
        # hierarchical registration includes the PR-5 decode kernel
        assert "decode_attention" in a["kernels"]
    finally:
        session.close()
        TuningSession.adopt(coordinator).close()


# ------------------------------------------------------- decode_attention
def test_decode_attention_kernel_matches_oracle():
    """Real backend: any k_chunk variant computes the same attention as
    the single-chunk oracle, and the spec round-trips from live args."""
    from repro.kernels.attention.ops import decode_attention

    spec = {"B": 2, "S": 64, "H": 4, "Hk": 2, "Dh": 16,
            "dtype": "float32"}
    comp = get_catalog().compilette("decode_attention", spec, aot=False)
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (2, 1, 4, 16), jnp.float32)
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16),
                          jnp.float32)
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16),
                          jnp.float32)
    length = jnp.int32(40)
    oracle = decode_attention(q, k, v, length=length, k_chunk=64)
    for point in comp.space.iter_valid():
        kern = comp.generate(point)
        np.testing.assert_allclose(
            np.asarray(kern.fn(q, k, v, length)), np.asarray(oracle),
            rtol=1e-5, atol=1e-5)
    extracted = get_catalog().spec_of("decode_attention", q, k, v, length)
    for kk, vv in spec.items():
        assert extracted[kk] == vv, kk


def test_decode_attention_tunes_per_cache_length_bucket():
    """Satellite acceptance: attach_kernels registers the decode kernel
    keyed per cache-length bucket; each bucket converges to its own
    cost-model optimum and the decode path adopts it at trace time."""
    from repro.models.layers import plane_decode_chunk

    model_cfg = REGISTRY["deepseek-7b"].reduced()
    clock = VirtualClock()
    cfg = TuningConfig(max_overhead=1.0, invest=0.5, pump_every=1)
    session = TuningSession(
        cfg, clock=clock, device="test:v", virtual=(clock, TPU_V5E),
        gen_cost_s=GEN_COST,
        evaluator_factory=lambda c: VirtualClockEvaluator(clock))
    plane = session.attach_kernels(model_cfg, batch=2, seq=24, max_len=300)
    handles = plane.handles("decode_attention")
    assert len(handles) == 1
    (h,) = handles
    assert h.specialization["S"] == 256      # pow2 bucket of 300
    for step in range(2000):
        h(step)
        clock.advance(0.001)   # application work accrues wall budget
        session.pump()
        if h.tuner.explorer.finished:
            break
    assert h.tuner.explorer.finished
    comp = h.tuner.compilette
    expected = min(
        comp.space.iter_valid(),
        key=lambda p: comp.simulate(p, TPU_V5E))
    assert h.tuner.explorer.best_point == expected
    # trace-time adoption: inside the session scope the decode path reads
    # the tuned chunk; outside (or with a program tuner owning the knob)
    # the config default stands
    assert plane_decode_chunk(model_cfg) == model_cfg.decode_k_chunk
    with session.scope():
        assert active_plane() is plane
        assert plane_decode_chunk(model_cfg) == expected["k_chunk"]
    plane.adopt_points = False
    with session.scope():
        assert plane_decode_chunk(model_cfg) == model_cfg.decode_k_chunk
    plane.adopt_points = True
    # a second cache-length cell gets its own handle (own bucket key)
    session.attach_kernels(model_cfg, batch=2, seq=24, max_len=1000)
    assert len(plane.handles("decode_attention")) == 2
    assert {m.specialization["S"]
            for m in plane.handles("decode_attention")} == {256, 1024}


def test_decode_attention_bucket_registry_keys_never_collide():
    """Regression: every cache-length bucket persists under its OWN
    registry key — no max_len pair may alias one entry — and a second
    session warm-starts each bucket from its own best independently."""
    model_cfg = REGISTRY["deepseek-7b"].reduced()
    registry = TunedRegistry()
    max_lens = (300, 1000, 5000)          # buckets 256 / 1024 / 4096

    def run_session():
        clock = VirtualClock()
        cfg = TuningConfig(max_overhead=1.0, invest=0.5, pump_every=1)
        session = TuningSession(
            cfg, clock=clock, device="test:v", registry=registry,
            virtual=(clock, TPU_V5E), gen_cost_s=GEN_COST,
            evaluator_factory=lambda c: VirtualClockEvaluator(clock))
        plane = None
        for max_len in max_lens:
            plane = session.attach_kernels(
                model_cfg, batch=2, seq=24, max_len=max_len)
        handles = plane.handles("decode_attention")
        for step in range(4000):
            for h in handles:
                h(step)
            clock.advance(0.001)
            session.pump()
            if all(h.tuner.explorer.finished for h in handles):
                break
        by_bucket = {h.specialization["S"]: h for h in handles}
        session.close()                   # flushes bests to the registry
        return by_bucket

    cold = run_session()
    assert sorted(cold) == [256, 1024, 4096]

    # distinct buckets -> distinct registry keys (the collision would
    # silently share one tuned point across every cache length); the
    # device part carries the kernel's source hash (satellite: editing
    # ops.py invalidates persisted bests)
    keys = {S: TunedRegistry.key("decode_attention",
                                 dict(h.specialization), h.registry_device)
            for S, h in cold.items()}
    assert len(set(keys.values())) == len(max_lens)
    assert all(":src-" in h.registry_device for h in cold.values())
    # and each key resolves to ITS bucket's best, not a shared one
    for S, h in cold.items():
        assert h.tuner.explorer.finished
        entry = registry.get("decode_attention",
                             dict(h.specialization), h.registry_device)
        assert entry == h.tuner.explorer.best_point, S

    warm = run_session()
    for S, h in warm.items():
        assert h.warm_started, S
        assert h.tuner.explorer.best_point == cold[S].tuner.explorer.best_point


# ------------------------------------------------------- cache byte bound
def _entry(cost: float, size: int | None = None) -> GeneratedKernel:
    meta = {"compiled_in_s": cost}
    if size is not None:
        meta["size_bytes"] = size
    return GeneratedKernel(point={}, fn=lambda *a: None,
                           generation_time_s=cost, specialization={},
                           meta=meta)


def test_generation_cache_byte_bound_evicts_cheapest():
    """Satellite: max_bytes bounds estimated executable residency; the
    victim is still the cheapest-to-regenerate entry in the LRU window."""
    cache = GenerationCache(max_bytes=3000)
    cache.put(("a",), _entry(0.001, 1000))   # cheapest to regenerate
    cache.put(("b",), _entry(0.500, 1000))   # expensive
    cache.put(("c",), _entry(0.002, 1000))
    assert cache.stats()["bytes"] == 3000 and cache.evictions == 0
    cache.put(("d",), _entry(0.100, 1000))   # overflow by bytes
    assert ("a",) not in cache               # cost-weighted victim
    assert ("b",) in cache and ("c",) in cache and ("d",) in cache
    assert cache.stats()["bytes"] == 3000
    assert cache.evictions == 1
    # replacing a key must not double-charge its bytes
    cache.put(("d",), _entry(0.100, 500))
    assert cache.stats()["bytes"] == 2500
    # a lone entry larger than the bound stays (newest never self-evicts)
    small = GenerationCache(max_bytes=10)
    small.put(("x",), _entry(0.1, 1000))
    assert ("x",) in small and small.stats()["bytes"] == 1000
    # entries without a recorded size charge the default estimate
    dflt = GenerationCache(max_bytes=DEFAULT_ENTRY_BYTES)
    dflt.put(("y",), _entry(0.1))
    assert dflt.stats()["bytes"] == DEFAULT_ENTRY_BYTES
    # the count bound keeps working beside the byte bound
    both = GenerationCache(max_entries=2, max_bytes=10**9)
    for i, name in enumerate(("p", "q", "r")):
        both.put((name,), _entry(0.1 * (i + 1), 10))
    assert len(both) == 2 and both.evictions == 1


def test_memory_pressure_shrinks_effective_byte_bound():
    """Satellite: the byte bound follows live device headroom — as free
    device memory shrinks, eviction tightens below the static max_bytes;
    with plenty free, the static bound rules unchanged."""
    free = {"bytes": 10**9}
    cache = GenerationCache(max_bytes=3000,
                            free_memory_fn=lambda: free["bytes"],
                            memory_headroom_frac=0.5)
    for name in ("a", "b", "c"):
        cache.put((name,), _entry(0.1, 1000))
    # plenty free: static bound rules, nothing evicted
    assert len(cache) == 3 and cache.pressure_evictions == 0
    assert cache.stats()["effective_max_bytes"] == 3000
    # device fills up: headroom says only 2000 bytes of cache allowed
    free["bytes"] = 4000
    cache.put(("d",), _entry(0.1, 1000))
    assert cache.stats()["effective_max_bytes"] == 2000
    assert cache.stats()["bytes"] <= 2000
    # evictions forced by PRESSURE (not the static bound) are counted
    assert cache.pressure_evictions > 0
    assert cache.evictions >= cache.pressure_evictions


def test_memory_pressure_static_fallback_when_unreadable():
    """No readable device stats (CPU hosts: free_memory_fn returns None)
    -> the static max_bytes bound applies exactly as before."""
    cache = GenerationCache(max_bytes=2000, free_memory_fn=lambda: None)
    for name in ("a", "b", "c"):
        cache.put((name,), _entry(0.1, 1000))
    assert cache.stats()["effective_max_bytes"] == 2000
    assert len(cache) == 2 and cache.pressure_evictions == 0
    # and with NO static bound either, pressure alone can still bound
    unbounded = GenerationCache(free_memory_fn=lambda: 2000,
                                memory_headroom_frac=0.5)
    for name in ("x", "y", "z"):
        unbounded.put((name,), _entry(0.1, 500))
    assert unbounded.stats()["effective_max_bytes"] == 1000
    assert unbounded.stats()["bytes"] <= 1000
    assert unbounded.pressure_evictions > 0


def test_device_free_memory_bytes_is_none_or_positive():
    """The jax probe degrades to None (static fallback) off-accelerator."""
    from repro.core import device_free_memory_bytes

    free = device_free_memory_bytes()
    assert free is None or free > 0


def test_aot_compile_records_size_estimate():
    """AOT-compiled kernel variants record their executable size for the
    byte-bounded cache (None is legal where the backend reports none)."""
    comp = get_catalog().compilette(
        "rmsnorm", {"N": 64, "d": 32, "dtype": "float32"}, aot=True)
    point = next(iter(comp.space.iter_valid()))
    kern = comp.generate(point)
    assert "size_bytes" in kern.meta
    size = kern.meta["size_bytes"]
    assert size is None or size > 0


# ------------------------------------------------------------------- lint
def test_no_deprecated_constructor_imports():
    """CI satellite, enforced in tier-1 too: src/repro/runtime and
    src/repro/launch must not import the deprecated constructors."""
    tool = (pathlib.Path(__file__).resolve().parent.parent
            / "tools" / "check_deprecated_imports.py")
    spec = importlib.util.spec_from_file_location("check_deprecated", tool)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert mod.violations() == []


# ------------------------------------------------------------ plane prune
def test_tuned_function_releases_live_args_on_convergence():
    """Converged handles must not keep pinning the last call's arrays."""
    clock = VirtualClock()
    session = make_session(clock)
    k = virtual_tuned(session, clock)
    for step in range(300):
        k(step)
        if k.handle is not None and k.handle.tuner.explorer.finished:
            break
    session.sweep()
    assert k.handle.state is TunerState.CONVERGED
    k(0)   # a call after convergence serves the best fn without pinning
    assert k._live_args == {}
    session.close()
