"""Trip-count-aware HLO analyzer: the roofline numbers ride on this."""

import jax
import jax.numpy as jnp
import pytest

from repro.distributed.hlo_analysis import analyze_hlo


def _hlo(fn, *args):
    return jax.jit(fn).lower(*args).compile().as_text()


def test_flat_matmul_flops_exact():
    M, K, N = 128, 256, 64
    t = analyze_hlo(_hlo(lambda a, b: a @ b,
                         jnp.ones((M, K)), jnp.ones((K, N))))
    assert t.flops == 2 * M * N * K


def test_scan_multiplies_by_trip_count():
    M, K, n = 64, 128, 10

    def f(x, ws):
        return jax.lax.scan(lambda c, w: (jnp.tanh(c @ w), None), x, ws)[0]

    t = analyze_hlo(_hlo(f, jnp.ones((M, K)), jnp.ones((n, K, K))))
    assert t.flops == pytest.approx(n * 2 * M * K * K)


def test_nested_scans_multiply():
    M, K = 64, 128

    def f(x, ws):
        def outer(c, blk):
            return jax.lax.scan(lambda c2, w: (c2 @ w, None), c, blk)[0], None
        return jax.lax.scan(outer, x, ws)[0]

    t = analyze_hlo(_hlo(f, jnp.ones((M, K)), jnp.ones((4, 5, K, K))))
    assert t.flops == pytest.approx(20 * 2 * M * K * K)


def test_remat_recompute_counted():
    M, K = 64, 128
    w1 = jnp.ones((K, K)) * 0.01
    w2 = jnp.ones((K, 1)) * 0.01

    def loss(x):
        h = jax.checkpoint(lambda x: jnp.tanh(x @ w1))(x)
        return jnp.sum(h @ w2)

    plain = analyze_hlo(_hlo(lambda x: jnp.sum(jnp.tanh(x @ w1) @ w2),
                             jnp.ones((M, K))))
    grad = analyze_hlo(_hlo(jax.grad(lambda x: loss(x)), jnp.ones((M, K))))
    # fwd + bwd at least doubles the dot flops (XLA may DCE the remat of a
    # single cheap op, so the recompute itself is not asserted here)
    assert grad.flops >= 2 * plain.flops - 1


def test_bytes_follow_xla_convention_on_matmul():
    M, K, N = 128, 256, 64
    t = analyze_hlo(_hlo(lambda a, b: a @ b,
                         jnp.ones((M, K)), jnp.ones((K, N))))
    expected = (M * K + K * N + 2 * M * N) * 4
    assert t.bytes == pytest.approx(expected, rel=0.3)


def test_elementwise_chains_are_fused_free():
    """A long elementwise chain should add ~no HBM traffic vs one op."""
    x = jnp.ones((256, 256))

    def chain(x):
        for _ in range(10):
            x = jnp.tanh(x) * 1.01 + 0.001
        return x

    t1 = analyze_hlo(_hlo(lambda x: jnp.tanh(x), x))
    t10 = analyze_hlo(_hlo(chain, x))
    assert t10.bytes <= t1.bytes * 6  # far less than 10 separate rw passes


def test_collective_bytes_under_spmd():
    import subprocess, sys, os, textwrap
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))), "src")
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp
        from jax.sharding import PartitionSpec as P, NamedSharding
        from repro.distributed.hlo_analysis import analyze_hlo
        from repro.launch.mesh import _mk, set_mesh
        mesh = _mk((8,), ('model',))
        w_s = NamedSharding(mesh, P(None, 'model'))
        x_s = NamedSharding(mesh, P())
        def f(x, w):
            return jnp.sum(x @ w, axis=-1)   # contraction forces a psum-ish
        with set_mesh(mesh):
            txt = jax.jit(f, in_shardings=(x_s, w_s)).lower(
                jax.ShapeDtypeStruct((64, 128), jnp.float32),
                jax.ShapeDtypeStruct((128, 512), jnp.float32),
            ).compile().as_text()
        t = analyze_hlo(txt)
        assert t.coll_bytes >= 0
        print('COLL', t.coll_bytes)
    """)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "COLL" in out.stdout
