"""Per-kernel allclose sweeps against the pure-jnp oracles.

Each Pallas kernel (interpret mode) and each jnp program variant is swept
over shapes/dtypes and random tuning points drawn from its own space.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.attention.ops import (
    attention_ref, decode_attention, flash_attention_jnp,
    flash_attention_pallas)
from repro.kernels.euclid.ops import (
    euclid_pallas, euclid_ref, generate_jnp_variant as euclid_variant,
    make_space as euclid_space, reference_simd, reference_sisd)
from repro.kernels.lintra.ops import (
    generate_jnp_variant as lintra_variant, lintra_pallas, lintra_ref)
from repro.kernels.matmul.ops import matmul_ref, make_space, tuned_matmul

KEY = jax.random.PRNGKey(0)


def rand(shape, dtype=jnp.float32, key=KEY):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


# ------------------------------------------------------------------ matmul
@pytest.mark.parametrize("shape", [(128, 128, 128), (192, 320, 256),
                                   (256, 128, 448), (64, 512, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_shapes_dtypes(shape, dtype):
    M, K, N = shape
    a = rand((M, K), dtype)
    b = rand((K, N), dtype, jax.random.PRNGKey(1))
    ref = matmul_ref(a, b)
    for pt in [
        dict(block_m=64, block_n=128, block_k=128, unroll=1, order="mn",
             scratch=1, lookahead=0),
        dict(block_m=128, block_n=128, block_k=128, unroll=2, order="nm",
             scratch=0, lookahead=1),
        dict(block_m=64, block_n=128, block_k=256, unroll=4, order="mn",
             scratch=1, lookahead=2),
    ]:
        out = tuned_matmul(a, b, point=pt)
        tol = 1e-4 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(out, ref, rtol=tol, atol=tol)


@settings(max_examples=12, deadline=None)
@given(idx=st.integers(0, 10**6))
def test_matmul_random_valid_points(idx):
    M, K, N = 192, 256, 256
    space = make_space(M, N, K)
    pts = list(space.iter_valid())
    pt = pts[idx % len(pts)]
    a = rand((M, K))
    b = rand((K, N), key=jax.random.PRNGKey(1))
    np.testing.assert_allclose(
        tuned_matmul(a, b, point=pt), matmul_ref(a, b), rtol=1e-4, atol=1e-4)


# ------------------------------------------------------------------ euclid
@pytest.mark.parametrize("n,m,d", [(128, 32, 32), (250, 90, 70), (64, 64, 128)])
def test_euclid_pallas_and_jnp(n, m, d):
    x = rand((n, d))
    c = rand((m, d), key=jax.random.PRNGKey(2))
    ref = euclid_ref(x, c)
    for pt in [
        dict(block_n=64, block_m=32, block_d=32, unroll=1, vectorize=1,
             order="nm", scratch=1, lookahead=0),
        dict(block_n=128, block_m=32, block_d=16, unroll=2, vectorize=0,
             order="mn", scratch=0, lookahead=1),
    ]:
        if pt["block_d"] > d:
            continue
        np.testing.assert_allclose(
            euclid_pallas(x, c, pt), ref, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(
            euclid_variant(pt, dim=d)(x, c), ref, rtol=1e-3, atol=1e-3)


@settings(max_examples=15, deadline=None)
@given(idx=st.integers(0, 10**6))
def test_euclid_random_points_vs_oracle(idx):
    n, m, d = 256, 64, 64
    space = euclid_space(n, m, d)
    pts = list(space.iter_valid())
    pt = pts[idx % len(pts)]
    x = rand((n, d))
    c = rand((m, d), key=jax.random.PRNGKey(2))
    np.testing.assert_allclose(
        euclid_variant(pt, dim=d)(x, c), euclid_ref(x, c),
        rtol=1e-3, atol=1e-3)


def test_euclid_references_agree():
    x = rand((128, 96))
    c = rand((48, 96), key=jax.random.PRNGKey(3))
    np.testing.assert_allclose(
        reference_sisd(96)(x, c), reference_simd(96)(x, c),
        rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ lintra
@pytest.mark.parametrize("h,w,bands", [(64, 100, 3), (120, 200, 3), (33, 50, 4)])
def test_lintra_variants(h, w, bands):
    img = rand((h, w, bands))
    a = jnp.arange(1.0, bands + 1)
    b = jnp.linspace(-1, 1, bands)
    ref = lintra_ref(img, a, b)
    fold = img.reshape(h, w * bands)
    ab = jnp.stack([jnp.tile(a, w), jnp.tile(b, w)])
    for pt in [
        dict(block_h=8, block_w=128, unroll=1, vectorize=1, order="hw",
             scratch=1, lookahead=0),
        dict(block_h=32, block_w=256, unroll=2, vectorize=0, order="wh",
             scratch=0, lookahead=2),
    ]:
        if pt["block_h"] > h:
            continue
        np.testing.assert_allclose(
            lintra_pallas(fold, ab, pt).reshape(h, w, bands), ref,
            rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(
            lintra_variant(pt, bands=bands, width=w)(img, a, b), ref,
            rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- attention
@pytest.mark.parametrize("T,H,Hk,Dh", [(128, 4, 4, 32), (192, 8, 2, 32),
                                       (96, 6, 3, 64)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_vs_ref(T, H, Hk, Dh, causal):
    B = 2
    q = rand((B, T, H, Dh))
    k = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(4))
    v = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(5))
    ref = attention_ref(q, k, v, causal=causal)
    out = flash_attention_jnp(q, k, v, causal=causal, q_chunk=64, k_chunk=48)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)
    outp = flash_attention_pallas(
        q, k, v, dict(block_q=64, block_kv=64), causal=causal)
    np.testing.assert_allclose(outp, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_window_and_offset():
    B, T, H, Hk, Dh = 1, 160, 4, 2, 32
    q = rand((B, 32, H, Dh))
    k = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(4))
    v = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(5))
    ref = attention_ref(q, k, v, causal=True, q_offset=128, window=64)
    out = flash_attention_jnp(q, k, v, causal=True, q_offset=128, window=64,
                              q_chunk=16, k_chunk=32)
    np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_flash_attention_grad_matches_ref():
    B, T, H, Hk, Dh = 2, 96, 4, 2, 16
    q = rand((B, T, H, Dh))
    k = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(4))
    v = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(5))
    g1 = jax.grad(lambda q: flash_attention_jnp(
        q, k, v, causal=True, q_chunk=32, k_chunk=32).sum())(q)
    g2 = jax.grad(lambda q: attention_ref(q, k, v, causal=True).sum())(q)
    np.testing.assert_allclose(g1, g2, rtol=5e-3, atol=5e-3)


def test_decode_attention_chunked_vs_ref():
    B, T, H, Hk, Dh = 2, 160, 8, 2, 32
    q = rand((B, 1, H, Dh))
    k = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(4))
    v = rand((B, T, Hk, Dh), key=jax.random.PRNGKey(5))
    for length in (64, 100, 160):
        ref = attention_ref(q, k[:, :length], v[:, :length], causal=False)
        out = decode_attention(q, k, v, length=length, k_chunk=32)
        np.testing.assert_allclose(out, ref, rtol=2e-3, atol=2e-3)


def test_attention_bf16_stability():
    B, T, H, Hk, Dh = 2, 128, 4, 2, 32
    q = rand((B, T, H, Dh), jnp.bfloat16)
    k = rand((B, T, Hk, Dh), jnp.bfloat16, jax.random.PRNGKey(4))
    v = rand((B, T, Hk, Dh), jnp.bfloat16, jax.random.PRNGKey(5))
    out = flash_attention_jnp(q, k, v, causal=True, q_chunk=64, k_chunk=64)
    assert out.dtype == jnp.bfloat16
    ref = attention_ref(q.astype(jnp.float32), k.astype(jnp.float32),
                        v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(out.astype(jnp.float32), ref,
                               rtol=5e-2, atol=5e-2)


# ------------------------------------------------------------------ rmsnorm
@pytest.mark.parametrize("n,d", [(64, 128), (100, 256), (256, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel_vs_ref(n, d, dtype):
    from repro.kernels.rmsnorm.ops import rmsnorm_pallas, rmsnorm_ref
    x = rand((n, d), dtype)
    w = rand((d,), jnp.float32, jax.random.PRNGKey(9))
    ref = rmsnorm_ref(x, w)
    for rows in (8, 32, 128):
        out = rmsnorm_pallas(x, w, dict(block_rows=rows))
        tol = 1e-5 if dtype == jnp.float32 else 2e-2
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32),
            rtol=tol, atol=tol)


def test_rmsnorm_profiles_prefer_larger_rows_when_lean():
    from repro.core import TwoPhaseExplorer
    from repro.core.profiles import SI_L1, TI_F3
    from repro.kernels.rmsnorm.ops import make_rmsnorm_compilette
    comp = make_rmsnorm_compilette(4096, 4096)
    for prof in (SI_L1, TI_F3):
        ex = TwoPhaseExplorer(comp.space)
        pt, sc = ex.run_to_completion(lambda p: comp.simulate(p, prof))
        assert pt is not None and sc < float("inf")
