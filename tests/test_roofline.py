"""Roofline derivation: HLO collective parsing + term math."""

import pytest

from repro.distributed.roofline import (
    Roofline, collective_stats, roofline_from)

HLO = """
HloModule test
%all-reduce = f32[128,64]{1,0} all-reduce(%dot), channel_id=1, replica_groups={{0,4},{1,5},{2,6},{3,7}}, use_global_device_ids=true
%ag = bf16[256,64]{1,0} all-gather(%p0), channel_id=2, replica_groups=[2,16]<=[32], dimensions={0}
%rs = f32[16,64]{1,0} reduce-scatter(%p1), channel_id=3, replica_groups=[1,8]<=[8], dimensions={0}
%a2a = bf16[64,64]{1,0} all-to-all(%p2), channel_id=4, replica_groups=[4,4]<=[16]
%cp = f32[32]{0} collective-permute(%p3), channel_id=5, source_target_pairs={{0,1}}
%ars = f32[8,8]{1,0} all-reduce-start(%x), channel_id=6, replica_groups={{0,1}}
%ard = f32[8,8]{1,0} all-reduce-done(%ars)
"""


def test_collective_parse_ops_and_groups():
    st = collective_stats(HLO)
    assert st.n_ops["all-reduce"] == 2          # plain + -start (not -done)
    assert st.n_ops["all-gather"] == 1
    assert st.n_ops["reduce-scatter"] == 1
    assert st.n_ops["all-to-all"] == 1
    assert st.n_ops["collective-permute"] == 1


def test_collective_traffic_model():
    st = collective_stats(HLO)
    # all-reduce: 2 * out * (g-1)/g with g=2 → 128*64*4 = 32768 bytes out
    ar = 2 * (128 * 64 * 4) * 0.5 + 2 * (8 * 8 * 4) * 0.5
    assert st.per_op_bytes["all-reduce"] == pytest.approx(ar)
    # all-gather: out*(g-1)/g, g=16, bf16
    ag = (256 * 64 * 2) * 15 / 16
    assert st.per_op_bytes["all-gather"] == pytest.approx(ag)
    # reduce-scatter: out*(g-1), g=8
    rs = (16 * 64 * 4) * 7
    assert st.per_op_bytes["reduce-scatter"] == pytest.approx(rs)


def test_roofline_terms_and_bound():
    cost = {"flops": 197e12, "bytes accessed": 819e9 * 2}  # 1 s vs 2 s
    roof = roofline_from(cost, HLO, n_chips=256, model_flops=197e12 * 256 * 0.5)
    assert roof.compute_s == pytest.approx(1.0)
    assert roof.memory_s == pytest.approx(2.0)
    assert roof.bound == "memory"
    assert roof.useful_ratio == pytest.approx(0.5)
    assert roof.roofline_frac == pytest.approx(0.25)  # 0.5s ideal / 2s


def test_model_flops_formulas():
    from repro.configs import REGISTRY
    from repro.configs.base import TRAIN_4K, DECODE_32K
    from repro.launch.shapes import model_flops

    cfg = REGISTRY["deepseek-7b"]
    mf = model_flops(cfg, TRAIN_4K)
    base = 6.0 * cfg.n_params() * TRAIN_4K.global_batch * TRAIN_4K.seq_len
    assert mf > base  # attention term adds on top
    assert mf < base * 1.5

    moe = REGISTRY["qwen3-moe-30b-a3b"]
    assert moe.n_active_params() < 0.2 * moe.n_params()  # 3B active of 30B

    dec = model_flops(cfg, DECODE_32K)
    assert dec < mf / 1000  # one token vs a full batch of sequences


def test_skip_matrix():
    from repro.configs import REGISTRY
    from repro.configs.base import LONG_500K, TRAIN_4K
    from repro.launch.shapes import skip_reason

    skipped = [a for a in REGISTRY
               if skip_reason(REGISTRY[a], LONG_500K) is not None]
    assert sorted(skipped) == sorted([
        "llama4-scout-17b-a16e", "qwen3-moe-30b-a3b", "command-r-35b",
        "deepseek-coder-33b", "qwen2.5-32b", "deepseek-7b", "qwen2-vl-7b",
        "whisper-tiny"])
    assert all(skip_reason(REGISTRY[a], TRAIN_4K) is None for a in REGISTRY)
