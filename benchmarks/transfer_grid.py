"""Transfer grid: cross-device warm starts on a synthetic device grid.

Donor devices (the 11 simulated cores of Fig. 5) tune the euclid kernel
to convergence into one shared registry, each entry stamped with its
:class:`~repro.core.transfer.DeviceTraits`. A grid of UNSEEN profiles —
perturbed FLOPs / bandwidth / VMEM variants of the donors, never tuned
before — then comes up twice on the same registry snapshot:

  * cold  (``transfer=False``): exact-fingerprint miss, explores from
    scratch — the pre-transfer-plane behaviour;
  * seeded (``transfer=True``): the nearest-fingerprint lookup ranks
    donor bests by trait similarity and injects the top-k as CANDIDATE
    seeds through the normal generate/evaluate/gate path.

CI smoke assertions (all deterministic on the VirtualClock):

  * seeded tuning reaches the known best in <= 2 regenerations on >= 80%
    of unseen profiles; cold needs >= 4 on every one;
  * seeded virtual time-to-best beats cold by >= 2x (geometric mean);
  * tuning overhead stays <= 5% of serving time in every budgeted run;
  * every seeded run flows its seeds through the gate (checks > 0 — a
    transfer seed is never a blind incumbent);
  * two same-seed grid runs are byte-identical as JSON.

    PYTHONPATH=src python benchmarks/transfer_grid.py [--quick] [--seed N]
"""

import argparse
import json
import os
import statistics
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table  # noqa: E402

from repro.api import TuningConfig, TuningSession  # noqa: E402
from repro.core import (  # noqa: E402
    TunedRegistry,
    VirtualClock,
    VirtualClockEvaluator,
    scaled_profile,
    virtual_compilette,
)
from repro.core.profiles import (  # noqa: E402
    ALL_PROFILES, DI_F2, DI_L2, SI_L1, TI_F3, TI_L2, TI_L3)
from repro.kernels.euclid.ops import make_euclid_compilette  # noqa: E402

N, M, D = 4096, 128, 64
STEP_BUSY_S = 0.010     # serving step each run's budget accrues from
COST_CLAMP_S = 0.001    # vmem-overflow points simulate at inf: clamp to a
                        # finite, still ~70x-worse-than-best cost so the
                        # virtual clock stays arithmetic and the budget
                        # can pay to measure (and reject) an invalid point
MAX_STEPS = 40000       # drive-loop backstop

GATE_SEEDED_REGENS = 2      # seeded runs must hit best within this many
GATE_COLD_REGENS = 4        # cold runs must need at least this many
GATE_MIN_FRAC = 0.8         # fraction of unseen profiles seeded must win
MIN_TTB_SPEEDUP = 2.0       # geo-mean cold/seeded time-to-best
MAX_OVERHEAD_PCT = 5.0

QUICK_DONORS = (SI_L1, DI_L2, DI_F2, TI_L2, TI_L3, TI_F3)

# (base profile, scale factors): mild perturbations — a new silicon rev
# or bin of a known core, the case transfer is for. VMEM only grows:
# shrinking it can move the optimum off the donor's (that harder case is
# exactly what the similarity floor + gate path exist to survive, but it
# is not the smoke gate).
UNSEEN_SPECS = (
    (TI_L3, {"flops": 1.25}),
    (TI_L3, {"bandwidth": 0.8}),
    (TI_L2, {"flops": 0.85, "bandwidth": 1.15}),
    (TI_F3, {"flops": 1.2}),
    (DI_L2, {"flops": 1.15}),
    (DI_F2, {"bandwidth": 1.2}),
    (TI_F3, {"bandwidth": 0.85, "vmem": 1.5}),
    (SI_L1, {"flops": 1.25, "vmem": 1.5}),
)
QUICK_UNSEEN = UNSEEN_SPECS[:6]


def unseen_profiles(quick):
    out = []
    for base, factors in (QUICK_UNSEEN if quick else UNSEEN_SPECS):
        tag = ",".join(f"{k[0]}{v:g}" for k, v in sorted(factors.items()))
        out.append((base.name,
                    scaled_profile(base, f"{base.name}~{tag}", **factors)))
    return out


def _session(clock, device, registry, *, transfer, budgeted):
    """One tuning session through the public front door.

    Donor (warm-up) sessions run unbudgeted so the registry fills fast;
    the measured unseen runs carry the production 4%-of-busy budget the
    overhead gate checks.
    """
    if budgeted:
        cfg = TuningConfig(max_overhead=0.04, invest=0.0,
                           budget_from="busy", pump_every=1,
                           gate_mode="check", transfer=transfer)
    else:
        cfg = TuningConfig(max_overhead=1.0, invest=1.0, pump_every=1,
                           gate_mode="check", transfer=transfer)
    return TuningSession(cfg, clock=clock, device=device, registry=registry)


def run_one(prof, device, registry, *, transfer, budgeted=True):
    """Tune euclid on ``prof`` to exploration exhaustion; full telemetry."""
    comp = make_euclid_compilette(N, M, D)
    clock = VirtualClock()
    session = _session(clock, device, registry,
                       transfer=transfer, budgeted=budgeted)
    vcomp = virtual_compilette(
        clock, "euclid", comp.space,
        lambda p: min(comp.simulate(p, prof), COST_CLAMP_S))
    # virtual marker: traits + candidate-cost estimates derive from the
    # exact profile being simulated
    vcomp.virtual = (clock, prof)
    vcomp.cost_model = comp.cost_model
    ref_s = min(comp.simulate(comp.space.default_point(), prof),
                COST_CLAMP_S)
    m = session.register("euclid", vcomp, VirtualClockEvaluator(clock),
                         reference_score_s=ref_s)

    best_log = []   # (virtual_s, score) at each best improvement
    steps = 0
    for i in range(MAX_STEPS):
        if m.tuner.explorer.finished:
            break
        m(i)
        clock.advance(STEP_BUSY_S)
        session.observe_busy(STEP_BUSY_S)
        session.pump()
        steps = i + 1
        s = m.tuner.explorer.best_score
        if s != float("inf") and (not best_log or s < best_log[-1][1]):
            best_log.append((clock(), s))

    stats = session.stats()
    tstats = m.tuner.stats()
    out = {
        "finished": m.tuner.explorer.finished,
        "steps": steps,
        "elapsed_s": clock(),
        "best_point": dict(m.tuner.explorer.best_point or {}),
        "best_score": float(m.tuner.explorer.best_score),
        "history": [(dict(p), float(s))
                    for p, s in m.tuner.explorer.history],
        "best_log": best_log,
        "overhead_pct": 100.0 * stats["overhead_frac"],
        "gate_checks": tstats.get("gate_checks", 0),
        "gate_failures": tstats.get("gate_failures", 0),
        "transfer_hits": stats.get("transfer_hits", 0),
        "transfer_adopted": stats.get("transfer_adopted", 0),
        "transfer_seeds": len(m.transfer_seed_keys),
    }
    session.close()
    return out


def warm_registry(donors):
    """Tune every donor profile into one shared registry (traits attach
    at save time); returns (registry, {donor name: best point})."""
    registry = TunedRegistry()
    bests = {}
    for prof in donors:
        r = run_one(prof, f"grid:{prof.name}", registry,
                    transfer=False, budgeted=False)
        bests[prof.name] = r["best_point"]
    return registry, bests


def regens_to(history, target):
    """1-based index of the first evaluated point at/below target."""
    for i, (_, s) in enumerate(history):
        if s <= target * (1.0 + 1e-9):
            return i + 1
    return len(history) + 1


def time_to(best_log, target, elapsed_s):
    for t, s in best_log:
        if s <= target * (1.0 + 1e-9):
            return t
    return elapsed_s


def run_grid(quick):
    """One full grid pass: warm donors, then cold-vs-seeded per unseen."""
    donors = QUICK_DONORS if quick else ALL_PROFILES
    registry, donor_bests = warm_registry(donors)
    snap = registry.snapshot()

    rows = []
    for base_name, prof in unseen_profiles(quick):
        # each unseen device starts from its own copy of the donor
        # registry: runs are independent and order-insensitive
        runs = {}
        for mode, transfer in (("cold", False), ("seeded", True)):
            reg = TunedRegistry()
            reg.merge_snapshot(snap)
            runs[mode] = run_one(prof, f"grid:new:{prof.name}", reg,
                                 transfer=transfer)
        cold, seeded = runs["cold"], runs["seeded"]
        # the known best on this profile: the better of the two
        # exhausted explorations (identical in practice — seeding adds
        # candidates, it does not remove any)
        target = min(cold["best_score"], seeded["best_score"])
        rows.append({
            "unseen": prof.name,
            "donor_base": base_name,
            "cold_regens": regens_to(cold["history"], target),
            "seeded_regens": regens_to(seeded["history"], target),
            "cold_ttb_s": time_to(cold["best_log"], target,
                                  cold["elapsed_s"]),
            "seeded_ttb_s": time_to(seeded["best_log"], target,
                                    seeded["elapsed_s"]),
            "seeds": seeded["transfer_seeds"],
            "adopted": seeded["transfer_adopted"],
            "gate_checks": seeded["gate_checks"],
            "overhead_pct": max(cold["overhead_pct"],
                                seeded["overhead_pct"]),
            "cold": cold,
            "seeded": seeded,
        })
    return {"donor_bests": donor_bests, "rows": rows}


def grid_digest(grid):
    """Determinism fingerprint: every observable of every run."""
    return json.dumps(grid, sort_keys=True, default=str)


def check(grid):
    rows = grid["rows"]
    violations = []
    for row in rows:
        for mode in ("cold", "seeded"):
            r = row[mode]
            if not r["finished"]:
                violations.append(
                    f"{row['unseen']} {mode}: exploration did not finish "
                    f"in {MAX_STEPS} steps")
            if r["overhead_pct"] > MAX_OVERHEAD_PCT:
                violations.append(
                    f"{row['unseen']} {mode}: tuning overhead "
                    f"{r['overhead_pct']:.2f}% > {MAX_OVERHEAD_PCT}%")
        if row["seeds"] < 1:
            violations.append(
                f"{row['unseen']}: no transfer seeds injected (similar "
                "donors exist — the nearest-fingerprint lookup is broken)")
        if row["seeds"] >= 1 and row["gate_checks"] < 1:
            violations.append(
                f"{row['unseen']}: transfer seeds adopted without a "
                "single gate check (seeds must be CANDIDATEs)")
        if row["cold_regens"] < GATE_COLD_REGENS:
            violations.append(
                f"{row['unseen']}: cold start found the best in "
                f"{row['cold_regens']} regens (< {GATE_COLD_REGENS}) — "
                "the grid is too easy to measure transfer on")

    frac_seeded = (sum(1 for r in rows
                       if r["seeded_regens"] <= GATE_SEEDED_REGENS)
                   / len(rows))
    if frac_seeded < GATE_MIN_FRAC:
        violations.append(
            f"seeded runs hit best within {GATE_SEEDED_REGENS} regens on "
            f"only {100 * frac_seeded:.0f}% of unseen profiles "
            f"(need >= {100 * GATE_MIN_FRAC:.0f}%)")

    speedups = [r["cold_ttb_s"] / r["seeded_ttb_s"] for r in rows
                if r["seeded_ttb_s"] > 0]
    speedup_geo = statistics.geometric_mean(speedups) if speedups else None
    if speedup_geo is None or speedup_geo < MIN_TTB_SPEEDUP:
        violations.append(
            f"seeded time-to-best speedup {speedup_geo} < "
            f"{MIN_TTB_SPEEDUP}x geo-mean over cold")

    summary = {
        "unseen_profiles": len(rows),
        "frac_seeded_le_2": frac_seeded,
        "frac_cold_ge_4": sum(1 for r in rows
                              if r["cold_regens"] >= GATE_COLD_REGENS)
        / len(rows),
        "ttb_speedup_geo": speedup_geo,
        "max_overhead_pct": max(r["overhead_pct"] for r in rows),
    }
    return summary, violations


def run(quick=False, seed=0, write=True):
    grid = run_grid(quick)
    summary, violations = check(grid)

    # determinism: an identical second grid must be byte-identical
    if grid_digest(run_grid(quick)) != grid_digest(grid):
        violations.append("two same-seed grid runs differ")

    cols = ["unseen", "donor_base", "seeded_regens", "cold_regens",
            "seeded_ttb_s", "cold_ttb_s", "seeds", "adopted",
            "gate_checks", "overhead_pct"]
    print(table([{c: r[c] for c in cols} for r in grid["rows"]], cols,
                title="transfer grid — unseen profiles, seeded vs cold"))
    if violations:
        print("\nGATE VIOLATIONS:")
        for v in violations:
            print(f"  {v}")
    else:
        print(f"\nseeded runs reached the best in <= {GATE_SEEDED_REGENS} "
              f"regens on {100 * summary['frac_seeded_le_2']:.0f}% of "
              f"{summary['unseen_profiles']} unseen profiles (cold needed "
              f">= {GATE_COLD_REGENS} on all); time-to-best "
              f"{summary['ttb_speedup_geo']:.1f}x faster seeded; overhead "
              f"<= {MAX_OVERHEAD_PCT}%; every seed gated; deterministic")

    payload = {
        "seed": seed,
        "quick": quick,
        "gates": {
            "seeded_regens_max": GATE_SEEDED_REGENS,
            "cold_regens_min": GATE_COLD_REGENS,
            "min_frac_seeded": GATE_MIN_FRAC,
            "min_ttb_speedup": MIN_TTB_SPEEDUP,
            "max_overhead_pct": MAX_OVERHEAD_PCT,
        },
        "summary": summary,
        "rows": [{k: v for k, v in r.items() if k not in ("cold", "seeded")}
                 for r in grid["rows"]],
        "donor_bests": grid["donor_bests"],
        "violations": violations,
    }
    if write:
        save("transfer_grid", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="6 donors / 6 unseen profiles (CI); same gates")
    ap.add_argument("--seed", type=int, default=0,
                    help="recorded in the artifact; the virtual grid "
                         "itself is deterministic by construction")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick, seed=args.seed)
    return 1 if payload["violations"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
