"""Scenario fleet — deterministic traffic replay across every model config.

The repo's fleet-scale analogue of the paper's fig7 workload study, and
the standing regression floor for every later perf PR: four seeded
traffic shapes (steady Poisson, bursty long-tail, ramp-up with host
work, phase change) replayed against each `repro.configs` architecture,
plus one multi-tenant scenario interleaving the whole fleet through a
single session. Everything runs on the VirtualClock with the virtual
cost-model kernel backend, so two runs with the same seed produce
byte-identical `bench_artifacts/scenarios.json`.

Gates (enforced here and by tests/test_replay.py, hard-failed in CI):
per-scenario tuning overhead <= 5% of productive runtime — the paper's
0.2-4.2% envelope with margin — and per-config speedup vs the static
reference >= 1.0.

    PYTHONPATH=src python benchmarks/scenario_fleet.py [--quick] [--seed N]
"""

from __future__ import annotations

import argparse
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table  # noqa: E402

import dataclasses  # noqa: E402

from repro.bench.replay import (  # noqa: E402
    fault_scenarios,
    fleet_scenarios,
    replay_scenario,
    replay_tuning_defaults,
)
from repro.configs import REGISTRY  # noqa: E402

MAX_OVERHEAD_PCT = 5.0
MIN_SPEEDUP = 1.0

# Fault scenarios replay a fixed-length trace even under --quick: the
# injected faults land on points the explorer only reaches some way into
# the search, and a 96-request trace can end before any faulted point is
# proposed. One config at 320 requests costs well under a second.
FAULT_TARGET = 320
FAULT_CONFIG = "deepseek-7b"

ROW_COLS = [
    "scenario", "config", "n_requests", "p50_ms", "p99_ms",
    "overhead_pct", "speedup_vs_ref", "speedup_all_in",
    "time_to_best_s", "cache_hit_rate", "swaps",
]

FAULT_COLS = [
    "scenario", "config", "n_requests", "overhead_pct", "speedup_vs_ref",
    "gate_checks", "gate_failures", "canary_calls", "canary_promotions",
    "rollbacks", "quarantined", "served_wrong_calls",
]


def _rows_from_report(scenario_name: str, report: dict) -> list[dict]:
    """Flatten one replay report into per-(scenario, config) table rows.

    Tuning economics (overhead, cache hits, time-to-best) are session
    totals — in the multi-tenant scenario every tenant's row carries the
    shared numbers, which is what the overhead gate must see: the cap
    bounds the process, not each tenant separately.
    """
    t = report["tuning"]
    rows = []
    for config, pt in sorted(report["per_tenant"].items()):
        rows.append({
            "scenario": scenario_name,
            "config": config,
            "n_requests": pt["n_requests"],
            "p50_ms": 1e3 * pt["p50_s"],
            "p99_ms": 1e3 * pt["p99_s"],
            "overhead_pct": t["overhead_pct"],
            "speedup_vs_ref": pt["speedup_vs_ref"],
            "speedup_all_in": t["speedup_all_in"],
            "time_to_best_s": t["time_to_best_s"],
            "cache_hit_rate": t["cache_hit_rate"],
            "swaps": t["swaps"],
            "regenerations": t["regenerations"],
        })
    return rows


def check_rows(rows: list[dict]) -> list[str]:
    """The CI gates: overhead envelope and never-slower-than-reference."""
    violations = []
    for r in rows:
        where = f"{r['scenario']}/{r['config']}"
        if r["overhead_pct"] > MAX_OVERHEAD_PCT:
            violations.append(
                f"{where}: tuning overhead {r['overhead_pct']:.2f}% "
                f"> {MAX_OVERHEAD_PCT}%")
        if r["speedup_vs_ref"] < MIN_SPEEDUP:
            violations.append(
                f"{where}: speedup vs reference "
                f"{r['speedup_vs_ref']:.6f} < {MIN_SPEEDUP}")
    return violations


def _fault_rows_from_report(scenario_name: str, report: dict) -> list[dict]:
    t = report["tuning"]
    rows = []
    for config, pt in sorted(report["per_tenant"].items()):
        rows.append({
            "scenario": scenario_name,
            "config": config,
            "n_requests": pt["n_requests"],
            "overhead_pct": t["overhead_pct"],
            "speedup_vs_ref": pt["speedup_vs_ref"],
            "gate_checks": t["gate_checks"],
            "gate_failures": t["gate_failures"],
            "canary_calls": t["canary_calls"],
            "canary_promotions": t["canary_promotions"],
            "rollbacks": t["rollbacks"],
            "quarantined": t["quarantined"],
            "served_wrong_calls": t["served_wrong_calls"],
        })
    return rows


def check_fault_rows(rows: list[dict], probation: int = 8) -> list[str]:
    """The trusted-swaps gates, CI-hard-failed like the clean ones.

    Every fault row must serve zero wrong-output production calls and
    stay inside the overhead envelope; each injected failure mode must
    actually trip its defense (quarantine, oracle gate, rollback); and
    canary exposure is bounded — a bad variant can touch at most
    ``canary_calls`` production calls before the rollback lands.
    """
    violations = []
    for r in rows:
        where = f"{r['scenario']}/{r['config']}"
        if r["served_wrong_calls"] != 0:
            violations.append(
                f"{where}: {r['served_wrong_calls']} production calls "
                "served by a wrong-output variant (must be 0)")
        if r["overhead_pct"] > MAX_OVERHEAD_PCT:
            violations.append(
                f"{where}: tuning overhead {r['overhead_pct']:.2f}% "
                f"> {MAX_OVERHEAD_PCT}% under faults")
        if r["speedup_vs_ref"] < MIN_SPEEDUP:
            violations.append(
                f"{where}: speedup vs reference "
                f"{r['speedup_vs_ref']:.6f} < {MIN_SPEEDUP} under faults")
        if "compile" in r["scenario"] and r["quarantined"] < 1:
            violations.append(
                f"{where}: injected compile failures never quarantined")
        if "wrong_output" in r["scenario"] and r["gate_failures"] < 1:
            violations.append(
                f"{where}: injected wrong-output variant never failed "
                "the oracle gate")
        if "tail" in r["scenario"] and r["rollbacks"] < 1:
            violations.append(
                f"{where}: injected tail regression never rolled back")
        # bounded rollback latency: each gate-passing variant gets one
        # canary episode, and an episode serves at most ``probation``
        # production calls before it promotes, rolls back, or is
        # superseded by a better candidate
        exposure_cap = (
            max(r["gate_checks"] - r["gate_failures"], 0) * probation)
        if r["canary_calls"] > exposure_cap:
            violations.append(
                f"{where}: {r['canary_calls']} canary calls exceed the "
                f"probation bound {exposure_cap}")
    return violations


def run(quick: bool = False, seed: int = 0, write: bool = True) -> dict:
    """Replay the full scenario x config grid; return the artifact payload.

    ``quick`` shortens every trace (fewer requests per tenant), not the
    grid — CI still covers all scenarios and all configs. ``write=False``
    skips the bench_artifacts dump (the determinism test compares two
    in-memory payloads instead).
    """
    target = 96 if quick else 320
    scenarios = fleet_scenarios(target)
    configs = dict(sorted(REGISTRY.items()))
    rows: list[dict] = []
    reports: dict[str, dict] = {}

    # one session per (scenario, config): the per-architecture envelope
    for sc in scenarios:
        for name, cfg in configs.items():
            report = replay_scenario(sc, {name: cfg}, seed=seed)
            reports[f"{sc.name}/{name}"] = report
            rows.extend(_rows_from_report(sc.name, report))

    # the whole fleet through ONE session: multi-tenant interleaving,
    # shared budget, shared generation cache across all architectures
    multi = replay_scenario(scenarios[0], configs, seed=seed)
    reports["multi_tenant"] = multi
    rows.extend(_rows_from_report("multi_tenant", multi))

    # fault-injection scenarios: the trusted-swaps defenses (oracle gate,
    # canaried promotion, compile-failure quarantine) exercised under
    # traffic with gate_mode="canary"; one representative config
    gated = dataclasses.replace(
        replay_tuning_defaults(), gate_mode="canary")
    fault_rows: list[dict] = []
    for sc in fault_scenarios(FAULT_TARGET):
        report = replay_scenario(
            sc, {FAULT_CONFIG: configs[FAULT_CONFIG]},
            seed=seed, config=gated)
        reports[f"{sc.name}/{FAULT_CONFIG}"] = report
        fault_rows.extend(_fault_rows_from_report(sc.name, report))

    violations = check_rows(rows) + check_fault_rows(
        fault_rows, probation=gated.canary_calls)
    payload = {
        "seed": seed,
        "quick": quick,
        "target_requests": target,
        "n_configs": len(configs),
        "n_scenarios": len(scenarios) + 1,   # + multi_tenant
        "gates": {"max_overhead_pct": MAX_OVERHEAD_PCT,
                  "min_speedup": MIN_SPEEDUP},
        "rows": rows,
        "fault_rows": fault_rows,
        "reports": reports,
        "violations": violations,
    }

    print(table(rows, ROW_COLS, "Scenario fleet — tuning under traffic"))
    n_swapped = sum(1 for r in rows if r["swaps"])
    print(f"\n{len(rows)} rows ({len(configs)} configs x "
          f"{len(scenarios)} scenarios + multi-tenant), "
          f"{n_swapped} with at least one swap")
    print()
    print(table(fault_rows, FAULT_COLS,
                "Fault injection — trusted swaps under attack"))
    if violations:
        print("\nGATE VIOLATIONS:")
        for v in violations:
            print(f"  {v}")
    else:
        print(f"gates OK: overhead <= {MAX_OVERHEAD_PCT}%, "
              f"speedup >= {MIN_SPEEDUP} on every row; fault rows "
              "served zero wrong calls, every injected fault tripped "
              "its defense")
    if write:
        save("scenarios", payload)
    return payload


def main(argv: list[str] | None = None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="short traces (CI); full grid either way")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)
    payload = run(quick=args.quick, seed=args.seed)
    return 1 if payload["violations"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
