"""Fleet fabric: N tuning replicas sharing one registry backend.

The same four-kernel serving scenario as ``compile_farm.py`` replayed
through N in {1, 2, 4} virtual-clock replicas wired to a single
``FleetBus`` backend and one shared compiled-variant cache (the
in-process analogue of a shared artifact store). Each replica owns a
hash stripe of every kernel's tuning space (``partition(i, N)``), peers'
published evaluations count as seen, and a peer's published best enters
each replica as a CANDIDATE through the normal gate/canary path — never
as a blind incumbent. Exploration is therefore paid once per fleet while
every replica converges to the fleet-wide best variant.

CI smoke assertions (all deterministic on the VirtualClock):

  * fleet-wide time-to-best (virtual time until EVERY replica serves the
    global best of every kernel) at N=4 beats N=1 by >= 2x;
  * the fleet compiles each variant once: shared-cache misses at N=2 and
    N=4 equal the N=1 count exactly;
  * per-replica tuning overhead stays <= 5% of runtime at every N;
  * two same-seed runs are byte-identical at every N (per-replica stats
    compare equal as JSON);
  * fault fleet: a wrong-output variant condemned by the replica that
    owns it serves ZERO production calls on every replica, is quarantined
    fleet-wide after one sync, and stays condemned for a fresh replica
    restarting from the merged on-disk registry (SharedFileBackend).

    PYTHONPATH=src python benchmarks/fleet_fabric.py [--quick] [--seed N]
"""

import argparse
import json
import os
import sys
import tempfile

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table  # noqa: E402

from repro.core import (  # noqa: E402
    Compilette,
    FleetBus,
    GenerationCache,
    Param,
    RegenerationPolicy,
    SharedFileBackend,
    TPU_V5E,
    TunedRegistry,
    VirtualClock,
    VirtualClockEvaluator,
    point_stripe,
    product_space,
    virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.kernel_plane import KernelTuningPlane

DEVICE = "bench:virtual"
GEN_COST_S = 0.001          # declared compile cost per variant
STEP_BUSY_S = 0.010         # serving step each replica's budget accrues from
SYNC_EVERY_S = 0.25         # fleet sync cadence (virtual seconds)
FLEET_SWEEP = (1, 2, 4)
MAX_OVERHEAD_PCT = 5.0
MIN_SPEEDUP = 2.0

SPECS = {
    "matmul": {"M": 256, "N": 256, "K": 256, "dtype": "float32"},
    "attention": {"B": 2, "Tq": 128, "Tkv": 128, "H": 4, "Hk": 2,
                  "Dh": 32, "causal": True, "dtype": "float32"},
    "rmsnorm": {"N": 512, "d": 256, "dtype": "float32"},
    "euclid": {"N": 128, "M": 64, "D": 32, "dtype": "float32"},
}


def run_fleet(n_replicas, *, iters=60000, backend=None, gen_cache=None):
    """One fleet lifetime: N replicas, lockstep traffic, shared backend.

    Every replica sees the FULL serving traffic (the fleet replicates a
    service, it does not shard requests) and runs the identical tuning
    config; only ``replica_id`` differs. The search strategy is
    ``random`` — exhaustive on these spaces, so the stripes are jointly
    exhaustive and the N=1 final best IS the global best.
    """
    backend = backend if backend is not None else FleetBus()
    gen_cache = gen_cache if gen_cache is not None else GenerationCache(
        max_entries=4096)
    replicas = []
    for rid in range(n_replicas):
        clock = VirtualClock()
        coord = TuningCoordinator(
            policy=RegenerationPolicy(
                max_overhead_frac=0.04, invest_frac=0.0, budget_from="busy"),
            registry=TunedRegistry(), device=DEVICE, clock=clock,
            strategy="random", async_generation=True,
            generation_cache=gen_cache, prefetch=1, compile_workers=1,
            replica_id=rid, replica_count=n_replicas,
            registry_backend=backend, sync_every_s=SYNC_EVERY_S)
        plane = KernelTuningPlane(
            coord, virtual=(clock, TPU_V5E), gen_cost_s=GEN_COST_S,
            evaluator_factory=lambda c, _clock=clock: VirtualClockEvaluator(
                _clock))
        handles = {n: plane.register_spec(n, s) for n, s in SPECS.items()}
        replicas.append({
            "clock": clock, "coord": coord, "handles": handles,
            # per-kernel timeline of best-SCORE improvements:
            # (virtual_s, score). Scores, not points: the cost model has
            # tied optima (e.g. lookahead-invariant kernels), and each
            # stripe legitimately keeps its own tie-winner — the fleet
            # converges on the best score, not one canonical point.
            "best_log": {n: [] for n in SPECS},
        })

    def record_bests(rep):
        for n, h in rep["handles"].items():
            score = h.tuner.explorer.best_score
            log = rep["best_log"][n]
            if score != float("inf") and (not log or score < log[-1][1]):
                log.append((rep["clock"](), score))

    def settled():
        # exploration drained everywhere AND every replica agrees on the
        # best score of every kernel (a strictly better peer best keeps
        # getting injected — and injection flips finished back to False —
        # so agreement + finished means propagation is complete)
        for rep in replicas:
            if not all(h.tuner.explorer.finished
                       for h in rep["handles"].values()):
                return False
        for n in SPECS:
            scores = [rep["handles"][n].tuner.explorer.best_score
                      for rep in replicas]
            if any(s != scores[0] for s in scores):
                return False
        return True

    done_at = None
    for i in range(iters):
        for rep in replicas:
            for h in rep["handles"].values():
                h(i)
            rep["clock"].advance(STEP_BUSY_S)
            rep["coord"].observe_busy(STEP_BUSY_S)
            rep["coord"].pump()
            record_bests(rep)
        if settled():
            done_at = i
            break
    for rep in replicas:
        rep["coord"].sync_fleet()

    return {
        "n_replicas": n_replicas,
        "done_at_iter": done_at,
        "cache": gen_cache.stats(),
        "replicas": [{
            "stats": rep["coord"].stats(),
            "best": {n: h.tuner.explorer.best_point
                     for n, h in rep["handles"].items()},
            "best_score": {n: h.tuner.explorer.best_score
                           for n, h in rep["handles"].items()},
            "best_log": rep["best_log"],
        } for rep in replicas],
    }


def fleet_time_to_best(run, targets):
    """Virtual time until EVERY replica serves the global best score.

    Per replica: the latest first-time-at-target over its kernels; fleet:
    the max over replicas (the fleet serves the best only once its
    slowest member does). Returns None if any replica never got there.
    """
    per_replica = []
    for rep in run["replicas"]:
        at = []
        for name, target in targets.items():
            hit = next((t for t, s in rep["best_log"][name]
                        if s <= target), None)
            if hit is None:
                return None
            at.append(hit)
        per_replica.append(max(at))
    return max(per_replica)


def replica_digest(run):
    """The determinism fingerprint: everything observable, JSON-stable."""
    return json.dumps(
        [{"stats": rep["stats"], "best": rep["best"],
          "best_log": rep["best_log"]} for rep in run["replicas"]],
        sort_keys=True, default=str)


# ------------------------------------------------------------- fault fleet
def _fault_compilette(clock, name, bad):
    """4-point space; ``bad`` is the fastest-measuring point but fails
    the output oracle — the dangerous case the gate must catch."""
    sp = product_space([Param("unroll", (1, 2, 4, 8), phase=1,
                              switch_rank=0)])

    def gen(point, **spec):
        return virtual_kernel(clock, 0.010 / point["unroll"], tag=dict(point))

    comp = Compilette(name, sp, gen)
    comp.gate_script = lambda point: dict(point) != bad
    return comp


def run_fault_fleet(registry_dir):
    """Two replicas + a restart on a SharedFileBackend, wrong-output fault.

    The replica that owns the bad point discovers the oracle failure and
    condemns it; after one sync the peer must never propose, canary or
    serve it; a THIRD replica restarting from the merged on-disk registry
    must come up with the point already condemned.
    """
    path = os.path.join(registry_dir, "fleet_tuned.json")
    bad = {"unroll": 8}
    owner = point_stripe(bad, 2)

    replicas = []
    for rid in range(2):
        clock = VirtualClock()
        backend = SharedFileBackend(path)   # own instance, shared file
        coord = TuningCoordinator(
            policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
            registry=TunedRegistry(), device=DEVICE, clock=clock,
            gate_mode="canary", canary_fraction=0.5, canary_calls=4,
            replica_id=rid, replica_count=2,
            registry_backend=backend, sync_every_s=None)
        m = coord.register(
            "k", _fault_compilette(clock, "k", bad),
            VirtualClockEvaluator(clock),
            reference_fn=virtual_kernel(clock, 0.010))
        replicas.append({"clock": clock, "coord": coord, "m": m})

    for i in range(400):
        for rep in replicas:
            rep["m"](i)
            rep["clock"].advance(STEP_BUSY_S)
            rep["coord"].observe_busy(STEP_BUSY_S)
            rep["coord"].pump()
    for rep in replicas:
        rep["coord"].sync_fleet()
        rep["coord"].close()

    # restart: a fresh replica seeded from the merged on-disk registry
    clock3 = VirtualClock()
    reg3 = TunedRegistry()
    coord3 = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=1.0, invest_frac=1.0),
        registry=reg3, device=DEVICE, clock=clock3, gate_mode="canary",
        replica_id=0, replica_count=2,
        registry_backend=SharedFileBackend(path), sync_every_s=None)
    m3 = coord3.register(
        "k", _fault_compilette(clock3, "k", bad),
        VirtualClockEvaluator(clock3),
        reference_fn=virtual_kernel(clock3, 0.010))

    rows, violations = [], []
    for rid, rep in enumerate(replicas):
        t = rep["m"].tuner
        wrong_calls = sum(life.calls for life in t._lives
                          if dict(life.point or {}) == bad)
        s = t.stats()
        rows.append({
            "replica": rid,
            "owns_bad": rid == owner,
            "active": s["active_point"],
            "wrong_calls": wrong_calls,
            "gate_failures": s["gate_failures"],
            "quarantined_local": t.explorer.is_quarantined(bad),
        })
        if wrong_calls != 0:
            violations.append(
                f"fault replica {rid}: {wrong_calls} production calls "
                "served by the wrong-output variant (must be 0)")
        if not t.explorer.is_quarantined(bad):
            violations.append(
                f"fault replica {rid}: bad point not quarantined "
                "after sync")
        if s["active_point"] == bad:
            violations.append(f"fault replica {rid}: serving the bad point")
        if rid != owner and any(dict(p) == bad
                                for p, _ in t.explorer.history):
            violations.append(
                f"fault replica {rid}: evaluated a point its peer "
                "condemned (compiled twice per fleet)")
    # exactly one replica (the stripe owner) paid the gate failure
    if sum(r["gate_failures"] for r in rows) != 1:
        violations.append(
            f"fault fleet: expected exactly 1 gate failure fleet-wide, "
            f"got {[r['gate_failures'] for r in rows]}")
    if not m3.tuner.explorer.is_quarantined(bad):
        violations.append(
            "fault restart: merged registry did not carry the fleet "
            "quarantine across restart")
    return {"rows": rows, "restart_quarantined":
            m3.tuner.explorer.is_quarantined(bad),
            "violations": violations}


# ------------------------------------------------------------------- main
def run(quick=False, seed=0, write=True):
    iters = 20000 if quick else 60000
    rows, runs, violations = [], {}, []

    for n in FLEET_SWEEP:
        r = run_fleet(n, iters=iters)
        runs[n] = r
        if r["done_at_iter"] is None:
            violations.append(f"N={n}: fleet never settled in {iters} iters")
            continue
        # determinism: an identical second fleet must be byte-identical
        r2 = run_fleet(n, iters=iters)
        if replica_digest(r) != replica_digest(r2):
            violations.append(f"N={n}: two same-seed runs differ")
        for rid, rep in enumerate(r["replicas"]):
            pct = 100.0 * rep["stats"]["overhead_frac"]
            if pct > MAX_OVERHEAD_PCT:
                violations.append(
                    f"N={n} replica {rid}: tuning overhead {pct:.2f}% "
                    f"> {MAX_OVERHEAD_PCT}%")

    targets = runs[1]["replicas"][0]["best_score"] if 1 in runs else {}
    for n in FLEET_SWEEP:
        r = runs[n]
        for rid, rep in enumerate(r["replicas"]):
            if rep["best_score"] != targets:
                violations.append(
                    f"N={n} replica {rid}: final best scores diverge from "
                    f"the global best: {rep['best_score']} != {targets}")
        ttb = fleet_time_to_best(r, targets)
        if ttb is None:
            violations.append(f"N={n}: some replica never reached the "
                              "global best")
        r["time_to_best"] = ttb
        rows.append({
            "replicas": n,
            "time_to_best_s": ttb,
            "fleet_compiles": r["cache"]["misses"],
            "cache_hits": r["cache"]["hits"],
            "syncs": sum(rep["stats"]["fleet"]["syncs"]
                         for rep in r["replicas"]),
            "max_overhead_pct": max(
                100.0 * rep["stats"]["overhead_frac"]
                for rep in r["replicas"]),
        })

    # the fleet compiles each variant exactly once: every fleet size pays
    # the same number of shared-cache misses as a lone replica
    base_compiles = runs[1]["cache"]["misses"]
    for n in FLEET_SWEEP[1:]:
        if runs[n]["cache"]["misses"] != base_compiles:
            violations.append(
                f"N={n}: fleet compiled {runs[n]['cache']['misses']} "
                f"variants, lone replica compiled {base_compiles} "
                "(must be equal)")

    speedup = None
    if runs[1].get("time_to_best") and runs[4].get("time_to_best"):
        speedup = runs[1]["time_to_best"] / runs[4]["time_to_best"]
        if speedup < MIN_SPEEDUP:
            violations.append(
                f"N=4 fleet time-to-best speedup {speedup:.2f}x "
                f"< {MIN_SPEEDUP}x vs N=1")

    with tempfile.TemporaryDirectory() as d:
        fault = run_fault_fleet(d)
    violations.extend(fault["violations"])

    payload = {
        "seed": seed,
        "quick": quick,
        "gates": {"min_speedup": MIN_SPEEDUP,
                  "max_overhead_pct": MAX_OVERHEAD_PCT,
                  "compile_once_per_fleet": True},
        "rows": rows,
        "speedup_n4": speedup,
        "fault": fault,
        "violations": violations,
    }

    print(table(rows, ["replicas", "time_to_best_s", "fleet_compiles",
                       "cache_hits", "syncs", "max_overhead_pct"],
                title="fleet fabric sweep (virtual seconds)"))
    print()
    print(table(fault["rows"],
                ["replica", "owns_bad", "active", "wrong_calls",
                 "gate_failures", "quarantined_local"],
                title="fault fleet — wrong-output variant, 2 replicas"))
    if violations:
        print("\nGATE VIOLATIONS:")
        for v in violations:
            print(f"  {v}")
    else:
        print(f"\nfleet time-to-best: {runs[1]['time_to_best']:.3f}s (N=1)"
              f" -> {runs[4]['time_to_best']:.3f}s (N=4), "
              f"{speedup:.2f}x faster; {base_compiles} compiles at every "
              f"N (once per fleet); overhead <= {MAX_OVERHEAD_PCT}% per "
              "replica; fault fleet served zero wrong calls and the "
              "quarantine survived restart")
    if write:
        save("fleet_fabric", payload)
    return payload


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--quick", action="store_true",
                    help="shorter settle cap (CI); same fleet grid")
    ap.add_argument("--seed", type=int, default=0,
                    help="recorded in the artifact; the virtual fabric "
                         "itself is deterministic by construction")
    args = ap.parse_args(argv)
    payload = run(quick=args.quick, seed=args.seed)
    return 1 if payload["violations"] else 0


if __name__ == "__main__":
    raise SystemExit(main())
