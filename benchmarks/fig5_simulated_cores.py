"""Paper Fig. 5 + Fig. 6 — the 11 simulated cores study.

For every simulated device profile, runs the online exploration of the
euclid kernel through the ``repro.tune`` session front door (a
``TuningSession`` per core on a ``VirtualClock``, the same coordinator/
budget/registry machinery production uses) and reports speedup +
energy-efficiency improvement over the SISD and SIMD references, then
the IO-vs-OOO ("lean-vs-fat") comparison on equivalent pairs:

  * ref-on-fat vs ref-on-lean  (hardware gap under static code)
  * tuned-on-lean vs ref-on-fat (can online tuning replace OOO hardware?)
"""

from __future__ import annotations

from repro.api import TuningConfig, TuningSession
from repro.core import VirtualClock, VirtualClockEvaluator, virtual_compilette
from repro.core.profiles import ALL_PROFILES, EQUIVALENT_PAIRS
from repro.kernels.euclid.ops import (
    euclid_flops, make_euclid_compilette)
from benchmarks.common import save, table

N, M, D = 4096, 128, 64
MAX_STEPS = 5000   # drive-loop backstop; exploration finishes far earlier


def ref_points():
    sisd = dict(block_n=64, block_m=32, block_d=16, unroll=1, vectorize=0,
                order="nm", scratch=1, lookahead=0)
    simd = dict(block_n=64, block_m=32, block_d=16, unroll=1, vectorize=1,
                order="nm", scratch=1, lookahead=0)
    return sisd, simd


def energy(prof, point, t, comp):
    vect = bool(point["vectorize"])
    fl = euclid_flops(N, M, D, vect)
    by = (N * D + M * D + N * M) * 4.0
    return prof.energy_j(t, fl, by)


def tuned_best(comp, prof, ref_score_s):
    """Online-tune euclid on ``prof`` via the session path; (point, s)."""
    clock = VirtualClock()
    session = TuningSession(
        TuningConfig(max_overhead=1.0, invest=1.0, pump_every=1),
        clock=clock, device=f"fig5:{prof.name}")
    # vmem-overflow points simulate at inf: clamp to a finite (still
    # astronomically bad) cost so the virtual clock stays arithmetic —
    # the explorer must be able to MEASURE an invalid point and move on
    vcomp = virtual_compilette(clock, "euclid", comp.space,
                               lambda p: min(comp.simulate(p, prof), 1.0))
    # virtual marker: candidate-cost estimates and device traits derive
    # from the exact profile being simulated
    vcomp.virtual = (clock, prof)
    vcomp.cost_model = comp.cost_model
    m = session.register("euclid", vcomp, VirtualClockEvaluator(clock),
                         reference_score_s=ref_score_s)
    for i in range(MAX_STEPS):
        if m.tuner.explorer.finished:
            break
        m(i)
        clock.advance(0.001)
        session.observe_busy(0.001)
        session.pump()
    assert m.tuner.explorer.finished, (
        f"{prof.name}: exploration did not finish in {MAX_STEPS} steps")
    bp = dict(m.tuner.explorer.best_point)
    bt = float(m.tuner.explorer.best_score)
    session.close()
    return bp, bt


def run() -> dict:
    comp = make_euclid_compilette(N, M, D)
    sisd, simd = ref_points()
    rows = []
    best = {}
    for prof in ALL_PROFILES:
        t_sisd = comp.simulate(sisd, prof)
        t_simd = comp.simulate(simd, prof)
        bp, bt = tuned_best(comp, prof, t_simd)
        best[prof.name] = (bp, bt)
        e_simd = energy(prof, simd, t_simd, comp)
        e_best = energy(prof, bp, bt, comp)
        rows.append({
            "core": prof.name,
            "speedup_vs_SISD": t_sisd / bt,
            "speedup_vs_SIMD": t_simd / bt,
            "energy_gain_vs_SIMD": e_simd / e_best,
            "best_unroll": bp["unroll"],
            "best_vect": bp["vectorize"],
            "best_block_d": bp["block_d"],
        })
    print(table(rows, ["core", "speedup_vs_SISD", "speedup_vs_SIMD",
                       "energy_gain_vs_SIMD", "best_unroll", "best_vect",
                       "best_block_d"],
                "Fig.5 — online auto-tuning on 11 simulated cores"))

    # ---- Fig. 6: lean (IO) vs fat (OOO) equivalent pairs ---------------
    pair_rows = []
    for lean, fat in EQUIVALENT_PAIRS:
        _, simd_pt = ref_points()
        t_ref_fat = comp.simulate(simd_pt, fat)
        t_ref_lean = comp.simulate(simd_pt, lean)
        bp_lean, t_best_lean = best[lean.name]
        e_ref_fat = energy(fat, simd_pt, t_ref_fat, comp)
        e_best_lean = energy(lean, bp_lean, t_best_lean, comp)
        pair_rows.append({
            "pair": f"{lean.name}/{fat.name}",
            "static_gap_ref": t_ref_lean / t_ref_fat,           # >1: lean slower
            "tuned_lean_gap": t_best_lean / t_ref_fat,
            "tuned_lean_speedup_vs_fat_ref": t_ref_fat / t_best_lean,
            "energy_gain_tuned_lean_vs_fat_ref": e_ref_fat / e_best_lean,
            "area_overhead_fat": fat.area_mm2 / lean.area_mm2 - 1,
        })
    import statistics
    geo = lambda xs: statistics.geometric_mean(xs)
    summary = {
        "static_gap_geo": geo([r["static_gap_ref"] for r in pair_rows]),
        "tuned_gap_geo": geo([r["tuned_lean_gap"] for r in pair_rows]),
        "tuned_lean_speedup_vs_fat_ref_geo": geo(
            [r["tuned_lean_speedup_vs_fat_ref"] for r in pair_rows]),
        "energy_gain_geo": geo(
            [r["energy_gain_tuned_lean_vs_fat_ref"] for r in pair_rows]),
    }
    print(table(pair_rows, list(pair_rows[0].keys()),
                "Fig.6 — lean(IO) vs fat(OOO) equivalent pairs"))
    print("summary:", {k: round(v, 3) for k, v in summary.items()})
    out = {"cores": rows, "pairs": pair_rows, "summary": summary,
           "best_points": {k: v[0] for k, v in best.items()}}
    save("fig5_simulated_cores", out)
    return out


if __name__ == "__main__":
    run()
