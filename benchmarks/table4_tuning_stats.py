"""Paper Table 4 — online auto-tuning statistics.

Explorable versions vs one-run exploration limit, kernels evaluated,
overhead fraction of application run-time, and duration-to-kernel-life on
the real platform (XLA:CPU).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Evaluator, OnlineAutotuner, RegenerationPolicy, TwoPhaseExplorer
from repro.kernels.euclid import ops as euclid
from repro.kernels.lintra import ops as lintra
from benchmarks.common import save, table

N_POINTS, M_CENTERS = 1024, 64


def one_run_limit(space) -> int:
    ex = TwoPhaseExplorer(space)
    n = 0
    while True:
        pt = ex.next_point()
        if pt is None:
            break
        ex.report(pt, 1.0)
        n += 1
    return n


def run(quick: bool = False) -> dict:
    rows = []
    cases = [("euclid", d) for d in ((32,) if quick else (32, 64, 128))]
    cases += [("lintra", s) for s in ((160,) if quick else (160, 292, 332))]
    for bench, size in cases:
        if bench == "euclid":
            dim = size
            comp = euclid.make_euclid_compilette(N_POINTS, M_CENTERS, dim)
            key = jax.random.PRNGKey(0)
            args = (jax.random.normal(key, (N_POINTS, dim)),
                    jax.random.normal(key, (M_CENTERS, dim)))
            spec = {"dim": dim}
        else:
            H, W, bands = size, 200, 3
            comp = lintra.make_lintra_compilette(H, W, bands)
            key = jax.random.PRNGKey(0)
            args = (jax.random.normal(key, (H, W, bands)),
                    jnp.ones(bands), jnp.zeros(bands))
            spec = {"bands": bands, "width": W}
        ev = Evaluator(mode="training", groups=1, group_size=3,
                       make_args=lambda a=args: a)
        at = OnlineAutotuner(comp, ev, policy=RegenerationPolicy(0.05, 0.15),
                             specialization=spec, wake_every=2)
        t0 = time.perf_counter()
        calls = 800
        for _ in range(calls):
            at(*args)
        wall = time.perf_counter() - t0
        s = at.stats()
        rows.append({
            "bench": bench, "size": size,
            "explorable": comp.space.n_valid_variants(),
            "one_run_limit": one_run_limit(comp.space),
            "kernel_calls": calls,
            "explored": s["n_explored"],
            "overhead_%": 100 * s["tuning_spent_s"] / wall,
            "overhead_ms": 1000 * s["tuning_spent_s"],
            "swaps": s["swaps"],
        })
    print(table(rows, list(rows[0].keys()),
                "Table 4 — online tuning statistics (real platform)"))
    save("table4_tuning_stats", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
