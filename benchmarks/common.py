"""Shared benchmark utilities."""

from __future__ import annotations

import json
import os
import sys

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "bench_artifacts")


def save(name: str, payload) -> None:
    os.makedirs(ARTIFACT_DIR, exist_ok=True)
    with open(os.path.join(ARTIFACT_DIR, f"{name}.json"), "w") as f:
        json.dump(payload, f, indent=1, default=str)


def table(rows: list[dict], cols: list[str], title: str = "") -> str:
    if title:
        out = [f"== {title} =="]
    else:
        out = []
    widths = {c: max(len(c), *(len(_fmt(r.get(c))) for r in rows)) for c in cols}
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    for r in rows:
        out.append("  ".join(_fmt(r.get(c)).ljust(widths[c]) for c in cols))
    return "\n".join(out)


def _fmt(v) -> str:
    if isinstance(v, float):
        if v == 0:
            return "0"
        if abs(v) >= 1000 or abs(v) < 0.001:
            return f"{v:.3e}"
        return f"{v:.4g}"
    return str(v)
