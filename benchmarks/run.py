"""Benchmark driver: one harness per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--quick]

Real-platform timings (table3/table4/fig7) run the online auto-tuner on
XLA:CPU; simulated-core studies (fig1/fig5/table5) use the analytical
device profiles; the roofline harness aggregates dry-run artifacts.
"""

from __future__ import annotations

import sys
import time


def main() -> None:
    quick = "--quick" in sys.argv
    t0 = time.time()
    from benchmarks import (fig1_motivational, fig5_simulated_cores,
                            fig7_varying_workload, roofline,
                            table3_exec_times, table4_tuning_stats,
                            table5_param_correlation)

    print("\n### Fig.1 — motivational static exploration\n")
    fig1_motivational.run()
    print("\n### Table 3 — real-platform execution times\n")
    table3_exec_times.run(quick=quick)
    print("\n### Table 4 — tuning statistics\n")
    table4_tuning_stats.run(quick=quick)
    print("\n### Fig.5/6 — 11 simulated cores\n")
    fig5_simulated_cores.run()
    print("\n### Fig.7 — varying workload\n")
    fig7_varying_workload.run(quick=quick)
    print("\n### Table 5 — parameter/pipeline correlation\n")
    table5_param_correlation.run()
    print("\n### Roofline (from dry-run artifacts)\n")
    roofline.run("single")
    print(f"\nall benchmarks done in {time.time()-t0:.1f}s")


if __name__ == "__main__":
    main()
