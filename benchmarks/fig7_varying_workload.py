"""Paper Fig. 7 — online auto-tuning speedup vs workload size.

Varies the specialized dimension and the number of points (workload) of
the CPU-bound kernel on the real platform, measuring the all-overheads
speedup of online auto-tuning vs the static reference. Small workloads
shouldn't pay off (crossover); larger ones should.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import Evaluator, OnlineAutotuner, RegenerationPolicy
from repro.kernels.euclid import ops as euclid
from benchmarks.common import save, table


def one(dim: int, n_points: int, calls: int) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (n_points, dim), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (64, dim), jnp.float32)
    args = (x, c)
    ref = jax.jit(euclid.reference_sisd(dim))
    ref(*args)
    t0 = time.perf_counter()
    for _ in range(calls):
        out = ref(*args)
    jax.block_until_ready(out)
    t_ref = time.perf_counter() - t0

    comp = euclid.make_euclid_compilette(n_points, 64, dim)
    ev = Evaluator(mode="training", groups=1, group_size=3,
                   make_args=lambda: args)
    at = OnlineAutotuner(comp, ev, policy=RegenerationPolicy(0.05, 0.5),
                         specialization={"dim": dim},
                         reference_fn=ref, wake_every=2)
    t0 = time.perf_counter()
    for _ in range(calls):
        out = at(*args)
    jax.block_until_ready(out)
    t_oat = time.perf_counter() - t0
    return {
        "dim": dim, "n_points": n_points, "calls": calls,
        "app_run_s": t_ref, "oat_run_s": t_oat,
        "speedup": t_ref / t_oat,
        "explored": at.stats()["n_explored"],
    }


def run(quick: bool = False) -> dict:
    rows = []
    grid = [(16, 256, 30), (64, 1024, 60)] if quick else [
        (8, 256, 30), (32, 256, 60), (32, 1024, 60),
        (64, 1024, 90), (128, 2048, 90),
    ]
    for dim, npts, calls in grid:
        rows.append(one(dim, npts, calls))
    print(table(rows, list(rows[0].keys()),
                "Fig.7 — speedup vs workload (all overheads included)"))
    save("fig7_varying_workload", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
