"""Paper Fig. 7 — online auto-tuning speedup vs workload size.

Reframed on the traffic-replay harness (`repro.bench.replay`): one
steady-Poisson scenario at growing trace lengths, served by the
deepseek-7b config on the virtual cost-model backend. The all-in
speedup (every tuning and init overhead charged) shows the paper's
crossover — short runs don't amortize exploration, longer ones do —
while the kernel-time speedup vs the static reference grows toward the
tuned optimum. Deterministic: seeded traces on the VirtualClock.
"""

from __future__ import annotations

import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table  # noqa: E402

from repro.bench.replay import Scenario, fixed_mix, poisson_arrivals, \
    replay_scenario  # noqa: E402
from repro.configs import REGISTRY  # noqa: E402

CONFIG = "deepseek-7b"


def one(n_requests: int, seed: int = 0) -> dict:
    scenario = Scenario(
        name=f"fig7_steady_{n_requests}",
        arrival=poisson_arrivals,
        prompt_mix=fixed_mix(512),
        decode_mix=fixed_mix(16),
        utilization=0.4,
        target_requests=n_requests,
    )
    rep = replay_scenario(scenario, {CONFIG: REGISTRY[CONFIG]}, seed=seed)
    pt = rep["per_tenant"][CONFIG]
    t = rep["tuning"]
    return {
        "n_requests": pt["n_requests"],
        "duration_s": rep["trace"]["duration_s"],
        "speedup_all_in": t["speedup_all_in"],
        "speedup_vs_ref": pt["speedup_vs_ref"],
        "overhead_pct": t["overhead_pct"],
        "time_to_best_s": t["time_to_best_s"],
        "swaps": t["swaps"],
        "regenerations": t["regenerations"],
    }


def run(quick: bool = False) -> dict:
    # the all-in crossover sits between ~600 and ~1300 requests: short
    # traces lose to exploration + init, the 2560-request trace wins 1.4x
    grid = [40, 320] if quick else [20, 80, 320, 1280, 2560]
    rows = [one(n) for n in grid]
    print(table(rows, list(rows[0].keys()),
                "Fig.7 — speedup vs workload (all overheads included)"))
    save("fig7_varying_workload", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run(quick="--quick" in sys.argv)
