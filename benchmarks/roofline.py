"""Roofline table (beyond-paper deliverable §g).

Aggregates the dry-run artifacts (dryrun_artifacts/*.json) into the
per-(arch × shape × mesh) roofline table: three terms in seconds, dominant
bottleneck, MODEL_FLOPS/HLO ratio, roofline fraction.
"""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import save, table

ART = os.path.join(os.path.dirname(__file__), "..", "dryrun_artifacts")


def load(mesh: str = "single", tag: str = "") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(ART, f"*_{mesh}{tag}.json"))):
        with open(path) as f:
            rec = json.load(f)
        if rec.get("status") == "skipped":
            rows.append({
                "arch": rec["arch"], "shape": rec["shape"],
                "status": "skipped",
            })
            continue
        if rec.get("status") != "ok":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "status": "FAILED"})
            continue
        r = rec["roofline"]
        rows.append({
            "arch": rec["arch"], "shape": rec["shape"], "status": "ok",
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "bound": r["bound"],
            "useful": r["useful_ratio"], "roofline_frac": r["roofline_frac"],
            "mem_gb": rec["memory"]["peak_per_device_gb"],
        })
    return rows


def run(mesh: str = "single") -> dict:
    rows = load(mesh)
    ok = [r for r in rows if r["status"] == "ok"]
    if not ok:
        print("no dry-run artifacts found — run repro.launch.dryrun first")
        return {"rows": rows}
    cols = ["arch", "shape", "compute_s", "memory_s", "collective_s",
            "bound", "useful", "roofline_frac", "mem_gb"]
    print(table(ok, cols, f"Roofline — {mesh}-pod baseline "
                          "(per-device terms, v5e constants)"))
    skipped = [r for r in rows if r["status"] == "skipped"]
    if skipped:
        print(f"skipped cells: {[(r['arch'], r['shape']) for r in skipped]}")
    # pick hillclimb candidates
    worst = min(ok, key=lambda r: r["roofline_frac"])
    coll = max(ok, key=lambda r: r["collective_s"] / max(
        max(r["compute_s"], r["memory_s"]), 1e-12))
    print(f"\nworst roofline fraction: {worst['arch']}/{worst['shape']} "
          f"({worst['roofline_frac']:.3f})")
    print(f"most collective-bound:   {coll['arch']}/{coll['shape']}")
    save(f"roofline_{mesh}", rows)
    return {"rows": rows, "worst": worst, "most_collective": coll}


if __name__ == "__main__":
    import sys
    run(sys.argv[1] if len(sys.argv) > 1 else "single")
