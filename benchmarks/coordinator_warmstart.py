"""Cold-start vs warm-start time-to-best under the TuningCoordinator.

Two measurements per scenario, both fully deterministic on the
VirtualClock (simulated seconds, so numbers are reproducible anywhere):

  * regenerations-to-best — how many generate+evaluate cycles before the
    process is *running* its best-known variant;
  * time-to-best — simulated wall time from process start to that swap,
    including all kernel calls and tuning overhead.

The cold process explores the space from scratch; the warm process loads
the registry the cold one persisted and re-validates the stored best with
a single regeneration. A multi-kernel scenario shows the same effect when
one shared budget serves several kernels at once. ``--strategy`` runs the
same scenarios under any registered search strategy (the warm-start
economics are strategy-independent: the registry seed is always proposed
first).

Generation runs through the double-buffered pipeline: each compile has a
declared simulated cost (``gen_cost_s``), candidates are built by the
async executor while the kernels keep serving, and both processes share
one process-wide ``GenerationCache``. The run reports ``gen_spent_s``
(compile cost charged to the budget), ``gen_stall_s`` (compile time the
hot path actually waited for) and the per-run cache hit rate — and
ASSERTS, as a CI smoke, that the warm-start replay is a 100% cache hit
with zero hot-path stall. ``--sync`` disables the pipeline to show the
stall the paper's original synchronous cycle would pay.

    PYTHONPATH=src python benchmarks/coordinator_warmstart.py \
        [--strategy greedy] [--sync]
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table

from repro.core import (
    Compilette, GenerationCache, Param, RegenerationPolicy, VirtualClock,
    VirtualClockEvaluator, product_space, virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator

DEVICE = "bench:virtual"
GEN_COST_S = 0.002   # simulated compile cost per variant


def make_kernel_suite(clock, n_kernels: int):
    """n kernels with distinct cost landscapes over an 8x2 point space."""
    suite = []
    for k in range(n_kernels):
        base = 0.004 * (k + 1)

        def cost_fn(p, base=base):
            return base / p["unroll"] + (0 if p["sched"] else base / 8)

        sp = product_space([
            Param("unroll", (1, 2, 4, 8), phase=1, switch_rank=0),
            Param("sched", (0, 1), phase=2),
        ])

        def gen(point, _cost_fn=cost_fn, **spec):
            return virtual_kernel(clock, _cost_fn(point))

        suite.append((f"kernel{k}",
                      Compilette(f"kernel{k}", sp, gen,
                                 gen_cost_s=GEN_COST_S),
                      base, {"unroll": 8, "sched": 1}))
    return suite


def run_process(registry_path, n_kernels: int, calls: int = 6000,
                strategy: str = "two_phase", gen_cache=None,
                async_generation=True, clock=None):
    """Simulate one process lifetime; return per-kernel time-to-best.

    ``clock`` is the HOST timeline: cold and warm runs of one scenario
    share it (together with the generation cache), because the cached
    virtual kernels close over the clock they were compiled with —
    per-run times are therefore reported relative to process start.
    """
    clock = clock if clock is not None else VirtualClock()
    t_start = clock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.5),
        registry_path=registry_path, device=DEVICE, clock=clock,
        strategy=strategy, async_generation=async_generation,
        generation_cache=gen_cache, prefetch=1)
    cache = coord.generation_cache
    hits0, misses0 = cache.hits, cache.misses
    managed = []
    for name, comp, base, best in make_kernel_suite(clock, n_kernels):
        m = coord.register(name, comp, ev,
                           reference_fn=virtual_kernel(clock, base))
        managed.append((m, best))

    to_best = {m.name: None for m, _ in managed}
    regens_at_best = {m.name: None for m, _ in managed}
    # per-kernel replay bill: this kernel's compile charge/stall at the
    # moment it is RUNNING its best-known variant again
    replay_gen = {m.name: None for m, _ in managed}
    replay_stall = {m.name: None for m, _ in managed}
    for i in range(calls):
        for m, best in managed:
            m(i)
            if to_best[m.name] is None and m.tuner._active_life.point == best:
                to_best[m.name] = clock() - t_start
                regens_at_best[m.name] = m.tuner.accounts.regenerations
                replay_gen[m.name] = m.tuner.accounts.gen_spent_s
                replay_stall[m.name] = m.tuner.accounts.gen_stall_s
        coord.maybe_pump()
    coord.save_registry()
    stats = coord.stats()
    hits, misses = cache.hits - hits0, cache.misses - misses0
    return {
        "time_to_best_s": to_best,
        "regens_to_best": regens_at_best,
        "total_regens": stats["regenerations"],
        "overhead_frac": stats["overhead_frac"],
        "gen_spent_s": stats["gen_spent_s"],
        "gen_stall_s": stats["gen_stall_s"],
        "cache_hit_rate": hits / (hits + misses) if hits + misses else 0.0,
        # the replay-to-best phase: what each kernel paid in compilation
        # before it was RUNNING its persisted best again
        "replay_gen_s": replay_gen,
        "replay_stall_s": replay_stall,
        "warm": [m.warm_started for m, _ in managed],
        "wall_s": clock() - t_start,
    }


def main() -> None:
    from repro.core import available_strategies

    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="two_phase",
                    choices=available_strategies())
    ap.add_argument("--sync", action="store_true",
                    help="synchronous generation (paper's original cycle): "
                         "compiles stall the hot path")
    args = ap.parse_args()
    async_generation = not args.sync

    rows = []
    results = {}
    for n_kernels in (1, 4):
        # one PROCESS-WIDE compiled-variant cache shared by the cold and
        # warm "processes" (the deployment analogue: a host-level
        # persistent compilation cache surviving a binary restart) — and
        # therefore one HOST clock, since cached virtual kernels advance
        # the clock they were compiled with
        gen_cache = GenerationCache()
        host_clock = VirtualClock()
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tuned.json")
            cold = run_process(path, n_kernels, strategy=args.strategy,
                               gen_cache=gen_cache, clock=host_clock,
                               async_generation=async_generation)
            warm = run_process(path, n_kernels, strategy=args.strategy,
                               gen_cache=gen_cache, clock=host_clock,
                               async_generation=async_generation)
        results[n_kernels] = (cold, warm)
        for phase, r in (("cold", cold), ("warm", warm)):
            ttb = [v for v in r["time_to_best_s"].values() if v is not None]
            rtb = [v for v in r["regens_to_best"].values() if v is not None]
            rows.append({
                "kernels": n_kernels,
                "start": phase,
                "reached_best": f"{len(ttb)}/{n_kernels}",
                "regens_to_best(max)": max(rtb) if rtb else None,
                "time_to_best_s(max)": max(ttb) if ttb else None,
                "total_regens": r["total_regens"],
                "overhead_%": 100 * r["overhead_frac"],
                "gen_stall_ms": 1e3 * r["gen_stall_s"],
                "cache_hit_%": 100 * r["cache_hit_rate"],
            })
    print(table(rows, ["kernels", "start", "reached_best",
                       "regens_to_best(max)", "time_to_best_s(max)",
                       "total_regens", "overhead_%", "gen_stall_ms",
                       "cache_hit_%"],
                title="coordinator cold vs warm start (virtual seconds)"))
    save("coordinator_warmstart", rows)

    cold1 = next(r for r in rows if r["kernels"] == 1 and r["start"] == "cold")
    warm1 = next(r for r in rows if r["kernels"] == 1 and r["start"] == "warm")
    speedup = cold1["time_to_best_s(max)"] / warm1["time_to_best_s(max)"]
    print(f"\nwarm start reaches best {speedup:.1f}x sooner "
          f"({warm1['regens_to_best(max)']} vs "
          f"{cold1['regens_to_best(max)']} regenerations)")

    # ---- CI smoke assertions (deterministic: VirtualClock) --------------
    for n_kernels, (cold, warm) in results.items():
        # the warm-start replay — everything a kernel generates up to
        # RUNNING its persisted best again — re-proposes only points the
        # cold process already compiled: a 100% generation-cache hit
        # rate, i.e. zero compile charge and zero hot-path stall, and a
        # single re-validating regeneration per kernel
        assert all(v == 1 for v in warm["regens_to_best"].values()), warm
        assert all(v == 0.0 for v in warm["replay_gen_s"].values()), warm
        assert all(v == 0.0 for v in warm["replay_stall_s"].values()), warm
        if async_generation:
            # double buffering: NO compile ever stalls the hot path
            assert cold["gen_stall_s"] == 0.0, (n_kernels, cold)
            assert warm["gen_stall_s"] == 0.0, (n_kernels, warm)
            print(f"[{n_kernels} kernel(s)] warm replay: 100% cache hit, "
                  f"0 stall; cold: {cold['gen_spent_s']*1e3:.0f} ms compile "
                  f"fully overlapped")
        else:
            assert cold["gen_stall_s"] > 0.0, (n_kernels, cold)
            print(f"[{n_kernels} kernel(s)] sync mode: hot path stalled "
                  f"{cold['gen_stall_s']*1e3:.0f} ms for compilation; "
                  f"warm replay still stall-free (cache)")


if __name__ == "__main__":
    main()
