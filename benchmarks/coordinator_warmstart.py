"""Cold-start vs warm-start time-to-best under the TuningCoordinator.

Two measurements per scenario, both fully deterministic on the
VirtualClock (simulated seconds, so numbers are reproducible anywhere):

  * regenerations-to-best — how many generate+evaluate cycles before the
    process is *running* its best-known variant;
  * time-to-best — simulated wall time from process start to that swap,
    including all kernel calls and tuning overhead.

The cold process explores the space from scratch; the warm process loads
the registry the cold one persisted and re-validates the stored best with
a single regeneration. A multi-kernel scenario shows the same effect when
one shared budget serves several kernels at once. ``--strategy`` runs the
same scenarios under any registered search strategy (the warm-start
economics are strategy-independent: the registry seed is always proposed
first).

    PYTHONPATH=src python benchmarks/coordinator_warmstart.py [--strategy greedy]
"""

import argparse
import os
import sys
import tempfile

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table

from repro.core import (
    Compilette, Param, RegenerationPolicy, VirtualClock,
    VirtualClockEvaluator, product_space, virtual_kernel,
)
from repro.runtime.coordinator import TuningCoordinator

DEVICE = "bench:virtual"


def make_kernel_suite(clock, n_kernels: int):
    """n kernels with distinct cost landscapes over an 8x2 point space."""
    suite = []
    for k in range(n_kernels):
        base = 0.004 * (k + 1)

        def cost_fn(p, base=base):
            return base / p["unroll"] + (0 if p["sched"] else base / 8)

        sp = product_space([
            Param("unroll", (1, 2, 4, 8), phase=1, switch_rank=0),
            Param("sched", (0, 1), phase=2),
        ])

        def gen(point, _cost_fn=cost_fn, **spec):
            return virtual_kernel(clock, _cost_fn(point))

        suite.append((f"kernel{k}", Compilette(f"kernel{k}", sp, gen), base,
                      {"unroll": 8, "sched": 1}))
    return suite


def run_process(registry_path, n_kernels: int, calls: int = 6000,
                strategy: str = "two_phase"):
    """Simulate one process lifetime; return per-kernel time-to-best."""
    clock = VirtualClock()
    ev = VirtualClockEvaluator(clock)
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=0.05, invest_frac=0.5),
        registry_path=registry_path, device=DEVICE, clock=clock,
        strategy=strategy)
    managed = []
    for name, comp, base, best in make_kernel_suite(clock, n_kernels):
        m = coord.register(name, comp, ev,
                           reference_fn=virtual_kernel(clock, base))
        managed.append((m, best))

    to_best = {m.name: None for m, _ in managed}
    regens_at_best = {m.name: None for m, _ in managed}
    for i in range(calls):
        for m, best in managed:
            m(i)
            if to_best[m.name] is None and m.tuner._active_life.point == best:
                to_best[m.name] = clock()
                regens_at_best[m.name] = m.tuner.accounts.regenerations
        coord.maybe_pump()
    coord.save_registry()
    stats = coord.stats()
    return {
        "time_to_best_s": to_best,
        "regens_to_best": regens_at_best,
        "total_regens": stats["regenerations"],
        "overhead_frac": stats["overhead_frac"],
        "warm": [m.warm_started for m, _ in managed],
        "wall_s": clock(),
    }


def main() -> None:
    from repro.core import available_strategies

    ap = argparse.ArgumentParser()
    ap.add_argument("--strategy", default="two_phase",
                    choices=available_strategies())
    args = ap.parse_args()

    rows = []
    for n_kernels in (1, 4):
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tuned.json")
            cold = run_process(path, n_kernels, strategy=args.strategy)
            warm = run_process(path, n_kernels, strategy=args.strategy)
        for phase, r in (("cold", cold), ("warm", warm)):
            ttb = [v for v in r["time_to_best_s"].values() if v is not None]
            rtb = [v for v in r["regens_to_best"].values() if v is not None]
            rows.append({
                "kernels": n_kernels,
                "start": phase,
                "reached_best": f"{len(ttb)}/{n_kernels}",
                "regens_to_best(max)": max(rtb) if rtb else None,
                "time_to_best_s(max)": max(ttb) if ttb else None,
                "total_regens": r["total_regens"],
                "overhead_%": 100 * r["overhead_frac"],
            })
    print(table(rows, ["kernels", "start", "reached_best",
                       "regens_to_best(max)", "time_to_best_s(max)",
                       "total_regens", "overhead_%"],
                title="coordinator cold vs warm start (virtual seconds)"))
    save("coordinator_warmstart", rows)

    cold1 = next(r for r in rows if r["kernels"] == 1 and r["start"] == "cold")
    warm1 = next(r for r in rows if r["kernels"] == 1 and r["start"] == "warm")
    speedup = cold1["time_to_best_s(max)"] / warm1["time_to_best_s(max)"]
    print(f"\nwarm start reaches best {speedup:.1f}x sooner "
          f"({warm1['regens_to_best(max)']} vs "
          f"{cold1['regens_to_best(max)']} regenerations)")


if __name__ == "__main__":
    main()
