"""Kernel-granular tuning plane: cold vs warm per-kernel economics.

Deterministic on the VirtualClock: the catalog's matmul / attention /
rmsnorm compilettes run in *virtual* mode (variants priced by their
analytical cost models on the TPU_V5E profile, compile cost declared), so
every number is reproducible anywhere.

Scenario: a cold process registers the three kernels through the
:class:`KernelTuningPlane` — each as an independent coordinator-managed
compilette with its own strategy (matmul=greedy, attention=random,
rmsnorm=two_phase) — and tunes them under ONE shared budget, persisting
its best points. A warm process (same registry, same process-wide
generation cache, same host clock — the restart-with-persistent-compile-
cache deployment) re-registers the same traffic.

CI smoke assertions:

  * every kernel in the warm process warm-starts and is RUNNING the cold
    process's best variant after exactly ONE re-validating regeneration;
  * the warm replay up to that point is a 100% generation-cache hit:
    zero compile charge, zero hot-path stall, per kernel;
  * per-kernel ``gen/stall/eval`` accounting sums consistently into the
    coordinator aggregate (the PR-4 acceptance rollup).

    PYTHONPATH=src python benchmarks/kernel_plane.py
"""

import os
import sys
import tempfile

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table

from repro.core import (
    GenerationCache,
    RegenerationPolicy,
    TPU_V5E,
    VirtualClock,
    VirtualClockEvaluator,
)
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.kernel_plane import KernelTuningPlane

DEVICE = "bench:virtual"
GEN_COST_S = 0.002

SPECS = {
    "matmul": {"M": 512, "N": 512, "K": 512, "dtype": "float32"},
    "attention": {"B": 4, "Tq": 512, "Tkv": 512, "H": 8, "Hk": 4,
                  "Dh": 64, "causal": True, "dtype": "float32"},
    "rmsnorm": {"N": 2048, "d": 512, "dtype": "float32"},
}
STRATEGIES = {"matmul": "greedy", "attention": "random",
              "rmsnorm": "two_phase"}


def run_process(registry_path, *, clock, gen_cache, targets=None,
                iters=4000):
    """One process lifetime over the three-kernel traffic.

    ``targets`` (kernel → point) makes this a WARM run: per-kernel
    time/regens/compile-bill are recorded at the moment the kernel is
    RUNNING that target variant again.
    """
    t_start = clock()
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=0.5, invest_frac=0.5),
        registry_path=registry_path, device=DEVICE, clock=clock,
        async_generation=True, generation_cache=gen_cache, prefetch=1)
    plane = KernelTuningPlane(
        coord, virtual=(clock, TPU_V5E), gen_cost_s=GEN_COST_S,
        evaluator_factory=lambda c: VirtualClockEvaluator(clock),
        strategies=STRATEGIES)
    handles = {n: plane.register_spec(n, s) for n, s in SPECS.items()}

    at_target = {n: None for n in handles}
    for i in range(iters):
        for n, h in handles.items():
            h(i)
            # the warm process has RE-VALIDATED the persisted best once
            # its explorer has measured it (the registry seed is proposed
            # first, so this fires at the first regeneration)
            if (targets is not None and at_target[n] is None
                    and h.tuner.accounts.regenerations >= 1
                    and h.tuner.explorer.best_point == targets[n]):
                at_target[n] = {
                    "time_s": clock() - t_start,
                    "regens": h.tuner.accounts.regenerations,
                    "gen_s": h.tuner.accounts.gen_spent_s,
                    "stall_s": h.tuner.accounts.gen_stall_s,
                }
        coord.pump()
        if all(h.tuner.explorer.finished for h in handles.values()):
            break
    coord.save_registry()
    stats = coord.stats()
    return {
        "handles": handles,
        "stats": stats,
        "warm": {n: h.warm_started for n, h in handles.items()},
        "best": {n: h.tuner.explorer.best_point
                 for n, h in handles.items()},
        "at_target": at_target,
        "wall_s": clock() - t_start,
    }


def main() -> None:
    # cold and warm share the host clock and the process-wide compiled-
    # variant cache (virtual kernels advance the clock they were built
    # with), exactly like benchmarks/coordinator_warmstart.py
    clock = VirtualClock()
    gen_cache = GenerationCache()
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tuned.json")
        cold = run_process(path, clock=clock, gen_cache=gen_cache)
        warm = run_process(path, clock=clock, gen_cache=gen_cache,
                           targets=cold["best"])

    rows = []
    for phase, r in (("cold", cold), ("warm", warm)):
        for name in SPECS:
            k = r["stats"]["kernels"][name]
            at = (r["at_target"] or {}).get(name)
            rows.append({
                "kernel": name,
                "start": phase,
                "strategy": k["strategy"],
                "warm_started": r["warm"][name],
                "regens": k["regenerations"],
                "swaps": k["swaps"],
                "gen_ms": 1e3 * k["gen_spent_s"],
                "stall_ms": 1e3 * k["gen_stall_s"],
                "regens_to_best": at["regens"] if at else None,
            })
    print(table(rows, ["kernel", "start", "strategy", "warm_started",
                       "regens", "swaps", "gen_ms", "stall_ms",
                       "regens_to_best"],
                title="kernel plane cold vs warm (virtual seconds)"))
    save("kernel_plane", rows)

    # ---- CI smoke assertions (deterministic: VirtualClock) --------------
    for name in SPECS:
        assert not cold["warm"][name], name
        assert warm["warm"][name], name
        at = warm["at_target"][name]
        # ONE re-validating regeneration puts the persisted best back in
        # service…
        assert at is not None and at["regens"] == 1, (name, at)
        # …and that replay compiled NOTHING: pure generation-cache hits,
        # zero budget charge, zero hot-path stall
        assert at["gen_s"] == 0.0 and at["stall_s"] == 0.0, (name, at)
    # double buffering: no compile ever stalls the hot path, either run
    assert cold["stats"]["gen_stall_s"] == 0.0
    assert warm["stats"]["gen_stall_s"] == 0.0
    # per-kernel accounting sums consistently into the aggregate
    for r in (cold, warm):
        s = r["stats"]
        for f in ("gen_spent_s", "gen_stall_s", "eval_spent_s"):
            rollup = (sum(k[f] for k in s["kernels"].values())
                      + s["retired_accounts"][f])
            assert abs(rollup - s[f]) < 1e-9, (f, rollup, s[f])
    print("\nwarm replay: every kernel back on its best variant after 1 "
          "regeneration, 100% cache hit, 0 compile charge, 0 stall")


if __name__ == "__main__":
    main()
