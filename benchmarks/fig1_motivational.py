"""Paper Fig. 1 — motivational static exploration.

Statically explores the euclid tuning space on two simulated cores
(Cortex-A8/A9 analogues: TI-L2 lean and TI-F2 fat) for the three
specialized dimensions. Reports best speedup vs the hand-vectorized
reference variant and the cross-core performance portability penalty
(paper: best-for-A8 run on A9 is 55 % slower, best-for-A9 on A8 21 %)."""

from __future__ import annotations

from repro.core import TwoPhaseExplorer
from repro.core.profiles import TI_F2, TI_L2
from repro.kernels.euclid.ops import make_euclid_compilette
from benchmarks.common import save, table

CORES = {"lean(TI-L2)": TI_L2, "fat(TI-F2)": TI_F2}
N_POINTS, M_CENTERS = 4096, 128


def reference_point():
    """The 'hand-vectorized reference': default vectorized variant."""
    return dict(block_n=64, block_m=32, block_d=16, unroll=1, vectorize=1,
                order="nm", scratch=1, lookahead=0)


def run(dims=(32, 64, 128)) -> dict:
    rows = []
    best_points = {}
    for dim in dims:
        comp = make_euclid_compilette(N_POINTS, M_CENTERS, dim)
        for cname, prof in CORES.items():
            ref_t = comp.simulate(reference_point(), prof)
            ex = TwoPhaseExplorer(comp.space)
            best, best_t = ex.run_to_completion(
                lambda p: comp.simulate(p, prof))
            n_valid = comp.space.n_valid_variants()
            best_points[(dim, cname)] = (best, best_t)
            rows.append({
                "dim": dim, "core": cname,
                "explorable": n_valid,
                "explored": ex.state.n_reported,
                "best_speedup_vs_ref": ref_t / best_t,
                "best_point": str({k: best[k] for k in
                                   ("block_n", "block_d", "unroll",
                                    "vectorize")}),
            })
    # cross-core portability penalty at the largest dim
    dim = dims[-1]
    comp = make_euclid_compilette(N_POINTS, M_CENTERS, dim)
    (bl, tl) = best_points[(dim, "lean(TI-L2)")]
    (bf, tf) = best_points[(dim, "fat(TI-F2)")]
    cross = {
        "best_lean_on_fat_penalty":
            comp.simulate(bl, TI_F2) / tf - 1.0,
        "best_fat_on_lean_penalty":
            comp.simulate(bf, TI_L2) / tl - 1.0,
    }
    out = {"rows": rows, "cross_core": cross}
    print(table(rows, ["dim", "core", "explorable", "explored",
                       "best_speedup_vs_ref", "best_point"],
                "Fig.1 — static exploration (simulated cores)"))
    print(f"cross-core penalty: best-lean-on-fat +{cross['best_lean_on_fat_penalty']:.0%}, "
          f"best-fat-on-lean +{cross['best_fat_on_lean_penalty']:.0%}")
    save("fig1_motivational", out)
    return out


if __name__ == "__main__":
    run()
