"""Paper Table 3 — execution times on the REAL platform (XLA:CPU).

For the CPU-bound (euclid/Streamcluster) and memory-bound (lintra/VIPS)
kernels, three input sizes each, measures:

  Ref       — compiler-default reference (SISD formulation)
  Spec-Ref  — hand-vectorized reference (SIMD formulation, specialized)
  O-AT      — online auto-tuned, ALL overheads included in the wall time
  BS-AT     — best statically auto-tuned variant (steady-state time)

The application is a loop of kernel calls (hundreds of ms to seconds),
matching the paper's short-running setting.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import (
    Evaluator, OnlineAutotuner, RegenerationPolicy, static_autotune)
from repro.kernels.euclid import ops as euclid
from repro.kernels.lintra import ops as lintra
from benchmarks.common import save, table

EUCLID_SIZES = {"small": 32, "medium": 64, "large": 128}
LINTRA_SIZES = {"small": (160, 200), "medium": (292, 292), "large": (332, 687)}
N_POINTS, M_CENTERS = 1024, 64
CALLS = 800


def _wall(fn, args, calls=CALLS) -> float:
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(calls):
        out = fn(*args)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def _wall_online(at, args, calls=CALLS) -> float:
    """Online-autotuned application run: tuning overheads inside."""
    t0 = time.perf_counter()
    for _ in range(calls):
        out = at(*args)
    jax.block_until_ready(out)
    return time.perf_counter() - t0


def bench_euclid(size_name: str, dim: int) -> dict:
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (N_POINTS, dim), jnp.float32)
    c = jax.random.normal(jax.random.PRNGKey(1), (M_CENTERS, dim), jnp.float32)
    args = (x, c)
    ref = jax.jit(euclid.reference_sisd(dim))
    spec_ref = jax.jit(euclid.reference_simd(dim))
    t_ref = _wall(ref, args)
    t_spec = _wall(spec_ref, args)

    comp = euclid.make_euclid_compilette(N_POINTS, M_CENTERS, dim)
    # NB: one XLA:CPU jit takes ~100-300 ms vs deGoal's us-scale codegen,
    # so the same budget policy admits fewer variants per second of app
    # time than the paper's runs; the budget mechanics are identical.
    ev = Evaluator(mode="training", groups=1, group_size=3,
                   make_args=lambda: args)
    at = OnlineAutotuner(comp, ev, policy=RegenerationPolicy(0.05, 0.15),
                         specialization={"dim": dim},
                         reference_fn=ref, wake_every=2)
    t_oat = _wall_online(at, args)
    stats = at.stats()

    _, bs_score, _ = static_autotune(
        comp, ev, specialization={"dim": dim}, only_no_leftover=True,
        max_points=30)
    t_bsat = bs_score * CALLS
    return {
        "bench": "euclid", "input": size_name,
        "Ref_s": t_ref, "SpecRef_s": t_spec, "OAT_s": t_oat,
        "BSAT_s": t_bsat,
        "OAT_speedup": t_ref / t_oat,
        "overhead_frac": stats["overhead_frac"],
        "explored": stats["n_explored"],
        "_stats": stats,
    }


def bench_lintra(size_name: str, hw: tuple[int, int]) -> dict:
    H, W = hw
    bands = 3
    key = jax.random.PRNGKey(0)
    img = jax.random.normal(key, (H, W, bands), jnp.float32)
    a = jnp.array([1.5, 0.5, 2.0])
    b = jnp.array([0.1, -0.2, 0.3])
    args = (img, a, b)
    ref = jax.jit(lintra.reference_sisd(bands, W))
    spec_ref = jax.jit(lintra.reference_simd(bands, W))
    t_ref = _wall(ref, args)
    t_spec = _wall(spec_ref, args)

    comp = lintra.make_lintra_compilette(H, W, bands)
    ev = Evaluator(mode="training", groups=1, group_size=3,
                   make_args=lambda: args)
    at = OnlineAutotuner(comp, ev, policy=RegenerationPolicy(0.05, 0.15),
                         specialization={"bands": bands, "width": W},
                         reference_fn=ref, wake_every=2)
    t_oat = _wall_online(at, args)
    stats = at.stats()
    _, bs_score, _ = static_autotune(
        comp, ev, specialization={"bands": bands, "width": W},
        max_points=25)
    return {
        "bench": "lintra", "input": size_name,
        "Ref_s": t_ref, "SpecRef_s": t_spec, "OAT_s": t_oat,
        "BSAT_s": bs_score * CALLS,
        "OAT_speedup": t_ref / t_oat,
        "overhead_frac": stats["overhead_frac"],
        "explored": stats["n_explored"],
        "_stats": stats,
    }


def run(quick: bool = False) -> dict:
    rows = []
    euclid_sizes = dict(list(EUCLID_SIZES.items())[:1]) if quick else EUCLID_SIZES
    lintra_sizes = dict(list(LINTRA_SIZES.items())[:1]) if quick else LINTRA_SIZES
    for name, dim in euclid_sizes.items():
        rows.append(bench_euclid(name, dim))
    for name, hw in lintra_sizes.items():
        rows.append(bench_lintra(name, hw))
    cols = ["bench", "input", "Ref_s", "SpecRef_s", "OAT_s", "BSAT_s",
            "OAT_speedup", "overhead_frac", "explored"]
    print(table(rows, cols, "Table 3 — execution times, real platform "
                            "(XLA:CPU), all overheads included"))
    save("table3_exec_times", rows)
    return {"rows": rows}


if __name__ == "__main__":
    run()
