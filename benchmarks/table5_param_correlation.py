"""Paper Table 5 / Fig. 8 — best-parameter ↔ pipeline-feature correlation.

Runs the full exploration on all 11 simulated cores for euclid and matmul
compilettes, tabulates the winning parameters, and computes simple
correlations with the pipeline features (paper §5.4):

  * unroll (hotUF)  ↔ dynamic scheduling (lean cores want more unrolling)
  * block sizes     ↔ issue width / VMEM
  * lookahead (pld) ↔ lean cores (fat cores hide DMA latency in hardware)
"""

from __future__ import annotations

import statistics

from repro.core import TwoPhaseExplorer
from repro.core.profiles import ALL_PROFILES
from repro.kernels.euclid.ops import make_euclid_compilette
from repro.kernels.matmul.ops import make_matmul_compilette
from benchmarks.common import save, table


def _pearson(xs, ys):
    if len(set(xs)) < 2 or len(set(ys)) < 2:
        return 0.0
    mx, my = statistics.mean(xs), statistics.mean(ys)
    num = sum((a - mx) * (b - my) for a, b in zip(xs, ys))
    den = (sum((a - mx) ** 2 for a in xs) *
           sum((b - my) ** 2 for b in ys)) ** 0.5
    return num / den if den else 0.0


def run() -> dict:
    comps = {
        "euclid": make_euclid_compilette(4096, 128, 64),
        "matmul": make_matmul_compilette(2048, 2048, 2048),
    }
    rows = []
    for prof in ALL_PROFILES:
        row = {"core": prof.name, "lean": int(not prof.overlap),
               "issue": prof.issue, "vpus": prof.vpus}
        for kname, comp in comps.items():
            ex = TwoPhaseExplorer(comp.space)
            bp, _ = ex.run_to_completion(lambda p: comp.simulate(p, prof))
            row[f"{kname}_unroll"] = bp["unroll"]
            row[f"{kname}_lookahead"] = bp["lookahead"]
            if kname == "matmul":
                row["matmul_bk"] = bp["block_k"]
                row["matmul_bm"] = bp["block_m"]
            else:
                row["euclid_bd"] = bp["block_d"]
                row["euclid_vect"] = bp["vectorize"]
        rows.append(row)

    corr = {
        "unroll_vs_lean(euclid)": _pearson(
            [r["lean"] for r in rows], [r["euclid_unroll"] for r in rows]),
        "unroll_vs_lean(matmul)": _pearson(
            [r["lean"] for r in rows], [r["matmul_unroll"] for r in rows]),
        "lookahead_vs_lean(matmul)": _pearson(
            [r["lean"] for r in rows], [r["matmul_lookahead"] for r in rows]),
        "block_d_vs_issue(euclid)": _pearson(
            [r["issue"] for r in rows], [r["euclid_bd"] for r in rows]),
        "block_k_vs_issue(matmul)": _pearson(
            [r["issue"] for r in rows], [r["matmul_bk"] for r in rows]),
    }
    print(table(rows, list(rows[0].keys()),
                "Table 5 — best auto-tuned parameters per simulated core"))
    print("correlations:", {k: round(v, 2) for k, v in corr.items()})
    out = {"rows": rows, "correlations": corr}
    save("table5_param_correlation", out)
    return out


if __name__ == "__main__":
    run()
