"""Compile farm: cold-start time-to-best vs worker count, M in {1, 2, 4}.

Deterministic on the VirtualClock: four catalog kernels (matmul,
attention, rmsnorm, euclid) tune in *virtual* mode under one shared
budget while a serving loop accrues busy time. The coordinator's farm
runs in ``"manual"`` mode with max-overlap semantics — one pump
completes one batch of up to M compiles whose wall time hides inside
the serving interval, so M workers let M kernels make progress per
pump instead of one.

CI smoke assertions:

  * time-to-best (virtual time until EVERY kernel finished exploring)
    shrinks monotonically with M, and M=4 beats M=1 by >= 2x;
  * ``gen_stall_s == 0`` at every M: no compile ever blocked serving;
  * two same-seed cold runs are byte-identical at every M (stats and
    farm counters compare equal as JSON);
  * per-kernel gen/stall/eval accounting sums into the aggregate
    exactly (|diff| < 1e-9);
  * a warm replay (same registry + generation cache) is a 100%
    cache hit: every kernel back on its best variant after one
    re-validating regeneration, zero compile charge, zero stall.

    PYTHONPATH=src python benchmarks/compile_farm.py
"""

import json
import os
import sys
import tempfile

sys.path.insert(0, "src")
sys.path.insert(0, os.path.dirname(__file__))

from common import save, table

from repro.core import (
    GenerationCache,
    RegenerationPolicy,
    TPU_V5E,
    VirtualClock,
    VirtualClockEvaluator,
)
from repro.runtime.coordinator import TuningCoordinator
from repro.runtime.kernel_plane import KernelTuningPlane

DEVICE = "bench:virtual"
GEN_COST_S = 0.001          # declared compile cost per variant
STEP_BUSY_S = 0.010         # the serving step the compiles overlap with
WORKER_SWEEP = (1, 2, 4)

SPECS = {
    "matmul": {"M": 256, "N": 256, "K": 256, "dtype": "float32"},
    "attention": {"B": 2, "Tq": 128, "Tkv": 128, "H": 4, "Hk": 2,
                  "Dh": 32, "causal": True, "dtype": "float32"},
    "rmsnorm": {"N": 512, "d": 256, "dtype": "float32"},
    "euclid": {"N": 128, "M": 64, "D": 32, "dtype": "float32"},
}


def run_process(workers, *, clock, gen_cache, registry_path,
                targets=None, iters=30000):
    """One process lifetime over the 4-kernel serve traffic.

    ``targets`` (kernel -> point) makes this a WARM run: per-kernel
    regens/compile-bill are recorded the moment the kernel is running
    that target variant again.
    """
    t_start = clock()
    coord = TuningCoordinator(
        policy=RegenerationPolicy(max_overhead_frac=0.5, invest_frac=0.5),
        registry_path=registry_path, device=DEVICE, clock=clock,
        async_generation=True, generation_cache=gen_cache,
        prefetch=2, compile_workers=workers)
    plane = KernelTuningPlane(
        coord, virtual=(clock, TPU_V5E), gen_cost_s=GEN_COST_S,
        evaluator_factory=lambda c: VirtualClockEvaluator(clock))
    handles = {n: plane.register_spec(n, s) for n, s in SPECS.items()}

    finished_at = {}
    at_target = {n: None for n in handles}
    for i in range(iters):
        for n, h in handles.items():
            h(i)
            if (targets is not None and at_target[n] is None
                    and h.tuner.accounts.regenerations >= 1
                    and h.tuner.explorer.best_point == targets[n]):
                at_target[n] = {
                    "regens": h.tuner.accounts.regenerations,
                    "gen_s": h.tuner.accounts.gen_spent_s,
                    "stall_s": h.tuner.accounts.gen_stall_s,
                }
        # the serving step: busy time the budget accrues from, and the
        # interval the farm's compile batches overlap with
        clock.advance(STEP_BUSY_S)
        coord.observe_busy(STEP_BUSY_S)
        coord.pump()
        for n, h in handles.items():
            if n not in finished_at and h.tuner.explorer.finished:
                finished_at[n] = clock() - t_start
        if len(finished_at) == len(handles):
            break
    coord.save_registry()
    return {
        "stats": coord.stats(),
        "farm": coord.generator.stats(),
        "best": {n: h.tuner.explorer.best_point
                 for n, h in handles.items()},
        "warm": {n: h.warm_started for n, h in handles.items()},
        "finished_at": finished_at,
        "time_to_best": max(finished_at.values()) if finished_at else None,
        "at_target": at_target,
    }


def cold_run(workers):
    clock = VirtualClock()
    with tempfile.TemporaryDirectory() as d:
        return run_process(
            workers, clock=clock, gen_cache=GenerationCache(),
            registry_path=os.path.join(d, "tuned.json"))


def main() -> None:
    rows, results = [], {}
    for workers in WORKER_SWEEP:
        r = cold_run(workers)
        results[workers] = r
        assert r["time_to_best"] is not None, (
            f"M={workers}: kernels never finished exploring")

        # determinism: an identical second run must be byte-identical
        r2 = cold_run(workers)
        for field in ("stats", "farm"):
            a = json.dumps(r[field], sort_keys=True, default=str)
            b = json.dumps(r2[field], sort_keys=True, default=str)
            assert a == b, f"M={workers}: non-deterministic {field}"

        # warm replay on the cold run's registry + compiled-variant cache
        clock = VirtualClock()
        gen_cache = GenerationCache()
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "tuned.json")
            cold = run_process(workers, clock=clock, gen_cache=gen_cache,
                               registry_path=path)
            warm = run_process(workers, clock=clock, gen_cache=gen_cache,
                               registry_path=path, targets=cold["best"])

        s, f = r["stats"], r["farm"]
        rows.append({
            "workers": workers,
            "time_to_best_s": r["time_to_best"],
            "gen_ms": 1e3 * s["gen_spent_s"],
            "stall_ms": 1e3 * s["gen_stall_s"],
            "regens": s["regenerations"],
            "speculative": f["speculative_submitted"],
            "rejected_spec": f["rejected_speculative"],
            "warm_gen_ms": 1e3 * warm["stats"]["gen_spent_s"],
        })

        # ---- CI smoke assertions (deterministic: VirtualClock) ----------
        assert s["gen_stall_s"] == 0.0, workers
        assert f["mode"] == "manual" and f["workers"] == workers
        for field in ("gen_spent_s", "gen_stall_s", "eval_spent_s"):
            rollup = (sum(k[field] for k in s["kernels"].values())
                      + s["retired_accounts"][field])
            assert abs(rollup - s[field]) < 1e-9, (workers, field)
        # warm replay: every kernel re-validates its persisted best with
        # ONE regeneration and compiles NOTHING (pure cache hits)
        for name in SPECS:
            assert warm["warm"][name], (workers, name)
            at = warm["at_target"][name]
            assert at is not None and at["regens"] == 1, (workers, name, at)
            assert at["gen_s"] == 0.0 and at["stall_s"] == 0.0, (
                workers, name, at)
        assert warm["stats"]["gen_stall_s"] == 0.0

    print(table(rows, ["workers", "time_to_best_s", "gen_ms", "stall_ms",
                       "regens", "speculative", "rejected_spec",
                       "warm_gen_ms"],
                title="compile farm cold-start sweep (virtual seconds)"))
    save("compile_farm", rows)

    # scaling: monotone in M, and the 4-worker farm at least halves the
    # single-worker cold start
    ttb = {w: results[w]["time_to_best"] for w in WORKER_SWEEP}
    assert ttb[4] <= ttb[2] <= ttb[1], ttb
    speedup = ttb[1] / ttb[4]
    assert speedup >= 2.0, f"M=4 speedup {speedup:.2f}x < 2x: {ttb}"
    print(f"\ncold-start time-to-best: {ttb[1]:.3f}s (M=1) -> "
          f"{ttb[4]:.3f}s (M=4), {speedup:.2f}x faster; stall 0 at every M; "
          "warm replay 100% cache hit")


if __name__ == "__main__":
    main()
