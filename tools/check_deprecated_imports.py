#!/usr/bin/env python
"""Fail on new imports of deprecated tuning constructors.

PR 5's session API (``repro.api``) is the single front door to the
tuning machinery: runtime and launch modules must construct through
``repro.TuningSession``, never ``TuningCoordinator`` /
``KernelTuningPlane`` / ``make_serve_coordinator`` directly. pyflakes
keeps ``src/`` clean of unused imports; this companion check makes the
*specific* deprecated imports fail CI (and the tier-1 suite, via
``tests/test_api.py``) so the collapsed entry points cannot creep back
into ``src/repro/runtime/`` or ``src/repro/launch/``.
"""

from __future__ import annotations

import ast
import pathlib
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent
SCOPES = ("src/repro/runtime", "src/repro/launch")
FORBIDDEN = {
    "TuningCoordinator",
    "KernelTuningPlane",
    "make_serve_coordinator",
}
# the modules that define the machinery itself (the plane module imports
# the coordinator it manages)
ALLOWED_FILES = {
    "src/repro/runtime/coordinator.py",
    "src/repro/runtime/kernel_plane.py",
}


def violations(root: pathlib.Path = ROOT) -> list[str]:
    out: list[str] = []
    for scope in SCOPES:
        for path in sorted((root / scope).rglob("*.py")):
            rel = path.relative_to(root).as_posix()
            if rel in ALLOWED_FILES:
                continue
            tree = ast.parse(path.read_text(), filename=rel)
            for node in ast.walk(tree):
                if isinstance(node, ast.ImportFrom):
                    names = [a.name for a in node.names]
                elif isinstance(node, ast.Import):
                    names = [a.name.rsplit(".", 1)[-1] for a in node.names]
                else:
                    continue
                for name in names:
                    if name in FORBIDDEN:
                        out.append(
                            f"{rel}:{node.lineno}: imports deprecated "
                            f"constructor {name!r} — go through "
                            f"repro.TuningSession (repro/api.py)")
    return out


def main() -> int:
    found = violations()
    for line in found:
        print(line)
    if found:
        return 1
    print("ok: no deprecated-constructor imports under "
          + " or ".join(SCOPES))
    return 0


if __name__ == "__main__":
    sys.exit(main())
