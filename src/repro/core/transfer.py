"""Transfer plane: cross-device warm starts ranked by trait similarity.

The registry warm-starts only on an *exact* device fingerprint, so a
heterogeneous fleet re-explores from cold on every new hardware profile —
exactly the cost the paper's Fig. 5/6 study shows online tuning should
amortize. This module closes that gap:

  * :class:`DeviceTraits` — a quantitative vector describing the device a
    registry entry was tuned on: peak fused-math throughput, memory
    bandwidth, on-chip scratch (VMEM), issue width and whether compute/DMA
    overlap. Derived from a :class:`~repro.core.profiles.DeviceProfile`
    for virtual backends, and from the platform fingerprint plus a
    cost-model probe for real ones. The coordinator attaches it to every
    ``TunedRegistry.put`` at save time.
  * :func:`similarity` — normalized distance over the trait axes mapped
    to ``(0, 1]``: throughput-like axes compare on log-ratio (a 2x faster
    device is as far from 1x as 4x is from 2x), the overlap axis is
    categorical (lean vs fat cores want different code shapes).
  * :func:`transfer_seeds` — on a fingerprint miss, the nearest-
    fingerprint lookup: rank every foreign device's best for the same
    (kernel, specialization) by trait similarity, apply a
    ``min_similarity`` floor, return the top-k. The caller feeds these
    into the search strategy as *transfer seeds* via
    ``SearchStrategy.inject_candidate`` — stripe-exempt like warm seeds,
    but flowing through the normal generate/evaluate/gate/canary path as
    CANDIDATEs. A transfer seed is never a blind incumbent.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Mapping

from repro.core.persistence import TunedRegistry, _canon
from repro.core.profiles import TPU_V5E, DeviceProfile

#: The axes of a trait vector, in canonical order. ``flops``,
#: ``bandwidth_gbps``, ``vmem_kb`` and ``issue`` are compared on
#: log-ratio; ``overlap`` is categorical (0.0 = lean/in-order,
#: 1.0 = fat/out-of-order).
TRAIT_AXES: tuple[str, ...] = (
    "flops", "bandwidth_gbps", "vmem_kb", "issue", "overlap")

# Distance charged for disagreeing on the categorical overlap axis: a
# lean and a fat core differ architecturally about as much as a 4x
# throughput gap (the paper's IO-vs-OOO split moves the optimum more
# than a clock bump does).
_OVERLAP_DISTANCE = math.log(4.0)


@dataclasses.dataclass(frozen=True)
class DeviceTraits:
    """Quantitative identity of the device a tuned point was found on."""

    flops: float           # peak fused-math throughput, FLOP/s
    bandwidth_gbps: float  # main-memory bandwidth, GB/s
    vmem_kb: float         # on-chip scratch, kB
    issue: float           # issue width
    overlap: float         # 1.0 = compute/DMA overlap, 0.0 = serialized

    def to_dict(self) -> dict[str, float]:
        return {axis: float(getattr(self, axis)) for axis in TRAIT_AXES}

    @classmethod
    def from_dict(cls, d: Any) -> "DeviceTraits | None":
        """Tolerant parse of a persisted trait dict; None unless every
        axis is present, numeric and finite (a registry written by a
        newer layout must degrade to no-transfer, not crash)."""
        if not isinstance(d, Mapping):
            return None
        values: dict[str, float] = {}
        for axis in TRAIT_AXES:
            v = d.get(axis)
            if not isinstance(v, (int, float)) or not math.isfinite(v):
                return None
            values[axis] = float(v)
        return cls(**values)

    @classmethod
    def from_profile(cls, profile: DeviceProfile) -> "DeviceTraits":
        return cls(
            flops=float(profile.peak_flops),
            bandwidth_gbps=float(profile.hbm_gbps),
            vmem_kb=float(profile.vmem_kb),
            issue=float(profile.issue),
            overlap=1.0 if profile.overlap else 0.0,
        )


def similarity(a: DeviceTraits, b: DeviceTraits) -> float:
    """Trait similarity in ``(0, 1]``; 1.0 = identical trait vectors.

    Mean per-axis distance mapped through ``exp(-d)``: throughput-like
    axes contribute ``|ln(a/b)|`` (scale-free), the overlap axis a fixed
    architectural penalty. Symmetric, and monotone in every axis gap.
    """
    d = 0.0
    for axis in ("flops", "bandwidth_gbps", "vmem_kb", "issue"):
        x = max(float(getattr(a, axis)), 1e-12)
        y = max(float(getattr(b, axis)), 1e-12)
        d += abs(math.log(x / y))
    d += _OVERLAP_DISTANCE * abs(a.overlap - b.overlap)
    return math.exp(-d / len(TRAIT_AXES))


# Nominal (profile, traits) per platform fingerprint prefix. Real
# backends have no DeviceProfile; the platform string picks a nominal
# profile and :func:`calibrated_traits` refines its throughput axes
# with a cost-model probe against the observed reference time.
_CPU_NOMINAL = dataclasses.replace(
    TPU_V5E, name="cpu-host", vpus=1, mxu_tflops=0.5,
    hbm_gbps=64.0, vmem_kb=1024, grid_step_overhead_ns=40.0)
_GPU_NOMINAL = dataclasses.replace(
    TPU_V5E, name="gpu-generic", mxu_tflops=90.0, hbm_gbps=900.0,
    vmem_kb=20 * 1024)
_PLATFORM_NOMINALS: tuple[tuple[str, DeviceProfile], ...] = (
    ("tpu", TPU_V5E),
    ("gpu", _GPU_NOMINAL),
    ("cuda", _GPU_NOMINAL),
    ("rocm", _GPU_NOMINAL),
    ("cpu", _CPU_NOMINAL),
)


def traits_from_fingerprint(device: str | None) -> DeviceTraits | None:
    """Best-effort traits for a real device fingerprint.

    The fingerprint's platform prefix (``platform:device_kind:...``)
    selects a nominal profile; unknown platforms yield None (the
    transfer plane then simply stays cold — never a wrong seed ranked
    by made-up numbers).
    """
    if not device:
        return None
    platform = str(device).split(":", 1)[0].strip().lower()
    for prefix, profile in _PLATFORM_NOMINALS:
        if platform.startswith(prefix):
            return DeviceTraits.from_profile(profile)
    return None


def device_traits(
    compilette: Any = None,
    device: str | None = None,
    profile: DeviceProfile | None = None,
) -> DeviceTraits | None:
    """Traits of the device ``compilette`` is being tuned on.

    Precedence: an explicit ``profile``, then the compilette's virtual
    marker (``compilette.virtual == (clock, profile)`` on simulated
    backends), then the platform fingerprint table. None when nothing
    is known — callers must treat that as transfer-disabled.
    """
    if profile is not None:
        return DeviceTraits.from_profile(profile)
    virtual = getattr(compilette, "virtual", None)
    if (isinstance(virtual, tuple) and len(virtual) == 2
            and virtual[1] is not None):
        return DeviceTraits.from_profile(virtual[1])
    return traits_from_fingerprint(device)


def calibrated_traits(
    traits: DeviceTraits | None,
    compilette: Any,
    specialization: Mapping[str, Any] | None,
    observed_score_s: float | None,
    device: str | None = None,
) -> DeviceTraits | None:
    """Refine fingerprint-table traits with one cost-model probe.

    Two real devices sharing a platform string (e.g. two ``cpu`` hosts
    of very different silicon) must not rank as identical. When the
    compilette carries a cost model, the ratio of its predicted
    reference time under the nominal platform profile to the *observed*
    reference time estimates how much faster/slower this device is than
    nominal; the throughput axes are scaled by it (clamped to 8x either
    way — a probe is a probe, not a benchmark). Virtual backends pass
    through unchanged: their traits already come from the exact profile.
    """
    if traits is None:
        return None
    virtual = getattr(compilette, "virtual", None)
    if isinstance(virtual, tuple) and len(virtual) == 2:
        return traits
    model = getattr(compilette, "cost_model", None)
    if (model is None or observed_score_s is None
            or not isinstance(observed_score_s, (int, float))
            or not math.isfinite(observed_score_s)
            or observed_score_s <= 0.0):
        return traits
    platform = str(device or "").split(":", 1)[0].strip().lower()
    profile = next(
        (nominal for prefix, nominal in _PLATFORM_NOMINALS
         if platform.startswith(prefix)), None)
    if profile is None:
        return traits
    try:
        predicted = float(model(
            dict(compilette.space.default_point()),
            dict(specialization or {}), profile))
    except Exception:
        return traits
    if not math.isfinite(predicted) or predicted <= 0.0:
        return traits
    ratio = min(max(predicted / float(observed_score_s), 1.0 / 8.0), 8.0)
    return dataclasses.replace(
        traits,
        flops=traits.flops * ratio,
        bandwidth_gbps=traits.bandwidth_gbps * ratio,
    )


@dataclasses.dataclass(frozen=True)
class TransferSeed:
    """One foreign best proposed as a transfer seed (a CANDIDATE)."""

    point: dict[str, Any]
    score_s: float         # the score on the FOREIGN device, not here
    device: str            # foreign registry device key
    similarity: float


def transfer_seeds(
    registry: TunedRegistry,
    kernel: str,
    specialization: dict[str, Any],
    device: str,
    traits: DeviceTraits | None,
    *,
    top_k: int = 3,
    min_similarity: float = 0.75,
) -> list[TransferSeed]:
    """Nearest-fingerprint lookup: top-k foreign bests by trait similarity.

    Scans every registry entry for the same (kernel, specialization)
    under a *different* device fingerprint, ranks the ones carrying
    traits by :func:`similarity` against the local traits, drops rows
    below ``min_similarity``, dedups by point (keeping the most similar
    donor) and returns at most ``top_k`` seeds — most similar first,
    deterministic under ties. Points condemned under ANY device key
    never surface (a seed that failed one device's oracle is blocked
    fleet-wide, not just where it failed), and the caller's explorer
    re-checks its local quarantine on injection.
    """
    if traits is None or top_k <= 0:
        return []
    banned = {_canon(p) for p in registry.fleet_quarantined_points(
        kernel, specialization)}
    ranked: list[TransferSeed] = []
    for dev, entry in registry.cross_device_entries(
            kernel, specialization, exclude_device=device):
        foreign = DeviceTraits.from_dict(entry.get("traits"))
        if foreign is None:
            continue
        sim = similarity(traits, foreign)
        if sim < min_similarity:
            continue
        point = entry.get("point")
        if not isinstance(point, dict) or _canon(point) in banned:
            continue
        ranked.append(TransferSeed(
            point=dict(point), score_s=float(entry["score_s"]),
            device=str(dev), similarity=sim))
    ranked.sort(key=lambda s: (-s.similarity, s.score_s,
                               _canon(s.point), s.device))
    seen: set[str] = set()
    out: list[TransferSeed] = []
    for seed in ranked:
        pk = _canon(seed.point)
        if pk in seen:
            continue
        seen.add(pk)
        out.append(seed)
        if len(out) >= top_k:
            break
    return out
