"""Regeneration decision (paper §3.3, extended for serving).

Two factors decide whether the auto-tuning thread may generate+evaluate a
new variant when it wakes up:

  * **overhead budget** — total tuning time (generation + evaluation) must
    stay below ``max_overhead_frac`` of the application time elapsed so
    far. This bounds the cost when tuning never finds anything better
    (paper: 0.2–4.2 % observed).
  * **investment factor** — a fraction ``invest_frac`` of the *time gained*
    by previously found variants may be re-invested into further
    exploration (paper: e.g. invest 10 % of gained time).

Gain estimation (paper §3.3): the only instrumentation is a counter of
kernel invocations; gained time = calls_since_swap × (t_reference − t_active)
accumulated over active-kernel lifetimes. Reference and variants are timed
once each, so gains are estimates, acceptable per the paper.

Serving extensions (the paper tunes a busy batch process; a server idles):

  * ``budget_from="busy"`` budgets from **busy time** — kernel-call time
    actually observed (calls × per-call score, same instrumentation-light
    estimate as gains) — instead of lifetime wall-clock, so a long-idle
    server accrues no budget it could burst onto one request.
  * ``charge_init=True`` charges the register()-time reference measurement
    (``init_spent_s``) against the budget: on a request path that init
    work is tuning overhead like any other.
  * an optional :class:`LatencyHeadroomGate` skips regeneration when the
    per-call latency headroom under an SLO is too thin to absorb one more
    generate+evaluate cycle.
"""

from __future__ import annotations

import dataclasses
import math


class LatencyHistogram:
    """Fixed-bucket log-latency histogram for tail (p99) estimation.

    The PR-3 EWMA answers "what does a typical call cost?"; an SLO is a
    statement about the *tail*, so the headroom gate needs a quantile
    estimate. Buckets are geometric (``buckets_per_decade`` per 10x), so
    the memory footprint is fixed (~one small int array) regardless of
    sample count, and a quantile is exact up to one bucket's relative
    width (~15% at the default 16 buckets/decade) — plenty for a gate
    whose threshold is a fraction of the SLO.
    """

    def __init__(
        self,
        lo_s: float = 1e-7,
        hi_s: float = 1e3,
        buckets_per_decade: int = 16,
    ) -> None:
        if not (0 < lo_s < hi_s):
            raise ValueError(f"need 0 < lo_s < hi_s, got {lo_s}, {hi_s}")
        self.lo_s = float(lo_s)
        self.buckets_per_decade = int(buckets_per_decade)
        decades = math.log10(hi_s / lo_s)
        # + 2: one underflow bucket (index 0) and one overflow bucket
        self._n = int(math.ceil(decades * self.buckets_per_decade)) + 2
        self._counts = [0] * self._n
        self.count = 0

    def _index(self, s: float) -> int:
        if s <= self.lo_s:
            return 0
        i = 1 + int(math.log10(s / self.lo_s) * self.buckets_per_decade)
        return min(i, self._n - 1)

    def _bucket_value(self, i: int) -> float:
        """Geometric midpoint of bucket ``i`` (its representative value)."""
        if i <= 0:
            return self.lo_s
        r = 10.0 ** (1.0 / self.buckets_per_decade)
        return self.lo_s * r ** (i - 0.5)

    def observe(self, s: float) -> None:
        if s < 0:
            return
        self._counts[self._index(s)] += 1
        self.count += 1

    def quantile(self, q: float) -> float:
        """Latency at quantile ``q`` (0 < q <= 1); 0.0 with no samples."""
        if not 0.0 < q <= 1.0:
            raise ValueError(f"quantile must be in (0, 1], got {q}")
        if self.count == 0:
            return 0.0
        rank = q * self.count
        seen = 0
        for i, c in enumerate(self._counts):
            seen += c
            if seen >= rank:
                return self._bucket_value(i)
        return self._bucket_value(self._n - 1)


@dataclasses.dataclass
class TuningAccounts:
    """Mutable accounting state shared with the auto-tuner."""

    app_start_s: float = 0.0            # perf_counter at app start
    tuning_spent_s: float = 0.0         # total generation+evaluation time
    gen_spent_s: float = 0.0            # generation (compile) component of
                                        # tuning_spent_s — charged in full
                                        # even when compilation overlapped
                                        # the hot path (async pipeline)
    gen_stall_s: float = 0.0            # generation time the hot path
                                        # actually WAITED for (synchronous
                                        # compiles); 0 for cache hits and
                                        # async-overlapped generations
    eval_spent_s: float = 0.0           # measurement component
    gen_requests: int = 0               # async generations requested
    init_spent_s: float = 0.0           # reference baseline measurement
                                        # (budgeted only when the policy
                                        # sets charge_init)
    gained_s: float = 0.0               # estimated saved time so far
    busy_s: float = 0.0                 # estimated kernel-call time observed
                                        # (calls x per-call score)
    observed_call_s: float = 0.0        # per-call latency fed to the
                                        # headroom gate: an EWMA of real
                                        # call latencies when the tuner is
                                        # coordinator-managed (ManagedTuner
                                        # times every call), else the
                                        # active kernel's measured score
    observed_tail_s: float = 0.0        # tail (histogram-quantile) per-call
                                        # latency at the headroom gate's
                                        # slo_quantile; 0 until samples
                                        # exist. Read instead of the EWMA
                                        # by quantile-configured gates.
    kernel_calls: int = 0               # invocation counter (instrumentation)
    regenerations: int = 0              # variants generated+evaluated
    swaps: int = 0                      # active-function replacements
    # --- trusted swaps (gate + canary state machine) -------------------
    gate_spent_s: float = 0.0           # oracle-check component of
                                        # tuning_spent_s (one variant
                                        # execution + comparison per check)
    gate_checks: int = 0                # oracle checks performed
    gate_failures: int = 0              # variants the oracle rejected
    canary_calls: int = 0               # production calls served by a
                                        # canary (not yet promoted) variant
    canary_promotions: int = 0          # canaries promoted to incumbent
    rollbacks: int = 0                  # canaries rolled back (tail
                                        # regression or raised exception)
    quarantined: int = 0                # points quarantined (gate failure,
                                        # rollback, or generation failure)


@dataclasses.dataclass(frozen=True)
class LatencyHeadroomGate:
    """SLO-aware regeneration gate for latency-critical paths.

    ``slo_s`` is the per-call latency objective of the tuned kernel (e.g.
    the per-token decode budget). Regeneration is allowed only when the
    active kernel leaves at least ``min_headroom_frac`` of the SLO as
    headroom AND the next generate+evaluate cycle is estimated to fit in
    that headroom — so tuning never lands on a request that is already
    close to its SLO.

    ``slo_quantile`` makes the gate tail-aware: instead of the per-call
    EWMA it reads the :class:`LatencyHistogram` quantile recorded in
    ``accounts.observed_tail_s`` (e.g. ``slo_quantile=0.99`` gates on
    p99), so a kernel whose *mean* is comfortable but whose tail already
    grazes the SLO is frozen — and an isolated mean-inflating outlier in
    an otherwise-tight tail is not double counted.
    """

    slo_s: float
    min_headroom_frac: float = 0.25
    slo_quantile: float | None = None   # e.g. 0.99: gate on tail latency

    def allows(
        self, observed_call_s: float, next_cost_estimate_s: float
    ) -> bool:
        if self.slo_s <= 0.0:
            return True
        headroom_s = self.slo_s - observed_call_s
        if headroom_s < self.min_headroom_frac * self.slo_s:
            return False
        return next_cost_estimate_s <= headroom_s


@dataclasses.dataclass(frozen=True)
class RegenerationPolicy:
    """Paper's two-factor budget: overhead limit + investment of gains."""

    max_overhead_frac: float = 0.01     # e.g. 1 % of app runtime
    invest_frac: float = 0.10           # e.g. reinvest 10 % of gained time
    budget_from: str = "wall"           # "wall" (paper) | "busy" (serving)
    charge_init: bool = False           # budget the reference measurement
    headroom: LatencyHeadroomGate | None = None

    def __post_init__(self) -> None:
        if self.budget_from not in ("wall", "busy"):
            raise ValueError(
                f"budget_from must be 'wall' or 'busy', "
                f"got {self.budget_from!r}")

    def budget_s(self, accounts: TuningAccounts, now_s: float) -> float:
        """Time the tuner is currently allowed to have spent in total."""
        if self.budget_from == "busy":
            elapsed = max(accounts.busy_s, 0.0)
        else:
            elapsed = max(now_s - accounts.app_start_s, 0.0)
        base = self.max_overhead_frac * elapsed
        investment = self.invest_frac * max(accounts.gained_s, 0.0)
        return base + investment

    def spent_s(self, accounts: TuningAccounts) -> float:
        """Tuning time charged against the budget."""
        spent = accounts.tuning_spent_s
        if self.charge_init:
            spent += accounts.init_spent_s
        return spent

    def headroom_allows(
        self, accounts: TuningAccounts, next_cost_estimate_s: float = 0.0
    ) -> bool:
        """SLO gate against the per-call latency recorded in ``accounts``.

        Headroom is a property of ONE kernel's latency, so multi-kernel
        schedulers must gate on the candidate kernel's accounts (not an
        aggregate: the max over kernels would let a slow prefill veto
        tuning of a fast decode forever). A quantile-configured gate
        reads the tail estimate (``observed_tail_s``) and falls back to
        the EWMA until the histogram has samples.
        """
        if self.headroom is None:
            return True
        observed = accounts.observed_call_s
        if (self.headroom.slo_quantile is not None
                and accounts.observed_tail_s > 0.0):
            observed = accounts.observed_tail_s
        return self.headroom.allows(observed, next_cost_estimate_s)

    def budget_allows(
        self,
        accounts: TuningAccounts,
        now_s: float,
        next_cost_estimate_s: float = 0.0,
    ) -> bool:
        return (
            self.spent_s(accounts) + next_cost_estimate_s
            <= self.budget_s(accounts, now_s)
        )

    def should_regenerate(
        self,
        accounts: TuningAccounts,
        now_s: float,
        next_cost_estimate_s: float = 0.0,
    ) -> bool:
        """True when generating+evaluating one more variant fits the budget."""
        return (
            self.headroom_allows(accounts, next_cost_estimate_s)
            and self.budget_allows(accounts, now_s, next_cost_estimate_s)
        )
