"""Regeneration decision (paper §3.3).

Two factors decide whether the auto-tuning thread may generate+evaluate a
new variant when it wakes up:

  * **overhead budget** — total tuning time (generation + evaluation) must
    stay below ``max_overhead_frac`` of the application time elapsed so
    far. This bounds the cost when tuning never finds anything better
    (paper: 0.2–4.2 % observed).
  * **investment factor** — a fraction ``invest_frac`` of the *time gained*
    by previously found variants may be re-invested into further
    exploration (paper: e.g. invest 10 % of gained time).

Gain estimation (paper §3.3): the only instrumentation is a counter of
kernel invocations; gained time = calls_since_swap × (t_reference − t_active)
accumulated over active-kernel lifetimes. Reference and variants are timed
once each, so gains are estimates, acceptable per the paper.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class TuningAccounts:
    """Mutable accounting state shared with the auto-tuner."""

    app_start_s: float = 0.0            # perf_counter at app start
    tuning_spent_s: float = 0.0         # total generation+evaluation time
    init_spent_s: float = 0.0           # reference baseline measurement (not
                                        # budgeted: it is normal app work)
    gained_s: float = 0.0               # estimated saved time so far
    kernel_calls: int = 0               # invocation counter (instrumentation)
    regenerations: int = 0              # variants generated+evaluated
    swaps: int = 0                      # active-function replacements


@dataclasses.dataclass(frozen=True)
class RegenerationPolicy:
    """Paper's two-factor budget: overhead limit + investment of gains."""

    max_overhead_frac: float = 0.01     # e.g. 1 % of app runtime
    invest_frac: float = 0.10           # e.g. reinvest 10 % of gained time

    def budget_s(self, accounts: TuningAccounts, now_s: float) -> float:
        """Time the tuner is currently allowed to have spent in total."""
        elapsed = max(now_s - accounts.app_start_s, 0.0)
        base = self.max_overhead_frac * elapsed
        investment = self.invest_frac * max(accounts.gained_s, 0.0)
        return base + investment

    def should_regenerate(
        self,
        accounts: TuningAccounts,
        now_s: float,
        next_cost_estimate_s: float = 0.0,
    ) -> bool:
        """True when generating+evaluating one more variant fits the budget."""
        return (
            accounts.tuning_spent_s + next_cost_estimate_s
            <= self.budget_s(accounts, now_s)
        )
