"""Static (offline) auto-tuning baseline — the paper's BS-AT columns.

Exhaustively explores the tuning space (optionally restricted to
leftover-free variants, as the paper does for Streamcluster to bound
exploration time) and returns the best point. Used to quantify how close
the *online* tuner lands to the statically found optimum (paper: within
~6 % on average).
"""

from __future__ import annotations

from typing import Any, Callable

from repro.core.compilette import Compilette
from repro.core.evaluator import Evaluator
from repro.core.tuning_space import Point


def static_autotune(
    compilette: Compilette,
    evaluator: Evaluator,
    *,
    specialization: dict[str, Any] | None = None,
    only_no_leftover: bool = False,
    max_points: int | None = None,
    score_fn: Callable[[Point], float] | None = None,
    strategy: str | None = None,
) -> tuple[Point | None, float, list[tuple[Point, float]]]:
    """Returns (best_point, best_score_s, full history).

    With ``strategy`` (a name from the :mod:`repro.core.explorer`
    registry) the exploration order is delegated to that strategy instead
    of the exhaustive scan; ``only_no_leftover`` applies only to the
    exhaustive scan.
    """
    from repro.core.explorer import _leftover_rank, make_strategy

    specialization = dict(specialization or {})

    def measure(point: Point) -> float:
        if score_fn is not None:
            return score_fn(point)
        kern = compilette.generate(point, **specialization)
        return evaluator.evaluate(kern.fn).score_s

    if strategy is not None:
        strat = make_strategy(strategy, compilette.space)
        best_point, best_score = strat.run_to_completion(
            measure, max_points=max_points)
        return best_point, best_score, list(strat.history)

    history: list[tuple[Point, float]] = []
    best_point: Point | None = None
    best_score = float("inf")
    n = 0
    for point in compilette.space.iter_valid():
        # no_leftover may return a bool or a numeric waste fraction
        # (0 = leftover-free)
        if only_no_leftover and _leftover_rank(compilette.space, point) > 0:
            continue
        if max_points is not None and n >= max_points:
            break
        n += 1
        score = measure(point)
        history.append((dict(point), score))
        if score < best_score:
            best_score = score
            best_point = dict(point)
    return best_point, best_score, history
