"""Simulated device profiles — the paper's gem5/McPAT study, TPU-ified.

The paper simulates 11 ARM cores: {single,dual,triple}-issue × {IO,OOO} ×
{1..3} VPUs (Table 1/2). The TPU-native analogue varies:

  * ``issue``        — number of scalar/vector issue slots (1–3); scales
                       VPU throughput and per-grid-step control overhead.
  * ``overlap``      — ``False`` = *lean* core (in-order analogue): DMA and
                       compute serialize; ``True`` = *fat* core (OOO
                       analogue): DMA/compute overlap (latency hiding à la
                       dynamic scheduling). Fat cores pay area + energy.
  * ``vpus``         — number of vector (VPU) pipes (1–3); SIMD throughput.
  * ``vmem_kb``      — VMEM size: the register-file/cache analogue that
                       creates holes in the tuning space (block footprints
                       that do not fit are invalid points).

Energy follows a McPAT-flavoured model: E = P_static·t + e_flop·FLOPs +
e_byte·DRAM bytes, with fat cores paying a dynamic-scheduling multiplier on
compute energy and extra static power via area.

These profiles drive the *analytical cost models* of the kernel
compilettes; they are the "simulated platform" of the reproduction. All
numbers are self-consistent fictions in TPU-ish units, not vendor data.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    issue: int              # 1..3 issue width (analogue of SI/DI/TI)
    overlap: bool           # False=lean/in-order, True=fat/out-of-order
    vpus: int               # number of vector pipes
    clock_ghz: float
    vmem_kb: int            # VMEM budget for kernel working sets
    hbm_gbps: float         # HBM bandwidth GB/s
    mxu_tflops: float       # matrix-unit peak (vectorized path), TFLOP/s
    grid_step_overhead_ns: float  # per grid-step control/DMA-issue cost
    area_mm2: float
    static_w: float
    e_flop_pj: float        # dynamic energy per FLOP
    e_byte_pj: float        # dynamic energy per DRAM byte

    @property
    def vpu_gflops(self) -> float:
        """Scalar/vector (non-MXU) path peak, GFLOP/s."""
        # 8 sublanes x 128 lanes x 2 flops per VPU at clock; scaled down to
        # keep the SISD:SIMD ratio paper-like.
        return self.vpus * self.issue * 64.0 * self.clock_ghz

    @property
    def peak_flops(self) -> float:
        return self.mxu_tflops * 1e12

    def exec_time_s(self, compute_s: float, memory_s: float, overhead_s: float) -> float:
        """Lean cores serialize compute and DMA; fat cores overlap them."""
        if self.overlap:
            return max(compute_s, memory_s) + 0.25 * min(compute_s, memory_s) + overhead_s
        return compute_s + memory_s + overhead_s

    def energy_j(self, time_s: float, flops: float, dram_bytes: float) -> float:
        sched_mult = 1.55 if self.overlap else 1.0
        dyn = flops * self.e_flop_pj * 1e-12 * sched_mult
        dyn += dram_bytes * self.e_byte_pj * 1e-12
        return self.static_w * time_s + dyn


def _mk(name: str, issue: int, overlap: bool, vpus: int) -> DeviceProfile:
    clock = {1: 0.7, 2: 0.85, 3: 0.94}[issue]
    vmem = {1: 256, 2: 512, 3: 1024}[issue]
    hbm = {1: 102.0, 2: 205.0, 3: 410.0}[issue]
    mxu = vpus * issue * 1.9 * clock          # TFLOP/s for the MXU path
    # Lean cores expose raw per-step latency; fat cores hide most of it.
    step_ns = (38.0 if not overlap else 14.0) / issue
    core_area = 0.45 * issue * (1.0 + 0.27 * (vpus - 1))
    if overlap:
        core_area *= 1.16  # OOO window/renaming area overhead (paper Fig.6d)
    area = core_area + {1: 1.52, 2: 3.19, 3: 5.88}[issue]
    static = 0.08 * area
    return DeviceProfile(
        name=name,
        issue=issue,
        overlap=overlap,
        vpus=vpus,
        clock_ghz=clock,
        vmem_kb=vmem,
        hbm_gbps=hbm,
        mxu_tflops=mxu,
        grid_step_overhead_ns=step_ns,
        area_mm2=area,
        static_w=static,
        e_flop_pj=0.65,
        e_byte_pj=4.4,
    )


# 11 profiles mirroring the paper's Table 2 (L=lean/in-order, F=fat/OOO).
SI_L1 = _mk("SI-L1", 1, False, 1)
DI_L1 = _mk("DI-L1", 2, False, 1)
DI_L2 = _mk("DI-L2", 2, False, 2)
TI_L1 = _mk("TI-L1", 3, False, 1)
TI_L2 = _mk("TI-L2", 3, False, 2)
TI_L3 = _mk("TI-L3", 3, False, 3)
DI_F1 = _mk("DI-F1", 2, True, 1)
DI_F2 = _mk("DI-F2", 2, True, 2)
TI_F1 = _mk("TI-F1", 3, True, 1)
TI_F2 = _mk("TI-F2", 3, True, 2)
TI_F3 = _mk("TI-F3", 3, True, 3)

ALL_PROFILES: tuple[DeviceProfile, ...] = (
    SI_L1, DI_L1, DI_L2, DI_F1, DI_F2, TI_L1, TI_L2, TI_L3, TI_F1, TI_F2, TI_F3
)

#: lean↔fat pairs with identical configs but scheduling (paper Fig. 6).
EQUIVALENT_PAIRS: tuple[tuple[DeviceProfile, DeviceProfile], ...] = (
    (DI_L1, DI_F1), (DI_L2, DI_F2), (TI_L1, TI_F1), (TI_L2, TI_F2), (TI_L3, TI_F3),
)

#: The "real TPU" target used for roofline terms (v5e-flavoured constants).
TPU_V5E = DeviceProfile(
    name="tpu-v5e",
    issue=3,
    overlap=True,
    vpus=4,
    clock_ghz=0.94,
    vmem_kb=128 * 1024 // 8,   # ~16 MiB usable VMEM expressed in kB
    hbm_gbps=819.0,
    mxu_tflops=197.0,
    grid_step_overhead_ns=6.0,
    area_mm2=0.0,
    static_w=0.0,
    e_flop_pj=0.45,
    e_byte_pj=3.2,
)


def by_name(name: str) -> DeviceProfile:
    for p in ALL_PROFILES + (TPU_V5E,):
        if p.name == name:
            return p
    raise KeyError(name)


def scaled_profile(
    base: DeviceProfile,
    name: str,
    *,
    flops: float = 1.0,
    bandwidth: float = 1.0,
    vmem: float = 1.0,
) -> DeviceProfile:
    """A synthetic neighbour of ``base`` with scaled roofline terms.

    Scales peak math throughput (via ``mxu_tflops``), HBM bandwidth and
    VMEM capacity independently while keeping the microarchitectural
    shape (issue width, overlap, VPU count, clock) fixed — the knob set
    a device *generation* moves, as opposed to a device *family*.
    Transfer-plane grids use this to build unseen-but-similar devices
    around :data:`ALL_PROFILES`.
    """
    if flops <= 0 or bandwidth <= 0 or vmem <= 0:
        raise ValueError(
            f"scale factors must be > 0, got flops={flops}, "
            f"bandwidth={bandwidth}, vmem={vmem}")
    return dataclasses.replace(
        base,
        name=name,
        mxu_tflops=base.mxu_tflops * flops,
        hbm_gbps=base.hbm_gbps * bandwidth,
        vmem_kb=max(1, int(round(base.vmem_kb * vmem))),
    )
