"""Variant correctness gate (trusted swaps, step 1).

The Kernel Tuning Toolkit (arXiv:1910.08498) validates every dynamically
tuned configuration against a reference implementation before it is
allowed to serve; this module is that validation step for the online
auto-tuner. On first harvest of a variant the gate runs it once on the
kernel's example inputs and compares the outputs against the catalog
oracle (``KernelDef.oracle`` — the kernel's ``ref.py``) within per-kernel
tolerances (``KernelDef.tolerance``, overridable per session).

Virtual backends carry no numerics: there a scripted verdict
(``compilette.gate_script``, a ``point -> bool`` callable installed by the
test/replay harness) decides pass/fail so VirtualClock runs stay
deterministic, and the check bills its natural cost — one simulated
execution of the variant — to the virtual clock.

The gate only renders verdicts; acting on a failure (explorer + registry
quarantine, never re-proposing or re-trusting the point) is the
auto-tuner's and coordinator's job.
"""

from __future__ import annotations

from typing import Any, Callable

from repro.core.tuning_space import Point

# gate_mode knob: "off" = promote on measurement alone (pre-gate behavior),
# "check" = oracle check then immediate swap, "canary" = oracle check then
# staged promotion (CANDIDATE -> CANARY -> INCUMBENT) with auto-rollback.
GATE_MODES = ("off", "check", "canary")

# Conservative defaults for float32 Pallas-vs-reference comparison; kernels
# that accumulate in lower precision declare looser per-kernel tolerances.
DEFAULT_RTOL = 1e-3
DEFAULT_ATOL = 1e-5


class VariantGate:
    """Oracle check for one compilette's freshly generated variants.

    ``check(point, fn)`` returns ``(ok, reason)``. A compilette without an
    oracle or example inputs (e.g. a program-level ``repro.tuned``
    function) passes trivially — the gate can only be as strong as the
    reference the kernel declares.
    """

    def __init__(
        self,
        compilette: Any,
        *,
        rtol: float | None = None,
        atol: float | None = None,
    ) -> None:
        self.compilette = compilette
        tol = dict(getattr(compilette, "tolerance", None) or {})
        self.rtol = float(rtol if rtol is not None
                          else tol.get("rtol", DEFAULT_RTOL))
        self.atol = float(atol if atol is not None
                          else tol.get("atol", DEFAULT_ATOL))
        self.checks = 0
        self.failures = 0

    def check(self, point: Point, fn: Callable[..., Any]) -> tuple[bool, str]:
        self.checks += 1
        ok, reason = self._verdict(point, fn)
        if not ok:
            self.failures += 1
        return ok, reason

    # ------------------------------------------------------------ verdicts
    def _scripted(self, script: Callable[..., Any], point: Point,
                  ) -> tuple[bool, str]:
        try:
            if bool(script(dict(point))):
                return True, ""
        except Exception as e:
            return False, f"gate script raised: {e!r}"
        return False, "scripted oracle mismatch"

    def _verdict(self, point: Point, fn: Callable[..., Any],
                 ) -> tuple[bool, str]:
        comp = self.compilette
        script = getattr(comp, "gate_script", None)
        if getattr(comp, "virtual", None) is not None:
            # Virtual variants carry no numerics. Bill the check's natural
            # cost — one simulated execution — then consult the script.
            try:
                fn(None)
            except Exception as e:
                return False, f"variant raised: {e!r}"
            if script is None:
                return True, ""
            return self._scripted(script, point)
        if script is not None:
            return self._scripted(script, point)
        oracle = getattr(comp, "oracle", None)
        example = getattr(comp, "example_call_args", None)
        if oracle is None or example is None:
            return True, ""
        try:
            args = example()
        except Exception:
            # no example inputs for this spec: nothing to run the check on
            return True, ""
        try:
            got = fn(*args)
        except Exception as e:
            return False, f"variant raised: {e!r}"
        try:
            want = oracle(*args)
        except Exception:
            # a broken oracle is an environment bug, not evidence against
            # the variant; failing closed here would quarantine the whole
            # space and silently end tuning
            return True, ""
        return self._compare(got, want)

    def _compare(self, got: Any, want: Any) -> tuple[bool, str]:
        import numpy as np

        g = tuple(got) if isinstance(got, (tuple, list)) else (got,)
        w = tuple(want) if isinstance(want, (tuple, list)) else (want,)
        if len(g) != len(w):
            return False, f"output arity {len(g)} != oracle arity {len(w)}"
        for i, (a, b) in enumerate(zip(g, w)):
            try:
                aa = np.asarray(a).astype(np.float64)
                bb = np.asarray(b).astype(np.float64)
            except (TypeError, ValueError):
                if a != b:
                    return False, f"output {i}: {a!r} != oracle {b!r}"
                continue
            if aa.shape != bb.shape:
                return False, (f"output {i} shape {aa.shape} != "
                               f"oracle shape {bb.shape}")
            if not np.allclose(aa, bb, rtol=self.rtol, atol=self.atol):
                err = float(np.max(np.abs(aa - bb))) if aa.size else 0.0
                return False, (f"output {i} max|err|={err:.3e} beyond "
                               f"rtol={self.rtol:g} atol={self.atol:g}")
        return True, ""
