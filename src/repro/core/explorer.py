"""Search strategies for online exploration of the tuning space.

The paper's two-phase explorer (§3.3) is ONE strategy among several: the
Kernel Tuning Toolkit (arXiv:1910.08498) and "Tuning the Tuner"
(arXiv:2505.03979) both treat the searcher as an interchangeable component
behind a single propose/report API. This module provides that API:

  * :class:`SearchStrategy` — the protocol every searcher implements:
    ``next_point() -> Point | None`` (pull-based proposal; ``None`` when
    exhausted), ``peek(n)`` (upcoming proposals WITHOUT consuming them —
    the coordinator prefetch-compiles them while a measurement runs),
    ``report(point, score_s) -> bool`` (feed a measurement back; True
    when it is the new best) and the ``finished`` property. The base
    class centralizes seen-point deduplication (a strategy never
    re-proposes a point), best tracking, history, warm-start seed points,
    the peek buffer and the ``run_to_completion`` driver.
  * a **string-keyed registry** — strategies self-register under a name:

        @register_strategy("my_search")
        class MySearch(SearchStrategy):
            def _propose(self) -> Point | None: ...
            def _observe(self, point, score_s, improved) -> None: ...

    ``make_strategy("my_search", space, ...)`` then builds one, and every
    consumer (``OnlineAutotuner(strategy="my_search")``,
    ``static_autotune``, the ``TuningCoordinator``, the serve/train loops
    and their CLI ``--strategy`` flags) accepts the name with no further
    plumbing. Implement ``_propose`` (return a candidate or ``None``;
    duplicates are filtered by the base class, so proposing an
    already-seen point is safe and simply asks ``_propose`` again) and
    optionally ``_observe`` (react to a measurement, e.g. recenter a
    neighborhood).

Built-in strategies:

  * ``two_phase`` (:class:`TwoPhaseExplorer`, the default) — the paper's
    order: phase 1 explores structural parameters least→most switched,
    leftover-free variants first; phase 2 freezes the phase-1 winner and
    explores the remaining codegen options combinatorially.
  * ``random`` (:class:`RandomSearch`) — a deterministic shuffle of the
    valid points (seeded), the classic baseline that "Tuning the Tuner"
    shows is surprisingly hard to beat on small spaces.
  * ``greedy`` (:class:`GreedyNeighborhood`) — hill-climbing: vary one
    parameter at a time around the incumbent best, recenter on
    improvement, and restart from an unseen point at local optima (so
    small spaces are still covered exhaustively).
  * ``cost_model`` (:class:`CostModelSearch`) — model-based: rank the
    unexplored points by the compilette's analytical cost-model
    predictions, continuously recalibrated against observed scores
    (per-parameter-value residuals), so the cheapest-looking candidates
    are measured first and systematic model bias self-corrects.
"""

from __future__ import annotations

import dataclasses
import hashlib
import inspect
import itertools
import json
import math
import random as _random
from typing import Any, Callable, Iterator, Sequence

from repro.core.tuning_space import Point, TuningSpace


def point_stripe(point: Point, replica_count: int) -> int:
    """Deterministic stripe owner of a point in an N-replica fleet.

    Hash-stripes the point space: sha256 of the point's canonical JSON
    modulo ``replica_count``. Stable across processes and runs (unlike
    Python's randomized ``hash()``), independent of the space object, so
    every replica computes the same owner for the same point — the
    stripes are disjoint and jointly exhaustive by construction.
    """
    n = int(replica_count)
    if n < 1:
        raise ValueError(f"replica_count must be >= 1, got {replica_count}")
    canon = json.dumps(dict(point), sort_keys=True,
                       separators=(",", ":"), default=str)
    digest = hashlib.sha256(canon.encode("utf-8")).digest()
    return int.from_bytes(digest[:8], "big") % n


def _leftover_rank(space: TuningSpace, point: Point) -> float:
    """0 = leftover-free; larger = more leftover (explored later)."""
    res = space.no_leftover(point)
    if isinstance(res, bool):
        return 0.0 if res else 1.0
    # numeric "amount of leftover" → gradual softening order
    return float(res)


@dataclasses.dataclass
class ExplorerState:
    phase: int = 1
    n_proposed: int = 0
    n_reported: int = 0
    finished: bool = False


class SearchStrategy:
    """Base class for pull-based search strategies.

    The auto-tuner asks for ``next_point()`` only when the regeneration
    policy grants budget, and feeds results back through
    ``report(point, score_s)``. Subclasses implement ``_propose`` (and
    optionally ``_observe``); deduplication, best tracking and warm-start
    seeds are handled here.
    """

    name: str = "base"

    def __init__(
        self,
        space: TuningSpace,
        base_point: Point | None = None,
        seed_points: "Sequence[Point]" = (),
    ) -> None:
        self.space = space
        # Initial state of unexplored parameters: pre-profiled defaults.
        # A supplied base point is merged OVER the defaults and restricted
        # to known parameters, so a stale persisted point (from an older
        # space definition) degrades gracefully instead of producing
        # candidates with missing/unknown keys.
        base = space.default_point()
        for k, v in dict(base_point or {}).items():
            if k in base:
                base[k] = v
        if not space.is_valid(base):
            # The pre-profiled default (or a merged stale point) can be a
            # hole for small problem shapes — e.g. every block_k option
            # exceeding K. Fall back to the first valid point so the
            # reference variant is always generatable; a genuinely empty
            # space keeps the invalid base (exploration proposes nothing
            # and callers can detect it up front).
            fallback = next(iter(space.iter_valid()), None)
            if fallback is not None:
                base = fallback
        self.base_point: Point = base
        self.state = ExplorerState()
        self.best_point: Point | None = None
        self.best_score: float = float("inf")
        self.history: list[tuple[Point, float]] = []
        self._seen: set[tuple] = set()
        # quarantined points: rejected by the variant gate (wrong output),
        # rolled back by the canary, or failed to generate — never proposed
        # again and never reported as best (see ``quarantine``).
        self._quarantined: set[tuple] = set()
        # peek(n) buffer: upcoming proposals drawn ahead of consumption;
        # next_point() serves from here first, so peeked order == proposed
        # order (absent intervening reports that reshape the search).
        self._peeked: list[Point] = []
        # Warm-start: seed points (e.g. a persisted best from a previous
        # run) are proposed before any enumeration, so a warm process
        # re-validates its known-best variant with a single regeneration.
        self._seeds: list[Point] = [
            dict(p) for p in seed_points
            if space.contains(p) and space.is_valid(p)
        ]
        # Fleet partitioning (see ``partition``): None = whole space.
        self._replica: tuple[int, int] | None = None
        # Points exempt from the stripe filter: warm-start seeds (the
        # fleet best must stay re-validatable everywhere) and injected
        # peer candidates.
        self._stripe_exempt: set[tuple] = set()
        # Peer bests already injected (idempotence across syncs).
        self._injected: set[tuple] = set()

    # ---------------------------------------------------- subclass hooks
    def _propose(self) -> Point | None:
        """Next candidate (may repeat a seen point) or None when done."""
        raise NotImplementedError

    def _observe(self, point: Point, score_s: float, improved: bool) -> None:
        """React to a reported measurement (e.g. recenter a neighborhood)."""

    # ------------------------------------------------------------------ api
    def _owns(self, point: Point) -> bool:
        """Does this replica's stripe (or exemption list) cover ``point``?"""
        if self._replica is None:
            return True
        if self.space.key(point) in self._stripe_exempt:
            return True
        replica_id, replica_count = self._replica
        return point_stripe(point, replica_count) == replica_id

    def partition(self, replica_id: int, replica_count: int) -> None:
        """Restrict proposals to this replica's hash stripe of the space.

        The fleet idiom: N replicas sharing a registry backend each call
        ``partition(i, N)`` so exploration is paid once per fleet — every
        point is owned (proposed, compiled, measured) by exactly one
        replica, per :func:`point_stripe`. Foreign points are marked seen
        as they stream past, so ``peek`` never leaks them and restart
        scans terminate. Warm-start seeds and :meth:`inject_candidate`
        points are exempt: a fleet best must stay locally re-validatable
        (through the gate) on every replica.
        """
        replica_id, replica_count = int(replica_id), int(replica_count)
        if replica_count < 1 or not 0 <= replica_id < replica_count:
            raise ValueError(
                f"invalid partition ({replica_id}, {replica_count})")
        if replica_count == 1:
            self._replica = None
            return
        self._replica = (replica_id, replica_count)
        for p in self._seeds:
            self._stripe_exempt.add(self.space.key(p))
        # already-buffered foreign points must not be served
        if self._peeked:
            self._peeked = [p for p in self._peeked if self._owns(p)]

    def mark_seen(self, point: Point) -> bool:
        """Record a peer replica's evaluation: never propose this point.

        Purges it from the peek buffer even when already drawn into the
        seen-set (a buffered prefetch IS seen), so a pending prefetch
        cannot re-compile work a peer already paid for. An *injected*
        candidate is exempt: the fleet best is published alongside its
        own evaluation, and the peer's measurement must not cancel this
        replica's re-validation of it (a repeat sync would otherwise
        purge the pending candidate while :meth:`inject_candidate`'s
        dedup refuses to re-queue it — losing the adoption entirely).
        Returns True if the call changed anything (newly marked or
        purged).
        """
        key = self.space.key(point)
        if key in self._injected:
            return False
        purged = False
        if self._peeked:
            kept = [p for p in self._peeked if self.space.key(p) != key]
            purged = len(kept) != len(self._peeked)
            self._peeked = kept
        if key in self._seen:
            return purged
        self._seen.add(key)
        return True

    def inject_candidate(self, point: Point) -> bool:
        """Queue an externally supplied candidate (a peer's published best).

        The point jumps the proposal queue and bypasses the seen-set
        (peer evaluations mark it seen, yet it must stay proposable
        here) — but it still flows through the normal generate/evaluate/
        gate/canary path, entering as CANDIDATE, never blind INCUMBENT.
        Idempotent per point; quarantined, locally measured or already
        queued points are refused. Returns True when queued.
        """
        if not (self.space.contains(point) and self.space.is_valid(point)):
            return False
        key = self.space.key(point)
        if key in self._quarantined or key in self._injected:
            return False
        if any(self.space.key(p) == key for p, _ in self.history):
            return False   # already measured locally
        if any(self.space.key(p) == key for p in self._peeked):
            return False   # already pending proposal
        self._injected.add(key)
        self._stripe_exempt.add(key)
        self._seen.add(key)
        self._peeked.insert(0, dict(point))
        self.state.finished = False   # an exhausted search has new work
        return True

    def _draw(self) -> Point | None:
        """Pull one deduplicated, valid, stripe-owned candidate."""
        while True:
            point = self._propose()
            if point is None:
                return None
            key = self.space.key(point)
            if key in self._seen:
                continue
            if not self._owns(point):
                # another replica's point: swallow it (counting it seen
                # keeps restart scans terminating) and ask again
                self._seen.add(key)
                continue
            self._seen.add(key)
            return point

    def next_point(self) -> Point | None:
        """Next variant to generate+evaluate, or None when done.

        Never yields the same point twice (``_propose`` duplicates are
        swallowed here) and never yields a hole. Points surfaced by a
        prior :meth:`peek` are served first, in peeked order.
        """
        if self.state.finished:
            return None
        if self._peeked:
            point = self._peeked.pop(0)
        else:
            point = self._draw()
            if point is None:
                self.state.finished = True
                return None
        self.state.n_proposed += 1
        return dict(point)

    def peek(self, n: int = 1) -> list[Point]:
        """Upcoming proposals WITHOUT consuming them (speculative prefetch).

        Returns up to ``n`` points that subsequent :meth:`next_point`
        calls will yield (in order, provided no intervening ``report``
        reshapes the search — a recentering strategy may then serve the
        already-peeked points before its new neighborhood). Peeking past
        the end of the space returns fewer points but does NOT mark the
        strategy finished: buffered points are still pending proposal.
        The coordinator uses this to compile the next 1–2 candidates in
        the background while the current measurement runs.
        """
        if self.state.finished:
            return []
        while len(self._peeked) < n:
            point = self._draw()
            if point is None:
                break
            self._peeked.append(point)
        return [dict(p) for p in self._peeked[:n]]

    def report(self, point: Point, score_s: float) -> bool:
        """Feed a measurement back; returns True if it is the new best."""
        self.state.n_reported += 1
        self.history.append((dict(point), score_s))
        improved = score_s < self.best_score
        if improved:
            self.best_score = score_s
            self.best_point = dict(point)
        self._observe(point, score_s, improved)
        return improved

    def quarantine(self, point: Point) -> None:
        """Mark ``point`` untrusted: never re-propose, never call it best.

        Idempotent. The point joins the seen set (so ``_propose``
        duplicates are swallowed and restart scans skip it), is purged
        from the peek buffer, and — if it currently holds the best slot —
        the best is recomputed from the reported history excluding every
        quarantined point, so a registry flush after a rollback persists
        the best *trusted* point.
        """
        key = self.space.key(point)
        self._quarantined.add(key)
        self._seen.add(key)
        if self._peeked:
            self._peeked = [
                p for p in self._peeked if self.space.key(p) != key]
        if (self.best_point is not None
                and self.space.key(self.best_point) == key):
            self.best_point, self.best_score = None, float("inf")
            for p, s in self.history:
                if self.space.key(p) in self._quarantined:
                    continue
                if s < self.best_score:
                    self.best_score, self.best_point = s, dict(p)

    def is_quarantined(self, point: Point) -> bool:
        return self.space.key(point) in self._quarantined

    @property
    def n_quarantined(self) -> int:
        return len(self._quarantined)

    @property
    def finished(self) -> bool:
        return self.state.finished

    def run_to_completion(
        self, evaluate, max_points: int | None = None
    ) -> tuple[Point | None, float]:
        """Exhaust the exploration with ``evaluate(point) -> seconds``.

        Used by the static tuner and the simulated-platform studies; the
        online auto-tuner instead paces itself with the regeneration policy.
        """
        n = 0
        while max_points is None or n < max_points:
            point = self.next_point()
            if point is None:
                break
            self.report(point, evaluate(point))
            n += 1
        return self.best_point, self.best_score


# --------------------------------------------------------------- registry
STRATEGIES: dict[str, type[SearchStrategy]] = {}


def register_strategy(name: str) -> Callable[[type], type]:
    """Class decorator: register a :class:`SearchStrategy` under ``name``."""

    def deco(cls: type) -> type:
        cls.name = name
        STRATEGIES[name] = cls
        return cls

    return deco


def available_strategies() -> tuple[str, ...]:
    return tuple(sorted(STRATEGIES))


def strategy_accepts(strategy: str, param: str) -> bool:
    """Does the named strategy's constructor take keyword ``param``?

    Lets callers wire optional capabilities (e.g. a compilette cost
    model as ``cost_fn``) only into strategies that can exploit them,
    without every strategy having to swallow ``**kwargs``.
    """
    cls = STRATEGIES.get(strategy)
    if cls is None:
        return False
    return param in inspect.signature(cls.__init__).parameters


def make_strategy(
    strategy: "str | SearchStrategy",
    space: TuningSpace,
    *,
    base_point: Point | None = None,
    seed_points: Sequence[Point] = (),
    **kwargs: Any,
) -> SearchStrategy:
    """Resolve a strategy name (or pass through an instance)."""
    if not isinstance(strategy, str):
        return strategy
    try:
        cls = STRATEGIES[strategy]
    except KeyError:
        raise ValueError(
            f"unknown search strategy {strategy!r}; "
            f"available: {', '.join(available_strategies())}"
        ) from None
    return cls(space, base_point=base_point, seed_points=seed_points, **kwargs)


# -------------------------------------------------------------- two-phase
@register_strategy("two_phase")
class TwoPhaseExplorer(SearchStrategy):
    """The paper's two-phase exploration (§3.3), the default strategy.

    Phase 1 explores the parameters that change the *structure* of the
    code (unrolling factors, vector length, vectorization), in order from
    the least switched to the most switched parameter; variants with no
    leftover code first, then gradually softening. Phase 2 freezes the
    best phase-1 parameters and explores the combinatorial choices of the
    remaining codegen options.
    """

    def __init__(
        self,
        space: TuningSpace,
        base_point: Point | None = None,
        seed_points: "Sequence[Point]" = (),
    ) -> None:
        super().__init__(space, base_point=base_point, seed_points=seed_points)
        self._phase1_iter = self._make_phase1_iter()
        self._phase2_iter: Iterator[Point] | None = None
        self._peek_holds_phase = False

    def peek(self, n: int = 1) -> list[Point]:
        """Peek, but never across an undetermined phase boundary.

        Phase 2 enumerates around the phase-1 *best*; while phase-1
        measurements are outstanding that best is not yet decided, and a
        peeked phase-2 candidate would be pinned to a stale incumbent
        (the coordinator's prefetch peeks routinely, so this is a live
        production path, not a test artifact). Returning fewer points is
        always legal for peek; the boundary is crossed on the next peek
        or proposal after the last phase-1 report lands.
        """
        self._peek_holds_phase = True
        try:
            return super().peek(n)
        finally:
            self._peek_holds_phase = False

    def _make_phase1_iter(self) -> Iterator[Point]:
        # Enumerate in least→most switched order, then stable-sort by
        # leftover rank: leftover-free first, gradually softening.
        candidates = [
            p for p in self.space.iter_phase1(self.base_point)
            if self.space.is_valid(p)
        ]
        candidates.sort(key=lambda p: _leftover_rank(self.space, p))
        return itertools.chain(iter(self._seeds), iter(candidates))

    def _make_phase2_iter(self) -> Iterator[Point]:
        assert self.best_point is not None
        candidates = [
            p for p in self.space.iter_phase2(self.best_point)
            if self.space.is_valid(p)
        ]
        return iter(candidates)

    def _propose(self) -> Point | None:
        while True:
            it = (self._phase1_iter if self.state.phase == 1
                  else self._phase2_iter)
            assert it is not None
            try:
                return next(it)
            except StopIteration:
                if self.state.phase == 1:
                    outstanding = (self.state.n_proposed + len(self._peeked)
                                   > self.state.n_reported)
                    if self._peek_holds_phase and outstanding:
                        # peek stops at the boundary (see peek docstring)
                        return None
                    if self.best_point is None:
                        # nothing valid at all
                        return None
                    self.state.phase = 2
                    self._phase2_iter = self._make_phase2_iter()
                    continue
                return None


# ----------------------------------------------------------------- random
@register_strategy("random")
class RandomSearch(SearchStrategy):
    """Uniform random order over the valid points (deterministic seed).

    Seed points are proposed first (warm start), then the remaining valid
    points in a seeded shuffle. On small spaces this is exhaustive; on
    large spaces it is the classic unbiased baseline.
    """

    def __init__(
        self,
        space: TuningSpace,
        base_point: Point | None = None,
        seed_points: "Sequence[Point]" = (),
        *,
        rng_seed: int = 0,
    ) -> None:
        super().__init__(space, base_point=base_point, seed_points=seed_points)
        candidates = list(space.iter_valid())
        _random.Random(rng_seed).shuffle(candidates)
        self._iter: Iterator[Point] = itertools.chain(
            iter(self._seeds), iter(candidates))

    def _propose(self) -> Point | None:
        return next(self._iter, None)


# ----------------------------------------------------------------- greedy
@register_strategy("greedy")
class GreedyNeighborhood(SearchStrategy):
    """Hill-climb over one parameter at a time.

    Starting from the base point (or a warm-start seed), propose every
    single-parameter variation of the incumbent best; whenever a
    measurement improves the best, the neighborhood recenters there. At a
    local optimum (no unseen neighbor left) the search restarts from the
    first unseen valid point, so a small space is still covered
    exhaustively and the strategy converges to the global optimum on it.
    """

    def __init__(
        self,
        space: TuningSpace,
        base_point: Point | None = None,
        seed_points: "Sequence[Point]" = (),
    ) -> None:
        super().__init__(space, base_point=base_point, seed_points=seed_points)
        self._queue: list[Point] = list(self._seeds)
        if space.is_valid(self.base_point):
            self._queue.append(dict(self.base_point))
        self._frontier_key: tuple | None = None   # neighborhood already queued

    def _neighbors(self, point: Point) -> Iterator[Point]:
        for p in self.space.params:
            for v in p.values:
                if v == point[p.name]:
                    continue
                q = dict(point)
                q[p.name] = v
                if self.space.is_valid(q):
                    yield q

    def _observe(self, point: Point, score_s: float, improved: bool) -> None:
        if improved:
            # recenter: pending neighbors of the old incumbent are stale
            # (any still-unseen ones are recovered by the restart scan)
            self._queue.clear()

    def _propose(self) -> Point | None:
        while True:
            if self._queue:
                return self._queue.pop(0)
            if self.best_point is not None:
                key = self.space.key(self.best_point)
                if key != self._frontier_key:
                    self._frontier_key = key
                    self._queue.extend(
                        q for q in self._neighbors(self.best_point)
                        if self.space.key(q) not in self._seen
                    )
                    if self._queue:
                        continue
            # local optimum (or nothing measured yet): restart from the
            # first unseen valid point, if any
            for q in self.space.iter_valid():
                if self.space.key(q) not in self._seen:
                    return q
            return None


# ------------------------------------------------------------- cost model
@register_strategy("cost_model")
class CostModelSearch(SearchStrategy):
    """Model-based search: measure the cheapest-*predicted* points first.

    Every valid point is priced once by ``cost_fn`` (the compilette's
    analytical cost model — ``OnlineAutotuner`` wires it automatically
    when the compilette carries one); proposals then pop the pending
    point with the lowest *calibrated* prediction. Calibration is a
    per-parameter-value residual table: each finite observation records
    ``ln(observed / predicted)`` against every ``(param, value)`` the
    point contains, and pending predictions are scaled by the mean
    residual of their own values — so a model that systematically
    mis-prices, say, ``unroll=8`` sinks those candidates without
    touching the rest of the ranking. Without a ``cost_fn`` the
    strategy degrades to deterministic enumeration order. Either way
    the whole space is eventually proposed (exhaustive on small
    spaces), seeds first, fully deterministic.
    """

    def __init__(
        self,
        space: TuningSpace,
        base_point: Point | None = None,
        seed_points: "Sequence[Point]" = (),
        *,
        cost_fn: Callable[[Point], float] | None = None,
    ) -> None:
        super().__init__(space, base_point=base_point, seed_points=seed_points)
        self._cost_fn = cost_fn
        self._seed_queue: list[Point] = [dict(p) for p in self._seeds]
        seed_keys = {space.key(p) for p in self._seeds}
        # pending: every valid point not yet proposed, keyed for O(1)
        # removal; _rank breaks prediction ties by enumeration order so
        # the proposal sequence is a pure function of the observations
        self._pending: dict[tuple, Point] = {}
        self._rank: dict[tuple, int] = {}
        self._predicted: dict[tuple, float] = {}
        for i, p in enumerate(space.iter_valid()):
            key = space.key(p)
            if key in self._pending or key in seed_keys:
                continue
            self._pending[key] = dict(p)
            self._rank[key] = i
            self._predicted[key] = self._predict(p)
        # calibration: per (param, canonical value) running mean of
        # ln(observed / predicted) over finite observations
        self._resid_sum: dict[tuple[str, str], float] = {}
        self._resid_n: dict[tuple[str, str], int] = {}

    def _predict(self, point: Point) -> float:
        if self._cost_fn is None:
            return 0.0   # no model: constant prediction = enumeration order
        try:
            pred = float(self._cost_fn(dict(point)))
        except Exception:
            return float("inf")
        return pred if math.isfinite(pred) and pred > 0.0 else float("inf")

    def _value_keys(self, point: Point) -> list[tuple[str, str]]:
        return [(str(k), json.dumps(v, sort_keys=True, default=str))
                for k, v in sorted(dict(point).items())]

    def _calibrated(self, key: tuple, point: Point) -> float:
        pred = self._predicted.get(key, float("inf"))
        if not math.isfinite(pred):
            return pred
        factors = [self._resid_sum[vk] / self._resid_n[vk]
                   for vk in self._value_keys(point)
                   if self._resid_n.get(vk)]
        if not factors:
            return pred
        return pred * math.exp(sum(factors) / len(factors))

    def _observe(self, point: Point, score_s: float, improved: bool) -> None:
        if self._cost_fn is None:
            return
        if not (isinstance(score_s, (int, float)) and math.isfinite(score_s)
                and score_s > 0.0):
            return
        pred = self._predict(point)
        if not math.isfinite(pred):
            return
        residual = math.log(float(score_s) / pred)
        for vk in self._value_keys(point):
            self._resid_sum[vk] = self._resid_sum.get(vk, 0.0) + residual
            self._resid_n[vk] = self._resid_n.get(vk, 0) + 1

    def _propose(self) -> Point | None:
        if self._seed_queue:
            return self._seed_queue.pop(0)
        if not self._pending:
            return None
        key = min(
            self._pending,
            key=lambda k: (self._calibrated(k, self._pending[k]),
                           self._rank[k]))
        return self._pending.pop(key)
