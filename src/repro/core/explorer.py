"""Two-phase online space exploration (paper §3.3).

Phase 1 explores the parameters that change the *structure* of the code
(unrolling factors, vector length, vectorization), in order from the least
switched to the most switched parameter. Within phase 1, variants with **no
leftover code** are explored first; once exhausted, the condition is
softened by gradually admitting variants with more leftover work.

Phase 2 freezes the best phase-1 parameters and explores the combinatorial
choices of the remaining codegen options (instruction scheduling, stack
minimization, prefetch stride).

The explorer is *pull-based*: the auto-tuner asks for ``next_point()`` only
when the regeneration policy grants budget, and feeds results back through
``report(point, score)``.
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Any, Iterator, Sequence

from repro.core.tuning_space import Point, TuningSpace


def _leftover_rank(space: TuningSpace, point: Point) -> float:
    """0 = leftover-free; larger = more leftover (explored later)."""
    res = space.no_leftover(point)
    if isinstance(res, bool):
        return 0.0 if res else 1.0
    # numeric "amount of leftover" → gradual softening order
    return float(res)


@dataclasses.dataclass
class ExplorerState:
    phase: int = 1
    n_proposed: int = 0
    n_reported: int = 0
    finished: bool = False


class TwoPhaseExplorer:
    def __init__(
        self,
        space: TuningSpace,
        base_point: Point | None = None,
        seed_points: "Sequence[Point]" = (),
    ) -> None:
        self.space = space
        # Initial state of non-phase-1 parameters: pre-profiled defaults.
        # A supplied base point is merged OVER the defaults and restricted
        # to known parameters, so a stale persisted point (from an older
        # space definition) degrades gracefully instead of producing
        # candidates with missing/unknown keys.
        base = space.default_point()
        for k, v in dict(base_point or {}).items():
            if k in base:
                base[k] = v
        self.base_point: Point = base
        self.state = ExplorerState()
        self.best_point: Point | None = None
        self.best_score: float = float("inf")
        self._seen: set[tuple] = set()
        self._pending: Point | None = None
        # Warm-start: seed points (e.g. a persisted best from a previous
        # run) are proposed before any enumeration, so a warm process
        # re-validates its known-best variant with a single regeneration.
        self._seeds: list[Point] = [
            dict(p) for p in seed_points
            if space.contains(p) and space.is_valid(p)
        ]
        self._phase1_iter = self._make_phase1_iter()
        self._phase2_iter: Iterator[Point] | None = None
        self.history: list[tuple[Point, float]] = []

    # ------------------------------------------------------------- ordering
    def _make_phase1_iter(self) -> Iterator[Point]:
        # Enumerate in least→most switched order, then stable-sort by
        # leftover rank: leftover-free first, gradually softening.
        candidates = [
            p for p in self.space.iter_phase1(self.base_point)
            if self.space.is_valid(p)
        ]
        candidates.sort(key=lambda p: _leftover_rank(self.space, p))
        return itertools.chain(self._seeds, candidates)

    def _make_phase2_iter(self) -> Iterator[Point]:
        assert self.best_point is not None
        candidates = [
            p for p in self.space.iter_phase2(self.best_point)
            if self.space.is_valid(p)
        ]
        return iter(candidates)

    # ------------------------------------------------------------------ api
    def next_point(self) -> Point | None:
        """Next variant to generate+evaluate, or None when done."""
        if self.state.finished:
            return None
        it = self._phase1_iter if self.state.phase == 1 else self._phase2_iter
        assert it is not None
        while True:
            try:
                point = next(it)
            except StopIteration:
                if self.state.phase == 1:
                    if self.best_point is None:
                        # nothing valid at all
                        self.state.finished = True
                        return None
                    self.state.phase = 2
                    self._phase2_iter = self._make_phase2_iter()
                    it = self._phase2_iter
                    continue
                self.state.finished = True
                return None
            key = self.space.key(point)
            if key in self._seen:
                continue
            self._seen.add(key)
            self.state.n_proposed += 1
            self._pending = point
            return dict(point)

    def report(self, point: Point, score_s: float) -> bool:
        """Feed a measurement back; returns True if it is the new best."""
        self.state.n_reported += 1
        self.history.append((dict(point), score_s))
        if score_s < self.best_score:
            self.best_score = score_s
            self.best_point = dict(point)
            return True
        return False

    @property
    def finished(self) -> bool:
        return self.state.finished

    def run_to_completion(self, evaluate) -> tuple[Point | None, float]:
        """Exhaust the exploration with ``evaluate(point) -> seconds``.

        Used by the static tuner and the simulated-platform studies; the
        online auto-tuner instead paces itself with the regeneration policy.
        """
        while True:
            point = self.next_point()
            if point is None:
                break
            self.report(point, evaluate(point))
        return self.best_point, self.best_score
