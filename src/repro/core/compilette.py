"""Compilette: the parametrizable function generator (paper §3.1).

In the paper a compilette is a deGoal generator that emits ARM machine code
at run time, specializing run-time constants and honouring the auto-tuned
parameters. Here, a compilette is an object that — given a tuning-space
point and a set of run-time-constant specializations — *instantiates a
concrete compiled executable*:

  * on the real backend, a ``jax.jit``-compiled XLA executable (optionally a
    Pallas kernel with the point's BlockSpec tiling), i.e. actual runtime
    machine-code generation by XLA — the TPU/CPU analogue of deGoal;
  * on a simulated device profile, a cost-model evaluation of the same
    point (the analogue of the paper's gem5 simulations).

The generator function receives ``(point, **specialization)`` and must
return a callable ``fn(*args)``. Generation cost is measured and reported —
it is part of the paper's claimed overhead budget.

Two pieces take generation cost OFF the application hot path:

  * :class:`GenerationCache` — memoizes :class:`GeneratedKernel`\\ s under
    ``(kernel, point, specialization, device fingerprint[, token])``. A
    point revisited after bucketing, tuner eviction or a warm start is a
    cache hit: the stored executable is returned with zero generation
    time instead of recompiling. The cache is owned by the process-wide
    ``TuningCoordinator`` (one per process), so entries survive tuner
    retirement and re-registration.
  * :class:`AsyncGenerator` — a single background compile executor (the
    coordinator's analogue of the paper's "new version in a code buffer"
    double-buffering): the tuning wake *requests* a variant and keeps the
    current active function serving until the compiled candidate is
    ready. In ``"thread"`` mode one worker thread compiles; in
    ``"manual"`` mode jobs complete only at explicit ``run_pending()``
    calls, which is what makes the pipeline deterministically testable
    under a :class:`~repro.core.VirtualClock` (no sleeps).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import queue
import threading
import time
from typing import Any, Callable, Mapping

from repro.core.persistence import _canon
from repro.core.tuning_space import Point, TuningSpace


@dataclasses.dataclass
class GeneratedKernel:
    """A concrete variant: the paper's 'new version in a code buffer'.

    ``generation_time_s`` is the cost *charged for this instantiation*: the
    measured (or simulated) compile time on a fresh compile, and ``0.0``
    on a :class:`GenerationCache` hit (``meta["source"] == "cache"``; the
    original compile cost is kept in ``meta["compiled_in_s"]``).
    """

    point: Point
    fn: Callable[..., Any]
    generation_time_s: float
    specialization: dict[str, Any]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


# Residency estimate for cache entries whose executable size is unknown
# (lazy jit wrappers, virtual kernels): a byte-bounded cache must charge
# SOMETHING per entry or unknown-size entries would make the bound a
# no-op.
DEFAULT_ENTRY_BYTES = 64 * 1024


def executable_bytes(fn: Callable[..., Any]) -> int | None:
    """Estimated resident bytes of an AOT-compiled XLA executable.

    Reads the compiled artifact's ``memory_analysis()`` (generated code
    plus temp scratch — the allocations the executable itself pins;
    argument/output buffers are caller-owned traffic, not residency).
    ``None`` when the callable is not an AOT ``Compiled`` object or the
    backend does not report an analysis.
    """
    try:
        analysis = fn.memory_analysis()
    except Exception:
        return None
    total = 0
    for attr in ("generated_code_size_in_bytes", "temp_size_in_bytes"):
        try:
            total += int(getattr(analysis, attr, 0) or 0)
        except Exception:
            continue
    return total if total > 0 else None


class GenerationCache:
    """Process-wide memo of compiled variants, keyed by full identity.

    The key is ``(kernel name, cache token, canonical point, canonical
    specialization, device fingerprint)`` — the same identity the
    ``TunedRegistry`` persists best points under, so anything the registry
    would warm-start, the cache can serve without recompiling. Entries are
    kept in LRU order; ``max_entries`` bounds residency (compiled XLA
    executables pin device memory), ``None`` means unbounded.

    **Cost-weighted eviction.** Entries are not equally expensive to get
    back: one attention step-program costs orders of magnitude more to
    recompile than a trivial rmsnorm variant, yet a pure LRU would let
    ten cheap variants displace it. Every entry records its
    ``generation_time_s``; when the cache overflows, the victim is the
    *cheapest-to-regenerate* entry among the ``evict_window`` least
    recently used (ties break toward the older entry, so equal-cost
    entries degrade to plain LRU). The window keeps the policy local:
    recently used entries are never sacrificed however cheap they are.

    **Byte bound.** ``max_bytes`` additionally bounds the *estimated
    resident bytes* of the cached executables (compiled XLA code pins
    host/device memory in proportion to its size, not its entry count):
    every entry is charged its ``meta["size_bytes"]`` — recorded at
    compile time from the AOT artifact's memory analysis — or
    :data:`DEFAULT_ENTRY_BYTES` when unknown. Overflowing either bound
    evicts through the same cost-weighted window; the newest entry is
    never its own victim, so one entry larger than ``max_bytes`` stays
    resident until displaced (evicting it on arrival would make the
    cache useless for exactly the kernels it exists to keep).

    Thread-safe: the coordinator's tuning thread, the async compile
    worker, and the application thread may all hit it concurrently.
    """

    def __init__(self, max_entries: int | None = None,
                 evict_window: int = 8,
                 max_bytes: int | None = None) -> None:
        self._table: "collections.OrderedDict[tuple, GeneratedKernel]" = (
            collections.OrderedDict())
        self._mu = threading.Lock()
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.evict_window = max(int(evict_window), 1)
        self._bytes = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0

    @staticmethod
    def key(
        kernel: str,
        point: Point,
        specialization: Mapping[str, Any],
        device: str,
        token: str | None = None,
    ) -> tuple:
        return (kernel, token, _canon(dict(point)),
                _canon(dict(specialization)), device)

    def get(self, key: tuple) -> GeneratedKernel | None:
        with self._mu:
            kern = self._table.get(key)
            if kern is None:
                self.misses += 1
                return None
            self._table.move_to_end(key)
            self.hits += 1
            return kern

    @staticmethod
    def _regen_cost(kern: GeneratedKernel) -> float:
        """What evicting this entry would cost to recompile later."""
        return float(kern.meta.get("compiled_in_s", kern.generation_time_s))

    @staticmethod
    def _entry_bytes(kern: GeneratedKernel) -> int:
        """Residency charge of one entry against the byte bound."""
        size = kern.meta.get("size_bytes")
        return int(size) if size else DEFAULT_ENTRY_BYTES

    def _over_bounds(self) -> bool:
        return (
            (self.max_entries is not None
             and len(self._table) > self.max_entries)
            or (self.max_bytes is not None and self._bytes > self.max_bytes)
        )

    def put(self, key: tuple, kern: GeneratedKernel) -> None:
        with self._mu:
            old = self._table.pop(key, None)
            if old is not None:
                self._bytes -= self._entry_bytes(old)
            self._table[key] = kern
            self._bytes += self._entry_bytes(kern)
            while self._over_bounds():
                if len(self._table) == 1:
                    if self.max_entries is not None and self.max_entries < 1:
                        # max_entries=0 (caching disabled): nothing can stay
                        _, lone = self._table.popitem(last=False)
                        self._bytes -= self._entry_bytes(lone)
                        self.evictions += 1
                        continue
                    # one entry larger than max_bytes: the newest entry is
                    # never its own victim, so it stays until displaced
                    break
                # cheapest-to-regenerate among the LRU window; min() keeps
                # the first (= least recently used) entry on cost ties.
                # The window never reaches the newest entry (cap at
                # len-1), so a fresh expensive compile cannot evict itself
                # the moment it lands.
                window = itertools.islice(
                    self._table.items(),
                    min(self.evict_window, len(self._table) - 1))
                victim, evicted = min(
                    window, key=lambda kv: self._regen_cost(kv[1]))
                del self._table[victim]
                self._bytes -= self._entry_bytes(evicted)
                self.evictions += 1

    def __len__(self) -> int:
        with self._mu:
            return len(self._table)

    def __contains__(self, key: tuple) -> bool:
        with self._mu:
            return key in self._table

    def clear(self) -> None:
        with self._mu:
            self._table.clear()
            self._bytes = 0

    def stats(self) -> dict[str, Any]:
        with self._mu:
            total = self.hits + self.misses
            return {
                "entries": len(self._table),
                "bytes": self._bytes,
                "max_bytes": self.max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "hit_rate": self.hits / total if total else 0.0,
            }


class Compilette:
    """Parametrizable kernel generator.

    Parameters
    ----------
    name:       kernel identity (used for persistence keys).
    space:      the tuning space (with validity holes).
    generate:   ``generate(point, **specialization) -> callable``; the
                callable must accept the kernel's runtime arguments. It
                should *close over* the specialized run-time constants —
                this is the deGoal ``#(...)`` inlining analogue (in JAX,
                trace-time constant folding).
    gen_cost_s: simulated generation cost — a float or
                ``f(point, specialization) -> seconds``. When set, the
                reported ``generation_time_s`` is this simulated cost
                instead of the measured wall time (``meta["simulated"]``
                is True), which is how virtual-clock tests model compile
                cost deterministically.
    cache_token: extra identity mixed into the generation-cache key.
                Compilettes that share a *name* but generate different
                programs (e.g. the serve step-programs of two different
                model configs) must carry distinct tokens, or a cache hit
                would hand one kernel the other's executable.
    """

    def __init__(
        self,
        name: str,
        space: TuningSpace,
        generate: Callable[..., Callable[..., Any]],
        cost_model: Callable[[Point, Mapping[str, Any], Any], float] | None = None,
        *,
        gen_cost_s: float | Callable[..., float] | None = None,
        cache_token: str | None = None,
    ) -> None:
        self.name = name
        self.space = space
        self._generate = generate
        # cost_model(point, specialization, profile) -> simulated seconds.
        self.cost_model = cost_model
        self.gen_cost_s = gen_cost_s
        self.cache_token = cache_token
        # Attached by the coordinator (attach_cache): process-wide memo of
        # compiled variants + the device fingerprint that keys it.
        self.cache: GenerationCache | None = None
        self.cache_device: str = "uncached"

    # ------------------------------------------------------------- caching
    def attach_cache(self, cache: GenerationCache | None,
                     device: str | None = None) -> None:
        """Route this compilette's generations through ``cache``."""
        self.cache = cache
        if device is not None:
            self.cache_device = device

    def cache_key(self, point: Point,
                  specialization: Mapping[str, Any]) -> tuple:
        return GenerationCache.key(
            self.name, point, specialization, self.cache_device,
            self.cache_token)

    def _simulated_cost(self, point: Point,
                        specialization: Mapping[str, Any]) -> float | None:
        if self.gen_cost_s is None:
            return None
        if callable(self.gen_cost_s):
            return float(self.gen_cost_s(dict(point), dict(specialization)))
        return float(self.gen_cost_s)

    def generate(self, point: Point, **specialization: Any) -> GeneratedKernel:
        """Instantiate ``point`` — from the cache when possible.

        A cache hit returns a fresh :class:`GeneratedKernel` wrapper
        (shared ``fn``, private ``meta``) with ``generation_time_s = 0``:
        nothing was compiled, so nothing is charged and nothing stalls.
        ``Compilette._generate`` runs at most once per cache key.
        """
        if not self.space.is_valid(point):
            raise ValueError(
                f"compilette {self.name!r}: point {point} is a hole in the "
                "tuning space (invalid variant)"
            )
        key = None
        if self.cache is not None:
            key = self.cache_key(point, specialization)
            cached = self.cache.get(key)
            if cached is not None:
                return GeneratedKernel(
                    point=dict(point),
                    fn=cached.fn,
                    generation_time_s=0.0,
                    specialization=dict(specialization),
                    meta={"source": "cache",
                          "compiled_in_s": cached.meta.get(
                              "compiled_in_s", cached.generation_time_s)},
                )
        t0 = time.perf_counter()
        fn = self._generate(dict(point), **specialization)
        dt = time.perf_counter() - t0
        sim = self._simulated_cost(point, specialization)
        kern = GeneratedKernel(
            point=dict(point),
            fn=fn,
            generation_time_s=dt if sim is None else sim,
            specialization=dict(specialization),
            meta={"source": "compiled", "simulated": sim is not None,
                  "compiled_in_s": dt if sim is None else sim,
                  # byte-bounded caches charge this residency estimate
                  # (None → DEFAULT_ENTRY_BYTES at the cache)
                  "size_bytes": executable_bytes(fn)},
        )
        if self.cache is not None and key is not None:
            self.cache.put(key, kern)
        return kern

    def simulate(self, point: Point, profile: Any, **specialization: Any) -> float:
        """Simulated execution time of ``point`` on a device ``profile``."""
        if self.cost_model is None:
            raise ValueError(f"compilette {self.name!r} has no cost model")
        return self.cost_model(dict(point), dict(specialization), profile)


# ------------------------------------------------------------- async pipeline
@dataclasses.dataclass(eq=False)
class GenerationTicket:
    """Handle for one in-flight (or completed) generation job."""

    compilette: Compilette
    point: Point
    specialization: dict[str, Any]
    speculative: bool = False
    # set at completion (under the generator lock):
    done: bool = False
    kern: GeneratedKernel | None = None
    error: BaseException | None = None
    gen_charge_s: float = 0.0   # unclaimed budget charge for the harvester
    stalled: bool = False       # the generation ran inline on the caller
                                # (cache-eviction race): a real stall
    # charge_cb(ticket, seconds): bills a speculative compile at completion
    _charge_cb: Callable[["GenerationTicket", float], None] | None = None

    def adopt(self) -> None:
        """A tuner claims a speculative ticket: the harvester (not the
        completion callback) will charge its generation time."""
        self.speculative = False
        self._charge_cb = None


class AsyncGenerator:
    """Single background compile executor shared by a whole coordinator.

    The paper keeps the application running the current version while the
    next one is emitted into a second code buffer; this is that overlap
    for XLA compiles. One executor per process mirrors the coordinator's
    single tuning thread: compilation parallelism is bounded at 1, so
    tuning can never oversubscribe the host the kernels run on.

    Modes:
      * ``"thread"`` — a daemon worker thread drains the job queue;
        generation time is measured wall time in the worker (real mode).
      * ``"manual"`` — jobs complete only when ``run_pending()`` is
        called (the coordinator calls it at the top of every ``pump``),
        so a job submitted at pump *k* is ready at pump *k+1*: fully
        deterministic under a ``VirtualClock``, no sleeps anywhere.

    ``submit`` deduplicates by cache key: a job already in flight is
    joined (the same ticket is returned), and a point already in the
    compilette's cache returns an immediately-done ticket. Speculative
    (prefetch) submissions carry a charge callback so their compile time
    is billed to the requesting tuner's accounts even if the prefetched
    variant is never proposed.
    """

    def __init__(self, mode: str = "thread",
                 worker_idle_timeout_s: float = 30.0) -> None:
        if mode not in ("thread", "manual"):
            raise ValueError(f"AsyncGenerator mode must be 'thread' or "
                             f"'manual', got {mode!r}")
        self.mode = mode
        self.worker_idle_timeout_s = worker_idle_timeout_s
        self._mu = threading.Lock()
        self._inflight: dict[tuple, GenerationTicket] = {}
        # negative memo: keys whose generation raised. Bounded by the
        # number of holes in the managed tuning spaces; without it a
        # prefetched hole would be compiled (and billed) a second time
        # when the tuner itself proposes the point.
        self._failed: dict[tuple, BaseException] = {}
        self._queue: "queue.Queue[GenerationTicket | None]" = queue.Queue()
        self._worker: threading.Thread | None = None
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.speculative_submitted = 0
        self.joined = 0

    # ------------------------------------------------------------ lifecycle
    def _ensure_worker(self) -> None:
        if self.mode != "thread":
            return
        with self._mu:
            if self._worker is not None:
                return
            self._worker = threading.Thread(
                target=self._worker_loop, daemon=True,
                name="variant-generator")
            self._worker.start()

    def _worker_loop(self) -> None:
        # The worker retires itself after an idle period (a fresh one is
        # spawned by the next submit), so a forgotten coordinator — e.g.
        # a per-request one that was never close()d — does not pin a
        # blocked daemon thread for the life of the process.
        while True:
            try:
                ticket = self._queue.get(timeout=self.worker_idle_timeout_s)
            except queue.Empty:
                with self._mu:
                    if self._queue.empty():
                        self._worker = None
                        return
                continue
            if ticket is None:
                with self._mu:
                    self._worker = None
                return
            self._run(ticket)

    def shutdown(self) -> None:
        with self._mu:
            worker = self._worker
        if worker is not None:
            self._queue.put(None)
            worker.join(timeout=5.0)

    # ------------------------------------------------------------- running
    def _run(self, ticket: GenerationTicket) -> None:
        t0 = time.perf_counter()
        try:
            kern = ticket.compilette.generate(
                ticket.point, **ticket.specialization)
            err = None
        except BaseException as e:  # generation failure = late-found hole
            # drop the traceback: it pins the whole _generate frame
            # (model state, tracing temporaries) for as long as the
            # failure memo lives, and no consumer ever re-raises
            kern, err = None, e.with_traceback(None)
        failed_charge = time.perf_counter() - t0
        if err is not None:
            try:
                # a declared simulated cost keeps failure billing
                # deterministic under virtual clocks (successes already
                # bill the declared cost via generation_time_s)
                sim = ticket.compilette._simulated_cost(
                    ticket.point, ticket.specialization)
                if sim is not None:
                    failed_charge = sim
            except Exception:
                pass
        with self._mu:
            ticket.kern = kern
            ticket.error = err
            if err is not None:
                self._failed[ticket.compilette.cache_key(
                    ticket.point, ticket.specialization)] = err
            charge = (kern.generation_time_s if kern is not None
                      else failed_charge)
            if ticket.speculative and ticket._charge_cb is not None:
                # prefetch: the requester is billed NOW (used or not);
                # the harvester must not charge a second time
                cb, ticket.gen_charge_s = ticket._charge_cb, 0.0
            else:
                cb, ticket.gen_charge_s = None, charge
            ticket.done = True
            self._inflight.pop(
                ticket.compilette.cache_key(
                    ticket.point, ticket.specialization), None)
            if err is None:
                self.completed += 1
            else:
                self.failed += 1
        if cb is not None:
            # outside the lock: the callback charges tuner/coordinator
            # accounts and may take their locks
            cb(ticket, charge)

    def run_pending(self, max_jobs: int | None = None) -> int:
        """Manual mode: complete queued jobs inline. No-op in thread mode
        (the worker drains the queue itself). Returns jobs completed."""
        if self.mode != "manual":
            return 0
        n = 0
        while max_jobs is None or n < max_jobs:
            try:
                ticket = self._queue.get_nowait()
            except queue.Empty:
                return n
            if ticket is None:
                continue
            self._run(ticket)
            n += 1
        return n

    # ------------------------------------------------------------- submit
    def submit(
        self,
        compilette: Compilette,
        point: Point,
        specialization: Mapping[str, Any],
        *,
        speculative: bool = False,
        charge_cb: Callable[[GenerationTicket, float], None] | None = None,
    ) -> GenerationTicket:
        """Request generation of ``point``; never blocks on the compile.

        Returns a ticket that is already ``done`` when the variant is in
        the cache, the in-flight ticket when the same key was already
        submitted (a non-speculative join adopts a speculative ticket),
        or a freshly queued job otherwise.
        """
        key = compilette.cache_key(point, specialization)

        def _join_locked(existing: GenerationTicket) -> GenerationTicket:
            self.joined += 1
            if not speculative:
                existing.adopt()
            return existing

        with self._mu:
            existing = self._inflight.get(key)
            if existing is not None:
                return _join_locked(existing)
            failed = self._failed.get(key)
            if failed is not None:
                # known hole: an already-billed failure, never recompiled
                return GenerationTicket(
                    compilette=compilette, point=dict(point),
                    specialization=dict(specialization), done=True,
                    error=failed, gen_charge_s=0.0)
        if compilette.cache is not None and key in compilette.cache:
            # hit: materialize through generate() so cache counters and
            # the zero-cost hit wrapper stay consistent. OUTSIDE the
            # generator lock: in the rare race where an LRU eviction
            # lands between the check and the get, generate() recompiles
            # inline — a bounded stall for this caller only, charged
            # below AND flagged as a stall, never a compile inside the
            # critical section. A failure on that inline path is a hole
            # like any other (a raise here would crash the caller's
            # pump/request thread).
            try:
                kern = compilette.generate(point, **dict(specialization))
            except BaseException as e:
                err = e.with_traceback(None)
                with self._mu:
                    self._failed[key] = err
                    self.failed += 1
                return GenerationTicket(
                    compilette=compilette, point=dict(point),
                    specialization=dict(specialization), done=True,
                    error=err, gen_charge_s=0.0)
            return GenerationTicket(
                compilette=compilette, point=dict(point),
                specialization=dict(specialization), done=True,
                kern=kern, gen_charge_s=kern.generation_time_s,
                stalled=kern.meta.get("source") == "compiled")
        with self._mu:
            existing = self._inflight.get(key)
            if existing is not None:   # raced in while we were unlocked
                return _join_locked(existing)
            ticket = GenerationTicket(
                compilette=compilette, point=dict(point),
                specialization=dict(specialization),
                speculative=speculative, _charge_cb=charge_cb)
            self._inflight[key] = ticket
            self.submitted += 1
            if speculative:
                self.speculative_submitted += 1
        # enqueue BEFORE ensuring the worker: an idle worker only retires
        # after seeing an empty queue, so the job is picked up either by
        # the surviving worker or by the one _ensure_worker spawns
        self._queue.put(ticket)
        self._ensure_worker()
        return ticket

    def poll(self, ticket: GenerationTicket) -> GenerationTicket | None:
        """Non-blocking readiness check: the ticket when done, else None."""
        with self._mu:
            return ticket if ticket.done else None

    def disown(self, ticket: GenerationTicket,
               charge_cb: Callable[[GenerationTicket, float], None] | None
               ) -> float:
        """Release a ticket nobody will harvest (its tuner is retiring).

        Returns the unclaimed charge of an already-completed ticket (the
        caller bills it); a still-in-flight ticket is converted to a
        speculative one so ``charge_cb`` bills it at completion — either
        way the compile cost reaches the budget exactly once.
        """
        with self._mu:
            if ticket.done:
                charge, ticket.gen_charge_s = ticket.gen_charge_s, 0.0
                return charge
            ticket.speculative = True
            ticket._charge_cb = charge_cb
            return 0.0

    @property
    def in_flight(self) -> int:
        with self._mu:
            return len(self._inflight)

    def stats(self) -> dict[str, Any]:
        with self._mu:
            return {
                "mode": self.mode,
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "speculative_submitted": self.speculative_submitted,
                "joined": self.joined,
                "in_flight": len(self._inflight),
            }
