"""Compilette: the parametrizable function generator (paper §3.1).

In the paper a compilette is a deGoal generator that emits ARM machine code
at run time, specializing run-time constants and honouring the auto-tuned
parameters. Here, a compilette is an object that — given a tuning-space
point and a set of run-time-constant specializations — *instantiates a
concrete compiled executable*:

  * on the real backend, a ``jax.jit``-compiled XLA executable (optionally a
    Pallas kernel with the point's BlockSpec tiling), i.e. actual runtime
    machine-code generation by XLA — the TPU/CPU analogue of deGoal;
  * on a simulated device profile, a cost-model evaluation of the same
    point (the analogue of the paper's gem5 simulations).

The generator function receives ``(point, **specialization)`` and must
return a callable ``fn(*args)``. Generation cost is measured and reported —
it is part of the paper's claimed overhead budget.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Mapping

from repro.core.tuning_space import Point, TuningSpace


@dataclasses.dataclass
class GeneratedKernel:
    """A concrete variant: the paper's 'new version in a code buffer'."""

    point: Point
    fn: Callable[..., Any]
    generation_time_s: float
    specialization: dict[str, Any]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


class Compilette:
    """Parametrizable kernel generator.

    Parameters
    ----------
    name:       kernel identity (used for persistence keys).
    space:      the tuning space (with validity holes).
    generate:   ``generate(point, **specialization) -> callable``; the
                callable must accept the kernel's runtime arguments. It
                should *close over* the specialized run-time constants —
                this is the deGoal ``#(...)`` inlining analogue (in JAX,
                trace-time constant folding).
    warmup:     if given, ``warmup(fn, *args)`` is called once after
                generation so that measured times exclude one-time compile
                cost when the evaluator asks for steady-state timing (the
                XLA compile itself is accounted as generation time).
    """

    def __init__(
        self,
        name: str,
        space: TuningSpace,
        generate: Callable[..., Callable[..., Any]],
        cost_model: Callable[[Point, Mapping[str, Any], Any], float] | None = None,
    ) -> None:
        self.name = name
        self.space = space
        self._generate = generate
        # cost_model(point, specialization, profile) -> simulated seconds.
        self.cost_model = cost_model

    def generate(self, point: Point, **specialization: Any) -> GeneratedKernel:
        if not self.space.is_valid(point):
            raise ValueError(
                f"compilette {self.name!r}: point {point} is a hole in the "
                "tuning space (invalid variant)"
            )
        t0 = time.perf_counter()
        fn = self._generate(dict(point), **specialization)
        dt = time.perf_counter() - t0
        return GeneratedKernel(
            point=dict(point),
            fn=fn,
            generation_time_s=dt,
            specialization=dict(specialization),
        )

    def simulate(self, point: Point, profile: Any, **specialization: Any) -> float:
        """Simulated execution time of ``point`` on a device ``profile``."""
        if self.cost_model is None:
            raise ValueError(f"compilette {self.name!r} has no cost model")
        return self.cost_model(dict(point), dict(specialization), profile)
