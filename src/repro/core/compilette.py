"""Compilette: the parametrizable function generator (paper §3.1).

In the paper a compilette is a deGoal generator that emits ARM machine code
at run time, specializing run-time constants and honouring the auto-tuned
parameters. Here, a compilette is an object that — given a tuning-space
point and a set of run-time-constant specializations — *instantiates a
concrete compiled executable*:

  * on the real backend, a ``jax.jit``-compiled XLA executable (optionally a
    Pallas kernel with the point's BlockSpec tiling), i.e. actual runtime
    machine-code generation by XLA — the TPU/CPU analogue of deGoal;
  * on a simulated device profile, a cost-model evaluation of the same
    point (the analogue of the paper's gem5 simulations).

The generator function receives ``(point, **specialization)`` and must
return a callable ``fn(*args)``. Generation cost is measured and reported —
it is part of the paper's claimed overhead budget.

Two pieces take generation cost OFF the application hot path:

  * :class:`GenerationCache` — memoizes :class:`GeneratedKernel`\\ s under
    ``(kernel, point, specialization, device fingerprint[, token])``. A
    point revisited after bucketing, tuner eviction or a warm start is a
    cache hit: the stored executable is returned with zero generation
    time instead of recompiling. The cache is owned by the process-wide
    ``TuningCoordinator`` (one per process), so entries survive tuner
    retirement and re-registration.
  * :class:`~repro.core.compile_farm.CompileFarm` — the background
    compile pool (the coordinator's analogue of the paper's "new version
    in a code buffer" double-buffering): the tuning wake *requests* a
    variant and keeps the current active function serving until the
    compiled candidate is ready. In ``"thread"`` mode worker threads
    compile; in ``"manual"`` mode jobs complete only at explicit
    ``run_pending()`` calls, which is what makes the pipeline
    deterministically testable under a :class:`~repro.core.VirtualClock`
    (no sleeps).
"""

from __future__ import annotations

import collections
import dataclasses
import itertools
import threading
import time
from typing import Any, Callable, Mapping

from repro.core.persistence import _canon
from repro.core.tuning_space import Point, TuningSpace


@dataclasses.dataclass
class GeneratedKernel:
    """A concrete variant: the paper's 'new version in a code buffer'.

    ``generation_time_s`` is the cost *charged for this instantiation*: the
    measured (or simulated) compile time on a fresh compile, and ``0.0``
    on a :class:`GenerationCache` hit (``meta["source"] == "cache"``; the
    original compile cost is kept in ``meta["compiled_in_s"]``).
    """

    point: Point
    fn: Callable[..., Any]
    generation_time_s: float
    specialization: dict[str, Any]
    meta: dict[str, Any] = dataclasses.field(default_factory=dict)


# Residency estimate for cache entries whose executable size is unknown
# (lazy jit wrappers, virtual kernels): a byte-bounded cache must charge
# SOMETHING per entry or unknown-size entries would make the bound a
# no-op.
DEFAULT_ENTRY_BYTES = 64 * 1024


def device_free_memory_bytes() -> int | None:
    """Free bytes on the default accelerator, or ``None`` when unknowable.

    Read from the device's ``memory_stats()`` (``bytes_limit`` minus
    ``bytes_in_use``); CPU backends and older jaxlibs report nothing and
    return ``None``, which callers treat as "no live pressure signal".
    """
    try:
        import jax

        stats = jax.local_devices()[0].memory_stats()
        if not stats:
            return None
        limit = stats.get("bytes_limit")
        used = stats.get("bytes_in_use")
        if limit is None or used is None:
            return None
        return max(int(limit) - int(used), 0)
    except Exception:
        return None


def executable_bytes(fn: Callable[..., Any]) -> int | None:
    """Estimated resident bytes of an AOT-compiled XLA executable.

    Reads the compiled artifact's ``memory_analysis()`` (generated code
    plus temp scratch — the allocations the executable itself pins;
    argument/output buffers are caller-owned traffic, not residency).
    ``None`` when the callable is not an AOT ``Compiled`` object or the
    backend does not report an analysis.
    """
    try:
        analysis = fn.memory_analysis()
    except Exception:
        return None
    total = 0
    for attr in ("generated_code_size_in_bytes", "temp_size_in_bytes"):
        try:
            total += int(getattr(analysis, attr, 0) or 0)
        except Exception:
            continue
    return total if total > 0 else None


class GenerationCache:
    """Process-wide memo of compiled variants, keyed by full identity.

    The key is ``(kernel name, cache token, canonical point, canonical
    specialization, device fingerprint)`` — the same identity the
    ``TunedRegistry`` persists best points under, so anything the registry
    would warm-start, the cache can serve without recompiling. Entries are
    kept in LRU order; ``max_entries`` bounds residency (compiled XLA
    executables pin device memory), ``None`` means unbounded.

    **Cost-weighted eviction.** Entries are not equally expensive to get
    back: one attention step-program costs orders of magnitude more to
    recompile than a trivial rmsnorm variant, yet a pure LRU would let
    ten cheap variants displace it. Every entry records its
    ``generation_time_s``; when the cache overflows, the victim is the
    *cheapest-to-regenerate* entry among the ``evict_window`` least
    recently used (ties break toward the older entry, so equal-cost
    entries degrade to plain LRU). The window keeps the policy local:
    recently used entries are never sacrificed however cheap they are.

    **Byte bound.** ``max_bytes`` additionally bounds the *estimated
    resident bytes* of the cached executables (compiled XLA code pins
    host/device memory in proportion to its size, not its entry count):
    every entry is charged its ``meta["size_bytes"]`` — recorded at
    compile time from the AOT artifact's memory analysis — or
    :data:`DEFAULT_ENTRY_BYTES` when unknown. Overflowing either bound
    evicts through the same cost-weighted window; the newest entry is
    never its own victim, so one entry larger than ``max_bytes`` stays
    resident until displaced (evicting it on arrival would make the
    cache useless for exactly the kernels it exists to keep).

    **Live memory pressure.** ``max_bytes`` is a static estimate; the
    device the executables actually pin is shared with activations and
    weights whose footprint the cache cannot predict. When a
    ``free_memory_fn`` is provided (the session wires
    :func:`device_free_memory_bytes`), every ``put`` re-derives the
    effective byte bound as ``min(max_bytes, memory_headroom_frac x
    free_device_bytes)`` — under pressure the cache shrinks itself
    before the allocator OOMs, and when the probe has no signal (CPU
    backends, virtual clocks) the static ``max_bytes`` bound applies
    unchanged. Evictions forced by the dynamic bound alone are counted
    in ``pressure_evictions``.

    Thread-safe: the coordinator's tuning thread, the async compile
    worker, and the application thread may all hit it concurrently.
    """

    def __init__(self, max_entries: int | None = None,
                 evict_window: int = 8,
                 max_bytes: int | None = None,
                 free_memory_fn: Callable[[], int | None] | None = None,
                 memory_headroom_frac: float = 0.5) -> None:
        self._table: "collections.OrderedDict[tuple, GeneratedKernel]" = (
            collections.OrderedDict())
        self._mu = threading.Lock()
        self.max_entries = max_entries
        self.max_bytes = max_bytes
        self.free_memory_fn = free_memory_fn
        self.memory_headroom_frac = float(memory_headroom_frac)
        self.evict_window = max(int(evict_window), 1)
        self._bytes = 0
        self._effective_max_bytes = max_bytes
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.pressure_evictions = 0

    @staticmethod
    def key(
        kernel: str,
        point: Point,
        specialization: Mapping[str, Any],
        device: str,
        token: str | None = None,
    ) -> tuple:
        return (kernel, token, _canon(dict(point)),
                _canon(dict(specialization)), device)

    def get(self, key: tuple) -> GeneratedKernel | None:
        with self._mu:
            kern = self._table.get(key)
            if kern is None:
                self.misses += 1
                return None
            self._table.move_to_end(key)
            self.hits += 1
            return kern

    @staticmethod
    def _regen_cost(kern: GeneratedKernel) -> float:
        """What evicting this entry would cost to recompile later."""
        return float(kern.meta.get("compiled_in_s", kern.generation_time_s))

    @staticmethod
    def _entry_bytes(kern: GeneratedKernel) -> int:
        """Residency charge of one entry against the byte bound."""
        size = kern.meta.get("size_bytes")
        return int(size) if size else DEFAULT_ENTRY_BYTES

    def _byte_bound(self) -> int | None:
        """The byte bound in force for this put: static cap shrunk by
        live device-memory pressure when the probe has a signal."""
        free = None
        if self.free_memory_fn is not None:
            try:
                free = self.free_memory_fn()
            except Exception:
                free = None
        if free is None:
            return self.max_bytes          # no signal: static estimate
        dynamic = int(free * self.memory_headroom_frac)
        if self.max_bytes is None:
            return dynamic
        return min(self.max_bytes, dynamic)

    def _over_bounds(self, byte_bound: int | None) -> bool:
        return (
            (self.max_entries is not None
             and len(self._table) > self.max_entries)
            or (byte_bound is not None and self._bytes > byte_bound)
        )

    def put(self, key: tuple, kern: GeneratedKernel) -> None:
        with self._mu:
            byte_bound = self._effective_max_bytes = self._byte_bound()
            # an eviction within the static bound can only have been
            # forced by the pressure-shrunk dynamic bound
            pressured = (byte_bound is not None
                         and (self.max_bytes is None
                              or byte_bound < self.max_bytes))
            old = self._table.pop(key, None)
            if old is not None:
                self._bytes -= self._entry_bytes(old)
            self._table[key] = kern
            self._bytes += self._entry_bytes(kern)
            while self._over_bounds(byte_bound):
                if len(self._table) == 1:
                    if self.max_entries is not None and self.max_entries < 1:
                        # max_entries=0 (caching disabled): nothing can stay
                        _, lone = self._table.popitem(last=False)
                        self._bytes -= self._entry_bytes(lone)
                        self.evictions += 1
                        continue
                    # one entry larger than max_bytes: the newest entry is
                    # never its own victim, so it stays until displaced
                    break
                # cheapest-to-regenerate among the LRU window; min() keeps
                # the first (= least recently used) entry on cost ties.
                # The window never reaches the newest entry (cap at
                # len-1), so a fresh expensive compile cannot evict itself
                # the moment it lands.
                window = itertools.islice(
                    self._table.items(),
                    min(self.evict_window, len(self._table) - 1))
                if pressured and not self._over_bounds(self.max_bytes):
                    # within every static bound: only the pressure-shrunk
                    # dynamic bound forced this victim out
                    self.pressure_evictions += 1
                victim, evicted = min(
                    window, key=lambda kv: self._regen_cost(kv[1]))
                del self._table[victim]
                self._bytes -= self._entry_bytes(evicted)
                self.evictions += 1

    def __len__(self) -> int:
        with self._mu:
            return len(self._table)

    def __contains__(self, key: tuple) -> bool:
        with self._mu:
            return key in self._table

    def clear(self) -> None:
        with self._mu:
            self._table.clear()
            self._bytes = 0

    def stats(self) -> dict[str, Any]:
        with self._mu:
            total = self.hits + self.misses
            return {
                "entries": len(self._table),
                "bytes": self._bytes,
                "max_bytes": self.max_bytes,
                "effective_max_bytes": self._effective_max_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "pressure_evictions": self.pressure_evictions,
                "hit_rate": self.hits / total if total else 0.0,
            }


class Compilette:
    """Parametrizable kernel generator.

    Parameters
    ----------
    name:       kernel identity (used for persistence keys).
    space:      the tuning space (with validity holes).
    generate:   ``generate(point, **specialization) -> callable``; the
                callable must accept the kernel's runtime arguments. It
                should *close over* the specialized run-time constants —
                this is the deGoal ``#(...)`` inlining analogue (in JAX,
                trace-time constant folding).
    gen_cost_s: simulated generation cost — a float or
                ``f(point, specialization) -> seconds``. When set, the
                reported ``generation_time_s`` is this simulated cost
                instead of the measured wall time (``meta["simulated"]``
                is True), which is how virtual-clock tests model compile
                cost deterministically.
    cache_token: extra identity mixed into the generation-cache key.
                Compilettes that share a *name* but generate different
                programs (e.g. the serve step-programs of two different
                model configs) must carry distinct tokens, or a cache hit
                would hand one kernel the other's executable.
    """

    def __init__(
        self,
        name: str,
        space: TuningSpace,
        generate: Callable[..., Callable[..., Any]],
        cost_model: Callable[[Point, Mapping[str, Any], Any], float] | None = None,
        *,
        gen_cost_s: float | Callable[..., float] | None = None,
        cache_token: str | None = None,
    ) -> None:
        self.name = name
        self.space = space
        self._generate = generate
        # cost_model(point, specialization, profile) -> simulated seconds.
        self.cost_model = cost_model
        self.gen_cost_s = gen_cost_s
        self.cache_token = cache_token
        # Attached by the coordinator (attach_cache): process-wide memo of
        # compiled variants + the device fingerprint that keys it.
        self.cache: GenerationCache | None = None
        self.cache_device: str = "uncached"
        # Extra identity a compilette contributes to the *persistence*
        # fingerprint (appended to the device key by the coordinator).
        # KernelCompilette sets "src-<hash>" of its ops.py so editing a
        # kernel's source invalidates exactly that kernel's warm starts.
        self.fingerprint_extra: str | None = None

    # ------------------------------------------------------------- caching
    def attach_cache(self, cache: GenerationCache | None,
                     device: str | None = None) -> None:
        """Route this compilette's generations through ``cache``."""
        self.cache = cache
        if device is not None:
            self.cache_device = device

    def cache_key(self, point: Point,
                  specialization: Mapping[str, Any]) -> tuple:
        return GenerationCache.key(
            self.name, point, specialization, self.cache_device,
            self.cache_token)

    def _simulated_cost(self, point: Point,
                        specialization: Mapping[str, Any]) -> float | None:
        if self.gen_cost_s is None:
            return None
        if callable(self.gen_cost_s):
            return float(self.gen_cost_s(dict(point), dict(specialization)))
        return float(self.gen_cost_s)

    def generate(self, point: Point, **specialization: Any) -> GeneratedKernel:
        """Instantiate ``point`` — from the cache when possible.

        A cache hit returns a fresh :class:`GeneratedKernel` wrapper
        (shared ``fn``, private ``meta``) with ``generation_time_s = 0``:
        nothing was compiled, so nothing is charged and nothing stalls.
        ``Compilette._generate`` runs at most once per cache key.
        """
        if not self.space.is_valid(point):
            raise ValueError(
                f"compilette {self.name!r}: point {point} is a hole in the "
                "tuning space (invalid variant)"
            )
        key = None
        if self.cache is not None:
            key = self.cache_key(point, specialization)
            cached = self.cache.get(key)
            if cached is not None:
                return GeneratedKernel(
                    point=dict(point),
                    fn=cached.fn,
                    generation_time_s=0.0,
                    specialization=dict(specialization),
                    meta={"source": "cache",
                          "compiled_in_s": cached.meta.get(
                              "compiled_in_s", cached.generation_time_s)},
                )
        t0 = time.perf_counter()
        fn = self._generate(dict(point), **specialization)
        dt = time.perf_counter() - t0
        sim = self._simulated_cost(point, specialization)
        kern = GeneratedKernel(
            point=dict(point),
            fn=fn,
            generation_time_s=dt if sim is None else sim,
            specialization=dict(specialization),
            meta={"source": "compiled", "simulated": sim is not None,
                  "compiled_in_s": dt if sim is None else sim,
                  # byte-bounded caches charge this residency estimate
                  # (None → DEFAULT_ENTRY_BYTES at the cache)
                  "size_bytes": executable_bytes(fn)},
        )
        if self.cache is not None and key is not None:
            self.cache.put(key, kern)
        return kern

    def simulate(self, point: Point, profile: Any, **specialization: Any) -> float:
        """Simulated execution time of ``point`` on a device ``profile``."""
        if self.cost_model is None:
            raise ValueError(f"compilette {self.name!r} has no cost model")
        return self.cost_model(dict(point), dict(specialization), profile)


# ------------------------------------------------------------- async pipeline
@dataclasses.dataclass(eq=False)
class GenerationTicket:
    """Handle for one in-flight (or completed) generation job."""

    compilette: Compilette
    point: Point
    specialization: dict[str, Any]
    speculative: bool = False
    # scheduling inputs (set at submit): the farm pops highest priority
    # first, non-speculative before speculative at equal priority, then
    # submission order — a total, deterministic order
    priority: float = 0.0
    seq: int = 0
    # set at completion (under the generator lock):
    done: bool = False
    kern: GeneratedKernel | None = None
    error: BaseException | None = None
    gen_charge_s: float = 0.0   # unclaimed budget charge for the harvester
    stalled: bool = False       # the generation ran inline on the caller
                                # (cache-eviction race): a real stall
    # charge_cb(ticket, seconds): bills a speculative compile at completion
    _charge_cb: Callable[["GenerationTicket", float], None] | None = None

    def adopt(self) -> None:
        """A tuner claims a speculative ticket: the harvester (not the
        completion callback) will charge its generation time."""
        self.speculative = False
        self._charge_cb = None
