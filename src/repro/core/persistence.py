"""Persistence of tuned configurations.

Tuned best-points are keyed by (kernel name, specialization, device) and
stored as JSON. The training loop saves the registry next to checkpoints so
a restarted (or elastically re-scaled) job resumes with the tuned kernels
instead of re-exploring — run-time auto-tuning state is part of the fault-
tolerance story.

The device key is a *fingerprint* ``platform:device_kind:compiler`` — a
tuned point is only transferable between identical devices compiled by the
same jax/jaxlib, so entries persisted under an older compiler simply miss
(cold start) instead of warm-starting a stale point. Registries written by
older layouts are still honoured through :func:`device_fallbacks`.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any

from repro.core.tuning_space import Point


def _canon(obj: Any) -> str:
    """Canonical JSON identity used by BOTH the tuned-point registry and
    the generation cache (``repro.core.compilette``), so the two key
    formats can never silently diverge. Deliberately STRICT: a
    non-JSON-serializable specialization value raises here, loudly —
    stringifying it would embed memory addresses in persisted keys and
    silently break warm starts across restarts."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def compiler_version() -> str:
    """jax/jaxlib version pair: tuned points do not survive the compiler."""
    try:
        import jax

        jver = getattr(jax, "__version__", "unknown")
        try:
            import jaxlib

            lver = getattr(jaxlib, "__version__", jver)
        except Exception:
            lver = jver
        return f"jax{jver}-jaxlib{lver}"
    except Exception:
        return "nojax"


def device_fingerprint() -> str:
    """Stable identity of the accelerator the process is tuning for.

    Tuned points are only transferable between identical devices under
    the same compiler, so the registry key includes platform, device kind
    and the jax/jaxlib version.
    """
    try:
        import jax

        d = jax.devices()[0]
        return f"{d.platform}:{d.device_kind}:{compiler_version()}"
    except Exception:
        return "unknown"


def device_fallbacks(device: str) -> tuple[str, ...]:
    """Legacy registry keys to try after an exact-fingerprint miss.

    Older layouts keyed entries by ``platform:device_kind`` (pre
    compiler-version) or by bare ``device_kind`` (pre-coordinator). Both
    remain readable; entries that DO carry a compiler version only match
    exactly, so a compiler upgrade degrades them to a cold start.
    """
    parts = device.split(":")
    out: list[str] = []
    if len(parts) >= 3:
        out.append(":".join(parts[:2]))   # platform:device_kind
    if len(parts) >= 2:
        out.append(parts[1])              # bare device_kind
    return tuple(out)


_META_KEY = "__registry_meta__"


class TunedRegistry:
    """Thread-safe: the coordinator's tuning thread calls ``put`` while
    the application thread may be inside ``save`` (request end,
    checkpoint), so mutation and serialization are serialized on an
    internal lock.

    **Aging.** Without hygiene the JSON accumulates dead entries forever
    (retired shapes, superseded compilers). Every entry carries a
    last-used stamp in *save generations* (a monotonic counter persisted
    with the file — wall time would mis-age registries that are loaded
    rarely but saved often). ``put`` and lookup hits refresh the stamp;
    ``save()`` advances the generation and compacts entries that (a) went
    unused for ``max_idle_saves`` saves or (b) were recorded under a
    *different* compiler version than the running one (they can only ever
    miss). Versionless legacy keys carry no compiler claim and age out
    through (a) alone. ``max_idle_saves=None`` disables idle compaction.

    The horizon is measured in SAVES, so size it to the caller's save
    cadence: the serve loop saves once per request (managed tuners are
    re-stamped by the pre-save flush, but an *evicted* bucket's entry is
    only refreshed if its shape re-registers), while a training job
    saves once per checkpoint. The default of 64 keeps a retired serve
    bucket warm for 64 requests and a checkpoint-style entry for 64
    checkpoints before reclaiming it.
    """

    def __init__(self, *, max_idle_saves: int | None = 64) -> None:
        self._table: dict[str, dict[str, Any]] = {}
        # Quarantine: per registry key, canonical-point -> reason for
        # points the variant gate rejected or the canary rolled back. A
        # quarantined point is never returned by lookups, never accepted
        # by ``put``, and survives save/load — a bad point is never
        # re-trusted after a warm start. Unlike best-point entries it
        # does NOT age out with idle saves (bad stays bad); only a
        # compiler change invalidates it (the variant it condemned no
        # longer exists).
        self._quarantine: dict[str, dict[str, str]] = {}
        self._mu = threading.Lock()
        self._generation = 0
        self.max_idle_saves = max_idle_saves
        self.compacted_total = 0

    @staticmethod
    def key(kernel: str, specialization: dict[str, Any], device: str) -> str:
        return _canon({"k": kernel, "s": specialization, "d": device})

    def put(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        score_s: float,
        strategy: str | None = None,
    ) -> None:
        k = self.key(kernel, specialization, device)
        with self._mu:
            if _canon(dict(point)) in self._quarantine.get(k, {}):
                return   # a condemned point never re-enters the registry
            cur = self._table.get(k)
            if cur is None or score_s < cur["score_s"]:
                entry = {"point": dict(point), "score_s": float(score_s),
                         "gen": self._generation}
                if strategy is not None:
                    # provenance: which search strategy found this best
                    entry["strategy"] = str(strategy)
                self._table[k] = entry
            else:
                # a worse score still proves the entry is in use
                cur["gen"] = self._generation

    def get(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        with self._mu:
            k = self.key(kernel, specialization, device)
            entry = self._table.get(k)
            if entry is None:
                return None
            if _canon(entry["point"]) in self._quarantine.get(k, {}):
                return None   # defensive: quarantine always wins
            entry["gen"] = self._generation   # last-used stamp
            return dict(entry["point"])

    def get_warm(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        """Exact-fingerprint lookup, then the legacy-key fallback chain."""
        point = self.get(kernel, specialization, device)
        if point is not None:
            return point
        for legacy in device_fallbacks(device):
            point = self.get(kernel, specialization, legacy)
            if point is not None:
                return point
        return None

    def __len__(self) -> int:
        with self._mu:
            return len(self._table)

    # ---------------------------------------------------------- quarantine
    def quarantine(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        reason: str = "",
    ) -> None:
        """Condemn ``point`` for this (kernel, spec, device) permanently.

        Drops a matching best entry (so warm starts can never seed it)
        and records the point + reason in the persisted quarantine table.
        """
        k = self.key(kernel, specialization, device)
        pk = _canon(dict(point))
        with self._mu:
            self._quarantine.setdefault(k, {})[pk] = str(reason)
            cur = self._table.get(k)
            if cur is not None and _canon(cur.get("point", {})) == pk:
                del self._table[k]

    def is_quarantined(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
    ) -> bool:
        k = self.key(kernel, specialization, device)
        with self._mu:
            return _canon(dict(point)) in self._quarantine.get(k, {})

    def quarantined_points(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> list[Point]:
        """Condemned points under the exact key AND the legacy fallbacks."""
        out: list[Point] = []
        seen: set[str] = set()
        with self._mu:
            for dev in (device, *device_fallbacks(device)):
                k = self.key(kernel, specialization, dev)
                for pk in self._quarantine.get(k, {}):
                    if pk in seen:
                        continue
                    seen.add(pk)
                    try:
                        out.append(dict(json.loads(pk)))
                    except (json.JSONDecodeError, TypeError):
                        continue
        return out

    @property
    def n_quarantined(self) -> int:
        with self._mu:
            return sum(len(v) for v in self._quarantine.values())

    # ---------------------------------------------------------- compaction
    @staticmethod
    def _entry_compiler(key: str) -> str | None:
        """Compiler version claimed by an entry's device key, if any."""
        try:
            device = json.loads(key).get("d", "")
        except (json.JSONDecodeError, AttributeError):
            return None
        parts = str(device).split(":")
        if len(parts) >= 3 and parts[2].startswith(("jax", "nojax")):
            return parts[2]
        return None   # versionless legacy key: no claim to test

    def _compact_locked(self) -> int:
        """Drop idle and foreign-compiler entries (caller holds the lock)."""
        current = compiler_version()
        dead = []
        for k, entry in self._table.items():
            claimed = self._entry_compiler(k)
            if claimed is not None and claimed != current:
                dead.append(k)
                continue
            if (self.max_idle_saves is not None
                    and self._generation - entry.get("gen", 0)
                    >= self.max_idle_saves):
                dead.append(k)
        for k in dead:
            del self._table[k]
        self.compacted_total += len(dead)
        # quarantine entries only die with the compiler that condemned
        # them — the exact variant no longer exists afterwards
        for k in [k for k in self._quarantine
                  if (c := self._entry_compiler(k)) is not None
                  and c != current]:
            del self._quarantine[k]
        return len(dead)

    # ------------------------------------------------------------------ io
    def save(self, path: str) -> None:
        with self._mu:
            self._generation += 1
            self._compact_locked()
            meta: dict[str, Any] = {"generation": self._generation}
            if self._quarantine:
                meta["quarantine"] = {
                    k: dict(v) for k, v in self._quarantine.items()}
            snapshot: dict[str, Any] = {_META_KEY: meta}
            snapshot.update(
                {k: dict(v) for k, v in self._table.items()})
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=1, sort_keys=True)
            os.replace(tmp, path)  # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, path: str) -> "TunedRegistry":
        reg = cls()
        if os.path.exists(path):
            # A registry is a cache: a corrupt or partially-written file
            # must degrade to a cold start, never crash the process.
            try:
                with open(path) as f:
                    table = json.load(f)
                if isinstance(table, dict):
                    meta = table.pop(_META_KEY, None)
                    if isinstance(meta, dict):
                        if isinstance(meta.get("generation"), int):
                            reg._generation = meta["generation"]
                        quar = meta.get("quarantine")
                        if isinstance(quar, dict):
                            reg._quarantine = {
                                k: {pk: str(r) for pk, r in v.items()}
                                for k, v in quar.items()
                                if isinstance(v, dict)
                            }
                    reg._table = {
                        k: v for k, v in table.items()
                        if isinstance(v, dict)
                        and isinstance(v.get("point"), dict)
                        and isinstance(v.get("score_s"), (int, float))
                    }
                    # pre-aging files carry no stamps: treat every entry
                    # as freshly used rather than instantly idle
                    for v in reg._table.values():
                        v.setdefault("gen", reg._generation)
            except (json.JSONDecodeError, OSError, UnicodeDecodeError):
                pass
        return reg
