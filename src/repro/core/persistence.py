"""Persistence of tuned configurations.

Tuned best-points are keyed by (kernel name, specialization, device) and
stored as JSON. The training loop saves the registry next to checkpoints so
a restarted (or elastically re-scaled) job resumes with the tuned kernels
instead of re-exploring — run-time auto-tuning state is part of the fault-
tolerance story.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any

from repro.core.tuning_space import Point


def _canon(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


class TunedRegistry:
    """Thread-safe: the coordinator's tuning thread calls ``put`` while
    the application thread may be inside ``save`` (request end,
    checkpoint), so mutation and serialization are serialized on an
    internal lock."""

    def __init__(self) -> None:
        self._table: dict[str, dict[str, Any]] = {}
        self._mu = threading.Lock()

    @staticmethod
    def key(kernel: str, specialization: dict[str, Any], device: str) -> str:
        return _canon({"k": kernel, "s": specialization, "d": device})

    def put(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        score_s: float,
    ) -> None:
        k = self.key(kernel, specialization, device)
        with self._mu:
            cur = self._table.get(k)
            if cur is None or score_s < cur["score_s"]:
                self._table[k] = {
                    "point": dict(point), "score_s": float(score_s)}

    def get(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        with self._mu:
            entry = self._table.get(self.key(kernel, specialization, device))
            return dict(entry["point"]) if entry else None

    def __len__(self) -> int:
        with self._mu:
            return len(self._table)

    # ------------------------------------------------------------------ io
    def save(self, path: str) -> None:
        with self._mu:
            snapshot = {k: dict(v) for k, v in self._table.items()}
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=1, sort_keys=True)
            os.replace(tmp, path)  # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, path: str) -> "TunedRegistry":
        reg = cls()
        if os.path.exists(path):
            # A registry is a cache: a corrupt or partially-written file
            # must degrade to a cold start, never crash the process.
            try:
                with open(path) as f:
                    table = json.load(f)
                if isinstance(table, dict):
                    reg._table = {
                        k: v for k, v in table.items()
                        if isinstance(v, dict)
                        and isinstance(v.get("point"), dict)
                        and isinstance(v.get("score_s"), (int, float))
                    }
            except (json.JSONDecodeError, OSError, UnicodeDecodeError):
                pass
        return reg
