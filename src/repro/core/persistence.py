"""Persistence of tuned configurations.

Tuned best-points are keyed by (kernel name, specialization, device) and
stored as JSON. The training loop saves the registry next to checkpoints so
a restarted (or elastically re-scaled) job resumes with the tuned kernels
instead of re-exploring — run-time auto-tuning state is part of the fault-
tolerance story.

The device key is a *fingerprint* ``platform:device_kind:compiler`` — a
tuned point is only transferable between identical devices compiled by the
same jax/jaxlib, so entries persisted under an older compiler simply miss
(cold start) instead of warm-starting a stale point. Registries written by
older layouts are still honoured through :func:`device_fallbacks`.
"""

from __future__ import annotations

import json
import os
import tempfile
import threading
from typing import Any

from repro.core.tuning_space import Point


def _canon(obj: Any) -> str:
    """Canonical JSON identity used by BOTH the tuned-point registry and
    the generation cache (``repro.core.compilette``), so the two key
    formats can never silently diverge. Deliberately STRICT: a
    non-JSON-serializable specialization value raises here, loudly —
    stringifying it would embed memory addresses in persisted keys and
    silently break warm starts across restarts."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def compiler_version() -> str:
    """jax/jaxlib version pair: tuned points do not survive the compiler."""
    try:
        import jax

        jver = getattr(jax, "__version__", "unknown")
        try:
            import jaxlib

            lver = getattr(jaxlib, "__version__", jver)
        except Exception:
            lver = jver
        return f"jax{jver}-jaxlib{lver}"
    except Exception:
        return "nojax"


def device_fingerprint() -> str:
    """Stable identity of the accelerator the process is tuning for.

    Tuned points are only transferable between identical devices under
    the same compiler, so the registry key includes platform, device kind
    and the jax/jaxlib version.
    """
    try:
        import jax

        d = jax.devices()[0]
        return f"{d.platform}:{d.device_kind}:{compiler_version()}"
    except Exception:
        return "unknown"


def device_fallbacks(device: str) -> tuple[str, ...]:
    """Legacy registry keys to try after an exact-fingerprint miss.

    Older layouts keyed entries by ``platform:device_kind`` (pre
    compiler-version) or by bare ``device_kind`` (pre-coordinator). Both
    remain readable; entries that DO carry a compiler version only match
    exactly, so a compiler upgrade degrades them to a cold start.
    """
    parts = device.split(":")
    out: list[str] = []
    if len(parts) >= 3:
        out.append(":".join(parts[:2]))   # platform:device_kind
    if len(parts) >= 2:
        out.append(parts[1])              # bare device_kind
    return tuple(out)


_META_KEY = "__registry_meta__"


class TunedRegistry:
    """Thread-safe: the coordinator's tuning thread calls ``put`` while
    the application thread may be inside ``save`` (request end,
    checkpoint), so mutation and serialization are serialized on an
    internal lock.

    **Aging.** Without hygiene the JSON accumulates dead entries forever
    (retired shapes, superseded compilers). Every entry carries a
    last-used stamp in *save generations* (a monotonic counter persisted
    with the file — wall time would mis-age registries that are loaded
    rarely but saved often). ``put`` and lookup hits refresh the stamp;
    ``save()`` advances the generation and compacts entries that (a) went
    unused for ``max_idle_saves`` saves or (b) were recorded under a
    *different* compiler version than the running one (they can only ever
    miss). Versionless legacy keys carry no compiler claim and age out
    through (a) alone. ``max_idle_saves=None`` disables idle compaction.

    The horizon is measured in SAVES, so size it to the caller's save
    cadence: the serve loop saves once per request (managed tuners are
    re-stamped by the pre-save flush, but an *evicted* bucket's entry is
    only refreshed if its shape re-registers), while a training job
    saves once per checkpoint. The default of 64 keeps a retired serve
    bucket warm for 64 requests and a checkpoint-style entry for 64
    checkpoints before reclaiming it.
    """

    def __init__(self, *, max_idle_saves: int | None = 64) -> None:
        self._table: dict[str, dict[str, Any]] = {}
        self._mu = threading.Lock()
        self._generation = 0
        self.max_idle_saves = max_idle_saves
        self.compacted_total = 0

    @staticmethod
    def key(kernel: str, specialization: dict[str, Any], device: str) -> str:
        return _canon({"k": kernel, "s": specialization, "d": device})

    def put(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        score_s: float,
        strategy: str | None = None,
    ) -> None:
        k = self.key(kernel, specialization, device)
        with self._mu:
            cur = self._table.get(k)
            if cur is None or score_s < cur["score_s"]:
                entry = {"point": dict(point), "score_s": float(score_s),
                         "gen": self._generation}
                if strategy is not None:
                    # provenance: which search strategy found this best
                    entry["strategy"] = str(strategy)
                self._table[k] = entry
            else:
                # a worse score still proves the entry is in use
                cur["gen"] = self._generation

    def get(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        with self._mu:
            entry = self._table.get(self.key(kernel, specialization, device))
            if entry is None:
                return None
            entry["gen"] = self._generation   # last-used stamp
            return dict(entry["point"])

    def get_warm(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        """Exact-fingerprint lookup, then the legacy-key fallback chain."""
        point = self.get(kernel, specialization, device)
        if point is not None:
            return point
        for legacy in device_fallbacks(device):
            point = self.get(kernel, specialization, legacy)
            if point is not None:
                return point
        return None

    def __len__(self) -> int:
        with self._mu:
            return len(self._table)

    # ---------------------------------------------------------- compaction
    @staticmethod
    def _entry_compiler(key: str) -> str | None:
        """Compiler version claimed by an entry's device key, if any."""
        try:
            device = json.loads(key).get("d", "")
        except (json.JSONDecodeError, AttributeError):
            return None
        parts = str(device).split(":")
        if len(parts) >= 3 and parts[2].startswith(("jax", "nojax")):
            return parts[2]
        return None   # versionless legacy key: no claim to test

    def _compact_locked(self) -> int:
        """Drop idle and foreign-compiler entries (caller holds the lock)."""
        current = compiler_version()
        dead = []
        for k, entry in self._table.items():
            claimed = self._entry_compiler(k)
            if claimed is not None and claimed != current:
                dead.append(k)
                continue
            if (self.max_idle_saves is not None
                    and self._generation - entry.get("gen", 0)
                    >= self.max_idle_saves):
                dead.append(k)
        for k in dead:
            del self._table[k]
        self.compacted_total += len(dead)
        return len(dead)

    # ------------------------------------------------------------------ io
    def save(self, path: str) -> None:
        with self._mu:
            self._generation += 1
            self._compact_locked()
            snapshot: dict[str, Any] = {
                _META_KEY: {"generation": self._generation}}
            snapshot.update(
                {k: dict(v) for k, v in self._table.items()})
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=1, sort_keys=True)
            os.replace(tmp, path)  # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, path: str) -> "TunedRegistry":
        reg = cls()
        if os.path.exists(path):
            # A registry is a cache: a corrupt or partially-written file
            # must degrade to a cold start, never crash the process.
            try:
                with open(path) as f:
                    table = json.load(f)
                if isinstance(table, dict):
                    meta = table.pop(_META_KEY, None)
                    if (isinstance(meta, dict)
                            and isinstance(meta.get("generation"), int)):
                        reg._generation = meta["generation"]
                    reg._table = {
                        k: v for k, v in table.items()
                        if isinstance(v, dict)
                        and isinstance(v.get("point"), dict)
                        and isinstance(v.get("score_s"), (int, float))
                    }
                    # pre-aging files carry no stamps: treat every entry
                    # as freshly used rather than instantly idle
                    for v in reg._table.values():
                        v.setdefault("gen", reg._generation)
            except (json.JSONDecodeError, OSError, UnicodeDecodeError):
                pass
        return reg
