"""Persistence of tuned configurations.

Tuned best-points are keyed by (kernel name, specialization, device) and
stored as JSON. The training loop saves the registry next to checkpoints so
a restarted (or elastically re-scaled) job resumes with the tuned kernels
instead of re-exploring — run-time auto-tuning state is part of the fault-
tolerance story.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

from repro.core.tuning_space import Point


def _canon(obj: Any) -> str:
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


class TunedRegistry:
    def __init__(self) -> None:
        self._table: dict[str, dict[str, Any]] = {}

    @staticmethod
    def key(kernel: str, specialization: dict[str, Any], device: str) -> str:
        return _canon({"k": kernel, "s": specialization, "d": device})

    def put(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        score_s: float,
    ) -> None:
        k = self.key(kernel, specialization, device)
        cur = self._table.get(k)
        if cur is None or score_s < cur["score_s"]:
            self._table[k] = {"point": dict(point), "score_s": float(score_s)}

    def get(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        entry = self._table.get(self.key(kernel, specialization, device))
        return dict(entry["point"]) if entry else None

    def __len__(self) -> int:
        return len(self._table)

    # ------------------------------------------------------------------ io
    def save(self, path: str) -> None:
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(self._table, f, indent=1, sort_keys=True)
            os.replace(tmp, path)  # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    @classmethod
    def load(cls, path: str) -> "TunedRegistry":
        reg = cls()
        if os.path.exists(path):
            with open(path) as f:
                reg._table = json.load(f)
        return reg
