"""Persistence of tuned configurations.

Tuned best-points are keyed by (kernel name, specialization, device) and
stored as JSON. The training loop saves the registry next to checkpoints so
a restarted (or elastically re-scaled) job resumes with the tuned kernels
instead of re-exploring — run-time auto-tuning state is part of the fault-
tolerance story.

The device key is a *fingerprint* ``platform:device_kind:compiler`` — a
tuned point is only transferable between identical devices compiled by the
same jax/jaxlib, so entries persisted under an older compiler simply miss
(cold start) instead of warm-starting a stale point. Registries written by
older layouts are still honoured through :func:`device_fallbacks`.
"""

from __future__ import annotations

import copy
import json
import os
import tempfile
import threading
import time
from typing import Any

from repro.core.tuning_space import Point


def _canon(obj: Any) -> str:
    """Canonical JSON identity used by BOTH the tuned-point registry and
    the generation cache (``repro.core.compilette``), so the two key
    formats can never silently diverge. Deliberately STRICT: a
    non-JSON-serializable specialization value raises here, loudly —
    stringifying it would embed memory addresses in persisted keys and
    silently break warm starts across restarts."""
    return json.dumps(obj, sort_keys=True, separators=(",", ":"))


def compiler_version() -> str:
    """jax/jaxlib version pair: tuned points do not survive the compiler."""
    try:
        import jax

        jver = getattr(jax, "__version__", "unknown")
        try:
            import jaxlib

            lver = getattr(jaxlib, "__version__", jver)
        except Exception:
            lver = jver
        return f"jax{jver}-jaxlib{lver}"
    except Exception:
        return "nojax"


def device_fingerprint() -> str:
    """Stable identity of the accelerator the process is tuning for.

    Tuned points are only transferable between identical devices under
    the same compiler, so the registry key includes platform, device kind
    and the jax/jaxlib version.
    """
    try:
        import jax

        d = jax.devices()[0]
        return f"{d.platform}:{d.device_kind}:{compiler_version()}"
    except Exception:
        return "unknown"


def device_fallbacks(device: str) -> tuple[str, ...]:
    """Legacy registry keys to try after an exact-fingerprint miss.

    Older layouts keyed entries by ``platform:device_kind`` (pre
    compiler-version) or by bare ``device_kind`` (pre-coordinator). Both
    remain readable; entries that DO carry a compiler version only match
    exactly, so a compiler upgrade degrades them to a cold start.
    """
    parts = device.split(":")
    out: list[str] = []
    if len(parts) >= 3:
        out.append(":".join(parts[:2]))   # platform:device_kind
    if len(parts) >= 2:
        out.append(parts[1])              # bare device_kind
    return tuple(out)


_META_KEY = "__registry_meta__"


class TunedRegistry:
    """Thread-safe: the coordinator's tuning thread calls ``put`` while
    the application thread may be inside ``save`` (request end,
    checkpoint), so mutation and serialization are serialized on an
    internal lock.

    **Aging.** Without hygiene the JSON accumulates dead entries forever
    (retired shapes, superseded compilers). Every entry carries a
    last-used stamp in *save generations* (a monotonic counter persisted
    with the file — wall time would mis-age registries that are loaded
    rarely but saved often). ``put`` and lookup hits refresh the stamp;
    ``save()`` advances the generation and compacts entries that (a) went
    unused for ``max_idle_saves`` saves or (b) were recorded under a
    *different* compiler version than the running one (they can only ever
    miss). Versionless legacy keys carry no compiler claim and age out
    through (a) alone. ``max_idle_saves=None`` disables idle compaction.

    The horizon is measured in SAVES, so size it to the caller's save
    cadence: the serve loop saves once per request (managed tuners are
    re-stamped by the pre-save flush, but an *evicted* bucket's entry is
    only refreshed if its shape re-registers), while a training job
    saves once per checkpoint. The default of 64 keeps a retired serve
    bucket warm for 64 requests and a checkpoint-style entry for 64
    checkpoints before reclaiming it.
    """

    def __init__(self, *, max_idle_saves: int | None = 64) -> None:
        self._table: dict[str, dict[str, Any]] = {}
        # Quarantine: per registry key, canonical-point -> reason for
        # points the variant gate rejected or the canary rolled back. A
        # quarantined point is never returned by lookups, never accepted
        # by ``put``, and survives save/load — a bad point is never
        # re-trusted after a warm start. Unlike best-point entries it
        # does NOT age out with idle saves (bad stays bad); only a
        # compiler change invalidates it (the variant it condemned no
        # longer exists).
        self._quarantine: dict[str, dict[str, str]] = {}
        # Evaluations: per registry key, canonical-point -> best observed
        # score. This is the fleet's "already paid for" ledger — a peer
        # replica that merges it marks those points seen in its explorer
        # and never re-compiles them. Like quarantine it unions across
        # replicas and only dies with a compiler change.
        self._evaluations: dict[str, dict[str, float]] = {}
        self._mu = threading.Lock()
        self._generation = 0
        self.max_idle_saves = max_idle_saves
        self.compacted_total = 0

    @staticmethod
    def key(kernel: str, specialization: dict[str, Any], device: str) -> str:
        return _canon({"k": kernel, "s": specialization, "d": device})

    def put(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        score_s: float,
        strategy: str | None = None,
        traits: dict[str, float] | None = None,
    ) -> None:
        k = self.key(kernel, specialization, device)
        with self._mu:
            if _canon(dict(point)) in self._quarantine.get(k, {}):
                return   # a condemned point never re-enters the registry
            cur = self._table.get(k)
            if cur is None or score_s < cur["score_s"]:
                entry = {"point": dict(point), "score_s": float(score_s),
                         "gen": self._generation}
                if strategy is not None:
                    # provenance: which search strategy found this best
                    entry["strategy"] = str(strategy)
                if traits is not None:
                    # device-trait vector: the transfer plane ranks this
                    # entry against dissimilar-fingerprint lookups
                    entry["traits"] = dict(traits)
                self._table[k] = entry
            else:
                # a worse score still proves the entry is in use
                cur["gen"] = self._generation
                if traits is not None and "traits" not in cur:
                    # a pre-transfer entry learns its device traits the
                    # first time the device describes itself
                    cur["traits"] = dict(traits)

    def get(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        with self._mu:
            k = self.key(kernel, specialization, device)
            entry = self._table.get(k)
            if entry is None:
                return None
            if _canon(entry["point"]) in self._quarantine.get(k, {}):
                return None   # defensive: quarantine always wins
            entry["gen"] = self._generation   # last-used stamp
            return dict(entry["point"])

    def best_entry(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> tuple[Point, float] | None:
        """Exact-key best point WITH its score (fleet adoption needs the
        score to decide whether a peer's best beats the local one)."""
        with self._mu:
            k = self.key(kernel, specialization, device)
            entry = self._table.get(k)
            if entry is None:
                return None
            if _canon(entry["point"]) in self._quarantine.get(k, {}):
                return None
            entry["gen"] = self._generation
            return dict(entry["point"]), float(entry["score_s"])

    def get_warm(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> Point | None:
        """Exact-fingerprint lookup, then the legacy-key fallback chain."""
        point = self.get(kernel, specialization, device)
        if point is not None:
            return point
        for legacy in device_fallbacks(device):
            point = self.get(kernel, specialization, legacy)
            if point is not None:
                return point
        return None

    def cross_device_entries(
        self,
        kernel: str,
        specialization: dict[str, Any],
        *,
        exclude_device: str | None = None,
    ) -> list[tuple[str, dict[str, Any]]]:
        """Best entries for this (kernel, spec) under OTHER device keys.

        The transfer plane's raw material after a fingerprint miss: every
        foreign device's best row — with its persisted trait vector, when
        recorded — quarantine-filtered under its OWN key (a point a
        similar device condemned never travels). Rows are deep copies
        sorted by device key, so downstream ranking is deterministic and
        cannot mutate the registry.
        """
        probe = json.loads(self.key(kernel, specialization, ""))
        out: list[tuple[str, dict[str, Any]]] = []
        with self._mu:
            for k, entry in self._table.items():
                try:
                    parsed = json.loads(k)
                except (json.JSONDecodeError, TypeError):
                    continue
                if (not isinstance(parsed, dict)
                        or parsed.get("k") != probe["k"]
                        or parsed.get("s") != probe["s"]):
                    continue
                dev = parsed.get("d")
                if (not isinstance(dev, str) or not dev
                        or dev == exclude_device):
                    continue
                if _canon(entry.get("point", {})) in self._quarantine.get(
                        k, {}):
                    continue
                out.append((dev, copy.deepcopy(entry)))
        out.sort(key=lambda row: row[0])
        return out

    def __len__(self) -> int:
        with self._mu:
            return len(self._table)

    # ---------------------------------------------------------- quarantine
    def quarantine(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        reason: str = "",
    ) -> None:
        """Condemn ``point`` for this (kernel, spec, device) permanently.

        Drops a matching best entry (so warm starts can never seed it)
        and records the point + reason in the persisted quarantine table.
        """
        k = self.key(kernel, specialization, device)
        pk = _canon(dict(point))
        with self._mu:
            self._quarantine.setdefault(k, {})[pk] = str(reason)
            cur = self._table.get(k)
            if cur is not None and _canon(cur.get("point", {})) == pk:
                del self._table[k]

    def is_quarantined(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
    ) -> bool:
        k = self.key(kernel, specialization, device)
        with self._mu:
            return _canon(dict(point)) in self._quarantine.get(k, {})

    def quarantined_points(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> list[Point]:
        """Condemned points under the exact key AND the legacy fallbacks."""
        out: list[Point] = []
        seen: set[str] = set()
        with self._mu:
            for dev in (device, *device_fallbacks(device)):
                k = self.key(kernel, specialization, dev)
                for pk in self._quarantine.get(k, {}):
                    if pk in seen:
                        continue
                    seen.add(pk)
                    try:
                        out.append(dict(json.loads(pk)))
                    except (json.JSONDecodeError, TypeError):
                        continue
        return out

    def fleet_quarantined_points(
        self, kernel: str, specialization: dict[str, Any]
    ) -> list[Point]:
        """Condemned points for this (kernel, spec) under ANY device key.

        The transfer plane's blocklist: a transfer seed that failed one
        device's oracle must never be re-seeded on any other device —
        the verdict travels with the registry, not with the device that
        paid for it.
        """
        probe = json.loads(self.key(kernel, specialization, ""))
        out: list[Point] = []
        seen: set[str] = set()
        with self._mu:
            for k, points in self._quarantine.items():
                try:
                    parsed = json.loads(k)
                except (json.JSONDecodeError, TypeError):
                    continue
                if (not isinstance(parsed, dict)
                        or parsed.get("k") != probe["k"]
                        or parsed.get("s") != probe["s"]):
                    continue
                for pk in points:
                    if pk in seen:
                        continue
                    seen.add(pk)
                    try:
                        out.append(dict(json.loads(pk)))
                    except (json.JSONDecodeError, TypeError):
                        continue
        return out

    @property
    def n_quarantined(self) -> int:
        with self._mu:
            return sum(len(v) for v in self._quarantine.values())

    # --------------------------------------------------------- evaluations
    def record_evaluation(
        self,
        kernel: str,
        specialization: dict[str, Any],
        device: str,
        point: Point,
        score_s: float,
    ) -> None:
        """Publish one measured (point, score) to the fleet ledger.

        Peers that merge this registry mark the point *seen* so it is
        never compiled twice per fleet. Keeps the best observed score per
        point (min merge is commutative, so sync order cannot change the
        merged state)."""
        k = self.key(kernel, specialization, device)
        pk = _canon(dict(point))
        s = float(score_s)
        with self._mu:
            evals = self._evaluations.setdefault(k, {})
            cur = evals.get(pk)
            if cur is None or s < cur:
                evals[pk] = s

    def evaluated_points(
        self, kernel: str, specialization: dict[str, Any], device: str
    ) -> list[Point]:
        """Points any replica has already measured under the exact key."""
        out: list[Point] = []
        with self._mu:
            k = self.key(kernel, specialization, device)
            for pk in self._evaluations.get(k, {}):
                try:
                    out.append(dict(json.loads(pk)))
                except (json.JSONDecodeError, TypeError):
                    continue
        return out

    @property
    def n_evaluations(self) -> int:
        with self._mu:
            return sum(len(v) for v in self._evaluations.values())

    # ---------------------------------------------------------- compaction
    @staticmethod
    def _entry_compiler(key: str) -> str | None:
        """Compiler version claimed by an entry's device key, if any."""
        try:
            device = json.loads(key).get("d", "")
        except (json.JSONDecodeError, AttributeError):
            return None
        parts = str(device).split(":")
        if len(parts) >= 3 and parts[2].startswith(("jax", "nojax")):
            return parts[2]
        return None   # versionless legacy key: no claim to test

    def _compact_locked(self) -> int:
        """Drop idle and foreign-compiler entries (caller holds the lock)."""
        current = compiler_version()
        dead = []
        for k, entry in self._table.items():
            claimed = self._entry_compiler(k)
            if claimed is not None and claimed != current:
                dead.append(k)
                continue
            if (self.max_idle_saves is not None
                    and self._generation - entry.get("gen", 0)
                    >= self.max_idle_saves):
                dead.append(k)
        for k in dead:
            del self._table[k]
        self.compacted_total += len(dead)
        # quarantine and evaluation ledgers only die with the compiler
        # that wrote them — the exact variants no longer exist afterwards
        for ledger in (self._quarantine, self._evaluations):
            for k in [k for k in ledger
                      if (c := self._entry_compiler(k)) is not None
                      and c != current]:
                del ledger[k]
        return len(dead)

    # ------------------------------------------------------------------ io
    def snapshot(self) -> dict[str, Any]:
        """Serializable full state — the unit the fleet backends merge."""
        with self._mu:
            return self._snapshot_locked()

    def _snapshot_locked(self) -> dict[str, Any]:
        meta: dict[str, Any] = {"generation": self._generation}
        if self._quarantine:
            meta["quarantine"] = {
                k: dict(v) for k, v in self._quarantine.items()}
        if self._evaluations:
            meta["evaluations"] = {
                k: dict(v) for k, v in self._evaluations.items()}
        snapshot: dict[str, Any] = {_META_KEY: meta}
        snapshot.update({k: dict(v) for k, v in self._table.items()})
        return snapshot

    def merge_snapshot(self, snapshot: dict[str, Any]) -> None:
        """Fold a (peer-merged) snapshot into the live registry.

        Same join as :func:`merge_snapshots`: quarantine and evaluation
        ledgers union (a point condemned by ANY replica is condemned
        here), bests adopt only on a strictly better score, and a newly
        condemned best is dropped. Idempotent and commutative, so sync
        cadence and replica order cannot change the result.
        """
        if not isinstance(snapshot, dict):
            return
        meta = snapshot.get(_META_KEY)
        meta = meta if isinstance(meta, dict) else {}
        with self._mu:
            quar = meta.get("quarantine")
            if isinstance(quar, dict):
                for k, v in quar.items():
                    if not isinstance(v, dict):
                        continue
                    mine = self._quarantine.setdefault(k, {})
                    for pk, reason in v.items():
                        if pk not in mine or str(reason) < mine[pk]:
                            mine[pk] = str(reason)
            evals = meta.get("evaluations")
            if isinstance(evals, dict):
                for k, v in evals.items():
                    if not isinstance(v, dict):
                        continue
                    mine_e = self._evaluations.setdefault(k, {})
                    for pk, s in v.items():
                        if not isinstance(s, (int, float)):
                            continue
                        if pk not in mine_e or float(s) < mine_e[pk]:
                            mine_e[pk] = float(s)
            for k, entry in snapshot.items():
                if k == _META_KEY or not isinstance(entry, dict):
                    continue
                if (not isinstance(entry.get("point"), dict)
                        or not isinstance(entry.get("score_s"), (int, float))):
                    continue
                if _canon(entry["point"]) in self._quarantine.get(k, {}):
                    continue
                cur = self._table.get(k)
                if cur is None or float(entry["score_s"]) < cur["score_s"]:
                    adopted = dict(entry)
                    adopted["point"] = dict(entry["point"])
                    adopted["score_s"] = float(entry["score_s"])
                    adopted["gen"] = self._generation
                    if isinstance(entry.get("traits"), dict):
                        adopted["traits"] = dict(entry["traits"])
                    else:
                        adopted.pop("traits", None)
                    self._table[k] = adopted
                elif ("traits" not in cur
                        and isinstance(entry.get("traits"), dict)):
                    # trait union: the key names one device, so a peer's
                    # trait vector for it applies to the held best too —
                    # without this a traits-less side would flap the
                    # merged metadata across sync order
                    cur["traits"] = dict(entry["traits"])
            # fleet quarantine always wins over a previously held best
            for k in list(self._table):
                if (_canon(self._table[k].get("point", {}))
                        in self._quarantine.get(k, {})):
                    del self._table[k]

    def save(self, path: str) -> None:
        with self._mu:
            self._generation += 1
            self._compact_locked()
            snapshot = self._snapshot_locked()
        LocalBackend(path).write(snapshot)

    @classmethod
    def load(cls, path: str) -> "TunedRegistry":
        reg = cls()
        table = LocalBackend(path).read()
        if isinstance(table, dict):
            table = dict(table)
            meta = table.pop(_META_KEY, None)
            if isinstance(meta, dict):
                if isinstance(meta.get("generation"), int):
                    reg._generation = meta["generation"]
                quar = meta.get("quarantine")
                if isinstance(quar, dict):
                    reg._quarantine = {
                        k: {pk: str(r) for pk, r in v.items()}
                        for k, v in quar.items()
                        if isinstance(v, dict)
                    }
                evals = meta.get("evaluations")
                if isinstance(evals, dict):
                    reg._evaluations = {
                        k: {pk: float(s) for pk, s in v.items()
                            if isinstance(s, (int, float))}
                        for k, v in evals.items()
                        if isinstance(v, dict)
                    }
            reg._table = {
                k: v for k, v in table.items()
                if isinstance(v, dict)
                and isinstance(v.get("point"), dict)
                and isinstance(v.get("score_s"), (int, float))
            }
            # pre-aging files carry no stamps: treat every entry
            # as freshly used rather than instantly idle
            for v in reg._table.values():
                v.setdefault("gen", reg._generation)
        return reg


# ---------------------------------------------------------------- backends
def merge_snapshots(
    a: dict[str, Any], b: dict[str, Any]
) -> dict[str, Any]:
    """Deterministic commutative join of two registry snapshots.

    The fleet's merge rule, applied identically by every backend:

    * best entries — lower ``score_s`` wins per (kernel, spec,
      fingerprint) key (under monotone per-replica improvement this
      coincides with last-write-wins); exact score ties break on the
      canonical JSON of the entry so the result never depends on
      argument order;
    * per-entry device traits — unioned: the winning entry keeps its
      trait vector, and a winner missing one adopts a candidate's (the
      key names one device, so any candidate's traits describe it);
    * quarantine — unioned: a point condemned by ANY replica is
      condemned fleet-wide, and a condemned best is dropped;
    * evaluations — unioned with min-score: work any replica already
      paid for is never re-paid;
    * generation — max.

    Commutativity + idempotence make the fabric a state-based CRDT: the
    merged registry is byte-identical regardless of sync interleaving.
    """
    out: dict[str, Any] = {}
    meta_a = a.get(_META_KEY) if isinstance(a.get(_META_KEY), dict) else {}
    meta_b = b.get(_META_KEY) if isinstance(b.get(_META_KEY), dict) else {}
    gen = max(int(meta_a.get("generation") or 0),
              int(meta_b.get("generation") or 0))

    quarantine: dict[str, dict[str, str]] = {}
    for meta in (meta_a, meta_b):
        quar = meta.get("quarantine")
        if not isinstance(quar, dict):
            continue
        for k, v in quar.items():
            if not isinstance(v, dict):
                continue
            merged = quarantine.setdefault(k, {})
            for pk, reason in v.items():
                if pk not in merged or str(reason) < merged[pk]:
                    merged[pk] = str(reason)

    evaluations: dict[str, dict[str, float]] = {}
    for meta in (meta_a, meta_b):
        evals = meta.get("evaluations")
        if not isinstance(evals, dict):
            continue
        for k, v in evals.items():
            if not isinstance(v, dict):
                continue
            merged_e = evaluations.setdefault(k, {})
            for pk, s in v.items():
                if not isinstance(s, (int, float)):
                    continue
                if pk not in merged_e or float(s) < merged_e[pk]:
                    merged_e[pk] = float(s)

    def _valid(entry: Any) -> bool:
        return (isinstance(entry, dict)
                and isinstance(entry.get("point"), dict)
                and isinstance(entry.get("score_s"), (int, float)))

    for k in sorted(set(a) | set(b)):
        if k == _META_KEY:
            continue
        ea, eb = a.get(k), b.get(k)
        candidates = [e for e in (ea, eb) if _valid(e)]
        candidates = [e for e in candidates
                      if _canon(e["point"]) not in quarantine.get(k, {})]
        if not candidates:
            continue
        winner = copy.deepcopy(min(
            candidates,
            key=lambda e: (float(e["score_s"]), _canon(e))))
        # trait union: the key names ONE device, so any candidate's trait
        # vector describes the winner's device too. A winner missing its
        # traits adopts the (deterministically chosen) donor's — without
        # this, merging {entry+traits} with {entry} would keep or drop
        # the metadata depending on argument order.
        if not isinstance(winner.get("traits"), dict):
            winner.pop("traits", None)
            donors = [e["traits"] for e in candidates
                      if isinstance(e.get("traits"), dict)]
            if donors:
                winner["traits"] = copy.deepcopy(min(donors, key=_canon))
        out[k] = winner

    meta: dict[str, Any] = {"generation": gen}
    if quarantine:
        meta["quarantine"] = quarantine
    if evaluations:
        meta["evaluations"] = evaluations
    out[_META_KEY] = meta
    return out


class RegistryBackend:
    """Where a :class:`TunedRegistry` synchronizes its state.

    One method matters: ``sync(snapshot)`` publishes this replica's
    snapshot, merges it with whatever the fleet has already published
    (per :func:`merge_snapshots`) and returns the merged state for the
    caller to adopt via :meth:`TunedRegistry.merge_snapshot`. Backends
    must make the merge atomic against concurrent replicas.
    """

    def sync(self, snapshot: dict[str, Any]) -> dict[str, Any]:
        raise NotImplementedError


class LocalBackend(RegistryBackend):
    """Single-writer JSON file — the classic per-process registry.

    ``write`` publishes via write-temp-then-``os.replace`` so a reader
    (or a crash) can never observe a torn file; ``read`` degrades a
    corrupt or missing file to a cold start. ``sync`` is last-writer-
    wins wholesale: there are no peers to merge with.
    """

    def __init__(self, path: str) -> None:
        self.path = str(path)

    def read(self) -> dict[str, Any] | None:
        if not os.path.exists(self.path):
            return None
        # A registry is a cache: a corrupt or partially-written file
        # must degrade to a cold start, never crash the process.
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (json.JSONDecodeError, OSError, UnicodeDecodeError):
            return None
        return data if isinstance(data, dict) else None

    def write(self, snapshot: dict[str, Any]) -> None:
        parent = os.path.dirname(self.path) or "."
        os.makedirs(parent, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=parent)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(snapshot, f, indent=1, sort_keys=True)
            os.replace(tmp, self.path)  # atomic publish
        except BaseException:
            if os.path.exists(tmp):
                os.unlink(tmp)
            raise

    def sync(self, snapshot: dict[str, Any]) -> dict[str, Any]:
        self.write(snapshot)
        return snapshot


class SharedFileBackend(LocalBackend):
    """One JSON file shared by N replicas, serialized by a lock file.

    ``sync`` takes the lock (``O_CREAT | O_EXCL`` — works on any shared
    filesystem), merges the caller's snapshot with the file contents
    under :func:`merge_snapshots`, publishes atomically via
    temp-then-rename, releases the lock, and returns the merged state.
    A crash between lock and publish leaves the previous file intact; a
    crash that leaks the lock is healed by stale-lock takeover — a lock
    older than ``stale_lock_s`` is broken and re-contested.
    """

    def __init__(
        self,
        path: str,
        *,
        lock_timeout_s: float = 10.0,
        stale_lock_s: float = 30.0,
        poll_s: float = 0.005,
    ) -> None:
        super().__init__(path)
        self.lock_path = self.path + ".lock"
        self.lock_timeout_s = float(lock_timeout_s)
        self.stale_lock_s = float(stale_lock_s)
        self.poll_s = float(poll_s)
        self.syncs = 0
        self.stale_takeovers = 0

    def _acquire_lock(self) -> None:
        parent = os.path.dirname(self.path) or "."
        os.makedirs(parent, exist_ok=True)
        deadline = time.monotonic() + self.lock_timeout_s
        while True:
            try:
                fd = os.open(self.lock_path,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY)
                with os.fdopen(fd, "w") as f:
                    f.write(str(os.getpid()))
                return
            except FileExistsError:
                try:
                    age = time.time() - os.path.getmtime(self.lock_path)
                except OSError:
                    continue  # holder released between open and stat
                if age > self.stale_lock_s:
                    # holder died mid-sync: break the lock and re-contest
                    # (unlink is idempotent if another waiter won the race)
                    try:
                        os.unlink(self.lock_path)
                        self.stale_takeovers += 1
                    except OSError:
                        pass
                    continue
                if time.monotonic() >= deadline:
                    raise TimeoutError(
                        f"registry lock {self.lock_path} held for "
                        f"{age:.1f}s (timeout {self.lock_timeout_s}s)")
                time.sleep(self.poll_s)

    def _release_lock(self) -> None:
        try:
            os.unlink(self.lock_path)
        except OSError:
            pass

    def sync(self, snapshot: dict[str, Any]) -> dict[str, Any]:
        self._acquire_lock()
        try:
            on_disk = self.read() or {}
            merged = merge_snapshots(on_disk, snapshot)
            self.write(merged)
        finally:
            self._release_lock()
        self.syncs += 1
        return merged


class FleetBus(RegistryBackend):
    """In-memory fleet backend for tests and virtual-clock benchmarks.

    Same merge semantics as :class:`SharedFileBackend`, no filesystem:
    N in-process replicas share one bus instance and observe each
    other's bests, evaluations and quarantines at every ``sync``.
    """

    def __init__(self) -> None:
        self._state: dict[str, Any] = {}
        self._mu = threading.Lock()
        self.syncs = 0

    def sync(self, snapshot: dict[str, Any]) -> dict[str, Any]:
        with self._mu:
            self._state = merge_snapshots(self._state, snapshot)
            self.syncs += 1
            return copy.deepcopy(self._state)

    def peek(self) -> dict[str, Any]:
        """Current merged fleet state (read-only copy, no publish)."""
        with self._mu:
            return copy.deepcopy(self._state)
