"""Compile farm: the parallel variant-generation pool (paper §3 scaled out).

PR 3's ``AsyncGenerator`` hid generation cost off the hot path but kept a
*single* background executor — with several catalog kernels tuning
concurrently, one slow AOT XLA compile serializes every other kernel's
pipeline and cold-start time-to-best scales with the *sum* of compile
costs instead of the max. :class:`CompileFarm` generalizes it into a pool
of M workers draining generation requests **and** speculative ``peek(n)``
prefetches for all registered tuners concurrently:

  * **gain-priority scheduling** — jobs carry a priority (the
    coordinator passes its scheduling estimate: potential speedup x
    remaining call volume, damped by regenerations already invested);
    the farm pops the highest-priority job first, non-speculative
    requests before speculation at equal priority, submission order as
    the final tie-break. The order is total and deterministic.
  * **per-kernel in-flight caps** — a kernel with a wide space could
    flood the queue with prefetch jobs and starve the rest; speculative
    submissions beyond ``per_kernel_cap`` in-flight jobs for the same
    kernel are *rejected* (``submit`` returns ``None``, the prefetcher
    just tries again next slot). A tuner's own non-speculative request
    is always admitted: there is at most one per tuner.
  * **three backends** — ``"thread"`` (default): up to ``workers``
    daemon threads compile concurrently (XLA's C++ compile releases the
    GIL for most of its work). ``"process"``: same worker threads, but
    a compilette exposing the ``process_payload`` protocol has the
    expensive trace+lower+compile executed in a spawned child process
    first, so even the GIL-holding tracing phase cannot stall serving;
    with jax's persistent compilation cache configured the parent's own
    compile then deserializes instead of recompiling (without it the
    parent recompiles — transparent in ``process_fallbacks``).
    ``"manual"``: no threads at all; jobs complete only at explicit
    ``run_pending()`` calls.

**Deterministic max-overlap semantics (manual mode).** One
``run_pending()`` call completes *up to* ``workers`` jobs, in priority
order — the virtual-time model of M workers each finishing one compile
per pump interval. The virtual clock is never advanced by a batch: like
the single-executor pipeline, compile latency is fully overlapped with
serving (a batch's wall-time is the *max* of its members' costs, hidden
inside the serving interval), while the budget is billed the *sum* of
every job's cost — ``gen_spent_s`` accrues in full, ``gen_stall_s``
stays exactly 0, and the existing VirtualClock test idiom ("requested at
pump k, harvestable at pump k+1") carries over unchanged.

**Atomic idle retirement.** The old single-worker queue had a race: a
job enqueued between the worker's ``queue.Empty`` timeout and its
retirement check could sit unserviced until the next submit spawned a
fresh worker. Farm workers wait on a condition variable under the same
mutex ``submit`` pushes under, so "queue still empty → deregister and
exit" is one critical section — a submit either sees the retiring worker
still registered (and its push is observed by that worker's emptiness
check) or sees it gone and spawns a replacement.

``AsyncGenerator`` remains as the single-worker alias for existing call
sites and tests.
"""

from __future__ import annotations

import heapq
import threading
import time
from typing import Any, Callable, Mapping

from repro.core.compilette import Compilette, GenerationTicket
from repro.core.tuning_space import Point

__all__ = ["AsyncGenerator", "CompileFarm", "run_process_payload"]

_MODES = ("thread", "manual", "process")


def run_process_payload(payload: tuple) -> tuple[float, int]:
    """Child-process entry: resolve and run one compile payload.

    ``payload`` is ``(module, attr, kwargs)`` — everything picklable —
    naming a module-level callable that performs the compile and returns
    its measured seconds. Returns ``(seconds, child_pid)``.
    """
    import importlib
    import os

    module, attr, kwargs = payload
    fn = getattr(importlib.import_module(module), attr)
    return float(fn(**dict(kwargs))), os.getpid()


class CompileFarm:
    """Pool of M background compile workers shared by a whole coordinator.

    See the module docstring for scheduling, backend and determinism
    semantics. ``submit`` deduplicates by cache key: a job already in
    flight is joined (the same ticket is returned), and a point already
    in the compilette's cache returns an immediately-done ticket.
    Speculative (prefetch) submissions carry a charge callback so their
    compile time is billed to the requesting tuner's accounts even if
    the prefetched variant is never proposed.
    """

    #: consecutive backlogged submits before an "auto" pool grows
    AUTO_GROW_AFTER = 2
    #: consecutive idle observations before an "auto" pool shrinks
    AUTO_SHRINK_AFTER = 8

    def __init__(self, mode: str = "thread", *,
                 workers: "int | str" = 1,
                 per_kernel_cap: int | None = None,
                 worker_idle_timeout_s: float = 30.0,
                 max_workers: int | None = None) -> None:
        if mode not in _MODES:
            raise ValueError(
                f"CompileFarm mode must be one of {_MODES}, got {mode!r}")
        self.mode = mode
        # Adaptive sizing: workers="auto" starts at 1 and grows under
        # sustained queue backlog (more queued+running jobs than workers
        # on AUTO_GROW_AFTER consecutive submits), shrinks back when the
        # farm is observed idle. The signals are pure queue-state
        # counters sampled at submits and manual pump ticks — no clocks,
        # no thread timing — so the manual/virtual backend resizes (and
        # therefore batches) byte-identically across same-seed runs.
        self.auto_sized = workers == "auto"
        if self.auto_sized:
            import os
            self.workers = 1
            self.max_workers = (max(int(max_workers), 1)
                                if max_workers is not None
                                else min(8, os.cpu_count() or 1))
        else:
            self.workers = max(int(workers), 1)
            self.max_workers = self.workers
        self._backlog_pressure = 0
        self._idle_pressure = 0
        self.grown = 0
        self.shrunk = 0
        self.per_kernel_cap = (None if per_kernel_cap is None
                               else max(int(per_kernel_cap), 1))
        self.worker_idle_timeout_s = worker_idle_timeout_s
        self._mu = threading.Lock()
        self._cv = threading.Condition(self._mu)
        # priority heap of (-priority, speculative, seq, ticket): highest
        # priority first, requests before speculation, then FIFO
        self._heap: list[tuple[float, int, int, GenerationTicket]] = []
        self._seq = 0
        self._inflight: dict[tuple, GenerationTicket] = {}
        # per-kernel-name in-flight counts (queued + running), for the cap
        self._kernel_inflight: dict[str, int] = {}
        # negative memo: keys whose generation raised. Bounded by the
        # number of holes in the managed tuning spaces; without it a
        # prefetched hole would be compiled (and billed) a second time
        # when the tuner itself proposes the point.
        self._failed: dict[tuple, BaseException] = {}
        self._threads: set[threading.Thread] = set()
        self._busy = 0                 # workers currently inside _run
        self._stopping = False
        self._pool = None              # lazy ProcessPoolExecutor
        self._pool_mu = threading.Lock()
        self.submitted = 0
        self.completed = 0
        self.failed = 0
        self.speculative_submitted = 0
        self.joined = 0
        self.rejected_speculative = 0
        self.process_offloaded = 0
        self.process_fallbacks = 0
        # escapes caught by _run_safe (raises past _run's own generate
        # catch, e.g. a non-canonicalizable point key or a raising
        # speculative charge callback) — each one used to kill a worker
        self.worker_errors = 0

    # ------------------------------------------------------------ lifecycle
    def _spawn_locked(self) -> None:
        """Keep enough workers alive for the queued work (caller holds
        the farm mutex)."""
        if self.mode == "manual" or self._stopping:
            return
        want = min(self.workers, len(self._heap) + self._busy)
        while len(self._threads) < want:
            t = threading.Thread(
                target=self._worker_loop, daemon=True,
                name=f"compile-farm-{self._seq}-{len(self._threads)}")
            self._threads.add(t)
            t.start()

    def _worker_loop(self) -> None:
        # Workers retire after an idle period (a fresh one is spawned by
        # the next submit), so a forgotten coordinator — e.g. a
        # per-request one that was never close()d — does not pin blocked
        # daemon threads for the life of the process.
        me = threading.current_thread()
        try:
            while True:
                with self._cv:
                    while not self._heap:
                        if self._stopping:
                            return
                        if not self._cv.wait(self.worker_idle_timeout_s):
                            # idle timeout with the queue STILL empty:
                            # retire inside the same critical section
                            # submit pushes under — a concurrent enqueue
                            # either lands before this check (and is
                            # served) or after the deregistration (and
                            # spawns a replacement)
                            if not self._heap:
                                # an idle-retiring worker is the thread
                                # backend's idleness signal
                                self._note_idle_locked()
                                return
                    ticket = heapq.heappop(self._heap)[-1]
                    self._busy += 1
                try:
                    self._run_safe(ticket)
                finally:
                    with self._cv:
                        self._busy -= 1
        finally:
            # Whatever path ends this loop, the thread MUST leave the
            # registry: _spawn_locked sizes the pool by |_threads|, so a
            # dead-but-registered thread would permanently occupy a slot
            # (the dead-worker bug the safe runner exists to prevent).
            with self._cv:
                self._threads.discard(me)

    def shutdown(self) -> None:
        """Drain queued jobs, stop the workers, release the process pool.

        The farm stays usable: a later submit respawns workers (matching
        the old single-executor behaviour).
        """
        with self._cv:
            threads = list(self._threads)
            self._stopping = True
            self._cv.notify_all()
        for t in threads:
            t.join(timeout=5.0)
        with self._cv:
            self._stopping = False
        with self._pool_mu:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # ------------------------------------------------------------- process
    def _process_pool(self):
        with self._pool_mu:
            if self._pool is None:
                import concurrent.futures
                import multiprocessing

                self._pool = concurrent.futures.ProcessPoolExecutor(
                    max_workers=self.workers,
                    mp_context=multiprocessing.get_context("spawn"))
            return self._pool

    def _offload(self, ticket: GenerationTicket) -> tuple[float, int] | None:
        """Run the ticket's compile payload in a child process.

        Returns ``(child_seconds, child_pid)``, or ``None`` when the
        compilette has no payload or the child failed — the caller then
        compiles in-thread as in "thread" mode (``process_fallbacks``).
        """
        payload_fn = getattr(ticket.compilette, "process_payload", None)
        if payload_fn is None:
            self.process_fallbacks += 1
            return None
        try:
            payload = payload_fn(ticket.point, ticket.specialization)
        except Exception:
            payload = None
        if payload is None:
            self.process_fallbacks += 1
            return None
        try:
            fut = self._process_pool().submit(run_process_payload, payload)
            seconds, pid = fut.result()
            self.process_offloaded += 1
            return float(seconds), int(pid)
        except Exception:
            self.process_fallbacks += 1
            return None

    # ------------------------------------------------------------- running
    def _run(self, ticket: GenerationTicket) -> None:
        child: tuple[float, int] | None = None
        if self.mode == "process":
            child = self._offload(ticket)
        t0 = time.perf_counter()
        try:
            kern = ticket.compilette.generate(
                ticket.point, **ticket.specialization)
            err = None
        except BaseException as e:  # generation failure = late-found hole
            # drop the traceback: it pins the whole _generate frame
            # (model state, tracing temporaries) for as long as the
            # failure memo lives, and no consumer ever re-raises
            kern, err = None, e.with_traceback(None)
        failed_charge = time.perf_counter() - t0
        if err is not None:
            try:
                # a declared simulated cost keeps failure billing
                # deterministic under virtual clocks (successes already
                # bill the declared cost via generation_time_s)
                sim = ticket.compilette._simulated_cost(
                    ticket.point, ticket.specialization)
                if sim is not None:
                    failed_charge = sim
            except Exception:
                pass
        if child is not None and kern is not None:
            # the child's compile is real compute the budget must see,
            # on top of whatever the parent's own generate measured
            kern.generation_time_s += child[0]
            kern.meta["process_compile_s"] = child[0]
            kern.meta["process_pid"] = child[1]
        elif child is not None:
            failed_charge += child[0]
        try:
            key = ticket.compilette.cache_key(
                ticket.point, ticket.specialization)
        except BaseException as e:
            # a point that cannot be canonicalized cannot be keyed — and
            # must not kill the worker holding the farm lock. Treat it
            # like a generation failure (the variant is unusable either
            # way) and fall back to an identity scan for the inflight
            # entry, which was registered under the same raising key
            # path only if submit managed to compute it.
            key = None
            if err is None:
                kern, err = None, e.with_traceback(None)
        with self._mu:
            ticket.kern = kern
            ticket.error = err
            if err is not None and key is not None:
                self._failed[key] = err
            charge = (kern.generation_time_s if kern is not None
                      else failed_charge)
            if ticket.speculative and ticket._charge_cb is not None:
                # prefetch: the requester is billed NOW (used or not);
                # the harvester must not charge a second time
                cb, ticket.gen_charge_s = ticket._charge_cb, 0.0
            else:
                cb, ticket.gen_charge_s = None, charge
            ticket.done = True
            if key is not None:
                self._inflight.pop(key, None)
            else:
                for k, t in list(self._inflight.items()):
                    if t is ticket:
                        del self._inflight[k]
                        break
            self._kernel_uncount(ticket.compilette.name)
            if err is None:
                self.completed += 1
            else:
                self.failed += 1
        if cb is not None:
            # outside the lock: the callback charges tuner/coordinator
            # accounts and may take their locks — and may raise; the
            # ticket is already complete, so the failure is the
            # callback owner's, not the worker's
            try:
                cb(ticket, charge)
            except BaseException:
                with self._mu:
                    self.worker_errors += 1

    def _run_safe(self, ticket: GenerationTicket) -> None:
        """``_run`` that never raises: the worker-pool survival guarantee.

        ``_run`` already converts a raising ``generate`` into a
        failed-harvest ticket; this belt-and-suspenders wrapper converts
        any *remaining* escape the same way, because an exception
        crossing the worker loop used to kill the thread while it stayed
        registered in ``_threads`` — permanently shrinking the pool
        below M (``_spawn_locked`` sizes by registered threads). Manual
        mode shares the guarantee: an escape here would otherwise crash
        the coordinator's pump thread mid-request.
        """
        try:
            self._run(ticket)
            return
        except BaseException as e:
            err = e.with_traceback(None)
        with self._mu:
            self.worker_errors += 1
            if ticket.done:
                return   # completed before the escape: books are settled
            ticket.kern = None
            ticket.error = err
            ticket.gen_charge_s = 0.0
            ticket.done = True
            self.failed += 1
            self._kernel_uncount(ticket.compilette.name)
            for k, t in list(self._inflight.items()):
                if t is ticket:
                    del self._inflight[k]
                    break

    def _kernel_uncount(self, name: str) -> None:
        n = self._kernel_inflight.get(name, 0) - 1
        if n > 0:
            self._kernel_inflight[name] = n
        else:
            self._kernel_inflight.pop(name, None)

    # ------------------------------------------------------------- sizing
    def _note_backlog_locked(self) -> None:
        """Auto sizing, sampled at submit (caller holds the mutex)."""
        if not self.auto_sized:
            return
        queued = len(self._heap) + self._busy
        if queued > self.workers:
            self._idle_pressure = 0
            self._backlog_pressure += 1
            if (self._backlog_pressure >= self.AUTO_GROW_AFTER
                    and self.workers < self.max_workers):
                self.workers += 1
                self.grown += 1
                self._backlog_pressure = 0
        else:
            self._backlog_pressure = 0

    def _note_idle_locked(self) -> None:
        """Auto sizing, sampled when the farm is observed with no work."""
        if not self.auto_sized:
            return
        if self._heap or self._busy:
            self._idle_pressure = 0
            return
        self._backlog_pressure = 0
        self._idle_pressure += 1
        if self._idle_pressure >= self.AUTO_SHRINK_AFTER and self.workers > 1:
            self.workers -= 1
            self.shrunk += 1
            self._idle_pressure = 0

    def run_pending(self, max_jobs: int | None = None) -> int:
        """Manual mode: complete up to ``max_jobs`` queued jobs inline —
        one *batch* of ``workers`` jobs by default (the max-overlap model
        of M workers each finishing one compile per pump interval). In
        priority order; returns jobs completed. No-op in thread/process
        mode (the workers drain the queue themselves)."""
        if self.mode != "manual":
            return 0
        with self._mu:
            self._note_idle_locked()
        batch = self.workers if max_jobs is None else max_jobs
        n = 0
        while n < batch:
            with self._mu:
                if not self._heap:
                    return n
                ticket = heapq.heappop(self._heap)[-1]
            self._run_safe(ticket)
            n += 1
        return n

    def drain(self) -> int:
        """Manual mode: complete EVERY queued job, however many workers.

        The explicit whole-queue flush for tests and teardown paths;
        scheduled pumping should go through batched ``run_pending``.
        """
        total = 0
        while True:
            n = self.run_pending(max_jobs=len(self._heap) or 1)
            if n == 0:
                return total
            total += n

    # ------------------------------------------------------------- submit
    def submit(
        self,
        compilette: Compilette,
        point: Point,
        specialization: Mapping[str, Any],
        *,
        speculative: bool = False,
        charge_cb: Callable[[GenerationTicket, float], None] | None = None,
        priority: float = 0.0,
    ) -> GenerationTicket | None:
        """Request generation of ``point``; never blocks on the compile.

        Returns a ticket that is already ``done`` when the variant is in
        the cache, the in-flight ticket when the same key was already
        submitted (a non-speculative join adopts a speculative ticket),
        a freshly queued job otherwise — or ``None`` when a *speculative*
        submission was rejected by the per-kernel in-flight cap.
        """
        key = compilette.cache_key(point, specialization)

        def _join_locked(existing: GenerationTicket) -> GenerationTicket:
            self.joined += 1
            if not speculative:
                existing.adopt()
            return existing

        with self._mu:
            existing = self._inflight.get(key)
            if existing is not None:
                return _join_locked(existing)
            failed = self._failed.get(key)
            if failed is not None:
                # known hole: an already-billed failure, never recompiled
                return GenerationTicket(
                    compilette=compilette, point=dict(point),
                    specialization=dict(specialization), done=True,
                    error=failed, gen_charge_s=0.0)
        if compilette.cache is not None and key in compilette.cache:
            # hit: materialize through generate() so cache counters and
            # the zero-cost hit wrapper stay consistent. OUTSIDE the
            # farm lock: in the rare race where an LRU eviction lands
            # between the check and the get, generate() recompiles
            # inline — a bounded stall for this caller only, charged
            # below AND flagged as a stall, never a compile inside the
            # critical section. A failure on that inline path is a hole
            # like any other (a raise here would crash the caller's
            # pump/request thread).
            try:
                kern = compilette.generate(point, **dict(specialization))
            except BaseException as e:
                err = e.with_traceback(None)
                with self._mu:
                    self._failed[key] = err
                    self.failed += 1
                return GenerationTicket(
                    compilette=compilette, point=dict(point),
                    specialization=dict(specialization), done=True,
                    error=err, gen_charge_s=0.0)
            return GenerationTicket(
                compilette=compilette, point=dict(point),
                specialization=dict(specialization), done=True,
                kern=kern, gen_charge_s=kern.generation_time_s,
                stalled=kern.meta.get("source") == "compiled")
        with self._cv:
            existing = self._inflight.get(key)
            if existing is not None:   # raced in while we were unlocked
                return _join_locked(existing)
            name = compilette.name
            if (speculative and self.per_kernel_cap is not None
                    and self._kernel_inflight.get(name, 0)
                    >= self.per_kernel_cap):
                # cap: this kernel already owns its share of the farm;
                # the prefetcher retries on a later slot, while other
                # kernels' jobs keep flowing
                self.rejected_speculative += 1
                return None
            self._seq += 1
            ticket = GenerationTicket(
                compilette=compilette, point=dict(point),
                specialization=dict(specialization),
                speculative=speculative, _charge_cb=charge_cb,
                priority=float(priority), seq=self._seq)
            self._inflight[key] = ticket
            self._kernel_inflight[name] = (
                self._kernel_inflight.get(name, 0) + 1)
            self.submitted += 1
            if speculative:
                self.speculative_submitted += 1
            heapq.heappush(
                self._heap,
                (-ticket.priority, 1 if speculative else 0,
                 ticket.seq, ticket))
            self._note_backlog_locked()
            self._spawn_locked()
            self._cv.notify()
        return ticket

    def poll(self, ticket: GenerationTicket) -> GenerationTicket | None:
        """Non-blocking readiness check: the ticket when done, else None."""
        with self._mu:
            return ticket if ticket.done else None

    def disown(self, ticket: GenerationTicket,
               charge_cb: Callable[[GenerationTicket, float], None] | None
               ) -> float:
        """Release a ticket nobody will harvest (its tuner is retiring).

        Returns the unclaimed charge of an already-completed ticket (the
        caller bills it); a still-in-flight ticket is converted to a
        speculative one so ``charge_cb`` bills it at completion — either
        way the compile cost reaches the budget exactly once.
        """
        with self._mu:
            if ticket.done:
                charge, ticket.gen_charge_s = ticket.gen_charge_s, 0.0
                return charge
            ticket.speculative = True
            ticket._charge_cb = charge_cb
            return 0.0

    @property
    def in_flight(self) -> int:
        with self._mu:
            return len(self._inflight)

    def kernel_in_flight(self, name: str) -> int:
        with self._mu:
            return self._kernel_inflight.get(name, 0)

    def stats(self) -> dict[str, Any]:
        with self._mu:
            return {
                "mode": self.mode,
                "workers": self.workers,
                "auto_sized": self.auto_sized,
                "max_workers": self.max_workers,
                "grown": self.grown,
                "shrunk": self.shrunk,
                "per_kernel_cap": self.per_kernel_cap,
                "submitted": self.submitted,
                "completed": self.completed,
                "failed": self.failed,
                "speculative_submitted": self.speculative_submitted,
                "joined": self.joined,
                "rejected_speculative": self.rejected_speculative,
                "process_offloaded": self.process_offloaded,
                "process_fallbacks": self.process_fallbacks,
                "worker_errors": self.worker_errors,
                "in_flight": len(self._inflight),
            }


class AsyncGenerator(CompileFarm):
    """Single-worker :class:`CompileFarm`: the pre-farm executor's name.

    Kept for existing call sites and tests; ``AsyncGenerator(mode)`` is
    exactly ``CompileFarm(mode, workers=1)``.
    """

    def __init__(self, mode: str = "thread",
                 worker_idle_timeout_s: float = 30.0) -> None:
        super().__init__(mode, workers=1,
                         worker_idle_timeout_s=worker_idle_timeout_s)
