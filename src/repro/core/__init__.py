"""Core contribution: online auto-tuning at the code-generation level.

Public API re-exports.
"""

from repro.core.autotuner import OnlineAutotuner
from repro.core.compile_farm import AsyncGenerator, CompileFarm
from repro.core.compilette import (
    DEFAULT_ENTRY_BYTES,
    Compilette,
    GeneratedKernel,
    GenerationCache,
    GenerationTicket,
    device_free_memory_bytes,
    executable_bytes,
)
from repro.core.decision import (
    LatencyHeadroomGate,
    LatencyHistogram,
    RegenerationPolicy,
    TuningAccounts,
)
from repro.core.evaluator import (
    Evaluator,
    Measurement,
    SimulatedEvaluator,
    VirtualClock,
    VirtualClockEvaluator,
    filtered_training_time,
    mean_real_time,
    virtual_compilette,
    virtual_kernel,
)
from repro.core.explorer import (
    CostModelSearch,
    GreedyNeighborhood,
    RandomSearch,
    SearchStrategy,
    TwoPhaseExplorer,
    available_strategies,
    make_strategy,
    point_stripe,
    register_strategy,
    strategy_accepts,
)
from repro.core.gate import GATE_MODES, VariantGate
from repro.core.persistence import (
    FleetBus,
    LocalBackend,
    RegistryBackend,
    SharedFileBackend,
    TunedRegistry,
    compiler_version,
    device_fallbacks,
    device_fingerprint,
    merge_snapshots,
)
from repro.core.profiles import (
    ALL_PROFILES,
    EQUIVALENT_PAIRS,
    TPU_V5E,
    DeviceProfile,
    scaled_profile,
)
from repro.core.static_tuner import static_autotune
from repro.core.transfer import (
    DeviceTraits,
    TransferSeed,
    device_traits,
    similarity,
    transfer_seeds,
)
from repro.core.tuning_space import (
    Param,
    Point,
    TuningSpace,
    clamped_options,
    product_space,
)

__all__ = [
    "OnlineAutotuner",
    "AsyncGenerator",
    "CompileFarm",
    "Compilette",
    "DEFAULT_ENTRY_BYTES",
    "GeneratedKernel",
    "GenerationCache",
    "GenerationTicket",
    "device_free_memory_bytes",
    "executable_bytes",
    "LatencyHeadroomGate",
    "LatencyHistogram",
    "RegenerationPolicy",
    "TuningAccounts",
    "Evaluator",
    "Measurement",
    "SimulatedEvaluator",
    "VirtualClock",
    "VirtualClockEvaluator",
    "filtered_training_time",
    "mean_real_time",
    "virtual_compilette",
    "virtual_kernel",
    "GATE_MODES",
    "VariantGate",
    "SearchStrategy",
    "TwoPhaseExplorer",
    "RandomSearch",
    "GreedyNeighborhood",
    "CostModelSearch",
    "available_strategies",
    "make_strategy",
    "point_stripe",
    "register_strategy",
    "strategy_accepts",
    "FleetBus",
    "LocalBackend",
    "RegistryBackend",
    "SharedFileBackend",
    "TunedRegistry",
    "compiler_version",
    "device_fallbacks",
    "device_fingerprint",
    "merge_snapshots",
    "ALL_PROFILES",
    "EQUIVALENT_PAIRS",
    "TPU_V5E",
    "DeviceProfile",
    "scaled_profile",
    "static_autotune",
    "DeviceTraits",
    "TransferSeed",
    "device_traits",
    "similarity",
    "transfer_seeds",
    "Param",
    "Point",
    "TuningSpace",
    "clamped_options",
    "product_space",
]
