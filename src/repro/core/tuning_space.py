"""Tuning-space formalization (paper §3.2).

The tuning space is a discrete space with ``Nc_par`` dimensions, one per
auto-tuned parameter. Each point is a candidate code variant. The space has
*holes*: points where code generation is impossible on the target
micro-architecture (paper Fig. 1 "empty results"); holes are expressed by a
``validator`` predicate supplied by the compilette.

Phases (paper §3.3):
  phase 1 — *structural* parameters (unrolling factors, vector length,
            vectorization): they change the shape of the generated code.
  phase 2 — remaining codegen options (instruction scheduling, stack
            minimization, prefetch stride): explored combinatorially after
            phase-1 winners are frozen.
"""

from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Any, Callable, Iterator, Mapping, Sequence

Point = dict[str, Any]


@dataclasses.dataclass(frozen=True)
class Param:
    """One auto-tuned parameter (one dimension of the tuning space).

    ``phase`` assigns it to the two-phase exploration; ``switch_rank``
    orders phase-1 parameters from least-switched (0) to most-switched,
    reproducing the paper's exploration order (hotUF, coldUF, vectLen, VE).
    """

    name: str
    values: tuple[Any, ...]
    phase: int = 1
    switch_rank: int = 0

    def __post_init__(self) -> None:
        if self.phase not in (1, 2):
            raise ValueError(f"phase must be 1 or 2, got {self.phase}")
        if not self.values:
            raise ValueError(f"parameter {self.name!r} has no values")

    @property
    def range_size(self) -> int:
        """RangeSize(Nc_i) in the paper's Eq. (1)."""
        return len(self.values)


@dataclasses.dataclass(frozen=True)
class TuningSpace:
    """Discrete tuning space with validity holes."""

    params: tuple[Param, ...]
    # validator(point) -> True when the variant can be generated on the
    # target (the space's holes are the False region).
    validator: Callable[[Point], bool] = lambda point: True
    # no_leftover(point) -> True when the variant covers the iteration space
    # exactly (paper §3.3 explores leftover-free variants first).
    no_leftover: Callable[[Point], bool] = lambda point: True

    def __post_init__(self) -> None:
        names = [p.name for p in self.params]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate parameter names: {names}")

    # ------------------------------------------------------------------ size
    @property
    def n_code_variants(self) -> int:
        """Eq. (1): N_codeVariants = prod RangeSize(Nc_i). Includes holes."""
        return math.prod(p.range_size for p in self.params)

    def n_valid_variants(self) -> int:
        return sum(1 for _ in self.iter_valid())

    # ------------------------------------------------------------ accessors
    def param(self, name: str) -> Param:
        for p in self.params:
            if p.name == name:
                return p
        raise KeyError(name)

    @property
    def phase1_params(self) -> tuple[Param, ...]:
        """Phase-1 params ordered least-switched -> most-switched."""
        ps = [p for p in self.params if p.phase == 1]
        return tuple(sorted(ps, key=lambda p: p.switch_rank))

    @property
    def phase2_params(self) -> tuple[Param, ...]:
        return tuple(p for p in self.params if p.phase == 2)

    def default_point(self) -> Point:
        return {p.name: p.values[0] for p in self.params}

    # ------------------------------------------------------------ iteration
    def iter_all(self) -> Iterator[Point]:
        names = [p.name for p in self.params]
        for combo in itertools.product(*(p.values for p in self.params)):
            yield dict(zip(names, combo))

    def iter_valid(self) -> Iterator[Point]:
        for point in self.iter_all():
            if self.validator(point):
                yield point

    def is_valid(self, point: Point) -> bool:
        return self.validator(dict(point))

    def contains(self, point: Mapping[str, Any]) -> bool:
        try:
            return all(point[p.name] in p.values for p in self.params)
        except KeyError:
            return False

    # Phase-1 sub-space iteration: vary phase-1 params, keep phase-2 fixed.
    def iter_phase1(self, base: Point) -> Iterator[Point]:
        """All phase-1 variations of ``base``.

        Order follows the paper: parameters are explored from the least
        switched to the most switched, i.e. the *first* phase-1 parameter
        changes most slowly.
        """
        p1 = self.phase1_params
        for combo in itertools.product(*(p.values for p in p1)):
            point = dict(base)
            point.update(dict(zip((p.name for p in p1), combo)))
            yield point

    def iter_phase2(self, base: Point) -> Iterator[Point]:
        """All phase-2 variations of ``base`` (combinatorial, paper §3.3)."""
        p2 = self.phase2_params
        for combo in itertools.product(*(p.values for p in p2)):
            point = dict(base)
            point.update(dict(zip((p.name for p in p2), combo)))
            yield point

    def key(self, point: Point) -> tuple:
        """Canonical hashable identity of a point."""
        return tuple(point[p.name] for p in self.params)


def product_space(params: Sequence[Param], **kwargs) -> TuningSpace:
    return TuningSpace(params=tuple(params), **kwargs)


def clamped_options(options: Sequence[int], bound: int) -> tuple[int, ...]:
    """Deduplicate integer options past ``bound``.

    Chunk/tile sizes larger than the problem extent all compile to the
    same program, so a space built from raw option lists would contain
    duplicate variants — and re-measuring duplicates wastes the shared
    regeneration budget. Used by the serve/train compilettes to bound
    chunk options by the (bucketed) sequence length.
    """
    return tuple(sorted({min(int(v), int(bound)) for v in options}))
