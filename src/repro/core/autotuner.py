"""Online auto-tuner (paper Fig. 2 + §3.3–3.4).

At program start a *reference function* is evaluated and becomes the active
function. The tuning thread periodically wakes up; if the regeneration
policy grants budget, it asks the search strategy (the paper's two-phase
explorer by default; any name in the :mod:`repro.core.explorer` registry —
``strategy="random"``, ``"greedy"``, ... — or a pre-built instance) for the
next variant, generates it with the compilette (run-time machine-code
generation), evaluates it, and **swaps the active function pointer** when
the new score is better.

Three scheduling modes:

  * cooperative (default): a wake-up is attempted every ``wake_every``
    kernel invocations, inline. Deterministic; used by tests and by the
    training loop's tuning phase.
  * threaded: a daemon thread wakes every ``wake_period_s`` seconds, like
    the paper's separate auto-tuning thread. The kernel-call path only
    reads a function pointer under no lock (pointer swap is atomic in
    CPython); the tuning thread serializes itself with a lock.
  * managed (``wake_every=None``): the autotuner never self-wakes; an
    external scheduler — the process-wide ``TuningCoordinator`` — calls
    ``wake()`` when it grants this kernel a regeneration slot.

Time is read through an injectable ``clock`` callable (default
``time.perf_counter``). Passing a ``VirtualClock`` makes the entire
control loop — budgets, overhead fractions, gain estimates — a
deterministic function of simulated costs (used by tests/benchmarks).
"""

from __future__ import annotations

import dataclasses
import math
import threading
import time
from typing import Any, Callable, Sequence

from repro.core.compile_farm import CompileFarm
from repro.core.compilette import (
    Compilette,
    GeneratedKernel,
    GenerationTicket,
)
from repro.core.decision import (
    LatencyHistogram,
    RegenerationPolicy,
    TuningAccounts,
)
from repro.core.evaluator import Measurement
from repro.core.explorer import SearchStrategy, make_strategy, strategy_accepts
from repro.core.gate import GATE_MODES, VariantGate
from repro.core.tuning_space import Point

# An external arbiter for regeneration budget (the coordinator's shared
# budget): gate(accounts, now_s, next_cost_estimate_s) -> allowed.
BudgetGate = Callable[[TuningAccounts, float, float], bool]


def _model_cost_fn(
    compilette: Compilette, specialization: dict[str, Any]
) -> Callable[[Any], float] | None:
    """Per-point predicted execution cost from the compilette's model.

    Wired into model-based strategies (``strategy="cost_model"``). The
    model is probed once on the space's default point: a model that
    cannot price this backend at all (e.g. it needs a device profile
    and none is attached) raises there and opts the strategy back into
    its model-free order instead of ranking everything ``inf``.
    """
    model = getattr(compilette, "cost_model", None)
    if model is None:
        return None
    virtual = getattr(compilette, "virtual", None)
    profile = (virtual[1] if isinstance(virtual, tuple) and len(virtual) == 2
               else None)
    spec = dict(specialization or {})
    try:
        model(dict(compilette.space.default_point()), dict(spec), profile)
    except Exception:
        return None

    def cost_fn(point: Any) -> float:
        try:
            return float(model(dict(point), dict(spec), profile))
        except Exception:
            return float("inf")

    return cost_fn


@dataclasses.dataclass
class KernelLife:
    """Bookkeeping for one active-kernel tenure (gain estimation)."""

    point: Point | None           # None = the reference function
    score_s: float
    calls: int = 0


# A canary call whose MEAN observed latency exceeds the incumbent's
# per-call score by this factor is a tail regression: roll back. The
# threshold compares the canary against the *incumbent it wants to
# replace* (a variant that measured fast but serves slow must not survive
# just because it beats its own lie), and uses the running mean so one
# noisy real-hardware call does not condemn a good point outright.
CANARY_REGRESSION_FACTOR = 1.5


@dataclasses.dataclass
class _CanaryState:
    """A gated variant serving a fraction of calls before promotion."""

    fn: Callable[..., Any]
    life: KernelLife              # shares the _lives gain accounting
    served: int = 0
    total_call_s: float = 0.0
    max_call_s: float = 0.0


class OnlineAutotuner:
    def __init__(
        self,
        compilette: Compilette,
        evaluator: Any,
        *,
        policy: RegenerationPolicy | None = None,
        specialization: dict[str, Any] | None = None,
        reference_fn: Callable[..., Any] | None = None,
        reference_score_s: float | None = None,
        base_point: Point | None = None,
        seed_points: Sequence[Point] = (),
        wake_every: int | None = 16,
        strategy: "str | SearchStrategy" = "two_phase",
        explorer: SearchStrategy | None = None,
        clock: Callable[[], float] | None = None,
        budget_gate: BudgetGate | None = None,
        generator: CompileFarm | None = None,
        gate: VariantGate | None = None,
        gate_mode: str = "off",
        canary_fraction: float = 0.25,
        canary_calls: int = 8,
        quarantine_cb: Callable[[Point, str], None] | None = None,
    ) -> None:
        if gate_mode not in GATE_MODES:
            raise ValueError(
                f"gate_mode must be one of {GATE_MODES}, got {gate_mode!r}")
        self.compilette = compilette
        self.evaluator = evaluator
        self.policy = policy or RegenerationPolicy()
        self.specialization = dict(specialization or {})
        self._clock = clock or time.perf_counter
        self._budget_gate = budget_gate
        # --- trusted swaps: oracle gate + canary state machine ------------
        # "off" promotes on measurement alone (pre-gate behavior); "check"
        # runs the oracle gate before the swap; "canary" additionally
        # stages promotion: the variant serves ~canary_fraction of calls,
        # its observed latency compared against the incumbent, with
        # automatic rollback + quarantine on regression or exception.
        self._gate = gate
        self._gate_mode = gate_mode
        self._canary: _CanaryState | None = None
        fraction = min(max(float(canary_fraction), 1e-6), 1.0)
        self._canary_period = max(1, round(1.0 / fraction))
        self._canary_calls = max(1, int(canary_calls))
        self._quarantine_cb = quarantine_cb
        # point whose variant served the most recent __call__ (None = the
        # reference function) — lets harnesses attribute every production
        # call to the exact variant that produced its output
        self.last_served_point: Point | None = None
        # Double-buffered generation: when an AsyncGenerator is injected
        # (by the coordinator), wake() REQUESTS the next variant and keeps
        # the current active_fn serving until the compile is ready.
        self._generator = generator
        self._pending: GenerationTicket | None = None
        # Scheduling priority the coordinator computed when it granted
        # this tuner the slot; passed through to the compile farm so the
        # farm's queue preserves the scheduler's gain ordering.
        self.submit_priority: float = 0.0
        # EWMA of real per-call latency (fed by ManagedTuner.__call__ via
        # observe_latency); None until the first observation. The
        # histogram beside it estimates the tail: when the policy's
        # headroom gate declares an slo_quantile, the gate reads
        # quantile(slo_quantile) instead of the EWMA.
        self._latency_ewma: float | None = None
        self._latency_hist = LatencyHistogram()
        # `explorer` (a pre-built instance) wins over `strategy` (a registry
        # name or instance); both default to the paper's two-phase order.
        # Model-based strategies additionally receive the compilette's
        # cost model (as a per-point `cost_fn`) when one is attached.
        strategy_kwargs: dict[str, Any] = {}
        if (explorer is None and isinstance(strategy, str)
                and strategy_accepts(strategy, "cost_fn")):
            cost_fn = _model_cost_fn(compilette, self.specialization)
            if cost_fn is not None:
                strategy_kwargs["cost_fn"] = cost_fn
        self.explorer = explorer or make_strategy(
            strategy, compilette.space,
            base_point=base_point, seed_points=seed_points,
            **strategy_kwargs,
        )
        self.accounts = TuningAccounts(app_start_s=self._clock())
        self._lock = threading.Lock()
        self._wake_every = None if wake_every is None else max(int(wake_every), 1)
        self._cost_ema: float | None = None   # EMA of gen+eval cost
        self._lives: list[KernelLife] = []
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

        # --- reference function: initial active function (paper §3) -------
        # The reference baseline is measured through normal, instrumented
        # application work (paper §3.3) — it is accounted separately and
        # does not consume the regeneration budget.
        t0 = self._clock()
        if reference_fn is None:
            ref = self.compilette.generate(
                self.explorer.base_point, **self.specialization
            )
            reference_fn = ref.fn
            self.accounts.init_spent_s += ref.generation_time_s
        if reference_score_s is None:
            m = self.evaluator.evaluate(reference_fn)
            reference_score_s = m.score_s
            # Charge the *marginal* instrumentation cost: the measurement
            # runs themselves. m.eval_time_s additionally bundles one-time
            # reference compilation, which is normal app work the first
            # real call would have paid anyway (paper §3.3) — charging it
            # would suppress serving-path tuning (charge_init policies)
            # for far longer than the instrumentation actually cost.
            self.accounts.init_spent_s += min(
                m.eval_time_s, m.score_s * m.n_runs)
        self.reference_score_s = reference_score_s
        # kept for external demotion (fleet quarantine of the incumbent)
        self._reference_fn: Callable[..., Any] = reference_fn
        self._active: Callable[..., Any] = reference_fn
        self._active_life = KernelLife(point=None, score_s=reference_score_s)
        self._lives.append(self._active_life)
        self._init_time_s = self._clock() - t0

    # -------------------------------------------------------------- calling
    @property
    def active_fn(self) -> Callable[..., Any]:
        return self._active

    @property
    def best_point(self) -> Point | None:
        return self.explorer.best_point

    def __call__(self, *args: Any) -> Any:
        if (self._canary is not None
                and self.accounts.kernel_calls % self._canary_period == 0):
            out = self._serve_canary(args)
        else:
            out = self._active(*args)
            self._active_life.calls += 1
            self.last_served_point = self._active_life.point
        self.accounts.kernel_calls += 1
        if (
            self._thread is None
            and self._wake_every is not None
            and self.accounts.kernel_calls % self._wake_every == 0
        ):
            self.wake()
        return out

    # ------------------------------------------------------------- canary
    def _serve_canary(self, args: tuple) -> Any:
        """Serve one production call through the canary variant.

        An exception rolls back to the incumbent (which then serves the
        call — the caller never sees the canary's failure); a mean
        observed latency beyond ``CANARY_REGRESSION_FACTOR`` x the
        incumbent's per-call score is a tail regression and also rolls
        back. After ``canary_calls`` clean served calls the canary is
        promoted to incumbent.
        """
        canary = self._canary
        t0 = self._clock()
        try:
            out = canary.fn(*args)
        except Exception as e:
            self._rollback(canary, f"canary raised: {e!r}")
            out = self._active(*args)
            self._active_life.calls += 1
            self.last_served_point = self._active_life.point
            return out
        call_s = self._clock() - t0
        canary.served += 1
        canary.life.calls += 1
        canary.total_call_s += call_s
        canary.max_call_s = max(canary.max_call_s, call_s)
        self.accounts.canary_calls += 1
        self.last_served_point = canary.life.point
        mean_s = canary.total_call_s / canary.served
        limit_s = CANARY_REGRESSION_FACTOR * max(
            self._active_life.score_s, 1e-12)
        if mean_s > limit_s:
            # keep gain/busy estimates honest: the tenure served at the
            # observed latency, not at the score the variant measured
            canary.life.score_s = mean_s
            self._rollback(
                canary,
                f"tail regression: mean {mean_s:.3e}s vs incumbent "
                f"{self._active_life.score_s:.3e}s")
        elif canary.served >= self._canary_calls:
            self._promote(canary)
        return out

    def _rollback(self, canary: _CanaryState, reason: str) -> None:
        self._canary = None
        self.accounts.rollbacks += 1
        self._quarantine(canary.life.point, reason)

    def _promote(self, canary: _CanaryState) -> None:
        self._active = canary.fn
        self._active_life = canary.life
        self._canary = None
        self.accounts.swaps += 1
        self.accounts.canary_promotions += 1

    def _quarantine(self, point: Point, reason: str) -> None:
        """Never trust this point again: strategy + (via cb) registry."""
        self.accounts.quarantined += 1
        self.explorer.quarantine(point)
        if self._quarantine_cb is not None:
            self._quarantine_cb(dict(point), reason)

    def adopt_quarantine(self, point: Point, reason: str = "") -> bool:
        """Adopt a condemnation published elsewhere (a peer replica).

        Unlike :meth:`_quarantine` this is an *external* verdict: the
        point is quarantined in the explorer, a matching in-flight canary
        is aborted silently (no rollback is charged — the canary did
        nothing wrong locally), and a matching ACTIVE incumbent is
        demoted back to the reference function (a peer's oracle or canary
        proved it wrong under traffic this replica has not seen yet).
        The registry write-through is skipped: the caller merged the
        quarantine from the registry in the first place. Returns True if
        any local state changed.
        """
        key = self.explorer.space.key(point)
        with self._lock:
            changed = False
            if not self.explorer.is_quarantined(point):
                self.explorer.quarantine(point)
                changed = True
            canary = self._canary
            if (canary is not None and canary.life.point is not None
                    and self.explorer.space.key(canary.life.point) == key):
                self._canary = None
                changed = True
            if (self._active_life.point is not None
                    and self.explorer.space.key(self._active_life.point)
                    == key):
                self._active = self._reference_fn
                self._active_life = self._lives[0]
                changed = True
            return changed

    # ------------------------------------------------------------ gains
    def _update_gains(self) -> None:
        """Refresh the derived accounting: gains and busy time.

        Both use the paper's instrumentation-light estimate — the only
        per-call record is a counter, so busy time is calls x measured
        per-call score accumulated over active-kernel tenures (exact under
        the VirtualClock, an estimate on real hardware).
        """
        gained = 0.0
        busy = 0.0
        for life in self._lives:
            gained += life.calls * (self.reference_score_s - life.score_s)
            busy += life.calls * life.score_s
        self.accounts.gained_s = gained
        self.accounts.busy_s = busy
        # Headroom gating prefers the EWMA of real observed call latencies
        # (one outlier call can no longer freeze/unfreeze tuning); the
        # measured score is the fallback for unmanaged tuners.
        self.accounts.observed_call_s = (
            self._latency_ewma if self._latency_ewma is not None
            else self._active_life.score_s)

    def observe_latency(self, call_s: float, alpha: float = 0.2) -> None:
        """Feed one real per-call latency into the EWMA + tail estimates."""
        if call_s < 0:
            return
        if self._latency_ewma is None:
            self._latency_ewma = float(call_s)
        else:
            self._latency_ewma += alpha * (float(call_s) - self._latency_ewma)
        # write through: the headroom gate must see fresh telemetry even
        # between _update_gains passes
        self.accounts.observed_call_s = self._latency_ewma
        self._latency_hist.observe(call_s)
        q = getattr(self.policy.headroom, "slo_quantile", None)
        if q is not None:
            self.accounts.observed_tail_s = self._latency_hist.quantile(q)

    # ------------------------------------------------------------ wake-up
    @property
    def generation_in_flight(self) -> bool:
        """A requested variant is still compiling in the background."""
        return self._pending is not None and not self._pending.done

    def _candidate_cost_estimate(self) -> float:
        """Cost-model prediction of the next regeneration's full charge.

        The budget gate otherwise estimates with the ACTIVE kernel's
        cost EWMA, which understates candidates slower than the
        incumbent — each admission can overshoot the shared budget by
        the difference, and the overshoots accumulate. When the
        compilette carries a cost model and a virtual profile, the
        upcoming candidate's generation + evaluation cost is knowable
        in advance; real backends (no model) keep the EWMA estimate.
        """
        comp = self.compilette
        virtual = getattr(comp, "virtual", None)
        if virtual is None or getattr(comp, "cost_model", None) is None:
            return 0.0
        peeked = self.explorer.peek(1)
        if not peeked:
            return 0.0
        point = peeked[0]
        try:
            gen = comp._simulated_cost(point, self.specialization) or 0.0
            est = gen + comp.simulate(
                point, virtual[1], **self.specialization)
        except Exception:
            return 0.0
        # a hole candidate priced at inf must still be admitted so the
        # normal cycle can report it and move on — never gate on it
        return est if math.isfinite(est) else 0.0

    def wake(self) -> bool:
        """One wake-up of the tuning thread. Returns True if it swapped.

        Without an :class:`AsyncGenerator` this is the paper's synchronous
        cycle: generate, evaluate, maybe swap — the compile stalls the
        wake. With one (coordinator-injected), a wake instead *requests*
        the next variant and returns immediately; the active function
        keeps serving until a later wake finds the compiled candidate
        ready and only then pays the (much cheaper) evaluation. The full
        generation time is charged to the budget either way — only the
        *stall* disappears.
        """
        with self._lock:
            # -- harvest: a previously requested variant may be ready ----
            if self._pending is not None:
                ticket = self._generator.poll(self._pending)
                if ticket is None:
                    return False   # still compiling; hot path unstalled
                self._pending = None
                if ticket.error is not None:
                    # late-found hole: charge the wasted compile,
                    # quarantine the point (a failing compile is as
                    # untrusted as a failing oracle), move on
                    self.accounts.tuning_spent_s += ticket.gen_charge_s
                    self.accounts.gen_spent_s += ticket.gen_charge_s
                    self.explorer.report(ticket.point, float("inf"))
                    self._quarantine(
                        ticket.point, f"generation failed: {ticket.error!r}")
                    return False
                if self.explorer.is_quarantined(ticket.point):
                    # condemned while the compile was in flight (e.g. a
                    # peer replica's verdict arrived via fleet sync): pay
                    # for the wasted compile, never evaluate or serve it
                    self.accounts.tuning_spent_s += ticket.gen_charge_s
                    self.accounts.gen_spent_s += ticket.gen_charge_s
                    return False
                return self._measure_and_swap(
                    ticket.point, ticket.kern,
                    gen_charge_s=ticket.gen_charge_s, stalled=ticket.stalled)
            if self.explorer.finished:
                return False
            self._update_gains()
            now = self._clock()
            estimate = self._cost_ema if self._cost_ema is not None else 0.0
            estimate = max(estimate, self._candidate_cost_estimate())
            gate = self._budget_gate or self.policy.should_regenerate
            if not gate(self.accounts, now, estimate):
                return False
            point = self.explorer.next_point()
            if point is None:
                return False
            # -- request: pipelined generation (double buffering) --------
            if self._generator is not None:
                ticket = self._generator.submit(
                    self.compilette, point, self.specialization,
                    priority=self.submit_priority)
                self.accounts.gen_requests += 1
                if not ticket.done:
                    self._pending = ticket
                    return False
                if ticket.error is not None:
                    self.explorer.report(point, float("inf"))
                    self._quarantine(
                        point, f"generation failed: {ticket.error!r}")
                    return False
                # cache hit: ready now at zero cost — evaluate in place
                # (ticket.stalled covers the rare eviction race where the
                # "hit" actually recompiled inline on this thread)
                return self._measure_and_swap(
                    point, ticket.kern,
                    gen_charge_s=ticket.gen_charge_s, stalled=ticket.stalled)
            # -- synchronous generate+evaluate (paper's original cycle) --
            t0 = self._clock()
            try:
                kern: GeneratedKernel = self.compilette.generate(
                    point, **self.specialization
                )
            except Exception as e:
                # Generation failures are holes discovered late: record the
                # spent time, quarantine the point and move on (the paper's
                # "could not generate code" entries). The whole interval is
                # generation (the evaluation never started), and it stalled
                # this wake.
                spent = self._clock() - t0
                self.accounts.tuning_spent_s += spent
                self.accounts.gen_spent_s += spent
                self.accounts.gen_stall_s += spent
                self.explorer.report(point, float("inf"))
                self._quarantine(point, f"generation failed: {e!r}")
                return False
            compiled = kern.meta.get("source", "compiled") == "compiled"
            if (compiled and kern.meta.get("simulated")
                    and hasattr(self._clock, "advance")):
                # a simulated compile cost stalls the virtual clock exactly
                # like a real synchronous XLA compile stalls the wall clock
                self._clock.advance(kern.generation_time_s)
            return self._measure_and_swap(
                point, kern, gen_charge_s=kern.generation_time_s,
                stalled=compiled, wall_t0=t0)

    def _measure_and_swap(
        self,
        point: Point,
        kern: GeneratedKernel,
        *,
        gen_charge_s: float,
        stalled: bool,
        wall_t0: float | None = None,
    ) -> bool:
        """Evaluate a generated variant, charge the accounts, maybe swap.

        ``wall_t0`` set means the generation ran synchronously inside this
        wake (the clock interval covers it); otherwise generation time was
        overlapped (or cached) and ``gen_charge_s`` is added explicitly so
        the budget still pays for it.
        """
        t_eval = self._clock()

        def _charge(spent: float, eval_s: float) -> None:
            self.accounts.tuning_spent_s += spent
            self.accounts.gen_spent_s += gen_charge_s
            self.accounts.eval_spent_s += eval_s
            if stalled:
                self.accounts.gen_stall_s += gen_charge_s

        try:
            measurement: Measurement = self.evaluator.evaluate(kern.fn)
        except Exception as e:
            eval_s = self._clock() - t_eval
            start = wall_t0 if wall_t0 is not None else t_eval
            spent = self._clock() - start
            if wall_t0 is None:
                spent += gen_charge_s
            _charge(spent, eval_s)
            self.explorer.report(point, float("inf"))
            self._quarantine(point, f"evaluation raised: {e!r}")
            return False
        eval_s = self._clock() - t_eval
        if wall_t0 is not None:
            spent = self._clock() - wall_t0
        else:
            spent = gen_charge_s + eval_s
        _charge(spent, eval_s)
        self.accounts.regenerations += 1
        self._cost_ema = (
            spent
            if self._cost_ema is None
            else 0.5 * self._cost_ema + 0.5 * spent
        )
        # --- variant gate: oracle check before the point may serve -------
        if self._gate_mode != "off" and self._gate is not None:
            t_gate = self._clock()
            ok, reason = self._gate.check(point, kern.fn)
            gate_s = self._clock() - t_gate
            self.accounts.tuning_spent_s += gate_s
            self.accounts.gate_spent_s += gate_s
            self.accounts.gate_checks += 1
            if not ok:
                self.accounts.gate_failures += 1
                self._quarantine(point, reason)
                self.explorer.report(point, float("inf"))
                return False
        is_best = self.explorer.report(point, measurement.score_s)
        if is_best and measurement.score_s < self._active_life.score_s:
            life = KernelLife(point=dict(point), score_s=measurement.score_s)
            self._lives.append(life)
            if self._gate_mode == "canary":
                # staged promotion: CANDIDATE -> CANARY. The incumbent
                # keeps serving most calls; a newer, better candidate
                # simply supersedes an unfinished canary (no quarantine —
                # it did nothing wrong, it just lost).
                self._canary = _CanaryState(fn=kern.fn, life=life)
                return False
            self._active = kern.fn
            self._active_life = life
            self.accounts.swaps += 1
            return True
        return False

    def abandon_pending(self, charge_cb=None) -> None:
        """Drop an unharvested generation request (tuner is retiring).

        The compile cost must still reach the budget: a completed ticket
        is billed here (so the caller can fold these accounts into its
        tombstone), an in-flight one is handed back to the generator
        with ``charge_cb`` to bill at completion.
        """
        with self._lock:
            ticket = self._pending
            self._pending = None
            if ticket is None or self._generator is None:
                return
            charge = self._generator.disown(ticket, charge_cb)
            if charge > 0.0:
                self.accounts.gen_spent_s += charge
                self.accounts.tuning_spent_s += charge

    def exhaust(self, max_wakes: int = 100000) -> None:
        """Drive wake-ups ignoring call pacing until budget or space ends.

        Synchronous tuners only: with an async generator, driving the
        pipeline is the coordinator's job (``pump`` completes and harvests
        in-flight generations).
        """
        for _ in range(max_wakes):
            if self.explorer.finished:
                break
            before = self.explorer.state.n_reported
            self.wake()
            if self.explorer.state.n_reported == before:
                break  # budget exhausted for now

    # ------------------------------------------------------------ threaded
    def start_thread(self, wake_period_s: float = 0.001) -> None:
        if self._thread is not None:
            return

        def _loop() -> None:
            while not self._stop.is_set():
                self.wake()
                if self.explorer.finished:
                    break
                self._stop.wait(wake_period_s)

        self._thread = threading.Thread(target=_loop, daemon=True)
        self._thread.start()

    def stop_thread(self) -> None:
        if self._thread is None:
            return
        self._stop.set()
        self._thread.join(timeout=5.0)
        self._thread = None

    # ------------------------------------------------------------- reports
    def stats(self) -> dict[str, Any]:
        self._update_gains()
        elapsed = self._clock() - self.accounts.app_start_s
        return {
            "strategy": self.explorer.name,
            "kernel_calls": self.accounts.kernel_calls,
            "regenerations": self.accounts.regenerations,
            "swaps": self.accounts.swaps,
            "tuning_spent_s": self.accounts.tuning_spent_s,
            "gen_spent_s": self.accounts.gen_spent_s,
            "gen_stall_s": self.accounts.gen_stall_s,
            "eval_spent_s": self.accounts.eval_spent_s,
            "generation_in_flight": self.generation_in_flight,
            "gate_mode": self._gate_mode,
            "gate_spent_s": self.accounts.gate_spent_s,
            "gate_checks": self.accounts.gate_checks,
            "gate_failures": self.accounts.gate_failures,
            "canary_calls": self.accounts.canary_calls,
            "canary_promotions": self.accounts.canary_promotions,
            "canary_in_flight": self._canary is not None,
            "rollbacks": self.accounts.rollbacks,
            "quarantined": self.accounts.quarantined,
            "gained_s": self.accounts.gained_s,
            "overhead_frac": (
                self.accounts.tuning_spent_s / elapsed if elapsed > 0 else 0.0
            ),
            "reference_score_s": self.reference_score_s,
            "active_score_s": self._active_life.score_s,
            "active_point": self._active_life.point,
            "best_point": self.explorer.best_point,
            "best_score_s": self.explorer.best_score,
            "exploration_finished": self.explorer.finished,
            "n_explored": self.explorer.state.n_reported,
        }
