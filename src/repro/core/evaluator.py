"""Kernel evaluation (paper §3.4).

Two evaluation modes:

  * ``real``      — time the variant on real input data (useful work is
                    performed during evaluation, measurements are noisier);
                    score = arithmetic mean of ``runs`` measurements.
  * ``training``  — time the variant on a training input with warmed
                    caches; score = the paper's robust filter: **the worst
                    value among the 3 best values of groups of 5
                    measurements** — filters oscillations from hardware
                    (pipeline/cache/counter fluctuations) and software
                    (interruptions).

Timing uses the host monotonic clock around ``block_until_ready`` when the
result is a JAX array, so asynchronous dispatch cannot fake speedups.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence


def _block(x: Any) -> None:
    try:
        import jax

        jax.block_until_ready(x)
    except Exception:  # non-jax results (plain python) need no sync
        pass


def time_once(fn: Callable[..., Any], args: Sequence[Any]) -> float:
    t0 = time.perf_counter()
    out = fn(*args)
    _block(out)
    return time.perf_counter() - t0


def filtered_training_time(
    fn: Callable[..., Any],
    args: Sequence[Any],
    *,
    groups: int = 3,
    group_size: int = 5,
    warmup: int = 1,
) -> float:
    """Paper's filter: worst of the ``groups`` best values of groups of
    ``group_size`` measurements."""
    for _ in range(warmup):
        time_once(fn, args)
    best_of_groups = []
    for _ in range(groups):
        samples = [time_once(fn, args) for _ in range(group_size)]
        best_of_groups.append(min(samples))
    return max(best_of_groups)


def mean_real_time(
    fn: Callable[..., Any],
    args: Sequence[Any],
    *,
    runs: int = 5,
    warmup: int = 1,
) -> float:
    for _ in range(warmup):
        time_once(fn, args)
    return sum(time_once(fn, args) for _ in range(runs)) / runs


@dataclasses.dataclass
class Measurement:
    score_s: float          # lower is better (execution time)
    n_runs: int
    mode: str               # "real" | "training" | "simulated"
    eval_time_s: float      # wall time spent evaluating (overhead accounting)


class Evaluator:
    """Scores generated kernels; the auto-tuner compares ``score_s``."""

    def __init__(
        self,
        *,
        mode: str = "training",
        groups: int = 3,
        group_size: int = 5,
        real_runs: int = 5,
        warmup: int = 1,
        make_args: Callable[[], Sequence[Any]] | None = None,
    ) -> None:
        if mode not in ("real", "training"):
            raise ValueError(f"unknown evaluation mode {mode!r}")
        self.mode = mode
        self.groups = groups
        self.group_size = group_size
        self.real_runs = real_runs
        self.warmup = warmup
        self.make_args = make_args

    def n_runs(self) -> int:
        if self.mode == "training":
            return self.groups * self.group_size + self.warmup
        return self.real_runs + self.warmup

    def evaluate(self, fn: Callable[..., Any], args: Sequence[Any] | None = None) -> Measurement:
        if args is None:
            if self.make_args is None:
                raise ValueError("no args supplied and no make_args factory")
            args = self.make_args()
        t0 = time.perf_counter()
        if self.mode == "training":
            score = filtered_training_time(
                fn, args, groups=self.groups, group_size=self.group_size, warmup=self.warmup
            )
        else:
            score = mean_real_time(fn, args, runs=self.real_runs, warmup=self.warmup)
        eval_time = time.perf_counter() - t0
        return Measurement(score_s=score, n_runs=self.n_runs(), mode=self.mode, eval_time_s=eval_time)


class VirtualClock:
    """Injectable simulated time source.

    A ``VirtualClock`` instance is callable (drop-in for
    ``time.perf_counter``) and only moves when something calls
    ``advance``. Injected into ``OnlineAutotuner``/``TuningCoordinator``
    (their ``clock`` parameter) it makes the whole tuning control loop —
    budget decisions, overhead accounting, time-to-best — a deterministic
    function of the simulated costs, so tests and benchmarks never sleep
    and never flake on a loaded host.
    """

    def __init__(self, start_s: float = 0.0) -> None:
        self._now = float(start_s)

    def __call__(self) -> float:
        return self._now

    def advance(self, dt_s: float) -> float:
        if dt_s < 0:
            raise ValueError(f"cannot advance a clock backwards ({dt_s})")
        self._now += float(dt_s)
        return self._now


def virtual_kernel(clock: VirtualClock, cost_s: float, tag: Any = None):
    """A fake kernel whose 'execution' advances ``clock`` by ``cost_s``.

    The cost is attached as ``fn.score_s`` so ``VirtualClockEvaluator``
    can read it back without re-running anything.
    """

    def fn(*args: Any) -> Any:
        clock.advance(cost_s)
        return args[0] if args else None

    fn.score_s = float(cost_s)  # type: ignore[attr-defined]
    fn.tag = tag                # type: ignore[attr-defined]
    return fn


def virtual_compilette(clock: VirtualClock, name: str, space, cost_fn,
                       *, gen_cost_s: float = 0.0):
    """A compilette over virtual kernels with a SIMULATED compile cost.

    ``cost_fn(point) -> seconds`` prices execution; ``gen_cost_s`` prices
    generation. The compile cost is *declared* (``Compilette.gen_cost_s``)
    rather than burned inside the generator, so the party that decides
    stall-vs-overlap charges it correctly: a synchronous ``wake()``
    advances the virtual clock by it (the hot path stalls, exactly like a
    real inline XLA compile), while the async pipeline and cache hits
    charge it to the budget without moving the clock — which is the
    whole point of double-buffered generation, and what the no-sleep
    tests in ``tests/test_generation_pipeline.py`` assert.
    """
    from repro.core.compilette import Compilette

    def gen(point, **spec):
        return virtual_kernel(clock, cost_fn(point), tag=dict(point))

    return Compilette(name, space, gen, gen_cost_s=gen_cost_s)


class VirtualClockEvaluator:
    """Deterministic evaluator driven by simulated time (no wall clock).

    ``evaluate`` reads the variant's cost instead of timing it — either
    via ``score_fn(fn)`` or, by default, from the ``score_s`` attribute
    that ``virtual_kernel`` attaches — then charges a fixed simulated
    measurement cost (``runs`` x score + ``fixed_eval_cost_s``) to the
    injected ``VirtualClock``. Budget/overhead accounting in the
    auto-tuner therefore behaves exactly as with a real evaluator, but
    bit-reproducibly.
    """

    def __init__(
        self,
        clock: VirtualClock,
        *,
        score_fn: Callable[[Callable[..., Any]], float] | None = None,
        runs: int = 1,
        fixed_eval_cost_s: float = 0.0,
    ) -> None:
        self.clock = clock
        self.score_fn = score_fn
        self.runs = max(int(runs), 1)
        self.fixed_eval_cost_s = float(fixed_eval_cost_s)
        self.mode = "virtual"

    def n_runs(self) -> int:
        return self.runs

    def evaluate(
        self, fn: Callable[..., Any], args: Sequence[Any] | None = None
    ) -> Measurement:
        if self.score_fn is not None:
            score = float(self.score_fn(fn))
        else:
            score = float(getattr(fn, "score_s"))
        eval_cost = self.runs * score + self.fixed_eval_cost_s
        self.clock.advance(eval_cost)
        return Measurement(
            score_s=score, n_runs=self.runs, mode="virtual",
            eval_time_s=eval_cost,
        )


class SimulatedEvaluator:
    """Evaluator against an analytical device profile (paper's gem5 analogue).

    ``evaluate`` consults the compilette cost model instead of running code.
    Evaluation wall-time is ~0; the simulated score drives replacement
    decisions exactly like a real measurement.
    """

    def __init__(self, compilette, profile, **specialization: Any) -> None:
        self.compilette = compilette
        self.profile = profile
        self.specialization = specialization
        self.mode = "simulated"

    def evaluate_point(self, point) -> Measurement:
        t0 = time.perf_counter()
        score = self.compilette.simulate(point, self.profile, **self.specialization)
        return Measurement(
            score_s=score, n_runs=1, mode="simulated", eval_time_s=time.perf_counter() - t0
        )
