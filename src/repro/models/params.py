"""Declarative parameter definitions.

Each model family declares its parameters once as a nested dict of
``ParamDef`` (shape + logical axes + initializer). From that single source
we derive:

  * ``init_tree``  — materialized parameters (smoke tests, examples),
  * ``spec_tree``  — ``PartitionSpec`` tree for pjit (dry-run, launcher),
  * ``abstract_tree`` — ``ShapeDtypeStruct`` tree (dry-run, no allocation).

Logical axis names are resolved to mesh axes by ``repro.distributed.sharding``.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]      # logical axis per dim
    init: str = "normal"              # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _iter_defs(tree: dict, path=()):
    for name in sorted(tree):
        node = tree[name]
        if isinstance(node, ParamDef):
            yield path + (name,), node
        else:
            yield from _iter_defs(node, path + (name,))


def _set(tree: dict, path, value):
    for p in path[:-1]:
        tree = tree.setdefault(p, {})
    tree[path[-1]] = value


def init_tree(defs: dict, key: jax.Array, dtype=jnp.float32) -> dict:
    out: dict = {}
    entries = list(_iter_defs(defs))
    keys = jax.random.split(key, max(len(entries), 1))
    for (path, d), k in zip(entries, keys):
        if d.init == "zeros":
            arr = jnp.zeros(d.shape, dtype)
        elif d.init == "ones":
            arr = jnp.ones(d.shape, dtype)
        else:
            arr = (jax.random.normal(k, d.shape, jnp.float32) * d.scale).astype(dtype)
        _set(out, path, arr)
    return out


def spec_tree(defs: dict, resolve: Callable[[str | None], Any]) -> dict:
    """resolve(logical_axis) -> mesh axis name(s) or None."""
    from jax.sharding import PartitionSpec as P

    out: dict = {}
    for path, d in _iter_defs(defs):
        _set(out, path, P(*(resolve(a) for a in d.axes)))
    return out


def abstract_tree(defs: dict, dtype=jnp.float32) -> dict:
    out: dict = {}
    for path, d in _iter_defs(defs):
        _set(out, path, jax.ShapeDtypeStruct(d.shape, dtype))
    return out


def cast_params(params, dtype):
    """Cast float parameters to the compute dtype ONCE, before the layer
    scan. With FSDP, weight all-gathers then move bf16 instead of fp32 —
    half the collective bytes per microbatch (beyond-paper §Perf H1)."""
    import jax.numpy as jnp

    def one(p):
        if hasattr(p, "dtype") and jnp.issubdtype(p.dtype, jnp.floating) \
                and p.dtype != dtype:
            return p.astype(dtype)
        return p

    return jax.tree.map(one, params)


def count_params(defs: dict) -> int:
    total = 0
    for _, d in _iter_defs(defs):
        n = 1
        for s in d.shape:
            n *= s
        total += n
    return total
