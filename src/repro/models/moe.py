"""Mixture-of-Experts FFN: GShard-style grouped capacity dispatch.

Tokens are reshaped into groups of ``moe_group_size``; each of the top-k
routing choices is dispatched as an independent top-1 slice (k small
dispatch tensors instead of one huge one), keeping the dispatch one-hot at
(G, S, E, C) with small C. Experts live on the ``expert``→model mesh axis;
groups follow the batch axes, so GSPMD materializes the dispatch/combine
einsums as all-to-all-style exchanges between the data and model axes.

Dropped tokens (capacity overflow) pass through with zero contribution, as
in GShard/Switch. A load-balancing auxiliary loss is returned.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.params import ParamDef
from repro.models import layers as L


def moe_defs(cfg: ModelConfig) -> dict:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    defs = {
        "router": ParamDef((d, E), ("embed", None), scale=s_in),
        "w_gate": ParamDef((E, d, ff), ("expert", "embed", None), scale=s_in),
        "w_up": ParamDef((E, d, ff), ("expert", "embed", None), scale=s_in),
        "w_down": ParamDef((E, ff, d), ("expert", None, "embed"), scale=s_out),
    }
    if cfg.n_shared_experts:
        defs["shared"] = L.mlp_defs(
            cfg, d_ff=cfg.d_ff * cfg.n_shared_experts
        )
    return defs


def capacity(cfg: ModelConfig, group_len: int | None = None) -> int:
    S = group_len if group_len is not None else cfg.moe_group_size
    return max(4, math.ceil(S / cfg.n_experts * cfg.capacity_factor))


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, d) → (out, aux_loss)."""
    B, T, d = x.shape
    E, k = cfg.n_experts, cfg.top_k
    N = B * T
    S = min(cfg.moe_group_size, N)
    G = -(-N // S)
    Np = G * S
    C = capacity(cfg, S)

    x_flat = x.reshape(N, d)
    if Np != N:   # ragged tail: pad tokens (they waste a little capacity)
        x_flat = jnp.concatenate(
            [x_flat, jnp.zeros((Np - N, d), x.dtype)], axis=0)
    xg = x_flat.reshape(G, S, d)
    xg = shard(xg, "groups", None, "embed")

    logits = jnp.einsum(
        "gsd,de->gse", xg.astype(jnp.float32), p["router"].astype(jnp.float32)
    )
    probs = jax.nn.softmax(logits, axis=-1)               # (G, S, E)
    gate_w, gate_idx = jax.lax.top_k(probs, k)            # (G, S, k)
    gate_w = gate_w / jnp.maximum(
        jnp.sum(gate_w, axis=-1, keepdims=True), 1e-9)

    # Switch/GShard load-balancing aux loss over all tokens.
    me = jnp.mean(probs, axis=(0, 1))                                   # (E,)
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(gate_idx, E, dtype=jnp.float32), axis=2),
        axis=(0, 1),
    ) / k                                                               # (E,)
    aux = E * jnp.sum(me * ce)

    out = jnp.zeros_like(xg)
    for j in range(k):                    # k independent top-1 dispatches
        e_j = gate_idx[..., j]                                   # (G, S)
        w_j = gate_w[..., j].astype(xg.dtype)                    # (G, S)
        onehot_e = jax.nn.one_hot(e_j, E, dtype=jnp.float32)     # (G, S, E)
        pos = jnp.einsum(
            "gse->gs",
            jnp.cumsum(onehot_e, axis=1) * onehot_e,
        ) - 1.0                                                  # (G, S)
        keep = (pos < C).astype(jnp.float32)
        pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        dispatch = (onehot_e[..., None] * pos_oh[..., None, :]
                    * keep[..., None, None]).astype(xg.dtype)    # (G,S,E,C)
        xe = jnp.einsum("gsec,gsd->gecd", dispatch, xg)          # (G,E,C,d)
        xe = shard(xe, "groups", "expert", None, None)
        g = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"].astype(xe.dtype))
        u = jnp.einsum("gecd,edf->gecf", xe, p["w_up"].astype(xe.dtype))
        h = jax.nn.silu(g) * u
        ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"].astype(xe.dtype))
        ye = shard(ye, "groups", "expert", None, None)
        combine = dispatch * w_j[..., None, None]
        out = out + jnp.einsum("gsec,gecd->gsd", combine, ye)

    out = out.reshape(Np, d)[:N].reshape(B, T, d)
    if cfg.n_shared_experts:
        out = out + L.mlp(x, p["shared"], cfg)
    return shard(out, "batch", "seq", "embed"), aux
