"""Qwen2-VL-style backbone: decoder LM with M-RoPE over (t, h, w).

The vision frontend is a STUB per the brief: ``input_specs`` provides
precomputed patch embeddings (B, P, d_model), which are prepended to the
text embeddings. Vision positions use an (t=0, h, w) grid; text positions
continue the temporal stream after the grid.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import cast_params
from repro.models.transformer import TransformerLM, forward_prefill, forward_train


def mrope_positions(P: int, T_text: int, B: int) -> jax.Array:
    """(3, B, P+T_text) positions: vision grid then text stream."""
    side = max(int(math.sqrt(P)), 1)
    idx = jnp.arange(P)
    vis_t = jnp.zeros((P,), jnp.int32)
    vis_h = (idx // side).astype(jnp.int32)
    vis_w = (idx % side).astype(jnp.int32)
    t0 = side  # text stream starts after the grid's spatial extent
    txt = t0 + jnp.arange(T_text, dtype=jnp.int32)
    pos = jnp.stack([
        jnp.concatenate([vis_t, txt]),
        jnp.concatenate([vis_h, txt]),
        jnp.concatenate([vis_w, txt]),
    ])                                                   # (3, P+T)
    return jnp.broadcast_to(pos[:, None], (3, B, P + T_text))


class VLM(TransformerLM):
    """Reuses the dense transformer stack with multimodal input assembly."""

    def _assemble(self, params, batch):
        cfg = self.cfg
        tokens = batch["tokens"]                        # (B, T_text)
        vision = batch["vision"]                        # (B, P, d)
        B, T_text = tokens.shape
        P = vision.shape[1]
        tok_x = L.embed_tokens(tokens, params["tok"], cfg)
        x = jnp.concatenate([vision.astype(tok_x.dtype), tok_x], axis=1)
        x = shard(x, "batch", "seq", "embed")
        positions = batch.get("positions")
        if positions is None:
            positions = mrope_positions(P, T_text, B)
        return x, positions, P

    def loss(self, params, batch):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        x, positions, P = self._assemble(params, batch)
        h, aux = forward_train(params, x, positions, cfg)
        logits = L.logits_out(h[:, P:], params["tok"], cfg)
        loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
        return loss + 0.01 * aux

    def prefill(self, params, batch):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        x, positions, P = self._assemble(params, batch)
        h, cache = forward_prefill(params, x, positions, cfg)
        logits = L.logits_out(h[:, -1:], params["tok"], cfg)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos, rope_pos=None):
        # The cache slot is `pos`; the M-RoPE temporal position of text
        # token i is `side + i` (the grid occupies one temporal step and
        # `side` spatial steps). pos counts vision patches + text tokens.
        if rope_pos is None:
            P = self.cfg.vision_patches
            side = max(int(math.sqrt(max(P, 1))), 1)
            rope_pos = pos - P + side
        return super().decode_step(params, cache, tokens, pos,
                                   rope_pos=rope_pos)
