"""Shared model layers: norms, RoPE/M-RoPE, GQA attention, MLPs, embeddings.

All layers are pure functions over param dicts (declared via ParamDef).
RoPE uses the interleaved-pair convention: the head dim is viewed as
(Dh//2, 2) pairs so sharding the head dim never splits a rotation pair.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.kernels.attention.ops import decode_attention, flash_attention_jnp
from repro.models.params import ParamDef
from repro.runtime.kernel_plane import active_plane


# ------------------------------------------------------------ kernel plane
def _plane_routes(*arrays: jax.Array):
    """The active kernel-tuning plane, when these EAGER arrays can route.

    Inside a jit trace the arguments are tracers: the coordinator-managed
    handle (a python-level function-pointer swap) cannot run there, so
    traced call sites instead adopt the plane's best-known points (see
    :func:`plane_attn_chunks`) and keep the pure-jnp kernel body.
    """
    plane = active_plane()
    if plane is None:
        return None
    if any(isinstance(a, jax.core.Tracer) for a in arrays):
        return None
    return plane


def plane_attn_chunks(cfg: ModelConfig) -> tuple[int, int]:
    """Attention chunk sizes: the plane's tuned blocks, else cfg defaults.

    This is the trace-time half of kernel-granular tuning: a jitted
    step-program generated while a plane is active inherits the
    attention kernel's independently tuned ``block_q``/``block_kv``
    instead of the config's hard-coded chunk sizes (warm-started
    registries make this bite from the very first trace of a restarted
    process).
    """
    plane = active_plane()
    if plane is not None and plane.adopt_points:
        best = plane.best_point("attention")
        if best is not None:
            return (int(best.get("block_q", cfg.attn_q_chunk)),
                    int(best.get("block_kv", cfg.attn_k_chunk)))
    return cfg.attn_q_chunk, cfg.attn_k_chunk


def plane_decode_chunk(cfg: ModelConfig) -> int:
    """Flash-decoding KV chunk: the plane's tuned ``k_chunk``, else cfg's.

    Trace-time adoption for the decode path: a jitted decode step traced
    while a plane is active inherits the ``decode_attention`` kernel's
    independently tuned chunk (per cache-length bucket) instead of the
    hard-coded ``cfg.decode_k_chunk`` — suppressed, like the attention
    chunks, when a program-level tuner owns the knob ("both" mode).
    """
    plane = active_plane()
    if plane is not None and plane.adopt_points:
        best = plane.best_point("decode_attention")
        if best is not None:
            return int(best.get("k_chunk", cfg.decode_k_chunk))
    return cfg.decode_k_chunk


# ----------------------------------------------------------------- norms
def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    plane = _plane_routes(x, scale)
    if plane is not None and eps == 1e-6 and x.ndim >= 2:
        # coordinator-managed handle: the fused Pallas kernel tuned as an
        # independent unit (block_rows its own space, own strategy)
        shape = x.shape
        y = plane.call("rmsnorm", x.reshape(-1, shape[-1]), scale)
        if y is not None:
            return y.reshape(shape)
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-5) -> jax.Array:
    x32 = x.astype(jnp.float32)
    mu = jnp.mean(x32, axis=-1, keepdims=True)
    var = jnp.var(x32, axis=-1, keepdims=True)
    return ((x32 - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(x.dtype)


def norm(x: jax.Array, scale: jax.Array, kind: str) -> jax.Array:
    return rms_norm(x, scale) if kind == "rmsnorm" else layer_norm(x, scale)


# ------------------------------------------------------------------ rope
def rope_freqs(d_head: int, theta: float) -> jax.Array:
    half = d_head // 2
    return theta ** (-jnp.arange(half, dtype=jnp.float32) / half)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, T, H, Dh); positions: (B, T) int32. Interleaved pairs."""
    B, T, H, Dh = x.shape
    freqs = rope_freqs(Dh, theta)                       # (Dh/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, T, Dh/2)
    cos = jnp.cos(ang)[:, :, None, :]                   # (B, T, 1, Dh/2)
    sin = jnp.sin(ang)[:, :, None, :]
    xp = x.astype(jnp.float32).reshape(B, T, H, Dh // 2, 2)
    x1, x2 = xp[..., 0], xp[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(B, T, H, Dh).astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float,
    sections: tuple[int, ...],
) -> jax.Array:
    """Qwen2-VL multimodal RoPE. positions: (3, B, T) for (t, h, w).

    The Dh/2 frequency pairs are split into len(sections) groups; group i
    rotates by positions[i].
    """
    B, T, H, Dh = x.shape
    half = Dh // 2
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(Dh, theta)                       # (half,)
    # Select which positional stream drives each frequency pair.
    sec_id = jnp.repeat(
        jnp.arange(len(sections)), jnp.array(sections),
        total_repeat_length=half,
    )                                                   # (half,)
    pos = positions.astype(jnp.float32)[sec_id]         # (half, B, T)
    ang = jnp.einsum("fbt,f->btf", pos, freqs)          # (B, T, half)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    xp = x.astype(jnp.float32).reshape(B, T, H, half, 2)
    x1, x2 = xp[..., 0], xp[..., 1]
    out = jnp.stack([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.reshape(B, T, H, Dh).astype(x.dtype)


def sinusoidal_embedding(T: int, d: int) -> jax.Array:
    pos = jnp.arange(T, dtype=jnp.float32)[:, None]
    dim = jnp.arange(d // 2, dtype=jnp.float32)[None, :]
    ang = pos / (10000.0 ** (2 * dim / d))
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


# ------------------------------------------------------------- attention
def attention_defs(cfg: ModelConfig, cross: bool = False) -> dict:
    d, H, Hk, Dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.d_head
    defs = {
        "wq": ParamDef((d, H, Dh), ("embed", "heads", None),
                       scale=1.0 / math.sqrt(d)),
        "wk": ParamDef((d, Hk, Dh), ("embed", "kv", None),
                       scale=1.0 / math.sqrt(d)),
        "wv": ParamDef((d, Hk, Dh), ("embed", "kv", None),
                       scale=1.0 / math.sqrt(d)),
        "wo": ParamDef((H, Dh, d), ("heads", None, "embed"),
                       scale=1.0 / math.sqrt(H * Dh)),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H, Dh), ("heads", None), init="zeros")
        defs["bk"] = ParamDef((Hk, Dh), ("kv", None), init="zeros")
        defs["bv"] = ParamDef((Hk, Dh), ("kv", None), init="zeros")
    return defs


def qkv_proj(x: jax.Array, p: dict, cfg: ModelConfig):
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = shard(q, "batch", "seq", "heads", None)
    k = shard(k, "batch", "seq", "kv", None)
    v = shard(v, "batch", "seq", "kv", None)
    return q, k, v


def attn_out(o: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    out = jnp.einsum("bthk,hkd->btd", o, p["wo"].astype(o.dtype))
    return shard(out, "batch", "seq", "embed")


def self_attention(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
    causal: bool = True,
    q_offset: int = 0,
) -> jax.Array:
    """Full-sequence attention (train / prefill / encoder)."""
    q, k, v = qkv_proj(x, p, cfg)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        pos2d = positions if positions.ndim == 2 else positions[None]
        q = apply_rope(q, pos2d, cfg.rope_theta)
        k = apply_rope(k, pos2d, cfg.rope_theta)
    plane = _plane_routes(q, k, v)
    o = None
    if (plane is not None and causal and q_offset == 0
            and cfg.window is None):
        # eager call with an active plane: the flash kernel runs as an
        # independently tuned coordinator-managed unit
        o = plane.call("attention", q, k, v)
    if o is None:
        qc, kc = plane_attn_chunks(cfg)
        o = flash_attention_jnp(
            q, k, v, causal=causal, q_offset=q_offset, window=cfg.window,
            q_chunk=qc, k_chunk=kc,
            scores_f32=cfg.attn_scores_f32,
        )
    return attn_out(o, p, cfg)


def self_attention_with_cache(
    x: jax.Array,
    p: dict,
    cfg: ModelConfig,
    *,
    positions: jax.Array,
) -> tuple[jax.Array, tuple[jax.Array, jax.Array]]:
    """Prefill: returns output and the (k, v) cache to keep."""
    q, k, v = qkv_proj(x, p, cfg)
    if cfg.mrope_sections is not None:
        q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
    elif positions is not None:
        pos2d = positions if positions.ndim == 2 else positions[None]
        q = apply_rope(q, pos2d, cfg.rope_theta)
        k = apply_rope(k, pos2d, cfg.rope_theta)
    qc, kc = plane_attn_chunks(cfg)
    o = flash_attention_jnp(
        q, k, v, causal=True, window=cfg.window,
        q_chunk=qc, k_chunk=kc,
        scores_f32=cfg.attn_scores_f32,
    )
    return attn_out(o, p, cfg), (k, v)


def to_bits(x: jax.Array) -> jax.Array:
    """bf16 → u16 bit view (exact; no-op for other dtypes).

    Used around scan-collected KV caches so XLA:CPU's float normalization
    cannot rewrite the internal ys dynamic-update-slice in f32 (which would
    double the dry-run cache footprint). Free on TPU."""
    return jax.lax.bitcast_convert_type(x, jnp.uint16) \
        if x.dtype == jnp.bfloat16 else x


def from_bits(x: jax.Array, like_dtype=jnp.bfloat16) -> jax.Array:
    return jax.lax.bitcast_convert_type(x, like_dtype) \
        if x.dtype == jnp.uint16 else x


def _dus_bits(cache: jax.Array, update: jax.Array, start: tuple) -> jax.Array:
    """dynamic_update_slice through a u16 bit-view for bf16 caches.

    XLA:CPU's float-normalization otherwise rewrites the bf16 DUS in f32,
    materializing an f32 copy of the whole cache in the dry-run memory
    analysis. The bit view is exact and a no-op on TPU.
    """
    if cache.dtype == jnp.bfloat16:
        c = jax.lax.bitcast_convert_type(cache, jnp.uint16)
        u = jax.lax.bitcast_convert_type(update.astype(jnp.bfloat16), jnp.uint16)
        out = jax.lax.dynamic_update_slice(c, u, start)
        return jax.lax.bitcast_convert_type(out, jnp.bfloat16)
    return jax.lax.dynamic_update_slice(cache, update.astype(cache.dtype), start)


def decode_self_attention(
    x: jax.Array,                    # (B, 1, d)
    p: dict,
    cfg: ModelConfig,
    cache_k: jax.Array,              # (B, S, Hk, Dh)
    cache_v: jax.Array,
    pos: jax.Array,                  # scalar int32: cache write slot
    rope_pos: jax.Array | None = None,   # rotary position (defaults to pos;
                                         # differs for VLM, where vision
                                         # patches share a grid position)
):
    """One-token decode against a KV cache (in-place cache update)."""
    q, k, v = qkv_proj(x, p, cfg)
    B = x.shape[0]
    positions = jnp.full((B, 1), rope_pos if rope_pos is not None else pos,
                         jnp.int32)
    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(positions[None], (3, B, 1))
        q = apply_mrope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_mrope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    elif cfg.use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    S = cache_k.shape[1]
    if cfg.window is not None and cfg.window < S:
        slot = pos % cfg.window
        S_eff = cfg.window
    else:
        slot = pos
        S_eff = S
    cache_k = _dus_bits(cache_k, k, (0, slot, 0, 0))
    cache_v = _dus_bits(cache_v, v, (0, slot, 0, 0))
    cache_k = shard(cache_k, "batch", "kv_seq", "kv", "kv_dh")
    cache_v = shard(cache_v, "batch", "kv_seq", "kv", "kv_dh")
    length = jnp.minimum(pos + 1, S_eff)
    plane = _plane_routes(q, cache_k, cache_v)
    o = None
    if plane is not None:
        # eager call with an active plane: flash-decoding runs as an
        # independently tuned unit, keyed per cache-length bucket
        o = plane.call("decode_attention", q, cache_k, cache_v,
                       jnp.asarray(length, jnp.int32))
    if o is None:
        o = decode_attention(q, cache_k, cache_v, length=length,
                             k_chunk=plane_decode_chunk(cfg))
    return attn_out(o, p, cfg), (cache_k, cache_v)


def cross_attention_defs(cfg: ModelConfig) -> dict:
    return attention_defs(cfg)


def cross_attention(
    x: jax.Array, p: dict, cfg: ModelConfig,
    enc_k: jax.Array, enc_v: jax.Array,
) -> jax.Array:
    """Decoder cross-attention against precomputed encoder K/V."""
    q = jnp.einsum("btd,dhk->bthk", x, p["wq"].astype(x.dtype))
    if x.shape[1] == 1:
        o = decode_attention(q, enc_k, enc_v,
                             k_chunk=plane_decode_chunk(cfg))
    else:
        o = flash_attention_jnp(
            q, enc_k, enc_v, causal=False,
            q_chunk=cfg.attn_q_chunk, k_chunk=cfg.attn_k_chunk)
    return attn_out(o, p, cfg)


def encoder_kv(p: dict, cfg: ModelConfig, enc_out: jax.Array):
    k = jnp.einsum("btd,dhk->bthk", enc_out, p["wk"].astype(enc_out.dtype))
    v = jnp.einsum("btd,dhk->bthk", enc_out, p["wv"].astype(enc_out.dtype))
    return k, v


# ------------------------------------------------------------------- mlp
def mlp_defs(cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(ff)
    if cfg.act == "swiglu":
        return {
            "w_gate": ParamDef((d, ff), ("embed", "ffn"), scale=s_in),
            "w_up": ParamDef((d, ff), ("embed", "ffn"), scale=s_in),
            "w_down": ParamDef((ff, d), ("ffn", "embed"), scale=s_out),
        }
    return {
        "w_up": ParamDef((d, ff), ("embed", "ffn"), scale=s_in),
        "w_down": ParamDef((ff, d), ("ffn", "embed"), scale=s_out),
    }


def mlp(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    if cfg.act == "swiglu":
        g = jnp.einsum("btd,df->btf", x, p["w_gate"].astype(x.dtype))
        u = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    else:
        h = jnp.einsum("btd,df->btf", x, p["w_up"].astype(x.dtype))
        h = jax.nn.gelu(h) if cfg.act == "gelu" else jnp.square(jax.nn.relu(h))
    h = shard(h, "batch", "seq", "ffn")
    out = jnp.einsum("btf,fd->btd", h, p["w_down"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed")


# ------------------------------------------------------------- embeddings
def embedding_defs(cfg: ModelConfig) -> dict:
    return {
        "embed": ParamDef((cfg.vocab, cfg.d_model), ("vocab", "embed"), scale=0.02),
        "unembed": ParamDef((cfg.d_model, cfg.vocab), ("embed", "vocab"),
                            scale=1.0 / math.sqrt(cfg.d_model)),
    }


def embed_tokens(tokens: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    x = p["embed"].astype(cfg.compute_dtype)[tokens]
    return shard(x, "batch", "seq", "embed")


def logits_out(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    logits = jnp.einsum("btd,dv->btv", x, p["unembed"].astype(x.dtype))
    logits = shard(logits, "batch", "seq", "vocab")
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def cross_entropy(
    logits: jax.Array,      # (B, T, V)
    labels: jax.Array,      # (B, T) int32
    mask: jax.Array | None = None,
) -> jax.Array:
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        mask = mask.astype(jnp.float32)
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
