"""Model dispatcher: config → model instance; constituent-kernel specs."""

from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.hymba import HymbaLM
from repro.models.rwkv6 import RWKV6LM
from repro.models.transformer import TransformerLM
from repro.models.vlm import VLM
from repro.models.whisper import WhisperLM


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return TransformerLM(cfg)
    if cfg.family == "vlm":
        return VLM(cfg)
    if cfg.family == "rwkv":
        return RWKV6LM(cfg)
    if cfg.family == "hybrid":
        return HymbaLM(cfg)
    if cfg.family == "encdec":
        return WhisperLM(cfg)
    raise ValueError(f"unknown model family {cfg.family!r}")


def model_kernel_specs(
    cfg: ModelConfig, *, batch: int, seq: int, max_len: int | None = None,
) -> list[tuple[str, dict]]:
    """Constituent tunable kernels of a model's step-programs.

    The hierarchical-registration shape list: for a (batch, seq) traffic
    cell, the step-programs decompose into these catalog kernels, each
    registered as an independent coordinator-managed compilette (its own
    tuning space, strategy, registry key and cache lines). The paper's
    unit of analysis — the individual short-running kernel — keyed by
    the run-time constants the model bakes into it.

    ``max_len`` is the (pre-bucketed) KV-cache extent of a decode path:
    when given, the flash-decoding ``decode_attention`` kernel registers
    keyed per cache-length bucket (training loops pass nothing — they
    have no decode step).
    """
    dt = str(jnp.dtype(cfg.compute_dtype))
    specs: list[tuple[str, dict]] = [
        # pre-attention / pre-MLP norms run over the flattened tokens
        ("rmsnorm", {"N": batch * seq, "d": cfg.d_model, "dtype": dt}),
        # MLP up-projection: the model's hot matmul shape
        ("matmul", {"M": batch * seq, "N": cfg.d_ff, "K": cfg.d_model,
                    "dtype": dt}),
    ]
    if cfg.n_heads and cfg.d_head:
        specs.append(
            ("attention", {"B": batch, "Tq": seq, "Tkv": seq,
                           "H": cfg.n_heads, "Hk": cfg.n_kv_heads,
                           "Dh": cfg.d_head, "causal": True, "dtype": dt}))
        if max_len:
            # decode path: the KV-chunk scan over the allocated cache
            specs.append(
                ("decode_attention", {"B": batch, "S": int(max_len),
                                      "H": cfg.n_heads,
                                      "Hk": cfg.n_kv_heads,
                                      "Dh": cfg.d_head, "dtype": dt}))
    return specs
