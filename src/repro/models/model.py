"""Model dispatcher: config → model instance."""

from __future__ import annotations

from repro.configs.base import ModelConfig
from repro.models.hymba import HymbaLM
from repro.models.rwkv6 import RWKV6LM
from repro.models.transformer import TransformerLM
from repro.models.vlm import VLM
from repro.models.whisper import WhisperLM


def build_model(cfg: ModelConfig):
    if cfg.family in ("dense", "moe"):
        return TransformerLM(cfg)
    if cfg.family == "vlm":
        return VLM(cfg)
    if cfg.family == "rwkv":
        return RWKV6LM(cfg)
    if cfg.family == "hybrid":
        return HymbaLM(cfg)
    if cfg.family == "encdec":
        return WhisperLM(cfg)
    raise ValueError(f"unknown model family {cfg.family!r}")
