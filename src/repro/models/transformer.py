"""Decoder-only transformer (dense / MoE / VLM backbones).

Layers are stacked (leading L axis) and iterated with ``jax.lax.scan`` so
HLO size is O(1) in depth — essential for compiling 48–64-layer models on
the 512-device dry-run host. Per-layer remat policy is configurable.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.moe import moe_defs, moe_ffn
from repro.models.params import ParamDef, cast_params


def stack_defs(defs: dict, n: int) -> dict:
    """Prepend a stacked 'layers' axis to every ParamDef in the tree."""
    out = {}
    for name, node in defs.items():
        if isinstance(node, ParamDef):
            out[name] = ParamDef(
                (n,) + node.shape, ("layers",) + node.axes, node.init, node.scale
            )
        else:
            out[name] = stack_defs(node, n)
    return out


def layer_defs(cfg: ModelConfig) -> dict:
    defs = {
        "ln1": ParamDef((cfg.d_model,), (None,), init="ones"),
        "attn": L.attention_defs(cfg),
    }
    if not cfg.parallel_block:
        defs["ln2"] = ParamDef((cfg.d_model,), (None,), init="ones")
    defs["ffn"] = moe_defs(cfg) if cfg.family == "moe" else L.mlp_defs(cfg)
    return defs


def transformer_defs(cfg: ModelConfig) -> dict:
    return {
        "tok": L.embedding_defs(cfg),
        "layers": stack_defs(layer_defs(cfg), cfg.n_layers),
        "ln_f": ParamDef((cfg.d_model,), (None,), init="ones"),
    }


def _remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "full":
        return jax.checkpoint(fn)
    return jax.checkpoint(
        fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable
    )


def _ffn_apply(x, lp, cfg) -> tuple[jax.Array, jax.Array]:
    if cfg.family == "moe":
        return moe_ffn(x, lp["ffn"], cfg)
    return L.mlp(x, lp["ffn"], cfg), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------- forward
def forward_train(params, x, positions, cfg: ModelConfig):
    """x: (B, T, d) embedded input → (final hidden, aux loss)."""

    def body(carry, lp):
        h, aux = carry
        hn = L.norm(h, lp["ln1"], cfg.norm)
        attn = L.self_attention(hn, lp["attn"], cfg, positions=positions)
        if cfg.parallel_block:
            f, a = _ffn_apply(hn, lp, cfg)
            h = h + attn + f
        else:
            h = h + attn
            f, a = _ffn_apply(L.norm(h, lp["ln2"], cfg.norm), lp, cfg)
            h = h + f
        h = shard(h, "batch", "seq", "embed")
        return (h, aux + a), None

    (x, aux), _ = jax.lax.scan(_remat(body, cfg), (x, jnp.zeros((), jnp.float32)),
                               params["layers"])
    return L.norm(x, params["ln_f"], cfg.norm), aux


def forward_prefill(params, x, positions, cfg: ModelConfig):
    """Causal forward that also returns stacked (L, B, T, Hk, Dh) KV caches."""

    def body(carry, lp):
        h = carry
        hn = L.norm(h, lp["ln1"], cfg.norm)
        attn, (k, v) = L.self_attention_with_cache(
            hn, lp["attn"], cfg, positions=positions)
        if cfg.parallel_block:
            f, _ = _ffn_apply(hn, lp, cfg)
            h = h + attn + f
        else:
            h = h + attn
            f, _ = _ffn_apply(L.norm(h, lp["ln2"], cfg.norm), lp, cfg)
            h = h + f
        h = shard(h, "batch", "seq", "embed")
        return h, (k, v)

    x, (ks, vs) = jax.lax.scan(_remat(body, cfg), x, params["layers"])
    return L.norm(x, params["ln_f"], cfg.norm), (ks, vs)


def forward_decode(params, x, cache, pos, cfg: ModelConfig, rope_pos=None):
    """One-token decode. x: (B, 1, d); cache: (k, v) with leading L axis.

    The stacked caches ride the scan *carry* (as u16 bit views) and each
    layer updates its slice in place — one buffer end-to-end, aliased with
    the donated input cache. ys would double-buffer 2×cache bytes.
    """
    ks, vs = cache

    def body(carry, inp):
        h, ks, vs = carry
        lp, i = inp
        ck = L.from_bits(jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False))
        cv = L.from_bits(jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False))
        hn = L.norm(h, lp["ln1"], cfg.norm)
        attn, (ck, cv) = L.decode_self_attention(
            hn, lp["attn"], cfg, ck, cv, pos, rope_pos=rope_pos)
        if cfg.parallel_block:
            f, _ = _ffn_apply(hn, lp, cfg)
            h = h + attn + f
        else:
            h = h + attn
            f, _ = _ffn_apply(L.norm(h, lp["ln2"], cfg.norm), lp, cfg)
            h = h + f
        ks = jax.lax.dynamic_update_index_in_dim(ks, L.to_bits(ck), i, 0)
        vs = jax.lax.dynamic_update_index_in_dim(vs, L.to_bits(cv), i, 0)
        return (h, ks, vs), None

    (x, ks, vs), _ = jax.lax.scan(
        body, (x, L.to_bits(ks), L.to_bits(vs)),
        (params["layers"], jnp.arange(cfg.n_layers)))
    return L.norm(x, params["ln_f"], cfg.norm), (L.from_bits(ks), L.from_bits(vs))


# ------------------------------------------------------------------ model
class TransformerLM:
    """Dense/MoE decoder LM with the standard step functions."""

    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg

    # --- params ---
    def param_defs(self) -> dict:
        return transformer_defs(self.cfg)

    # --- steps ---
    def loss(self, params, batch) -> jax.Array:
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        tokens = batch["tokens"]                      # (B, T)
        B, T = tokens.shape
        x = L.embed_tokens(tokens, params["tok"], cfg)
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(T)[None], (B, T)))
        h, aux = forward_train(params, x, positions, cfg)
        logits = L.logits_out(h, params["tok"], cfg)
        loss = L.cross_entropy(logits, batch["labels"], batch.get("mask"))
        return loss + 0.01 * aux

    def prefill(self, params, batch):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = L.embed_tokens(tokens, params["tok"], cfg)
        positions = batch.get(
            "positions", jnp.broadcast_to(jnp.arange(T)[None], (B, T)))
        h, cache = forward_prefill(params, x, positions, cfg)
        logits = L.logits_out(h[:, -1:], params["tok"], cfg)
        return logits, cache

    def decode_step(self, params, cache, tokens, pos, rope_pos=None):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        x = L.embed_tokens(tokens, params["tok"], cfg)    # (B, 1, d)
        h, cache = forward_decode(params, x, cache, pos, cfg, rope_pos=rope_pos)
        logits = L.logits_out(h, params["tok"], cfg)
        return logits, cache

    def init_cache_shape(self, batch: int, max_len: int):
        cfg = self.cfg
        S = min(max_len, cfg.window) if cfg.window else max_len
        shape = (cfg.n_layers, batch, S, cfg.n_kv_heads, cfg.d_head)
        return (jax.ShapeDtypeStruct(shape, cfg.compute_dtype),) * 2

    def init_cache(self, batch: int, max_len: int):
        return tuple(
            jnp.zeros(s.shape, s.dtype) for s in self.init_cache_shape(batch, max_len)
        )
