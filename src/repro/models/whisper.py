"""Whisper-style encoder–decoder (audio backbone; conv frontend stubbed).

Per the brief, the modality frontend is a STUB: ``input_specs`` provides
precomputed frame embeddings (B, F, d). The encoder is a bidirectional
transformer over frames (sinusoidal positions); the decoder is causal with
cross-attention (learned positions), tied unembedding.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef, cast_params


def whisper_defs(cfg: ModelConfig) -> dict:
    from repro.models.transformer import stack_defs

    d = cfg.d_model
    enc_layer = {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "attn": L.attention_defs(cfg),
        "ln2": ParamDef((d,), (None,), init="ones"),
        "ffn": L.mlp_defs(cfg),
    }
    dec_layer = {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "attn": L.attention_defs(cfg),
        "ln_c": ParamDef((d,), (None,), init="ones"),
        "xattn": L.attention_defs(cfg),
        "ln2": ParamDef((d,), (None,), init="ones"),
        "ffn": L.mlp_defs(cfg),
    }
    return {
        "tok": {"embed": ParamDef((cfg.vocab, d), ("vocab", "embed"), scale=0.02)},
        "dec_pos": ParamDef((cfg.max_decode_len, d), (None, "embed"), scale=0.01),
        "enc_layers": stack_defs(enc_layer, cfg.enc_layers),
        "enc_ln_f": ParamDef((d,), (None,), init="ones"),
        "dec_layers": stack_defs(dec_layer, cfg.n_layers),
        "dec_ln_f": ParamDef((d,), (None,), init="ones"),
    }


class WhisperLM:
    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg

    def param_defs(self) -> dict:
        return whisper_defs(self.cfg)

    # ------------------------------------------------------------ encoder
    def encode(self, params, audio_embeds: jax.Array) -> jax.Array:
        cfg = self.cfg
        B, F, d = audio_embeds.shape
        x = audio_embeds.astype(cfg.compute_dtype)
        x = x + L.sinusoidal_embedding(F, d).astype(x.dtype)[None]
        x = shard(x, "batch", "seq", "embed")

        def body(h, lp):
            hn = L.norm(h, lp["ln1"], cfg.norm)
            h = h + L.self_attention(hn, lp["attn"], cfg,
                                     positions=None, causal=False)
            h = h + L.mlp(L.norm(h, lp["ln2"], cfg.norm), lp["ffn"], cfg)
            return shard(h, "batch", "seq", "embed"), None

        x, _ = jax.lax.scan(jax.checkpoint(body), x, params["enc_layers"])
        return L.norm(x, params["enc_ln_f"], cfg.norm)

    # ------------------------------------------------------------ decoder
    def _embed_dec(self, params, tokens, pos0=0):
        cfg = self.cfg
        B, T = tokens.shape
        x = params["tok"]["embed"].astype(cfg.compute_dtype)[tokens]
        pe = jax.lax.dynamic_slice_in_dim(
            params["dec_pos"].astype(x.dtype), pos0, T, axis=0)
        return shard(x + pe[None], "batch", "seq", "embed")

    def _logits(self, params, h):
        logits = jnp.einsum(
            "btd,vd->btv", h, params["tok"]["embed"].astype(h.dtype))
        return shard(logits, "batch", "seq", "vocab")

    def _decode_stack(self, params, x, enc_out, mode, cache=None, pos=None):
        cfg = self.cfg

        if mode == "decode":
            ks, vs, xks, xvs = cache

            def body(carry, inp):
                h, ks, vs = carry
                lp, i, xk, xv = inp
                ck = L.from_bits(
                    jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False))
                cv = L.from_bits(
                    jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False))
                hn = L.norm(h, lp["ln1"], cfg.norm)
                attn, (ck, cv) = L.decode_self_attention(
                    hn, lp["attn"], cfg, ck, cv, pos)
                h = h + attn
                hc = L.norm(h, lp["ln_c"], cfg.norm)
                h = h + L.cross_attention(hc, lp["xattn"], cfg, xk, xv)
                h = h + L.mlp(L.norm(h, lp["ln2"], cfg.norm), lp["ffn"], cfg)
                ks = jax.lax.dynamic_update_index_in_dim(
                    ks, L.to_bits(ck), i, 0)
                vs = jax.lax.dynamic_update_index_in_dim(
                    vs, L.to_bits(cv), i, 0)
                return (h, ks, vs), None

            (h, ks, vs), _ = jax.lax.scan(
                body, (x, L.to_bits(ks), L.to_bits(vs)),
                (params["dec_layers"], jnp.arange(cfg.n_layers), xks, xvs))
            caches = (L.from_bits(ks), L.from_bits(vs), xks, xvs)
            return L.norm(h, params["dec_ln_f"], cfg.norm), caches

        def body(h, lp):
            hn = L.norm(h, lp["ln1"], cfg.norm)
            if mode == "prefill":
                attn, (ck, cv) = L.self_attention_with_cache(
                    hn, lp["attn"], cfg, positions=None)
            else:
                attn = L.self_attention(hn, lp["attn"], cfg,
                                        positions=None, causal=True)
            h = h + attn
            hc = L.norm(h, lp["ln_c"], cfg.norm)
            xk, xv = L.encoder_kv(lp["xattn"], cfg, enc_out)
            h = h + L.cross_attention(hc, lp["xattn"], cfg, xk, xv)
            h = h + L.mlp(L.norm(h, lp["ln2"], cfg.norm), lp["ffn"], cfg)
            h = shard(h, "batch", "seq", "embed")
            if mode == "train":
                return h, None
            return h, (ck, cv, xk, xv)

        h, caches = jax.lax.scan(
            jax.checkpoint(body), x, params["dec_layers"])
        return L.norm(h, params["dec_ln_f"], cfg.norm), caches

    # -------------------------------------------------------------- steps
    def loss(self, params, batch):
        params = cast_params(params, self.cfg.compute_dtype)
        enc_out = self.encode(params, batch["audio_embeds"])
        x = self._embed_dec(params, batch["tokens"])
        h, _ = self._decode_stack(params, x, enc_out, "train")
        logits = self._logits(params, h)
        return L.cross_entropy(logits, batch["labels"], batch.get("mask"))

    def prefill(self, params, batch):
        params = cast_params(params, self.cfg.compute_dtype)
        enc_out = self.encode(params, batch["audio_embeds"])
        x = self._embed_dec(params, batch["tokens"])
        h, caches = self._decode_stack(params, x, enc_out, "prefill")
        logits = self._logits(params, h[:, -1:])
        return logits, caches

    def decode_step(self, params, cache, tokens, pos):
        params = cast_params(params, self.cfg.compute_dtype)
        x = self._embed_dec(params, tokens, pos0=pos)
        h, cache = self._decode_stack(
            params, x, None, "decode", cache=cache, pos=pos)
        logits = self._logits(params, h)
        return logits, cache

    def init_cache_shape(self, batch: int, max_len: int):
        cfg = self.cfg
        kv = (cfg.n_layers, batch, max_len, cfg.n_kv_heads, cfg.d_head)
        xkv = (cfg.n_layers, batch, cfg.enc_frames, cfg.n_kv_heads, cfg.d_head)
        return (
            jax.ShapeDtypeStruct(kv, cfg.compute_dtype),
            jax.ShapeDtypeStruct(kv, cfg.compute_dtype),
            jax.ShapeDtypeStruct(xkv, cfg.compute_dtype),
            jax.ShapeDtypeStruct(xkv, cfg.compute_dtype),
        )

    def init_cache(self, batch: int, max_len: int):
        return tuple(jnp.zeros(s.shape, s.dtype)
                     for s in self.init_cache_shape(batch, max_len))
