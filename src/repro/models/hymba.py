"""Hymba: hybrid layers with *parallel* attention + Mamba heads.

Each layer normalizes once, feeds the same input to a GQA attention branch
(sliding-window) and a selective-SSM branch in parallel, combines them with
learned per-channel output gains, then applies a standard FFN block. The
SSM state makes decode O(1) in sequence length — the hybrid runs the
long_500k cell with a bounded (window) KV cache plus SSM state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef, cast_params
from repro.models.ssm import ssm_branch, ssm_defs


def hymba_layer_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    return {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "ln2": ParamDef((d,), (None,), init="ones"),
        "attn": L.attention_defs(cfg),
        "ssm": ssm_defs(cfg),
        "beta_attn": ParamDef((d,), (None,), init="ones", scale=0.5),
        "beta_ssm": ParamDef((d,), (None,), init="ones", scale=0.5),
        "ffn": L.mlp_defs(cfg),
    }


def hymba_defs(cfg: ModelConfig) -> dict:
    from repro.models.transformer import stack_defs

    return {
        "tok": L.embedding_defs(cfg),
        "layers": stack_defs(hymba_layer_defs(cfg), cfg.n_layers),
        "ln_f": ParamDef((cfg.d_model,), (None,), init="ones"),
    }


class HymbaLM:
    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg

    def param_defs(self) -> dict:
        return hymba_defs(self.cfg)

    # ------------------------------------------------------------ forward
    def _layer(self, h, lp, *, positions, mode, cache=None, pos=None):
        cfg = self.cfg
        hn = L.norm(h, lp["ln1"], cfg.norm)
        if mode == "decode":
            ck, cv, conv_buf, hs = cache
            attn, (ck, cv) = L.decode_self_attention(
                hn, lp["attn"], cfg, ck, cv, pos)
            s, (conv_buf, hs) = ssm_branch(
                hn, lp["ssm"], cfg, state=(conv_buf, hs))
            new_cache = (ck, cv, conv_buf, hs)
        elif mode == "prefill":
            attn, (k, v) = L.self_attention_with_cache(
                hn, lp["attn"], cfg, positions=positions)
            s, (conv_buf, hs) = ssm_branch(hn, lp["ssm"], cfg)
            new_cache = (k, v, conv_buf, hs)
        else:
            attn = L.self_attention(hn, lp["attn"], cfg, positions=positions)
            s, _ = ssm_branch(hn, lp["ssm"], cfg)
            new_cache = None
        mix = attn * lp["beta_attn"].astype(h.dtype) \
            + s * lp["beta_ssm"].astype(h.dtype)
        h = h + 0.5 * mix
        h = h + L.mlp(L.norm(h, lp["ln2"], cfg.norm), lp["ffn"], cfg)
        return shard(h, "batch", "seq", "embed"), new_cache

    def loss(self, params, batch):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = L.embed_tokens(tokens, params["tok"], cfg)
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

        def body(h, lp):
            h, _ = self._layer(h, lp, positions=positions, mode="train")
            return h, None

        h, _ = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
        h = L.norm(h, params["ln_f"], cfg.norm)
        logits = L.logits_out(h, params["tok"], cfg)
        return L.cross_entropy(logits, batch["labels"], batch.get("mask"))

    def prefill(self, params, batch):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        tokens = batch["tokens"]
        B, T = tokens.shape
        x = L.embed_tokens(tokens, params["tok"], cfg)
        positions = jnp.broadcast_to(jnp.arange(T)[None], (B, T))

        def body(h, lp):
            h, cache = self._layer(h, lp, positions=positions, mode="prefill")
            return h, cache

        h, caches = jax.lax.scan(jax.checkpoint(body), x, params["layers"])
        h = L.norm(h, params["ln_f"], cfg.norm)
        logits = L.logits_out(h[:, -1:], params["tok"], cfg)
        # prefill cache may exceed the decode window: keep the tail slice
        k, v, conv_buf, hs = caches
        W = self._cache_window(T)
        if k.shape[2] > W:
            k, v = k[:, :, -W:], v[:, :, -W:]
        return logits, (k, v, conv_buf, hs)

    def decode_step(self, params, cache, tokens, pos):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        x = L.embed_tokens(tokens, params["tok"], cfg)
        ks, vs, convs, hss = cache

        def body(carry, inp):
            h, ks, vs = carry
            lp, i, conv_buf, hs = inp
            ck = L.from_bits(jax.lax.dynamic_index_in_dim(ks, i, 0, keepdims=False))
            cv = L.from_bits(jax.lax.dynamic_index_in_dim(vs, i, 0, keepdims=False))
            h, (ck, cv, conv_buf, hs) = self._layer(
                h, lp, positions=None, mode="decode",
                cache=(ck, cv, conv_buf, hs), pos=pos)
            ks = jax.lax.dynamic_update_index_in_dim(ks, L.to_bits(ck), i, 0)
            vs = jax.lax.dynamic_update_index_in_dim(vs, L.to_bits(cv), i, 0)
            return (h, ks, vs), (conv_buf, hs)

        (h, ks, vs), (convs, hss) = jax.lax.scan(
            body, (x, L.to_bits(ks), L.to_bits(vs)),
            (params["layers"], jnp.arange(cfg.n_layers), convs, hss))
        h = L.norm(h, params["ln_f"], cfg.norm)
        logits = L.logits_out(h, params["tok"], cfg)
        return logits, (L.from_bits(ks), L.from_bits(vs), convs, hss)

    # ------------------------------------------------------------- caches
    def _cache_window(self, max_len: int) -> int:
        cfg = self.cfg
        return min(max_len, cfg.window) if cfg.window else max_len

    def init_cache_shape(self, batch: int, max_len: int):
        cfg = self.cfg
        W = self._cache_window(max_len)
        Lr = cfg.n_layers
        di = cfg.d_model
        return (
            jax.ShapeDtypeStruct((Lr, batch, W, cfg.n_kv_heads, cfg.d_head),
                                 cfg.compute_dtype),
            jax.ShapeDtypeStruct((Lr, batch, W, cfg.n_kv_heads, cfg.d_head),
                                 cfg.compute_dtype),
            jax.ShapeDtypeStruct((Lr, batch, cfg.ssm_conv - 1, di),
                                 cfg.compute_dtype),
            jax.ShapeDtypeStruct((Lr, batch, di, cfg.ssm_state), jnp.float32),
        )

    def init_cache(self, batch: int, max_len: int):
        return tuple(jnp.zeros(s.shape, s.dtype)
                     for s in self.init_cache_shape(batch, max_len))
