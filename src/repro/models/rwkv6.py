"""RWKV-6 "Finch" (attention-free, data-dependent per-channel decay).

Recurrence (per head, head size C):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    y_t = r_t^T S_{t-1} + (r_t · (u ⊙ k_t)) v_t^T
with w_t = exp(-exp(ŵ_t)) produced by a data-dependent LoRA (the defining
RWKV-6 feature), plus token-shift ddlerp mixing and a squared-ReLU
channel-mix FFN.

Training uses a chunk-parallel form (GLA-style): within a chunk the decays
are folded into q̃ = r ⊙ exp(cl_{t-1}) and k̃ = k ⊙ exp(−cl_t), clamped in
log space to ±30 for fp32 safety; chunks are scanned with remat. The chunk
length is an auto-tunable (the paper's unroll-factor analogue for this
architecture — see DESIGN.md §6). Decode is O(1): one state update.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models import layers as L
from repro.models.params import ParamDef, cast_params

LORA_MIX = 32
LORA_DECAY = 64
CLAMP = 30.0


def rwkv_layer_defs(cfg: ModelConfig) -> dict:
    d, ff = cfg.d_model, cfg.d_ff
    C = cfg.rwkv_head_size
    H = d // C
    s = 1.0 / math.sqrt(d)
    return {
        "ln1": ParamDef((d,), (None,), init="ones"),
        "ln2": ParamDef((d,), (None,), init="ones"),
        "tm": {  # time-mix block
            "mu_x": ParamDef((d,), (None,), init="zeros"),
            "mu": ParamDef((5, d), (None, None), init="zeros"),
            "lora_a": ParamDef((d, 5 * LORA_MIX), ("embed", None), scale=s),
            "lora_b": ParamDef((5, LORA_MIX, d), (None, None, "embed"),
                               scale=0.01),
            "wr": ParamDef((d, d), ("embed", "heads"), scale=s),
            "wk": ParamDef((d, d), ("embed", "heads"), scale=s),
            "wv": ParamDef((d, d), ("embed", "heads"), scale=s),
            "wg": ParamDef((d, d), ("embed", "heads"), scale=s),
            "wo": ParamDef((d, d), ("heads", "embed"), scale=s),
            "w_base": ParamDef((d,), (None,), init="zeros"),
            "w_lora_a": ParamDef((d, LORA_DECAY), ("embed", None), scale=s),
            "w_lora_b": ParamDef((LORA_DECAY, d), (None, "embed"), scale=0.01),
            "u": ParamDef((H, C), ("heads", None), init="zeros"),
            "ln_x": ParamDef((d,), (None,), init="ones"),
        },
        "cm": {  # channel-mix block
            "mu_k": ParamDef((d,), (None,), init="zeros"),
            "mu_r": ParamDef((d,), (None,), init="zeros"),
            "wk": ParamDef((d, ff), ("embed", "ffn"), scale=s),
            "wv": ParamDef((ff, d), ("ffn", "embed"), scale=1.0 / math.sqrt(ff)),
            "wr": ParamDef((d, d), ("embed", "heads"), scale=s),
        },
    }


def rwkv_defs(cfg: ModelConfig) -> dict:
    from repro.models.transformer import stack_defs

    return {
        "tok": L.embedding_defs(cfg),
        "ln_in": ParamDef((cfg.d_model,), (None,), init="ones"),
        "layers": stack_defs(rwkv_layer_defs(cfg), cfg.n_layers),
        "ln_f": ParamDef((cfg.d_model,), (None,), init="ones"),
    }


def _token_shift(x: jax.Array, prev: jax.Array | None = None) -> jax.Array:
    """shift(x)[t] = x[t-1]; position 0 takes `prev` (decode) or zeros."""
    if x.shape[1] == 1 and prev is not None:
        return prev[:, None, :]
    shifted = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    if prev is not None:
        shifted = shifted.at[:, 0].set(prev)
    return shifted


def _ddlerp(x, xx, p):
    """RWKV-6 data-dependent token-shift mixing → 5 mixed inputs."""
    s = jnp.tanh(jnp.einsum(
        "btd,dk->btk", x + xx * p["mu_x"].astype(x.dtype),
        p["lora_a"].astype(x.dtype)))
    s = s.reshape(*s.shape[:-1], 5, LORA_MIX)
    dyn = jnp.einsum("btnk,nkd->btnd", s, p["lora_b"].astype(x.dtype))
    mix = p["mu"].astype(x.dtype)[None, None] + dyn        # (B,T,5,d)
    return tuple(x + xx * mix[:, :, i] for i in range(5))


def wkv_chunked(r, k, v, logw, u, S0, chunk: int):
    """Chunk-parallel WKV. r/k/v/logw: (B, T, H, C); u: (H, C);
    S0: (B, H, C, C). Returns (y (B,T,H,C), S_final)."""
    B, T, H, C = r.shape
    Lc = min(chunk, T)
    n = -(-T // Lc)
    Tp = n * Lc
    if Tp != T:
        # identity padding: logw=0 (decay 1), r/k/v=0 → state frozen past T
        pad = lambda x, v=0.0: jnp.concatenate(
            [x, jnp.full((B, Tp - T, H, C), v, x.dtype)], axis=1)
        r, k, v_, logw = pad(r), pad(k), pad(v), pad(logw)
        v = v_
    rr = r.reshape(B, n, Lc, H, C).transpose(1, 0, 2, 3, 4)
    kk = k.reshape(B, n, Lc, H, C).transpose(1, 0, 2, 3, 4)
    vv = v.reshape(B, n, Lc, H, C).transpose(1, 0, 2, 3, 4)
    ww = logw.reshape(B, n, Lc, H, C).transpose(1, 0, 2, 3, 4)

    mask = jnp.tril(jnp.ones((Lc, Lc), jnp.float32), k=-1)  # strict lower

    def body(S, inp):
        rc, kc, vc, lw = inp                     # (B, Lc, H, C)
        cl = jnp.cumsum(lw, axis=1)              # inclusive
        cl_prev = cl - lw                        # exclusive
        qt = rc * jnp.exp(jnp.maximum(cl_prev, -CLAMP))
        kt = kc * jnp.exp(jnp.minimum(-cl, CLAMP))
        att = jnp.einsum("blhc,bmhc->bhlm", qt, kt) * mask[None, None]
        y = jnp.einsum("bhlm,bmhc->blhc", att, vc)
        bonus = jnp.einsum("blhc,hc,blhc->blh", rc, u, kc)
        y = y + bonus[..., None] * vc
        y = y + jnp.einsum("blhc,bhcd->blhd", qt, S)
        cl_end = cl[:, -1:]                      # (B,1,H,C)
        k2 = kc * jnp.exp(jnp.maximum(cl_end - cl, -CLAMP))
        S = jnp.exp(jnp.maximum(cl_end[:, 0], -CLAMP))[..., None] * S \
            + jnp.einsum("blhc,blhd->bhcd", k2, vc)
        return S, y

    S, ys = jax.lax.scan(jax.checkpoint(body), S0, (rr, kk, vv, ww))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, Tp, H, C)[:, :T]
    return y, S


def time_mix(x, p, cfg: ModelConfig, *, S0=None, x_prev=None):
    """Returns (out, S_final, last_x). x: (B, T, d)."""
    B, T, d = x.shape
    C = cfg.rwkv_head_size
    H = d // C
    xx = _token_shift(x, x_prev) - x
    xw, xk, xv, xr, xg = _ddlerp(x, xx, p)

    r = jnp.einsum("btd,de->bte", xr, p["wr"].astype(x.dtype))
    k = jnp.einsum("btd,de->bte", xk, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,de->bte", xv, p["wv"].astype(x.dtype))
    g = jnp.einsum("btd,de->bte", xg, p["wg"].astype(x.dtype))
    w_raw = p["w_base"].astype(jnp.float32) + jnp.einsum(
        "btd,dk,ke->bte", xw.astype(jnp.float32),
        p["w_lora_a"].astype(jnp.float32), p["w_lora_b"].astype(jnp.float32))
    logw = -jnp.exp(jnp.clip(w_raw, -8.0, 4.0))           # log decay < 0

    rs = r.reshape(B, T, H, C).astype(jnp.float32)
    ks = k.reshape(B, T, H, C).astype(jnp.float32)
    vs = v.reshape(B, T, H, C).astype(jnp.float32)
    ws = logw.reshape(B, T, H, C)
    if S0 is None:
        S0 = jnp.zeros((B, H, C, C), jnp.float32)
    y, S = wkv_chunked(rs, ks, vs, ws, p["u"].astype(jnp.float32), S0,
                       cfg.scan_chunk)
    y = y.reshape(B, T, d).astype(x.dtype)
    # per-head group norm (scale-only), then output gating
    yh = y.reshape(B, T, H, C)
    yh32 = yh.astype(jnp.float32)
    mu = jnp.mean(yh32, axis=-1, keepdims=True)
    var = jnp.var(yh32, axis=-1, keepdims=True)
    yh = ((yh32 - mu) * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype)
    y = (yh.reshape(B, T, d) * p["ln_x"].astype(x.dtype))
    y = y * jax.nn.silu(g)
    out = jnp.einsum("btd,de->bte", y, p["wo"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed"), S, x[:, -1]


def channel_mix(x, p, cfg: ModelConfig, *, x_prev=None):
    xx = _token_shift(x, x_prev) - x
    xk = x + xx * p["mu_k"].astype(x.dtype)
    xr = x + xx * p["mu_r"].astype(x.dtype)
    k = jnp.einsum("btd,df->btf", xk, p["wk"].astype(x.dtype))
    k = jnp.square(jax.nn.relu(k))
    k = shard(k, "batch", "seq", "ffn")
    kv = jnp.einsum("btf,fd->btd", k, p["wv"].astype(x.dtype))
    r = jax.nn.sigmoid(jnp.einsum("btd,de->bte", xr, p["wr"].astype(x.dtype)))
    return shard(r * kv, "batch", "seq", "embed"), x[:, -1]


class RWKV6LM:
    def __init__(self, cfg: ModelConfig) -> None:
        self.cfg = cfg
        assert cfg.d_model % cfg.rwkv_head_size == 0

    def param_defs(self) -> dict:
        return rwkv_defs(self.cfg)

    def _forward(self, params, x, state=None):
        """state: (S, xa, xc) stacked over layers, or None (train)."""
        cfg = self.cfg
        decode = state is not None

        def body(carry, inp):
            h = carry
            if decode:
                lp, S0, xa, xc = inp
            else:
                lp, S0, xa, xc = inp, None, None, None
            a, S, last_a = time_mix(
                L.norm(h, lp["ln1"], cfg.norm), lp["tm"], cfg,
                S0=S0, x_prev=xa)
            h = h + a
            c, last_c = channel_mix(
                L.norm(h, lp["ln2"], cfg.norm), lp["cm"], cfg, x_prev=xc)
            h = h + c
            h = shard(h, "batch", "seq", "embed")
            return h, (S, last_a, last_c)

        if decode:
            xs = (params["layers"],) + tuple(state)
        else:
            xs = params["layers"]
        fn = body if decode else jax.checkpoint(body)
        h, new_state = jax.lax.scan(fn, x, xs)
        return L.norm(h, params["ln_f"], cfg.norm), new_state

    def loss(self, params, batch):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        tokens = batch["tokens"]
        x = L.embed_tokens(tokens, params["tok"], cfg)
        x = L.norm(x, params["ln_in"], cfg.norm)
        h, _ = self._forward(params, x)
        logits = L.logits_out(h, params["tok"], cfg)
        return L.cross_entropy(logits, batch["labels"], batch.get("mask"))

    def prefill(self, params, batch):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        tokens = batch["tokens"]
        x = L.embed_tokens(tokens, params["tok"], cfg)
        x = L.norm(x, params["ln_in"], cfg.norm)
        h, state = self._forward(params, x)
        logits = L.logits_out(h[:, -1:], params["tok"], cfg)
        return logits, state

    def decode_step(self, params, state, tokens, pos):
        cfg = self.cfg
        params = cast_params(params, cfg.compute_dtype)
        x = L.embed_tokens(tokens, params["tok"], cfg)
        x = L.norm(x, params["ln_in"], cfg.norm)
        h, state = self._forward(params, x, state=state)
        logits = L.logits_out(h, params["tok"], cfg)
        return logits, state

    def init_cache_shape(self, batch: int, max_len: int):
        cfg = self.cfg
        C = cfg.rwkv_head_size
        H = cfg.d_model // C
        Lr = cfg.n_layers
        return (
            jax.ShapeDtypeStruct((Lr, batch, H, C, C), jnp.float32),
            jax.ShapeDtypeStruct((Lr, batch, cfg.d_model), cfg.compute_dtype),
            jax.ShapeDtypeStruct((Lr, batch, cfg.d_model), cfg.compute_dtype),
        )

    def init_cache(self, batch: int, max_len: int):
        return tuple(jnp.zeros(s.shape, s.dtype)
                     for s in self.init_cache_shape(batch, max_len))
