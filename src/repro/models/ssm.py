"""Selective SSM (Mamba-style) branch used by the Hymba hybrid.

Diagonal data-dependent SSM:
    h_t = exp(Δ_t ⊙ A) ⊙ h_{t-1} + (Δ_t x_t) ⊗ B_t
    y_t = C_t · h_t + D ⊙ x_t
with a short causal depthwise conv + SiLU in front and a SiLU output gate.

Training uses a chunk-parallel associative scan (chunk length =
``scan_chunk``, auto-tunable); decode keeps (conv buffer, h) state — O(1)
per token, which is what makes the hybrid eligible for long_500k.
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.params import ParamDef


def ssm_defs(cfg: ModelConfig) -> dict:
    d = cfg.d_model
    di = cfg.d_model          # inner width = d_model (parallel-branch hybrid)
    st = cfg.ssm_state
    ck = cfg.ssm_conv
    s = 1.0 / math.sqrt(d)
    return {
        "w_in": ParamDef((d, 2 * di), ("embed", "heads"), scale=s),
        "conv_w": ParamDef((ck, di), (None, "heads"), scale=0.5),
        "w_b": ParamDef((di, st), ("heads", None), scale=1.0 / math.sqrt(di)),
        "w_c": ParamDef((di, st), ("heads", None), scale=1.0 / math.sqrt(di)),
        "w_dt": ParamDef((di, 1), ("heads", None), scale=1.0 / math.sqrt(di)),
        "dt_bias": ParamDef((di,), ("heads",), init="zeros"),
        "a_log": ParamDef((di, st), ("heads", None), init="zeros"),
        "d_skip": ParamDef((di,), ("heads",), init="ones"),
        "w_out": ParamDef((di, d), ("heads", "embed"), scale=1.0 / math.sqrt(di)),
    }


def _causal_conv(x: jax.Array, w: jax.Array, prev: jax.Array | None):
    """Depthwise causal conv. x: (B, T, di); w: (ck, di);
    prev: (B, ck-1, di) decode buffer or None (zero history)."""
    ck = w.shape[0]
    if prev is None:
        prev = jnp.zeros((x.shape[0], ck - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([prev, x], axis=1)          # (B, T+ck-1, di)
    out = sum(
        xp[:, i:i + x.shape[1]] * w[i][None, None] for i in range(ck)
    )
    new_prev = xp[:, -(ck - 1):] if ck > 1 else prev
    return out, new_prev


def ssm_scan_chunked(a, b, h0, chunk: int):
    """Associative scan of h_t = a_t h_{t-1} + b_t in chunks.

    a, b: (B, T, di, st); h0: (B, di, st). Returns (h_all, h_final)."""
    B, T, di, st = a.shape
    Lc = min(chunk, T)
    n = -(-T // Lc)
    Tp = n * Lc
    if Tp != T:
        # identity padding: a=1 (no decay), b=0 → state frozen past T
        a = jnp.concatenate(
            [a, jnp.ones((B, Tp - T, di, st), a.dtype)], axis=1)
        b = jnp.concatenate(
            [b, jnp.zeros((B, Tp - T, di, st), b.dtype)], axis=1)

    ar = a.reshape(B, n, Lc, di, st).transpose(1, 0, 2, 3, 4)
    br = b.reshape(B, n, Lc, di, st).transpose(1, 0, 2, 3, 4)

    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    def body(h, inp):
        ac, bc = inp                                  # (B, Lc, di, st)
        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_all = a_cum * h[:, None] + b_cum
        return h_all[:, -1], h_all

    h, ys = jax.lax.scan(jax.checkpoint(body), h0, (ar, br))
    h_all = ys.transpose(1, 0, 2, 3, 4).reshape(B, Tp, di, st)[:, :T]
    return h_all, h


def ssm_branch(x, p, cfg: ModelConfig, *, state=None):
    """x: (B, T, d). state: (conv_buf, h) or None.
    Returns (y, new_state)."""
    B, T, d = x.shape
    st = cfg.ssm_state
    conv_buf, h0 = state if state is not None else (None, None)

    xz = jnp.einsum("btd,de->bte", x, p["w_in"].astype(x.dtype))
    xi, z = jnp.split(xz, 2, axis=-1)                # (B, T, di) each
    xi = shard(xi, "batch", "seq", "heads")
    xi, conv_buf = _causal_conv(xi, p["conv_w"].astype(x.dtype), conv_buf)
    xi = jax.nn.silu(xi)

    xf = xi.astype(jnp.float32)
    bt = jnp.einsum("btd,ds->bts", xf, p["w_b"].astype(jnp.float32))
    ct = jnp.einsum("btd,ds->bts", xf, p["w_c"].astype(jnp.float32))
    # rank-1 data-dependent step size (scalar per token + per-channel bias)
    dt_raw = jnp.einsum("btd,do->bto", xf, p["w_dt"].astype(jnp.float32))
    dt = jax.nn.softplus(
        dt_raw + p["dt_bias"].astype(jnp.float32)[None, None]
    )                                                 # (B, T, di)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))      # (di, st), negative
    a = jnp.exp(dt[..., None] * A[None, None])        # (B, T, di, st)
    b = (dt * xf)[..., None] * bt[:, :, None, :]      # (B, T, di, st)

    if h0 is None:
        h0 = jnp.zeros((B, xi.shape[-1], st), jnp.float32)
    if T == 1:
        h_last = a[:, 0] * h0 + b[:, 0]
        h_all = h_last[:, None]
    else:
        h_all, h_last = ssm_scan_chunked(a, b, h0, cfg.scan_chunk)

    y = jnp.einsum("btds,bts->btd", h_all, ct)        # (B, T, di)
    y = y + p["d_skip"].astype(jnp.float32)[None, None] * xf
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = jnp.einsum("btd,de->bte", y, p["w_out"].astype(x.dtype))
    return shard(out, "batch", "seq", "embed"), (conv_buf, h_last)
