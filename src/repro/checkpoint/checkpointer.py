"""Fault-tolerant checkpointing with elastic (re-mesh) restore.

Design points for 1000+-node posture:

  * **Logical layout**: checkpoints store the *unsharded logical* arrays
    (np arrays in an .npz per pytree leaf path) plus a JSON manifest —
    restore works on any mesh shape (elastic scaling / topology change).
  * **Atomicity**: write to ``<dir>/tmp.<uuid>``, fsync, then
    ``os.replace`` into ``step_<N>`` and update the ``LATEST`` pointer
    atomically — a preempted writer never corrupts the latest checkpoint.
  * **Retention**: keep the newest ``keep`` checkpoints.
  * The auto-tuner registry (tuned kernel configs) is saved alongside, so
    a restarted job resumes with tuned kernels instead of re-exploring.

On a real multi-host cluster each host would write its data-parallel shard
(Orbax-style); the logical-layout path here is the single-process analogue
that keeps restore mesh-independent, which is what the elastic tests
verify.
"""

from __future__ import annotations

import json
import os
import shutil
import uuid
from typing import Any

import jax
import numpy as np


def _flatten(tree: Any, path=()) -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], path + (str(k),)))
    elif isinstance(tree, (tuple, list)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, path + (str(i),)))
    else:
        out["/".join(path)] = tree
    return out


def _unflatten_into(skeleton: Any, flat: dict[str, Any], path=()) -> Any:
    if isinstance(skeleton, dict):
        return {k: _unflatten_into(v, flat, path + (str(k),))
                for k, v in skeleton.items()}
    if isinstance(skeleton, tuple):
        return tuple(_unflatten_into(v, flat, path + (str(i),))
                     for i, v in enumerate(skeleton))
    if isinstance(skeleton, list):
        return [_unflatten_into(v, flat, path + (str(i),))
                for i, v in enumerate(skeleton)]
    return flat["/".join(path)]


class Checkpointer:
    def __init__(self, directory: str, keep: int = 3) -> None:
        self.dir = directory
        self.keep = keep
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- saving
    def save(self, step: int, state: Any, extra: dict | None = None) -> str:
        flat = _flatten(state)
        tmp = os.path.join(self.dir, f"tmp.{uuid.uuid4().hex}")
        os.makedirs(tmp)
        arrays = {k: np.asarray(v) for k, v in flat.items()}
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        manifest = {
            "step": step,
            "keys": sorted(arrays),
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
            f.flush()
            os.fsync(f.fileno())
        final = os.path.join(self.dir, f"step_{step:010d}")
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)                       # atomic publish
        self._update_latest(step)
        self._gc()
        return final

    def _update_latest(self, step: int) -> None:
        tmp = os.path.join(self.dir, f".latest.{uuid.uuid4().hex}")
        with open(tmp, "w") as f:
            f.write(str(step))
        os.replace(tmp, os.path.join(self.dir, "LATEST"))

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:010d}"),
                          ignore_errors=True)

    # ------------------------------------------------------------ loading
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_"):
                try:
                    out.append(int(name.split("_")[1]))
                except ValueError:
                    pass
        return sorted(out)

    def latest_step(self) -> int | None:
        p = os.path.join(self.dir, "LATEST")
        if not os.path.exists(p):
            steps = self.all_steps()
            return steps[-1] if steps else None
        with open(p) as f:
            return int(f.read().strip())

    def restore(self, skeleton: Any, step: int | None = None,
                shardings: Any = None) -> tuple[Any, dict]:
        """Restore into ``skeleton`` structure; optionally device_put with
        per-leaf shardings (elastic re-mesh restore)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = os.path.join(self.dir, f"step_{step:010d}")
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data = np.load(os.path.join(d, "arrays.npz"))
        flat = {k: data[k] for k in data.files}
        state = _unflatten_into(skeleton, flat)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, manifest
