"""Deterministic traffic-replay harness: the fleet-scale fig7 analogue.

The paper's workload study (fig7) varies one kernel's workload on one
platform; a serving fleet varies *everything at once* — arrival bursts,
prompt/cache-length mixes, traffic ramps, phase changes, and several
architectures sharing one process. This module synthesizes those
workloads as **seeded, scripted traces** and re-serves them through a
:class:`repro.api.TuningSession` on the :class:`~repro.core.VirtualClock`
with the virtual cost-model kernel backend, so every run is exact clock
arithmetic: two replays with the same seed produce byte-identical
metrics on any host, with zero sleeps.

The moving parts:

  * **arrival processes** — :func:`poisson_arrivals` (steady),
    :func:`bursty_arrivals` (on/off modulated), :func:`ramp_arrivals`
    (linear rate ramp via thinning), :func:`phase_arrivals`
    (piecewise-constant rate phases);
  * **length mixes** — :func:`fixed_mix`, :func:`choice_mix`,
    :func:`longtail_mix` (clipped lognormal, the long-tail prompt/cache
    distribution), :func:`phase_mix` (mid-trace workload change);
  * **traces** — :func:`make_trace` scripts one tenant's requests from a
    :class:`Scenario`; :func:`merge_traces` interleaves several tenants
    into one multi-tenant trace;
  * **the engine** — :func:`replay` advances the session's virtual clock
    to each arrival, serves the request through the tenant's registered
    kernel handles (each call advances the clock by the active variant's
    cost-model score and feeds ``observe_latency`` through the managed
    handle), credits scripted non-kernel work via ``observe_busy``, and
    paces tuning with ``maybe_pump`` — then reports per-tenant
    p50/p99/speedup and session-level overhead/time-to-best/cache-hit
    metrics.

Request latency includes queueing: a burst (or a tuning evaluation)
pushes the clock past later arrivals, so the overhead envelope is
directly visible in the tail quantiles.
"""

from __future__ import annotations

import dataclasses
import json
import math
import random
from typing import Any, Callable, Mapping, Sequence

from repro.core.evaluator import VirtualClockEvaluator
from repro.core.profiles import TPU_V5E, DeviceProfile
from repro.runtime.lifecycle import TunerState, pow2_bucket

__all__ = [
    "Request",
    "Scenario",
    "Trace",
    "bursty_arrivals",
    "choice_mix",
    "fault_injection_hook",
    "fault_scenarios",
    "fixed_mix",
    "fleet_scenarios",
    "longtail_mix",
    "make_trace",
    "merge_traces",
    "phase_arrivals",
    "phase_mix",
    "poisson_arrivals",
    "ramp_arrivals",
    "reference_request_cost_s",
    "replay",
    "replay_scenario",
    "replay_session",
    "replay_tuning_defaults",
]

#: default simulated compile cost per generated variant (seconds) — the
#: same constant the kernel-plane tier-1 tests use
GEN_COST_S = 0.002

#: device label for replay sessions: a fixed fingerprint keeps registry
#: keys (and the emitted JSON) byte-identical across hosts
REPLAY_DEVICE = "fleet:v"


# ========================================================= arrival processes
# Uniform signature: (rng, rate_hz, duration_s, **kwargs) -> sorted times.
def poisson_arrivals(rng: random.Random, rate_hz: float,
                     duration_s: float) -> list[float]:
    """Homogeneous Poisson arrivals: exponential inter-arrival gaps."""
    out: list[float] = []
    t = 0.0
    while True:
        t += rng.expovariate(rate_hz)
        if t >= duration_s:
            return out
        out.append(t)


def bursty_arrivals(rng: random.Random, rate_hz: float, duration_s: float,
                    *, burst_factor: float = 6.0,
                    burst_frac: float = 0.25,
                    cycle_frac: float = 0.125) -> list[float]:
    """On/off modulated Poisson: lulls punctuated by dense bursts.

    The trace alternates lull/burst windows (``cycle_frac`` of the trace
    each full cycle, ``burst_frac`` of a cycle bursting); rates are
    scaled so the *average* rate stays ``rate_hz`` — burst windows run
    ``burst_factor`` times hotter than lulls.
    """
    cycle = max(duration_s * cycle_frac, 1e-9)
    burst_len = cycle * burst_frac
    lull_len = cycle - burst_len
    # solve lull_rate from the average-rate constraint
    lull_rate = rate_hz * cycle / (lull_len + burst_factor * burst_len)
    burst_rate = burst_factor * lull_rate
    out: list[float] = []
    t0 = 0.0
    bursting = False
    while t0 < duration_s:
        win = burst_len if bursting else lull_len
        rate = burst_rate if bursting else lull_rate
        end = min(t0 + win, duration_s)
        t = t0
        while True:
            t += rng.expovariate(rate)
            if t >= end:
                break
            out.append(t)
        t0 += win
        bursting = not bursting
    return out


def ramp_arrivals(rng: random.Random, rate_hz: float, duration_s: float,
                  *, start_frac: float = 0.25,
                  end_frac: float = 1.75) -> list[float]:
    """Linearly ramping rate (thinning a peak-rate Poisson stream).

    The instantaneous rate ramps ``start_frac*rate_hz`` →
    ``end_frac*rate_hz`` across the trace (mean ``~rate_hz`` for the
    default symmetric fracs).
    """
    peak = rate_hz * max(start_frac, end_frac)
    out: list[float] = []
    t = 0.0
    while True:
        t += rng.expovariate(peak)
        if t >= duration_s:
            return out
        frac = start_frac + (end_frac - start_frac) * (t / duration_s)
        if rng.random() < frac * rate_hz / peak:
            out.append(t)


def phase_arrivals(rng: random.Random, rate_hz: float, duration_s: float,
                   *, phases: Sequence[float] = (1.5, 0.25, 1.25)
                   ) -> list[float]:
    """Piecewise-constant rate phases (abrupt traffic regime changes).

    ``phases`` are per-phase rate multipliers over equal-length windows.
    """
    out: list[float] = []
    phase_len = duration_s / len(phases)
    for i, mult in enumerate(phases):
        t = i * phase_len
        end = min((i + 1) * phase_len, duration_s)
        rate = max(mult * rate_hz, 1e-12)
        while True:
            t += rng.expovariate(rate)
            if t >= end:
                break
            out.append(t)
    return out


# ================================================================ length mixes
# A mix draws one integer length from (rng, phase) where phase ∈ [0, 1)
# is the request's position in the trace — so mixes can themselves shift
# mid-trace (phase_mix).
Mix = Callable[[random.Random, float], int]


def fixed_mix(value: int) -> Mix:
    """Every request gets the same length."""
    return lambda rng, phase: int(value)


def choice_mix(options: Sequence[int],
               weights: Sequence[float] | None = None) -> Mix:
    """Weighted categorical mix (e.g. a bimodal short/long split)."""
    opts = [int(o) for o in options]
    w = list(weights) if weights is not None else None

    def draw(rng: random.Random, phase: float) -> int:
        return rng.choices(opts, weights=w, k=1)[0]

    return draw


def longtail_mix(lo: int, hi: int, *, sigma: float = 1.0) -> Mix:
    """Clipped lognormal around ``lo``: most requests short, a heavy
    tail out to ``hi`` — the long-tail prompt/cache-length shape."""
    mu = math.log(max(lo, 1))

    def draw(rng: random.Random, phase: float) -> int:
        v = int(round(rng.lognormvariate(mu, sigma)))
        return max(lo, min(hi, v))

    return draw


def phase_mix(before: Mix, after: Mix, *, switch_at: float = 0.5) -> Mix:
    """Workload change mid-trace: ``before`` then ``after`` the switch."""
    def draw(rng: random.Random, phase: float) -> int:
        return before(rng, phase) if phase < switch_at else after(rng, phase)

    return draw


# ============================================================ scenario / trace
@dataclasses.dataclass(frozen=True)
class Request:
    """One scripted request of a trace (all times in virtual seconds)."""

    t_arrival_s: float
    tenant: str            # model-config name (the REGISTRY key)
    prompt_len: int        # prefill extent (tokens)
    decode_steps: int      # decode calls against the KV-cache kernel
    host_cost_s: float = 0.0   # scripted non-kernel work (observe_busy)


@dataclasses.dataclass(frozen=True)
class Trace:
    """A deterministic, seeded request script (sorted by arrival)."""

    name: str
    seed: int
    duration_s: float
    tenants: tuple[str, ...]
    requests: tuple[Request, ...]


@dataclasses.dataclass(frozen=True)
class Scenario:
    """A traffic shape, independent of any concrete model config.

    ``utilization`` is the target offered load (mean request service
    time x arrival rate); drivers turn it into a per-config rate via
    :func:`reference_request_cost_s`, so a 35B and a tiny encoder see
    the *same relative pressure*. ``target_requests`` sizes the trace
    (expected arrivals), which keeps virtual durations config-adaptive.
    """

    name: str
    arrival: Callable[..., list[float]]
    prompt_mix: Mix
    decode_mix: Mix
    utilization: float = 0.4
    target_requests: int = 320
    host_cost_frac: float = 0.0   # scripted host work per request, as a
    #                               fraction of the reference request cost
    arrival_kwargs: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)
    # Failure injection (seeded, per tuning point — see
    # :func:`fault_injection_hook`): ``compile_fail_rate`` makes drawn
    # points raise at generation time, ``wrong_output_rate`` makes them
    # fail the variant gate's scripted oracle, ``tail_regression_rate``
    # makes them measure fast but serve ``tail_factor`` x slower (the
    # canary's rollback trigger). Empty = clean scenario.
    faults: Mapping[str, Any] = dataclasses.field(default_factory=dict)


def make_trace(scenario: Scenario, tenant: str, rate_hz: float,
               seed: int, *, host_cost_s: float = 0.0) -> Trace:
    """Script one tenant's requests for ``scenario`` at ``rate_hz``.

    Seeding is by *string* (sha512-based), so the trace is identical
    across processes and machines — never ``hash()``-randomized.
    """
    if rate_hz <= 0:
        raise ValueError(f"rate_hz must be positive, got {rate_hz}")
    rng = random.Random(f"{seed}:{scenario.name}:{tenant}")
    duration_s = scenario.target_requests / rate_hz
    times = scenario.arrival(rng, rate_hz, duration_s,
                             **dict(scenario.arrival_kwargs))
    requests = []
    for t in times:
        phase = t / duration_s
        requests.append(Request(
            t_arrival_s=t,
            tenant=tenant,
            prompt_len=max(1, int(scenario.prompt_mix(rng, phase))),
            decode_steps=max(0, int(scenario.decode_mix(rng, phase))),
            host_cost_s=float(host_cost_s),
        ))
    return Trace(name=f"{scenario.name}:{tenant}", seed=seed,
                 duration_s=duration_s, tenants=(tenant,),
                 requests=tuple(requests))


def merge_traces(name: str, traces: Sequence[Trace]) -> Trace:
    """Interleave per-tenant traces into one multi-tenant trace."""
    if not traces:
        raise ValueError("merge_traces needs at least one trace")
    requests = sorted(
        (r for tr in traces for r in tr.requests),
        key=lambda r: (r.t_arrival_s, r.tenant))
    tenants = tuple(t for tr in traces for t in tr.tenants)
    return Trace(name=name, seed=traces[0].seed,
                 duration_s=max(tr.duration_s for tr in traces),
                 tenants=tenants, requests=tuple(requests))


def fleet_scenarios(target_requests: int = 320) -> list[Scenario]:
    """The standing scenario set: one per traffic shape the paper's
    fig7 claim must survive (steady, bursty, ramp, phase change)."""
    longtail = longtail_mix(128, 2048, sigma=0.8)
    return [
        Scenario(name="steady_poisson", arrival=poisson_arrivals,
                 prompt_mix=fixed_mix(512), decode_mix=fixed_mix(4),
                 utilization=0.4, target_requests=target_requests),
        Scenario(name="bursty_longtail", arrival=bursty_arrivals,
                 prompt_mix=longtail, decode_mix=choice_mix(
                     (2, 4, 16), weights=(0.6, 0.3, 0.1)),
                 utilization=0.35, target_requests=target_requests),
        Scenario(name="ramp_up", arrival=ramp_arrivals,
                 prompt_mix=longtail, decode_mix=fixed_mix(4),
                 utilization=0.35, target_requests=target_requests,
                 host_cost_frac=0.05),
        Scenario(name="phase_change", arrival=phase_arrivals,
                 prompt_mix=phase_mix(fixed_mix(256), fixed_mix(1024)),
                 decode_mix=phase_mix(fixed_mix(8), fixed_mix(2)),
                 utilization=0.4, target_requests=target_requests),
    ]


# ============================================================ fault injection
def _canon_point(point: Mapping[str, Any]) -> str:
    return json.dumps(dict(point), sort_keys=True, separators=(",", ":"))


def _fault_draw(seed: int, kind: str, kernel: str,
                point: Mapping[str, Any]) -> float:
    """Deterministic uniform draw per (seed, fault kind, kernel, point).

    String-seeded like the traces, so the same points fault on every
    host and the replay report stays byte-identical per seed.
    """
    key = f"fault:{seed}:{kind}:{kernel}:{_canon_point(point)}"
    return random.Random(key).random()


def _safe_base_point(space: Any) -> Mapping[str, Any]:
    """The point the auto-tuner's reference variant is generated from.

    Mirrors ``SearchStrategy.__init__``: the space default, falling back
    to the first valid point when the default is a hole. Faults must
    never hit it — a process that cannot build its reference variant
    has no incumbent to roll back to.
    """
    base = space.default_point()
    if not space.is_valid(base):
        fallback = next(iter(space.iter_valid()), None)
        if fallback is not None:
            base = fallback
    return base


def _point_faulted(seed: int, kind: str, comp: Any,
                   point: Mapping[str, Any], rate: float) -> bool:
    if rate <= 0.0:
        return False
    if _canon_point(point) == _canon_point(_safe_base_point(comp.space)):
        return False
    return _fault_draw(seed, kind, comp.name, point) < rate


def fault_injection_hook(faults: Mapping[str, Any], seed: int,
                         clock: Any) -> Callable[[Any], None]:
    """Compilette hook installing seeded faults (for ``compilette_hook``).

    Three deterministic failure modes, drawn independently per (kernel,
    tuning point) and never hitting the reference base point:

    * ``compile_fail_rate`` — generation raises (the compile-farm /
      harvest failure path: billed, quarantined, hole reported);
    * ``wrong_output_rate`` — the variant gate's scripted oracle
      (``comp.gate_script``) rejects the point (the virtual analogue of
      a miscompiled variant producing wrong numerics);
    * ``tail_regression_rate`` — the generated virtual kernel *lies*:
      it measures at ``tail_lie`` x its honest cost (so the explorer
      adopts it) but every production call advances the clock by
      ``tail_factor`` x the honest cost — exactly the
      fast-in-microbenchmark, slow-in-production variant the canary
      state machine exists to roll back.
    """
    compile_fail = float(faults.get("compile_fail_rate", 0.0))
    wrong_output = float(faults.get("wrong_output_rate", 0.0))
    tail_rate = float(faults.get("tail_regression_rate", 0.0))
    tail_factor = float(faults.get("tail_factor", 4.0))
    tail_lie = float(faults.get("tail_lie", 0.25))

    def hook(comp: Any) -> None:
        if wrong_output > 0.0:
            comp.gate_script = lambda point, _c=comp: not _point_faulted(
                seed, "wrong", _c, point, wrong_output)
        if compile_fail <= 0.0 and tail_rate <= 0.0:
            return
        inner = comp._generate

        def generate(point: Mapping[str, Any], **sp: Any):
            if _point_faulted(seed, "compile", comp, point, compile_fail):
                raise RuntimeError(
                    f"injected compile failure: {comp.name} {dict(point)}")
            fn = inner(dict(point), **sp)
            if not _point_faulted(seed, "tail", comp, point, tail_rate):
                return fn
            honest = getattr(fn, "score_s", None)
            if honest is None:
                return fn        # real backend: nothing to lie about
            extra = honest * max(tail_factor - 1.0, 0.0)

            def lying(*args: Any) -> Any:
                clock.advance(extra)      # serves slow...
                return fn(*args)

            lying.score_s = honest * tail_lie   # ...measures fast
            lying.tag = getattr(fn, "tag", None)
            return lying

        comp._generate = generate

    return hook


def fault_scenarios(target_requests: int = 320) -> list[Scenario]:
    """Failure-injection scenario set for the trusted-swaps gates.

    One scenario per injected failure mode; drivers run these with
    ``gate_mode="canary"`` and assert zero wrong-output calls served,
    at least one gate rejection / rollback, and bounded canary exposure
    (see ``benchmarks/scenario_fleet.py``).
    """
    longtail = longtail_mix(128, 2048, sigma=0.8)
    return [
        # compile-failure holes under burst pressure: billed + quarantined
        # while the serving hot path stays alive
        Scenario(name="faulty_compiles_burst", arrival=bursty_arrivals,
                 prompt_mix=longtail, decode_mix=choice_mix(
                     (2, 4, 16), weights=(0.6, 0.3, 0.1)),
                 utilization=0.35, target_requests=target_requests,
                 faults={"compile_fail_rate": 0.25}),
        # wrong-output variants mid-trace: the gate must reject every one
        # before it serves a single production call
        Scenario(name="wrong_output_variant", arrival=poisson_arrivals,
                 prompt_mix=fixed_mix(512), decode_mix=fixed_mix(4),
                 utilization=0.4, target_requests=target_requests,
                 faults={"wrong_output_rate": 0.3}),
        # measures-fast-serves-slow variants: the canary detects the tail
        # regression and rolls back to the incumbent automatically
        Scenario(name="tail_regression", arrival=poisson_arrivals,
                 prompt_mix=fixed_mix(512), decode_mix=fixed_mix(4),
                 utilization=0.4, target_requests=target_requests,
                 faults={"tail_regression_rate": 0.25, "tail_factor": 4.0,
                         "tail_lie": 0.25}),
    ]


# =========================================================== reference probe
def reference_request_cost_s(
        cfg: Any, scenario: Scenario, *,
        profile: DeviceProfile = TPU_V5E, batch: int = 1) -> float:
    """Cost-model estimate of one reference request (seconds).

    Deterministic probe at the scenario's median shapes: drivers divide
    ``scenario.utilization`` by this to get a per-config arrival rate,
    normalizing offered load across wildly different architectures.
    """
    from repro.kernels.catalog import get_catalog
    from repro.models.model import model_kernel_specs

    rng = random.Random(f"probe:{scenario.name}:{cfg.name}")
    prompts = sorted(scenario.prompt_mix(rng, 0.5) for _ in range(33))
    decodes = sorted(scenario.decode_mix(rng, 0.5) for _ in range(33))
    prompt, decode = prompts[16], decodes[16]
    seq_b = pow2_bucket(max(prompt, 1))
    max_b = pow2_bucket(prompt + decode) if decode else None
    catalog = get_catalog()
    total = 0.0
    for name, spec in model_kernel_specs(
            cfg, batch=batch, seq=seq_b, max_len=max_b):
        comp = catalog.compilette(name, spec)
        if comp.cost_model is None:
            continue
        point = next(iter(comp.space.iter_valid()), None)
        if point is None:
            continue
        mult = decode if name == "decode_attention" else 1
        total += comp.simulate(point, profile) * mult
    if total <= 0.0:
        raise ValueError(
            f"config {cfg.name!r} has no tunable kernel with a cost "
            f"model at scenario {scenario.name!r} shapes")
    return total


# ================================================================= the engine
def _quantile(sorted_vals: Sequence[float], q: float) -> float:
    """Nearest-rank quantile on a pre-sorted list (exact arithmetic)."""
    if not sorted_vals:
        return 0.0
    i = max(0, min(len(sorted_vals) - 1,
                   math.ceil(q * len(sorted_vals)) - 1))
    return sorted_vals[i]


def _snap_unit(ratio: float, tol: float = 1e-9) -> float:
    """Snap a ratio within ``tol`` of 1.0 to exactly 1.0."""
    return 1.0 if abs(ratio - 1.0) < tol else ratio


def replay(session: Any, trace: Trace,
           configs: Mapping[str, Any] | None = None,
           *, batch: int = 1) -> dict[str, Any]:
    """Re-serve a scripted trace through ``session``, deterministically.

    The session must run on an advanceable clock (``VirtualClock``):
    idle gaps, kernel calls, scripted host work and tuning evaluations
    all move the same simulated timeline, so latency quantiles, the
    overhead fraction and time-to-best come out as exact arithmetic.

    ``configs`` maps tenant name → ``ModelConfig``; by default the names
    resolve through ``repro.configs.get_config``. Kernel handles are
    registered lazily per (tenant, seq-bucket, cache-bucket) cell via
    ``session.attach_kernels`` — the cold-start registration (including
    its reference measurement) lands in that request's latency, exactly
    like first-traffic in a serving process.
    """
    clock = session.coordinator.clock
    if not hasattr(clock, "advance"):
        raise TypeError(
            "replay() needs a session on an advanceable VirtualClock "
            "(TuningSession(..., clock=VirtualClock())); refusing to "
            "fake wall time")
    if configs is None:
        from repro.configs import get_config
        configs = {t: get_config(t) for t in trace.tenants}

    lifecycle = session.coordinator.lifecycle
    t0 = clock()
    # (tenant, seq_bucket, cache_bucket) -> (prefill handles, decode handles)
    cells: dict[tuple, tuple[list, list]] = {}

    def handles_for(req: Request) -> tuple[list, list]:
        from repro.models.model import model_kernel_specs

        cfg = configs[req.tenant]
        seq_b = lifecycle.bucket_length(max(int(req.prompt_len), 1))
        cache = req.prompt_len + req.decode_steps
        max_b = (lifecycle.bucket_length(max(int(cache), 1))
                 if req.decode_steps else None)
        cell = (req.tenant, seq_b, max_b)
        got = cells.get(cell)
        if got is not None and all(
                h.state is not TunerState.RETIRED
                for part in got for _, h in part):
            return got
        plane = session.attach_kernels(
            cfg, batch=batch, seq=seq_b, max_len=max_b)
        prefill: list = []
        decode: list = []
        for name, spec in model_kernel_specs(
                cfg, batch=batch, seq=seq_b, max_len=max_b):
            h = plane.register_spec(name, spec, require=False)
            if h is None:
                continue   # untunable at this spec: served untuned
            (decode if name == "decode_attention" else prefill).append(
                (name, h))
        cells[cell] = (prefill, decode)
        return cells[cell]

    latencies: dict[str, list[float]] = {t: [] for t in trace.tenants}
    ref_s: dict[str, float] = {t: 0.0 for t in trace.tenants}
    busy_s: dict[str, float] = {t: 0.0 for t in trace.tenants}
    host_total_s = 0.0
    last_swap_s: float | None = None
    # fault-injection bookkeeping (installed by replay_scenario): counts
    # production calls served by a variant the scenario scripted to be
    # wrong-output — the trusted-swaps gate requires this stays ZERO
    fault_seed, faults = getattr(session, "_replay_faults", (0, {}))
    wrong_rate = float(faults.get("wrong_output_rate", 0.0))
    served_wrong_calls = 0

    def timed_call(handle: Any, tenant: str) -> None:
        nonlocal served_wrong_calls
        c0 = clock()
        handle(0)
        busy_s[tenant] += clock() - c0
        ref_s[tenant] += handle.tuner.reference_score_s
        if wrong_rate > 0.0:
            served = handle.tuner.last_served_point
            if served is not None and _point_faulted(
                    fault_seed, "wrong", handle.tuner.compilette,
                    served, wrong_rate):
                served_wrong_calls += 1

    for req in trace.requests:
        arrival = t0 + req.t_arrival_s
        now = clock()
        if arrival > now:
            clock.advance(arrival - now)        # idle until the arrival
        prefill, decode = handles_for(req)      # cold cells register here
        for _, h in prefill:
            timed_call(h, req.tenant)
        for _ in range(req.decode_steps):
            for _, h in decode:
                timed_call(h, req.tenant)
        if req.host_cost_s > 0.0:
            clock.advance(req.host_cost_s)      # scripted non-kernel work
            session.observe_busy(req.host_cost_s)
            host_total_s += req.host_cost_s
        latencies[req.tenant].append(clock() - arrival)
        if session.maybe_pump():                # True: this slot swapped
            last_swap_s = clock() - t0

    stats = session.stats()
    cache = stats["generation_cache"]
    tuning_spent = stats["tuning_spent_s"]
    init_spent = stats["init_spent_s"]
    busy_total = stats["busy_s"]
    ref_total = sum(ref_s.values()) + host_total_s
    all_in_denominator = busy_total + tuning_spent + init_spent
    per_tenant: dict[str, dict[str, Any]] = {}
    for tenant in trace.tenants:
        lat = sorted(latencies[tenant])
        per_tenant[tenant] = {
            "n_requests": len(lat),
            "p50_s": _quantile(lat, 0.50),
            "p99_s": _quantile(lat, 0.99),
            "mean_s": sum(lat) / len(lat) if lat else 0.0,
            "ref_s": ref_s[tenant],
            "busy_s": busy_s[tenant],
            # active variants only ever swap to strictly faster ones, so
            # this is >= 1.0 by construction — the CI gate checks it
            # (snapped: never-swapped handles accumulate ref_s and
            # busy_s in different orders, drifting ~1 ulp below 1.0)
            "speedup_vs_ref": _snap_unit(
                ref_s[tenant] / busy_s[tenant]
                if busy_s[tenant] > 0 else 1.0),
            "n_handles": len({
                id(h)
                for (t, _, _), parts in cells.items() if t == tenant
                for part in parts for _, h in part}),
        }
    return {
        "trace": {
            "name": trace.name,
            "seed": trace.seed,
            "n_requests": len(trace.requests),
            "duration_s": trace.duration_s,
            "tenants": list(trace.tenants),
        },
        "per_tenant": per_tenant,
        "tuning": {
            "tuning_spent_s": tuning_spent,
            "gen_spent_s": stats["gen_spent_s"],
            "gen_stall_s": stats["gen_stall_s"],
            "eval_spent_s": stats["eval_spent_s"],
            "init_spent_s": init_spent,
            "busy_s": busy_total,
            "gained_s": stats["gained_s"],
            "swaps": stats["swaps"],
            "regenerations": stats["regenerations"],
            # tuning work as a share of total productive runtime — the
            # paper's 0.2–4.2 % envelope, fleet-checked (the reference
            # measurement is reported separately as init_spent_s: the
            # reference variant must be built to serve at all)
            "overhead_pct": (
                100.0 * tuning_spent / (busy_total + tuning_spent)
                if busy_total + tuning_spent > 0 else 0.0),
            "cache_hit_rate": cache["hit_rate"],
            "cache_hits": cache["hits"],
            "cache_misses": cache["misses"],
            "time_to_best_s": last_swap_s,
            # every overhead charged, init included: < 1.0 means this
            # trace was too short for tuning to pay for itself (fig7's
            # crossover), > 1.0 means net win all-in
            "speedup_all_in": (ref_total / all_in_denominator
                               if all_in_denominator > 0 else 1.0),
            # trusted swaps: oracle-gate + canary counters (all zero in
            # gate_mode="off") and the fault-injection correctness gate
            "gate_mode": stats["gate_mode"],
            "gate_spent_s": stats["gate_spent_s"],
            "gate_checks": stats["gate_checks"],
            "gate_failures": stats["gate_failures"],
            "canary_calls": stats["canary_calls"],
            "canary_promotions": stats["canary_promotions"],
            "rollbacks": stats["rollbacks"],
            "quarantined": stats["quarantined"],
            "served_wrong_calls": served_wrong_calls,
        },
    }


# ========================================================== session builders
def replay_tuning_defaults() -> "Any":
    """Serving-grade session config for replay runs: strict busy-time
    budget (4 % cap keeps the reported overhead under the 5 % gate with
    margin), pow2 bucketing, no idle eviction (traces are short), tight
    pump cadence, async generation."""
    from repro.api import TuningConfig

    return TuningConfig(
        max_overhead=0.04, invest=0.0, budget_from="busy",
        charge_init=False, seq_buckets=True, idle_evict_s=None,
        pump_every=2, async_generation=True, prefetch=1,
        kernel_tuning="kernel", cache_entries=4096)


def replay_session(clock: Any, *, config: Any | None = None,
                   profile: DeviceProfile = TPU_V5E,
                   gen_cost_s: float = GEN_COST_S,
                   device: str = REPLAY_DEVICE,
                   registry: Any | None = None,
                   registry_backend: Any | None = None,
                   compilette_hook: Callable[[Any], None] | None = None,
                   ) -> "Any":
    """A ``TuningSession`` on the virtual cost-model kernel backend."""
    from repro.api import TuningSession

    return TuningSession(
        config if config is not None else replay_tuning_defaults(),
        clock=clock, device=device, registry=registry,
        registry_backend=registry_backend,
        virtual=(clock, profile), gen_cost_s=gen_cost_s,
        evaluator_factory=lambda comp: VirtualClockEvaluator(clock),
        compilette_hook=compilette_hook)


def replay_scenario(scenario: Scenario, configs: Mapping[str, Any],
                    *, seed: int = 0, batch: int = 1,
                    profile: DeviceProfile = TPU_V5E,
                    gen_cost_s: float | None = None,
                    config: Any | None = None) -> dict[str, Any]:
    """One scenario end to end: fresh clock + session, per-config rates
    from the reference probe, multi-tenant merge when ``configs`` has
    several entries, replay, close. Returns the :func:`replay` report.

    ``gen_cost_s=None`` scales the simulated compile cost to half the
    *cheapest* tenant's reference request (capped at :data:`GEN_COST_S`):
    the paper's compilettes generate machine code in time proportional
    to kernel size, so a tiny encoder must not pay a 35B model's
    compile bill — and the overhead envelope stays comparable across
    the fleet.
    """
    from repro.core.evaluator import VirtualClock

    n_tenants = len(configs)
    if n_tenants == 0:
        raise ValueError("replay_scenario needs at least one config")
    ref_costs = {
        name: reference_request_cost_s(
            configs[name], scenario, profile=profile, batch=batch)
        for name in sorted(configs)}
    if gen_cost_s is None:
        gen_cost_s = min(GEN_COST_S,
                         max(1e-6, 0.5 * min(ref_costs.values())))
    traces = []
    for name, ref_cost in ref_costs.items():
        rate_hz = scenario.utilization / n_tenants / ref_cost
        traces.append(make_trace(
            scenario, name, rate_hz, seed,
            host_cost_s=scenario.host_cost_frac * ref_cost))
    trace = (traces[0] if n_tenants == 1
             else merge_traces(scenario.name, traces))
    clock = VirtualClock()
    hook = (fault_injection_hook(scenario.faults, seed, clock)
            if scenario.faults else None)
    session = replay_session(clock, config=config, profile=profile,
                             gen_cost_s=gen_cost_s, compilette_hook=hook)
    # replay() reads this back to count wrong-output calls served (the
    # same deterministic draws the hook's scripted gate uses)
    session._replay_faults = (seed, dict(scenario.faults))
    try:
        return session.replay(trace, dict(configs), batch=batch)
    finally:
        session.close()
