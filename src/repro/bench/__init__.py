"""Deterministic workload benchmarking layer (traffic replay).

``repro.bench.replay`` synthesizes seeded, virtual-clock traffic traces
(Poisson/bursty arrivals, long-tail prompt and cache-length mixes, ramp
and phase-change patterns, multi-tenant interleaving) and re-serves them
through a :class:`repro.api.TuningSession` — the repo's fleet-scale
analogue of the paper's fig7 workload study.
"""

from repro.bench.replay import (
    Request,
    Scenario,
    Trace,
    bursty_arrivals,
    choice_mix,
    fixed_mix,
    fleet_scenarios,
    longtail_mix,
    make_trace,
    merge_traces,
    phase_arrivals,
    phase_mix,
    poisson_arrivals,
    ramp_arrivals,
    reference_request_cost_s,
    replay,
    replay_scenario,
    replay_session,
    replay_tuning_defaults,
)

__all__ = [
    "Request",
    "Scenario",
    "Trace",
    "bursty_arrivals",
    "choice_mix",
    "fixed_mix",
    "fleet_scenarios",
    "longtail_mix",
    "make_trace",
    "merge_traces",
    "phase_arrivals",
    "phase_mix",
    "poisson_arrivals",
    "ramp_arrivals",
    "reference_request_cost_s",
    "replay",
    "replay_scenario",
    "replay_session",
    "replay_tuning_defaults",
]
