"""Fused RMSNorm Pallas TPU kernel (row-tiled, fp32 statistics).

Tuning point: block_rows (coldUF analogue — rows per program instance),
lookahead (pld analogue, cost-model only). The feature dim stays whole per
program (the reduction axis must be resident).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._pallas_compat import CompilerParams

Point = dict[str, Any]


def _rms_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)           # (rows, d)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps) * w_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def rmsnorm_pallas(
    x: jax.Array,          # (N, d) — callers flatten (B, T, d)
    w: jax.Array,          # (d,)
    point: Point,
    *,
    eps: float = 1e-6,
    interpret: bool = True,
) -> jax.Array:
    N, d = x.shape
    rows = min(point.get("block_rows", 128), N)
    grid = (pl.cdiv(N, rows),)
    return pl.pallas_call(
        functools.partial(_rms_kernel, eps=eps),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows, d), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rows, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((N, d), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel",)),
        interpret=interpret,
    )(x, w.reshape(1, d))
