"""RMSNorm kernel: wrapper + compilette + cost model (memory-bound op)."""

from __future__ import annotations

import math
from typing import Any

import jax

import jax.numpy as jnp

from repro.core.compilette import Compilette
from repro.core.profiles import TPU_V5E, DeviceProfile
from repro.core.tuning_space import Param, Point, TuningSpace
from repro.kernels.catalog import KernelDef, example_fill
from repro.kernels.rmsnorm.ref import rmsnorm_ref
from repro.kernels.rmsnorm.rmsnorm import rmsnorm_pallas

DEFAULT_POINT: Point = {"block_rows": 128, "lookahead": 1}


def make_space(N: int, d: int, *, vmem_kb: int = TPU_V5E.vmem_kb) -> TuningSpace:
    params = (
        Param("block_rows", (8, 32, 128, 512), phase=1, switch_rank=0),
        Param("lookahead", (0, 1, 2), phase=2),
    )

    def validator(p: Point) -> bool:
        rows = min(p["block_rows"], N)
        return 2 * rows * d * 4 <= vmem_kb * 1024

    def no_leftover(p: Point) -> float:
        rows = min(p["block_rows"], N)
        n = math.ceil(N / rows)
        return (n * rows) / N - 1.0

    return TuningSpace(params=params, validator=validator,
                       no_leftover=no_leftover)


def rmsnorm_cost_model(point: Point, spec: dict[str, Any],
                       profile: DeviceProfile) -> float:
    N, d = spec["N"], spec["d"]
    rows = min(point["block_rows"], N)
    if 2 * rows * d * 4 > profile.vmem_kb * 1024:
        return float("inf")
    flops = 4.0 * N * d
    compute_s = flops / (profile.vpu_gflops * 1e9)
    mem_s = 2.0 * N * d * 4.0 / (profile.hbm_gbps * 1e9)
    steps = math.ceil(N / rows)
    overhead_s = steps * profile.grid_step_overhead_ns * 1e-9
    t = profile.exec_time_s(compute_s, mem_s, overhead_s)
    if not profile.overlap and point["lookahead"] > 0:
        t -= min(compute_s, mem_s) * min(0.35 * point["lookahead"], 0.7)
    return t


def make_rmsnorm_compilette(N: int, d: int, *, interpret: bool = True,
                            vmem_kb: int = TPU_V5E.vmem_kb) -> Compilette:
    space = make_space(N, d, vmem_kb=vmem_kb)

    def generate(point: Point, **spec: Any):
        @jax.jit
        def fn(x, w):
            return rmsnorm_pallas(x, w, point, interpret=interpret)
        return fn

    def cost_model(point, spec, profile):
        full = {"N": N, "d": d}
        full.update(spec)
        return rmsnorm_cost_model(point, full, profile)

    return Compilette("rmsnorm", space, generate, cost_model=cost_model)


# ---------------------------------------------------------- kernel catalog
def _catalog_generate(point: Point, spec: dict[str, Any], *,
                      interpret: bool = True):
    @jax.jit
    def fn(x, w):
        return rmsnorm_pallas(x, w, point, interpret=interpret)
    return fn


def _extract_spec(x, w, **overrides: Any) -> dict[str, Any]:
    N, d = x.shape
    return {"N": int(N), "d": int(d), "dtype": str(x.dtype), **overrides}


def _abstract_args(spec: dict[str, Any]) -> tuple:
    dt = spec.get("dtype", "float32")
    return (jax.ShapeDtypeStruct((spec["N"], spec["d"]), dt),
            jax.ShapeDtypeStruct((spec["d"],), dt))


def _example_args(spec: dict[str, Any]) -> tuple:
    dt = spec.get("dtype", "float32")
    return (example_fill((spec["N"], spec["d"]), dt),
            example_fill((spec["d"],), dt))


KERNEL = KernelDef(
    name="rmsnorm",
    make_space=lambda spec: make_space(spec["N"], spec["d"]),
    generate=_catalog_generate,
    cost_model=rmsnorm_cost_model,
    extract_spec=_extract_spec,
    abstract_args=_abstract_args,
    example_args=_example_args,
    default_point=DEFAULT_POINT,
    oracle=rmsnorm_ref,
    tolerance={"rtol": 1e-3, "atol": 1e-5},
)


__all__ = ["DEFAULT_POINT", "KERNEL", "make_space", "make_rmsnorm_compilette",
           "rmsnorm_cost_model", "rmsnorm_pallas", "rmsnorm_ref"]
