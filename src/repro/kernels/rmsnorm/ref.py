"""Pure-jnp oracle for the fused RMSNorm kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rmsnorm_ref(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps) * w.astype(jnp.float32)).astype(x.dtype)
