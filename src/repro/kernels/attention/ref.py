"""Pure-jnp oracle for attention (naive full-softmax, GQA-aware)."""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,      # (B, Tq, H, Dh)
    k: jax.Array,      # (B, Tkv, Hk, Dh)
    v: jax.Array,      # (B, Tkv, Hk, Dh)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    window: int | None = None,
) -> jax.Array:
    B, Tq, H, Dh = q.shape
    _, Tkv, Hk, _ = k.shape
    G = H // Hk
    scale = float(scale if scale is not None else Dh ** -0.5)

    qg = q.reshape(B, Tq, Hk, G, Dh).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * scale
    q_pos = q_offset + jnp.arange(Tq)[:, None]
    k_pos = jnp.arange(Tkv)[None, :]
    mask = jnp.ones((Tq, Tkv), dtype=bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= k_pos > q_pos - window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, vf)
    return o.reshape(B, Tq, H, Dh).astype(q.dtype)
