"""Flash-attention Pallas TPU kernel (blockwise online softmax), GQA-aware.

Tuning point:
  block_q   — query rows per program (coldUF analogue)
  block_kv  — key/value rows per inner grid step (vectLen analogue)
  sched     — "arbitrary" | "parallel" semantics hint on the kv axis (IS)
  lookahead — DMA pipeline depth hint (pld analogue, cost-model only)

Layout: q (B*H, Tq, Dh), k/v (B*Hk, Tkv, Dh) with H = G·Hk. The kv block
index map folds the GQA group: kv head = q head // G.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pallas_compat import CompilerParams

Point = dict[str, Any]
NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, block_q: int, block_kv: int,
               n_kv: int, q_offset: int, t_kv: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0]                       # (bq, d)
    k = k_ref[0]                       # (bkv, d)
    v = v_ref[0]
    ragged = t_kv % block_kv != 0
    if ragged:
        # leftover handling: zero the padded tail of the final kv block
        kv_idx = ik * block_kv + jax.lax.broadcasted_iota(
            jnp.int32, k.shape, 0)
        k = jnp.where(kv_idx < t_kv, k, 0)
        v = jnp.where(kv_idx < t_kv, v, 0)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    ) * scale                          # (bq, bkv)

    q_pos = q_offset + iq * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 0)
    k_pos = ik * block_kv + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_kv), 1)
    if causal:
        s = jnp.where(q_pos >= k_pos, s, NEG_INF)
    if ragged:
        s = jnp.where(k_pos < t_kv, s, NEG_INF)

    m_prev = m_ref[...]                # (bq, 1)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    m_ref[...] = m_new

    @pl.when(ik == n_kv - 1)
    def _publish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l).astype(o_ref.dtype)


def flash_attention_pallas(
    q: jax.Array,      # (B, Tq, H, Dh)
    k: jax.Array,      # (B, Tkv, Hk, Dh)
    v: jax.Array,      # (B, Tkv, Hk, Dh)
    point: Point,
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    interpret: bool = True,
) -> jax.Array:
    B, Tq, H, Dh = q.shape
    _, Tkv, Hk, _ = k.shape
    G = H // Hk
    scale = float(scale if scale is not None else Dh ** -0.5)
    bq = min(point["block_q"], Tq)
    bkv = min(point["block_kv"], Tkv)

    qf = q.transpose(0, 2, 1, 3).reshape(B * H, Tq, Dh)
    kf = k.transpose(0, 2, 1, 3).reshape(B * Hk, Tkv, Dh)
    vf = v.transpose(0, 2, 1, 3).reshape(B * Hk, Tkv, Dh)

    n_q, n_kv = pl.cdiv(Tq, bq), pl.cdiv(Tkv, bkv)
    grid = (B * H, n_q, n_kv)

    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, block_q=bq, block_kv=bkv,
        n_kv=n_kv, q_offset=q_offset, t_kv=Tkv,
    )
    sem = point.get("sched", "arbitrary")
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, Dh), lambda bh, iq, ik: (bh, iq, 0)),
            pl.BlockSpec((1, bkv, Dh), lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
            pl.BlockSpec((1, bkv, Dh), lambda bh, iq, ik, g=G: (bh // g, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, Dh), lambda bh, iq, ik: (bh, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, Tq, Dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, Dh), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", sem)
        ),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, Tq, Dh).transpose(0, 2, 1, 3)
