"""Attention ops: chunked-jnp flash implementation, dispatcher, compilette.

``flash_attention_jnp`` is the framework's memory-efficient attention used
by every model for train/prefill (O(T·d) live memory, online softmax,
double-checkpointed so the backward recomputes score blocks). It is also
the oracle-equivalent path the Pallas kernel is validated against, and the
path the 512-device dry-run lowers (Pallas does not lower on the CPU
dry-run; the launcher flips ``impl="pallas"`` on real TPU).
"""

from __future__ import annotations

import functools
import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.compilette import Compilette
from repro.core.profiles import TPU_V5E, DeviceProfile
from repro.core.tuning_space import Param, Point, TuningSpace
from repro.kernels.attention.attention import flash_attention_pallas
from repro.kernels.attention.ref import attention_ref
from repro.kernels.catalog import KernelDef, example_fill

NEG_INF = -1e30

DEFAULT_POINT: Point = {
    "block_q": 256, "block_kv": 512, "sched": "arbitrary", "lookahead": 1,
}


# ------------------------------------------------------- chunked jnp flash
@functools.partial(
    jax.jit,
    static_argnames=(
        "causal", "scale", "q_offset", "window", "q_chunk", "k_chunk",
        "scores_f32"),
)
def flash_attention_jnp(
    q: jax.Array,      # (B, Tq, H, Dh)
    k: jax.Array,      # (B, Tkv, Hk, Dh)
    v: jax.Array,      # (B, Tkv, Hk, Dh)
    *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    window: int | None = None,
    q_chunk: int = 256,
    k_chunk: int = 512,
    scores_f32: bool = True,
) -> jax.Array:
    B, Tq, H, Dh = q.shape
    _, Tk, Hk, _ = k.shape
    G = H // Hk
    scale = float(scale if scale is not None else Dh ** -0.5)
    qc = min(q_chunk, Tq)
    kc = min(k_chunk, Tk)
    n_q = math.ceil(Tq / qc)
    n_k = math.ceil(Tk / kc)
    Tq_p, Tk_p = n_q * qc, n_k * kc
    orig_dtype = q.dtype

    q = jnp.pad(q, ((0, 0), (0, Tq_p - Tq), (0, 0), (0, 0))) if Tq_p != Tq else q
    if Tk_p != Tk:
        k = jnp.pad(k, ((0, 0), (0, Tk_p - Tk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, Tk_p - Tk), (0, 0), (0, 0)))

    # (n_q, B, Hk, G, qc, Dh) — kept in the input dtype: bf16 operands with
    # fp32 accumulation is the MXU fast path; scale is applied on the fp32
    # scores.
    qb = q.reshape(B, n_q, qc, Hk, G, Dh).transpose(1, 0, 3, 4, 2, 5)
    # (n_k, B, Hk, kc, Dh)
    kb = k.reshape(B, n_k, kc, Hk, Dh).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, n_k, kc, Hk, Dh).transpose(1, 0, 3, 2, 4)

    q_ids = jnp.arange(qc)
    k_ids = jnp.arange(kc)

    def per_q_chunk(_, inp):
        qcur, iq = inp

        def body(carry, kv_inp):
            m, l, acc = carry
            kblk, vblk, ik = kv_inp
            # scores_f32=False models the Pallas flash kernel's memory
            # profile in this jnp fallback: score blocks never leave VMEM
            # on TPU, so materializing them in bf16 here keeps the HBM
            # traffic estimate honest; softmax stats stay fp32 either way.
            s = jnp.einsum(
                "bhgqd,bhkd->bhgqk", qcur, kblk,
                preferred_element_type=(
                    jnp.float32 if scores_f32 else None),
            ).astype(jnp.float32) * scale
            q_pos = q_offset + iq * qc + q_ids[:, None]
            k_pos = ik * kc + k_ids[None, :]
            mask = k_pos < Tk
            if causal:
                mask &= q_pos >= k_pos
            if window is not None:
                mask &= k_pos > q_pos - window
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + jnp.sum(p, axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p.astype(vblk.dtype), vblk,
                preferred_element_type=jnp.float32,
            )
            return (m_new, l, acc), None

        init = (
            jnp.full((B, Hk, G, qc), NEG_INF, jnp.float32),
            jnp.zeros((B, Hk, G, qc), jnp.float32),
            jnp.zeros((B, Hk, G, qc, Dh), jnp.float32),
        )
        (m, l, acc), _ = jax.lax.scan(
            jax.checkpoint(body), init, (kb, vb, jnp.arange(n_k))
        )
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return None, out.astype(orig_dtype)

    _, outs = jax.lax.scan(
        jax.checkpoint(per_q_chunk), None, (qb, jnp.arange(n_q))
    )
    # outs: (n_q, B, Hk, G, qc, Dh) → (B, Tq, H, Dh)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(B, Tq_p, H, Dh)
    return out[:, :Tq].astype(orig_dtype)


# ------------------------------------------------------------ decode path
def decode_attention(
    q: jax.Array,      # (B, 1, H, Dh) — one new token
    k: jax.Array,      # (B, S, Hk, Dh) KV cache
    v: jax.Array,
    *,
    length: jax.Array | int | None = None,
    scale: float | None = None,
    k_chunk: int = 4096,
) -> jax.Array:
    """Flash-decoding: online-softmax scan over KV chunks.

    Chunking bounds the live working set to one chunk (essential both on
    TPU and for the CPU dry-run, where XLA materializes bf16 math as f32 —
    a whole-cache op would double the cache's memory footprint).
    """
    B, Tq, H, Dh = q.shape
    _, S, Hk, _ = k.shape
    G = H // Hk
    scale = float(scale if scale is not None else Dh ** -0.5)
    qg = q.reshape(B, Tq, Hk, G, Dh)
    kc = min(k_chunk, S)
    n = math.ceil(S / kc)
    if n * kc != S:       # ragged tail: fall back to a single chunk
        kc, n = S, 1
    kb = k.reshape(B, n, kc, Hk, Dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(B, n, kc, Hk, Dh).transpose(1, 0, 2, 3, 4)
    len_b = None if length is None else jnp.asarray(length).reshape(-1, 1)

    def body(carry, inp):
        m, l, acc = carry
        kblk, vblk, ik = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kblk,
                       preferred_element_type=jnp.float32) * scale
        if len_b is not None:
            k_pos = ik * kc + jnp.arange(kc)
            valid = k_pos[None, :] < len_b
            s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p.astype(vblk.dtype), vblk,
            preferred_element_type=jnp.float32)
        return (m_new, l, acc), None

    init = (
        jnp.full((B, Hk, G, Tq), NEG_INF, jnp.float32),
        jnp.zeros((B, Hk, G, Tq), jnp.float32),
        jnp.zeros((B, Hk, G, Tq, Dh), jnp.float32),
    )
    (m, l, acc), _ = jax.lax.scan(body, init, (kb, vb, jnp.arange(n)))
    o = acc / jnp.maximum(l, 1e-30)[..., None]
    # (B,Hk,G,Tq,Dh) -> (B,Tq,H,Dh)
    o = o.transpose(0, 3, 1, 2, 4).reshape(B, Tq, H, Dh)
    return o.astype(q.dtype)


# -------------------------------------------------------------- dispatcher
def attention(
    q, k, v, *,
    causal: bool = True,
    scale: float | None = None,
    q_offset: int = 0,
    window: int | None = None,
    impl: str = "chunked",
    point: Point | None = None,
    interpret: bool = True,
):
    if impl == "chunked":
        p = dict(DEFAULT_POINT if point is None else point)
        return flash_attention_jnp(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset,
            window=window, q_chunk=p["block_q"], k_chunk=p["block_kv"],
        )
    if impl == "ref":
        return attention_ref(
            q, k, v, causal=causal, scale=scale, q_offset=q_offset, window=window
        )
    if impl == "pallas":
        if window is not None:
            raise NotImplementedError("pallas path: window masking not yet wired")
        p = dict(DEFAULT_POINT if point is None else point)
        return flash_attention_pallas(
            q, k, v, p, causal=causal, scale=scale, q_offset=q_offset,
            interpret=interpret,
        )
    raise ValueError(f"unknown attention impl {impl!r}")


# ------------------------------------------------------------ tuning space
def make_space(
    Tq: int, Tkv: int, Dh: int,
    *,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> TuningSpace:
    params = (
        Param("block_q", (128, 256, 512), phase=1, switch_rank=0),
        Param("block_kv", (128, 256, 512, 1024), phase=1, switch_rank=1),
        Param("sched", ("arbitrary", "parallel"), phase=2),
        Param("lookahead", (0, 1, 2), phase=2),
    )

    def validator(p: Point) -> bool:
        bq, bkv = min(p["block_q"], Tq), min(p["block_kv"], Tkv)
        words = bq * Dh * 2 + 2 * bkv * Dh + bq * bkv + 2 * bq
        return words * 4 <= vmem_kb * 1024

    def no_leftover(p: Point) -> float:
        waste = 1.0
        for dim, blk in ((Tq, min(p["block_q"], Tq)), (Tkv, min(p["block_kv"], Tkv))):
            n = math.ceil(dim / blk)
            waste *= (n * blk) / dim
        return waste - 1.0

    return TuningSpace(params=params, validator=validator, no_leftover=no_leftover)


def attention_cost_model(
    point: Point, spec: dict[str, Any], profile: DeviceProfile
) -> float:
    B, Tq, Tkv, H, Dh = spec["B"], spec["Tq"], spec["Tkv"], spec["H"], spec["Dh"]
    causal = spec.get("causal", True)
    bq, bkv = min(point["block_q"], Tq), min(point["block_kv"], Tkv)
    words = bq * Dh * 2 + 2 * bkv * Dh + bq * bkv + 2 * bq
    if words * 4 > profile.vmem_kb * 1024:
        return float("inf")
    frac = 0.5 if causal else 1.0
    flops = 4.0 * B * H * Tq * Tkv * Dh * frac
    eff = bkv / (bkv + 128.0)
    compute_s = flops / (profile.peak_flops * eff)
    n_q = math.ceil(Tq / bq)
    bytes_total = (B * H * Tq * Dh + B * H * Tkv * Dh * n_q * 2) * 2.0
    mem_s = bytes_total / (profile.hbm_gbps * 1e9)
    steps = B * H * n_q * math.ceil(Tkv / bkv)
    overhead_s = steps * profile.grid_step_overhead_ns * 1e-9 * (
        0.8 if point["sched"] == "arbitrary" else 1.0)
    t = profile.exec_time_s(compute_s, mem_s, overhead_s)
    if not profile.overlap and point["lookahead"] > 0:
        t -= min(compute_s, mem_s) * min(0.35 * point["lookahead"], 0.7)
    return t


def make_attention_compilette(
    B: int, Tq: int, Tkv: int, H: int, Hk: int, Dh: int,
    *,
    causal: bool = True,
    interpret: bool = True,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> Compilette:
    space = make_space(Tq, Tkv, Dh, vmem_kb=vmem_kb)

    def generate(point: Point, **spec: Any):
        @jax.jit
        def fn(q, k, v):
            return flash_attention_pallas(
                q, k, v, point, causal=causal, interpret=interpret
            )
        return fn

    def cost_model(point: Point, spec: dict[str, Any], profile: DeviceProfile) -> float:
        full = {"B": B, "Tq": Tq, "Tkv": Tkv, "H": H, "Dh": Dh, "causal": causal}
        full.update(spec)
        return attention_cost_model(point, full, profile)

    return Compilette("attention", space, generate, cost_model=cost_model)


# ---------------------------------------------------------- kernel catalog
def _catalog_generate(point: Point, spec: dict[str, Any], *,
                      interpret: bool = True):
    causal = bool(spec.get("causal", True))

    @jax.jit
    def fn(q, k, v):
        return flash_attention_pallas(q, k, v, point, causal=causal,
                                      interpret=interpret)
    return fn


def _extract_spec(q, k, v, **overrides: Any) -> dict[str, Any]:
    B, Tq, H, Dh = q.shape
    _, Tkv, Hk, _ = k.shape
    return {"B": int(B), "Tq": int(Tq), "Tkv": int(Tkv), "H": int(H),
            "Hk": int(Hk), "Dh": int(Dh), "causal": True,
            "dtype": str(q.dtype), **overrides}


def _shapes(spec: dict[str, Any]):
    dt = spec.get("dtype", "float32")
    q = (spec["B"], spec["Tq"], spec["H"], spec["Dh"])
    kv = (spec["B"], spec["Tkv"], spec["Hk"], spec["Dh"])
    return ((q, dt), (kv, dt), (kv, dt))


def _abstract_args(spec: dict[str, Any]) -> tuple:
    return tuple(jax.ShapeDtypeStruct(s, d) for s, d in _shapes(spec))


def _example_args(spec: dict[str, Any]) -> tuple:
    return tuple(example_fill(s, d, scale=0.1) for s, d in _shapes(spec))


def _catalog_oracle(q, k, v):
    # the catalog registers causal attention only (_extract_spec pins
    # causal=True), so the oracle mirrors that fixed setting
    return attention_ref(q, k, v, causal=True)


KERNEL = KernelDef(
    name="attention",
    make_space=lambda spec: make_space(spec["Tq"], spec["Tkv"], spec["Dh"]),
    generate=_catalog_generate,
    cost_model=attention_cost_model,
    extract_spec=_extract_spec,
    abstract_args=_abstract_args,
    example_args=_example_args,
    default_point=DEFAULT_POINT,
    oracle=_catalog_oracle,
    # flash blocks re-scale every partial softmax sum vs the oracle's
    # single full-row softmax
    tolerance={"rtol": 2e-3, "atol": 1e-5},
)


__all__ = [
    "DEFAULT_POINT",
    "KERNEL",
    "flash_attention_jnp",
    "flash_attention_pallas",
    "decode_attention",
    "attention",
    "attention_ref",
    "make_space",
    "make_attention_compilette",
    "attention_cost_model",
]
