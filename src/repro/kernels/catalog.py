"""Kernel catalog: the kernel-granular tuning plane's registry.

The paper's unit of analysis is the individual short-running kernel, and
the coordinator built in PRs 1–3 is the unit of *management* — this
module makes them meet. Every op module under ``repro/kernels/*/ops.py``
exposes a declarative :class:`KernelDef`; the process-wide
:class:`KernelCatalog` discovers them and builds
:class:`KernelCompilette`\\ s — coordinator-ready generators that know how
to extract their tuning *spec* (the run-time constants: problem shape,
dtype) from live call arguments, how to AOT-compile a variant so the real
XLA compile cost lands in ``gen_spent_s`` (where the async pipeline hides
it), and how to price themselves on a simulated device profile for
deterministic virtual-clock tests.

**Adding a new tunable kernel is ~20 lines** in your ``ops.py``::

    from repro.kernels.catalog import KernelDef
    import jax, jax.numpy as jnp

    def _generate(point, spec, *, interpret=True):
        # close over the point: this is the deGoal specialization analogue
        @jax.jit
        def fn(x):
            return my_kernel(x, point, interpret=interpret)
        return fn

    KERNEL = KernelDef(
        name="mykernel",
        make_space=lambda spec: make_space(spec["N"]),     # reuse yours
        generate=_generate,
        cost_model=my_cost_model,                          # optional
        extract_spec=lambda x, **kw: {"N": x.shape[0],
                                      "dtype": str(x.dtype), **kw},
        abstract_args=lambda spec: (jax.ShapeDtypeStruct(
            (spec["N"],), spec["dtype"]),),
        example_args=lambda spec: (jnp.ones((spec["N"],),
                                            spec["dtype"]),),
    )

Nothing else: ``discover_kernels()`` imports every ``kernels/*/ops.py``
and registers the ``KERNEL`` attribute it finds, the
:class:`~repro.runtime.kernel_plane.KernelTuningPlane` registers built
compilettes with the :class:`~repro.runtime.coordinator.TuningCoordinator`
(own strategy, registry warm-start key, generation-cache entries and
lifecycle bucketing per kernel), and the serve/train CLIs' ``--kernel-
tuning`` / ``--kernel-strategy`` flags pick the kernel up by name.
"""

from __future__ import annotations

import dataclasses
import hashlib
import importlib
import os
import time
from typing import Any, Callable, Mapping

from repro.core.compilette import Compilette
from repro.core.profiles import DeviceProfile
from repro.core.tuning_space import Point, TuningSpace

__all__ = [
    "KernelDef",
    "KernelCompilette",
    "KernelCatalog",
    "compile_in_process",
    "discover_kernels",
    "example_fill",
    "get_catalog",
]


def example_fill(shape: tuple[int, ...], dtype: Any, *,
                 scale: float = 1.0) -> Any:
    """Deterministic non-constant example array for ``example_args``.

    Constant fills make the variant gate vacuous for some kernels —
    e.g. euclidean distances between identical all-ones rows are exactly
    zero, so any multiplicative corruption compares equal to the oracle.
    A short repeating ramp keeps outputs non-degenerate while staying
    cheap, seedless and bit-identical across processes. ``scale`` caps
    the amplitude for kernels that exponentiate (attention softmax).
    """
    import jax.numpy as jnp

    n = 1
    for s in shape:
        n *= int(s)
    vals = ((jnp.arange(n, dtype=jnp.float32) % 13.0) - 6.0) / 6.0 * scale
    return vals.reshape(shape).astype(dtype)


@dataclasses.dataclass(frozen=True)
class KernelDef:
    """Declarative description of one tunable kernel.

    ``generate(point, spec, *, interpret)`` must return the concrete
    callable for that tuning point with the spec's run-time constants
    closed over; ``extract_spec(*call_args, **overrides)`` maps live
    arguments (shapes/dtypes) to the spec dict that keys tuners, registry
    entries and generation-cache lines; ``abstract_args(spec)`` /
    ``example_args(spec)`` rebuild AOT avals / concrete evaluation
    arguments from a spec alone.
    """

    name: str
    make_space: Callable[[Mapping[str, Any]], TuningSpace]
    generate: Callable[..., Callable[..., Any]]
    extract_spec: Callable[..., dict[str, Any]]
    cost_model: Callable[
        [Point, Mapping[str, Any], DeviceProfile], float] | None = None
    abstract_args: Callable[[Mapping[str, Any]], tuple] | None = None
    example_args: Callable[[Mapping[str, Any]], tuple] | None = None
    default_point: Point | None = None
    # sha256 prefix of the defining ops.py source, stamped by
    # discover_kernels: persisted bests and cached executables are keyed
    # under it, so editing a kernel's source cold-starts exactly that
    # kernel instead of warm-starting from stale bests
    source_hash: str | None = None
    # correctness reference: ``oracle(*example_args(spec))`` computes the
    # ground-truth output the variant gate compares a freshly generated
    # variant against (the kernel's ``ref.py``); ``tolerance`` supplies
    # per-kernel {"rtol": ..., "atol": ...} bounds for that comparison
    # (kernels accumulating in low precision declare looser ones)
    oracle: Callable[..., Any] | None = None
    tolerance: Mapping[str, float] | None = None


class KernelCompilette(Compilette):
    """A :class:`~repro.core.Compilette` bound to one kernel spec.

    Three generation backends, chosen at build time:

    * **AOT** (default, real backend): the variant is lowered and
      compiled inside ``_generate`` — ``jit(fn).lower(*avals).compile()``
      — so the *actual XLA compile cost* is measured into
      ``generation_time_s`` (and thus ``gen_spent_s``) instead of
      polluting the first evaluation. Version-guarded: any lowering
      failure falls back to the lazy ``jax.jit`` wrapper
      (``aot_fallbacks`` counts them).
    * **lazy** (``aot=False``): the paper-faithful behaviour before this
      PR — generation returns the un-lowered jit wrapper and the first
      evaluation pays the compile.
    * **virtual** (``virtual=(clock, profile)``): generation returns a
      simulated kernel whose calls advance the injected
      :class:`~repro.core.VirtualClock` by the analytical
      ``cost_model`` estimate — the deterministic backend the tier-1
      kernel-plane tests and ``benchmarks/kernel_plane.py`` run on.
    """

    def __init__(
        self,
        defn: KernelDef,
        spec: Mapping[str, Any],
        *,
        interpret: bool = True,
        aot: bool = True,
        virtual: "tuple[Any, DeviceProfile] | None" = None,
        gen_cost_s: "float | Callable[..., float] | None" = None,
        cache_token: str | None = None,
    ) -> None:
        self.defn = defn
        self.spec = dict(spec)
        self.interpret = interpret
        self.aot = bool(aot) and virtual is None
        self.virtual = virtual
        self.aot_compiles = 0
        self.aot_fallbacks = 0
        # correctness gate hooks (read by repro.core.gate.VariantGate):
        # the catalog oracle + tolerances, and an optional scripted
        # verdict ``gate_script(point) -> bool`` — the deterministic
        # pass/fail the virtual backend uses in place of real numerics
        # (installed by tests and the fault-injection replay harness)
        self.oracle = defn.oracle
        self.tolerance = dict(defn.tolerance) if defn.tolerance else None
        self.gate_script: Callable[[Point], bool] | None = None

        cost_model = None
        if defn.cost_model is not None:
            def cost_model(point, sp, profile, _d=defn):
                return _d.cost_model(point, {**self.spec, **sp}, profile)

        super().__init__(
            defn.name,
            defn.make_space(self.spec),
            self._build,
            cost_model=cost_model,
            gen_cost_s=gen_cost_s,
            cache_token=cache_token,
        )
        if defn.source_hash:
            # source identity reaches both persistence layers: the
            # coordinator appends fingerprint_extra to the registry
            # device key, and the generation cache keys on the token —
            # an edited ops.py invalidates this kernel's entries only
            self.fingerprint_extra = f"src-{defn.source_hash}"
            self.cache_token = (
                f"{self.cache_token}+{self.fingerprint_extra}"
                if self.cache_token else self.fingerprint_extra)

    # ------------------------------------------------------------ generate
    def _build(self, point: Point, **sp: Any) -> Callable[..., Any]:
        spec = {**self.spec, **sp}
        if self.virtual is not None:
            clock, profile = self.virtual
            if self.defn.cost_model is None:
                raise ValueError(
                    f"kernel {self.name!r} has no cost model: cannot "
                    "generate virtual variants")
            from repro.core.evaluator import virtual_kernel
            return virtual_kernel(
                clock, self.defn.cost_model(dict(point), spec, profile),
                tag=dict(point))
        fn = self.defn.generate(dict(point), spec, interpret=self.interpret)
        if self.aot and self.defn.abstract_args is not None:
            fn = self._aot_compile(fn, spec)
        return fn

    def _aot_compile(self, fn: Callable[..., Any],
                     spec: Mapping[str, Any]) -> Callable[..., Any]:
        try:
            import jax

            jitted = fn if hasattr(fn, "lower") else jax.jit(fn)
            compiled = jitted.lower(*self.defn.abstract_args(spec)).compile()
            self.aot_compiles += 1
            return compiled
        except Exception:
            # older jax without the AOT API, or a backend that refuses to
            # lower this program ahead of time: degrade to the lazy
            # wrapper (first evaluation pays the compile, as before)
            self.aot_fallbacks += 1
            return fn

    # ----------------------------------------------------- process backend
    def process_payload(self, point: Point,
                        specialization: Mapping[str, Any]) -> tuple | None:
        """Picklable compile job for the farm's ``"process"`` backend.

        ``(module, attr, kwargs)`` naming :func:`compile_in_process`,
        which re-resolves this kernel from the child's own catalog and
        AOT-compiles the point there — the GIL-heavy trace/lower phase
        runs outside the serving process, and with jax's persistent
        compilation cache configured the parent's subsequent compile
        deserializes instead of recompiling. ``None`` (fall back to an
        in-thread compile) for virtual/lazy backends, where generation
        is cheap by construction.
        """
        if self.virtual is not None or not self.aot:
            return None
        return ("repro.kernels.catalog", "compile_in_process", {
            "kernel": self.defn.name,
            "point": dict(point),
            "spec": {**self.spec, **dict(specialization)},
            "interpret": self.interpret,
        })

    # ------------------------------------------------------------- helpers
    def has_valid_points(self) -> bool:
        """False when every point is a hole at this spec (untunable shape)."""
        return next(iter(self.space.iter_valid()), None) is not None

    def abstract_call_args(self) -> tuple:
        if self.defn.abstract_args is None:
            raise ValueError(f"kernel {self.name!r} declares no abstract args")
        return self.defn.abstract_args(self.spec)

    def example_call_args(self) -> tuple:
        """Concrete arrays of the spec's shapes (evaluation fallback)."""
        if self.defn.example_args is None:
            raise ValueError(f"kernel {self.name!r} declares no example args")
        return self.defn.example_args(self.spec)


def compile_in_process(kernel: str, point: Mapping[str, Any],
                       spec: Mapping[str, Any],
                       interpret: bool = True) -> float:
    """Child-process entry for the compile farm's ``"process"`` backend.

    Resolves ``kernel`` from this process's own catalog and AOT-compiles
    ``point`` — the compiled executable itself stays here (XLA
    executables don't pickle), but the compile populates jax's
    persistent compilation cache when one is configured, and the
    returned wall seconds let the parent charge the true compile cost.
    """
    comp = get_catalog().compilette(
        kernel, spec, interpret=interpret, aot=True)
    start = time.perf_counter()
    comp._build(dict(point))
    return time.perf_counter() - start


class KernelCatalog:
    """Name → :class:`KernelDef` registry (one per process)."""

    def __init__(self) -> None:
        self._defs: dict[str, KernelDef] = {}

    def register(self, defn: KernelDef) -> KernelDef:
        self._defs[defn.name] = defn
        return defn

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._defs))

    def __contains__(self, name: str) -> bool:
        return name in self._defs

    def get(self, name: str) -> KernelDef:
        try:
            return self._defs[name]
        except KeyError:
            raise KeyError(
                f"unknown kernel {name!r}; discovered: "
                f"{', '.join(self.names()) or '(none)'}") from None

    def spec_of(self, name: str, *args: Any, **overrides: Any) -> dict:
        return self.get(name).extract_spec(*args, **overrides)

    def compilette(self, name: str, spec: Mapping[str, Any],
                   **opts: Any) -> KernelCompilette:
        return KernelCompilette(self.get(name), spec, **opts)


_CATALOG = KernelCatalog()
_DISCOVERED = False


def discover_kernels(catalog: KernelCatalog | None = None) -> KernelCatalog:
    """Import every ``repro.kernels.<pkg>.ops`` and register its KERNEL.

    Idempotent; op packages without an ``ops`` module or a ``KERNEL``
    attribute are skipped silently (the kernels layer is optional). The
    scan walks the package path directly (the op directories are PEP-420
    namespace packages, which ``pkgutil.iter_modules`` does not list).
    """
    catalog = catalog if catalog is not None else _CATALOG
    import repro.kernels as pkg

    sources: dict[str, str] = {}
    for root in pkg.__path__:
        for entry in sorted(os.listdir(root)):
            path = os.path.join(root, entry, "ops.py")
            if os.path.isfile(path):
                sources.setdefault(entry, path)
    for name in sorted(sources):
        try:
            mod = importlib.import_module(f"repro.kernels.{name}.ops")
        except ImportError:
            continue
        defn = getattr(mod, "KERNEL", None)
        if isinstance(defn, KernelDef):
            if defn.source_hash is None:
                # stamp in place (the dataclass is frozen, but the ops
                # module's KERNEL object must keep its identity so
                # re-discovery stays idempotent)
                with open(sources[name], "rb") as fh:
                    digest = hashlib.sha256(fh.read()).hexdigest()[:12]
                object.__setattr__(defn, "source_hash", digest)
            catalog.register(defn)
    return catalog


def get_catalog() -> KernelCatalog:
    """The process-wide catalog, discovery run once on first use."""
    global _DISCOVERED
    if not _DISCOVERED:
        discover_kernels(_CATALOG)
        _DISCOVERED = True
    return _CATALOG
