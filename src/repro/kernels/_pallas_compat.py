"""Pallas API drift shims.

``pltpu.CompilerParams`` is the current name; jax 0.4.x ships it as
``TPUCompilerParams``. Kernels import the alias from here so they run on
both.
"""

from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None)
if CompilerParams is None:
    CompilerParams = pltpu.TPUCompilerParams
