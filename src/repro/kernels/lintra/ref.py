"""Pure-jnp oracle for the VIPS linear-transform kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def lintra_ref(x: jax.Array, a: jax.Array, b: jax.Array) -> jax.Array:
    """y[h, w, band] = a[band] * x[h, w, band] + b[band].

    ``x`` is (H, W, bands); ``a``/``b`` are (bands,).
    """
    return x * a[None, None, :] + b[None, None, :]


def lintra_ref_folded(x: jax.Array, ab: jax.Array) -> jax.Array:
    """Folded layout oracle: x (H, W*bands), ab (2, W*bands)."""
    return x * ab[0][None, :] + ab[1][None, :]
