"""VIPS ``im_lintra_vec`` Pallas TPU kernel (memory-bound case study).

``y[h, w, band] = a[band] * x[h, w, band] + b[band]`` — each pixel is
loaded and processed exactly once, so the kernel is HBM-bandwidth-bound.
Run-time constants specialized into the generated code: the number of
bands and the image width (as in the paper's compilette).

The image is laid out as (H, W·bands): the band dimension is folded into
the minor axis so the per-band multiply/add becomes a tiled broadcast.

Tuning point: block_h (coldUF), block_w (vectLen, lane-multiples), unroll
(hotUF: independent row strips), order/scratch/lookahead (phase 2).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels._pallas_compat import CompilerParams

Point = dict[str, Any]


def _lintra_kernel(x_ref, ab_ref, o_ref, *, unroll: int):
    x = x_ref[...]                      # (bh, bw)
    a = ab_ref[0:1, :]                  # (1, bw) multiplication factors
    b = ab_ref[1:2, :]                  # (1, bw) addition factors
    bh = x.shape[0]
    sub = bh // unroll
    # hotUF: independent row strips keep multiple FMA chains in flight.
    outs = []
    for u in range(unroll):
        xs = x[u * sub:(u + 1) * sub, :]
        outs.append(xs * a + b)
    o_ref[...] = jnp.concatenate(outs, axis=0) if unroll > 1 else outs[0]


def lintra_pallas(
    x: jax.Array,        # (H, W*bands)
    ab: jax.Array,       # (2, W*bands): row 0 = a tiled, row 1 = b tiled
    point: Point,
    *,
    interpret: bool = True,
) -> jax.Array:
    H, WB = x.shape
    bh, bw = point["block_h"], point["block_w"]
    bw = min(bw, WB)
    unroll = point.get("unroll", 1)

    n_h, n_w = pl.cdiv(H, bh), pl.cdiv(WB, bw)
    order = point.get("order", "hw")
    if order == "hw":
        grid = (n_h, n_w)
        x_map = lambda i, j: (i, j)
        ab_map = lambda i, j: (0, j)
    else:
        grid = (n_w, n_h)
        x_map = lambda j, i: (i, j)
        ab_map = lambda j, i: (0, j)

    kernel = functools.partial(_lintra_kernel, unroll=unroll)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bh, bw), x_map),
            pl.BlockSpec((2, bw), ab_map),
        ],
        out_specs=pl.BlockSpec((bh, bw), x_map),
        out_shape=jax.ShapeDtypeStruct((H, WB), x.dtype),
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")
        ),
        interpret=interpret,
    )(x, ab)
