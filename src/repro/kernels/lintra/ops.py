"""Lintra kernel: compilettes, wrappers, cost model (memory-bound study).

Specialized run-time constants (paper §4.3): the number of bands and the
image width. The jnp backend generates real XLA:CPU program variants; the
pallas backend targets TPU; the cost model serves the simulated profiles.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.compilette import Compilette
from repro.core.profiles import TPU_V5E, DeviceProfile
from repro.core.tuning_space import Param, Point, TuningSpace
from repro.kernels.catalog import KernelDef, example_fill
from repro.kernels.lintra.lintra import lintra_pallas
from repro.kernels.lintra.ref import lintra_ref, lintra_ref_folded

DEFAULT_POINT: Point = {
    "block_h": 64, "block_w": 256, "unroll": 1,
    "vectorize": 1, "order": "hw", "scratch": 1, "lookahead": 1,
}


def make_space(
    H: int, W: int, bands: int,
    *,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> TuningSpace:
    WB = W * bands
    params = (
        Param("block_h", (8, 32, 64, 128), phase=1, switch_rank=0),     # coldUF
        Param("block_w", (128, 256, 512, 1024), phase=1, switch_rank=1),  # vectLen
        Param("unroll", (1, 2, 4), phase=1, switch_rank=2),             # hotUF
        Param("vectorize", (1, 0), phase=1, switch_rank=3),             # VE
        Param("order", ("hw", "wh"), phase=2),                          # IS
        Param("scratch", (1, 0), phase=2),                              # SM
        Param("lookahead", (0, 1, 2), phase=2),                         # pld
    )

    def validator(p: Point) -> bool:
        if p["block_h"] % p["unroll"] != 0:
            return False
        if p["block_h"] > H or min(p["block_w"], WB) > WB:
            return False
        words = 2 * p["block_h"] * min(p["block_w"], WB) + 2 * min(p["block_w"], WB)
        return words * 4 <= vmem_kb * 1024

    def no_leftover(p: Point) -> float:
        waste = 1.0
        for dim, blk in ((H, p["block_h"]), (WB, min(p["block_w"], WB))):
            n = math.ceil(dim / blk)
            waste *= (n * blk) / dim
        return waste - 1.0

    return TuningSpace(params=params, validator=validator, no_leftover=no_leftover)


# ------------------------------------------------------------- jnp variants
def generate_jnp_variant(point: Point, *, bands: int, width: int):
    """Specialized XLA:CPU variant: bands and width are trace-time consts.

    The paper's key observation for this kernel: the reference C code
    *reloads the run-time-constant a/b vectors every loop iteration*, while
    the compilette inlines them — most of the observed speedup. We mirror
    that: variants close over `a`/`b` handling strategy.
    """
    unroll = point["unroll"]
    vect = bool(point["vectorize"])
    n_strips = unroll

    @jax.jit
    def fn(x, a, b):
        # x: (H, W, bands) fp32
        H = x.shape[0]
        if vect:
            xs = x.reshape(H, width * bands)
            af = jnp.tile(a, width)
            bf = jnp.tile(b, width)
            # hotUF: independent row strips
            strip = max(H // n_strips, 1)
            outs = []
            for u in range(n_strips):
                lo = u * strip
                hi = H if u == n_strips - 1 else (u + 1) * strip
                outs.append(xs[lo:hi] * af[None, :] + bf[None, :])
            y = jnp.concatenate(outs, axis=0) if n_strips > 1 else outs[0]
            return y.reshape(H, width, bands)
        # SISD path: per-band loop (the paper's scalar code shape)
        cols = [x[:, :, k] * a[k] + b[k] for k in range(bands)]
        return jnp.stack(cols, axis=-1)

    return fn


# --------------------------------------------------------------------- cost
def lintra_cost_model(
    point: Point, spec: dict[str, Any], profile: DeviceProfile
) -> float:
    H, W, bands = spec["H"], spec["W"], spec["bands"]
    WB = W * bands
    bh, bw = point["block_h"], min(point["block_w"], WB)
    unroll, vect = point["unroll"], bool(point["vectorize"])
    lookahead = point["lookahead"]

    words = 2 * bh * bw + 2 * bw
    if words * 4 > profile.vmem_kb * 1024:
        return float("inf")

    flops = 2.0 * H * WB
    if vect:
        eff_u = max(0.85, unroll / (unroll + 0.3)) if profile.overlap else unroll / (unroll + 1.0)
        compute_s = flops / (profile.vpu_gflops * 1e9 * eff_u)
    else:
        # scalar per-band path: an order of magnitude off the vector pipe
        compute_s = flops / (profile.vpu_gflops * 1e9 * 0.12)

    bytes_total = 2.0 * H * WB * 4.0   # read once + write once: streaming
    mem_s = bytes_total / (profile.hbm_gbps * 1e9)

    steps = math.ceil(H / bh) * math.ceil(WB / bw)
    good_order = (point["order"] == "hw") == (H >= WB / 128)
    overhead_s = steps * profile.grid_step_overhead_ns * (0.8 if good_order else 1.0) * 1e-9

    t = profile.exec_time_s(compute_s, mem_s, overhead_s)
    if not profile.overlap and lookahead > 0:
        t -= min(compute_s, mem_s) * min(0.35 * lookahead, 0.7)
    return t


# --------------------------------------------------------------- compilette
def make_lintra_compilette(
    H: int, W: int, bands: int,
    *,
    backend: str = "jnp",
    interpret: bool = True,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> Compilette:
    space = make_space(H, W, bands, vmem_kb=vmem_kb)

    def generate(point: Point, **spec: Any):
        b_ = spec.get("bands", bands)
        w_ = spec.get("width", W)
        if backend == "jnp":
            return generate_jnp_variant(point, bands=b_, width=w_)
        elif backend == "pallas":
            @jax.jit
            def fn(x, ab):
                return lintra_pallas(x, ab, point, interpret=interpret)
            return fn
        raise ValueError(f"unknown backend {backend!r}")

    def cost_model(point: Point, spec: dict[str, Any], profile: DeviceProfile) -> float:
        full = {"H": H, "W": W, "bands": bands}
        full.update(spec)
        return lintra_cost_model(point, full, profile)

    return Compilette("lintra", space, generate, cost_model=cost_model)


def reference_sisd(bands: int, width: int):
    """Reference that RELOADS a/b per row (the paper's C-code behaviour)."""
    @jax.jit
    def fn(x, a, b):
        rows = []
        for k in range(bands):
            # reload (re-broadcast) constants per band, scalar-ish path
            rows.append(x[:, :, k] * a[k] + b[k])
        return jnp.stack(rows, axis=-1)
    return fn


def reference_simd(bands: int, width: int):
    """Hand-vectorized reference (single fused broadcast op)."""
    @jax.jit
    def fn(x, a, b):
        return lintra_ref(x, a, b)
    return fn


# ---------------------------------------------------------- kernel catalog
def _catalog_generate(point: Point, spec: dict[str, Any], *,
                      interpret: bool = True):
    # the jnp backend IS this container's real platform: XLA:CPU emits
    # genuinely different machine code per point
    return generate_jnp_variant(point, bands=spec["bands"], width=spec["W"])


def _extract_spec(x, a, b, **overrides: Any) -> dict[str, Any]:
    H, W, bands = x.shape
    return {"H": int(H), "W": int(W), "bands": int(bands),
            "dtype": str(x.dtype), **overrides}


def _shapes(spec: dict[str, Any]):
    dt = spec.get("dtype", "float32")
    return (((spec["H"], spec["W"], spec["bands"]), dt),
            ((spec["bands"],), dt), ((spec["bands"],), dt))


def _abstract_args(spec: dict[str, Any]) -> tuple:
    return tuple(jax.ShapeDtypeStruct(s, d) for s, d in _shapes(spec))


def _example_args(spec: dict[str, Any]) -> tuple:
    return tuple(example_fill(s, d) for s, d in _shapes(spec))


KERNEL = KernelDef(
    name="lintra",
    make_space=lambda spec: make_space(spec["H"], spec["W"], spec["bands"]),
    generate=_catalog_generate,
    cost_model=lintra_cost_model,
    extract_spec=_extract_spec,
    abstract_args=_abstract_args,
    example_args=_example_args,
    default_point=DEFAULT_POINT,
    oracle=lintra_ref,
    # a single fused multiply-add per element: no accumulation at all
    tolerance={"rtol": 1e-5, "atol": 1e-7},
)


__all__ = [
    "DEFAULT_POINT",
    "KERNEL",
    "make_space",
    "make_lintra_compilette",
    "generate_jnp_variant",
    "lintra_cost_model",
    "lintra_ref",
    "lintra_ref_folded",
    "lintra_pallas",
    "reference_sisd",
    "reference_simd",
]
