# Compute hot-spot kernels (paper's tuned units) + the kernel catalog.
# Each <name>/ops.py exposes a declarative KERNEL (KernelDef); the
# catalog discovers them and builds coordinator-ready KernelCompilettes.
# See repro/kernels/catalog.py for the ~20-line recipe to add one.

from repro.kernels.catalog import (
    KernelCatalog,
    KernelCompilette,
    KernelDef,
    discover_kernels,
    get_catalog,
)

__all__ = [
    "KernelCatalog",
    "KernelCompilette",
    "KernelDef",
    "discover_kernels",
    "get_catalog",
]
