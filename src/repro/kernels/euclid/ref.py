"""Pure-jnp oracle for the euclidean-distance kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def euclid_ref(x: jax.Array, c: jax.Array) -> jax.Array:
    """dist[n, m] = sum_d (x[n,d] - c[m,d])^2, computed naively in fp32."""
    x = x.astype(jnp.float32)
    c = c.astype(jnp.float32)
    diff = x[:, None, :] - c[None, :, :]
    return jnp.sum(diff * diff, axis=-1)
