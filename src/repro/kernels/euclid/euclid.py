"""Squared euclidean distance Pallas TPU kernel (Streamcluster case study).

``dist[n, m] = sum_d (X[n, d] - C[m, d])**2`` — the paper's CPU-bound
kernel. The *dimension* ``d`` is a run-time constant specialized into the
generated code (deGoal ``#()`` analogue = JAX trace-time constant).

Tuning point:
  block_n   — points per program        (coldUF analogue)
  block_m   — centers per program
  block_d   — d-chunk per grid step     (vectLen × 128 lanes)
  unroll    — independent accumulators inside block_d (hotUF)
  vectorize — 1: MXU path (‖x‖² + ‖c‖² − 2·x@cᵀ)   (VE=SIMD)
              0: VPU path (broadcast-diff-square-sum)  (VE=SISD)
  order, scratch, lookahead — phase-2 codegen options (IS/SM/pld analogues)
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pallas_compat import CompilerParams

Point = dict[str, Any]


def _euclid_kernel(x_ref, c_ref, o_ref, acc_ref, *, unroll: int, n_d: int,
                   vectorize: bool, d_rem: int):
    kd = pl.program_id(2)
    acc = acc_ref if acc_ref is not None else o_ref

    @pl.when(kd == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    x = x_ref[...]  # (bn, bd)
    c = c_ref[...]  # (bm, bd)
    bd = x.shape[-1]
    if d_rem:
        # leftover code: mask the final partial d chunk
        valid = jnp.where(kd == n_d - 1, d_rem, bd)
        xi = jax.lax.broadcasted_iota(jnp.int32, x.shape, 1)
        x = jnp.where(xi < valid, x, 0)
        ci = jax.lax.broadcasted_iota(jnp.int32, c.shape, 1)
        c = jnp.where(ci < valid, c, 0)
    sub = bd // unroll
    partials = []
    for u in range(unroll):
        xs = x[:, u * sub:(u + 1) * sub]
        cs = c[:, u * sub:(u + 1) * sub]
        if vectorize:
            # MXU path: ||x-c||^2 = ||x||^2 + ||c||^2 - 2 x.c
            xx = jnp.sum(xs * xs, axis=-1, keepdims=True)        # (bn,1)
            cc = jnp.sum(cs * cs, axis=-1, keepdims=True).T      # (1,bm)
            xc = jnp.dot(xs, cs.T, preferred_element_type=jnp.float32)
            partials.append(xx + cc - 2.0 * xc)
        else:
            diff = xs[:, None, :] - cs[None, :, :]               # (bn,bm,sub)
            partials.append(jnp.sum(diff * diff, axis=-1))
    total = functools.reduce(jnp.add, partials)
    acc[...] += total.astype(acc.dtype)

    if acc_ref is not None:
        @pl.when(kd == n_d - 1)
        def _publish():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def _euclid_kernel_noscratch(x_ref, c_ref, o_ref, *, unroll, n_d, vectorize,
                             d_rem):
    _euclid_kernel(x_ref, c_ref, o_ref, None, unroll=unroll, n_d=n_d,
                   vectorize=vectorize, d_rem=d_rem)


def euclid_pallas(
    x: jax.Array,       # (N, D) points
    c: jax.Array,       # (M, D) centers
    point: Point,
    *,
    interpret: bool = True,
) -> jax.Array:
    N, D = x.shape
    M, D2 = c.shape
    assert D == D2
    bn, bm, bd = point["block_n"], point["block_m"], point["block_d"]
    bd = min(bd, D)
    unroll = point.get("unroll", 1)
    use_scratch = bool(point.get("scratch", 1))
    order = point.get("order", "nm")
    vectorize = bool(point.get("vectorize", 1))

    n_n, n_m, n_d = pl.cdiv(N, bn), pl.cdiv(M, bm), pl.cdiv(D, bd)
    if order == "nm":
        grid = (n_n, n_m, n_d)
        x_map = lambda i, j, k: (i, k)
        c_map = lambda i, j, k: (j, k)
        o_map = lambda i, j, k: (i, j)
    else:
        grid = (n_m, n_n, n_d)
        x_map = lambda j, i, k: (i, k)
        c_map = lambda j, i, k: (j, k)
        o_map = lambda j, i, k: (i, j)

    kernel = functools.partial(
        _euclid_kernel if use_scratch else _euclid_kernel_noscratch,
        unroll=unroll, n_d=n_d, vectorize=vectorize, d_rem=D % bd,
    )
    scratch = [pltpu.VMEM((bn, bm), jnp.float32)] if use_scratch else []

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, bd), x_map),
            pl.BlockSpec((bm, bd), c_map),
        ],
        out_specs=pl.BlockSpec((bn, bm), o_map),
        out_shape=jax.ShapeDtypeStruct((N, M), jnp.float32),
        scratch_shapes=scratch,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(x, c)
