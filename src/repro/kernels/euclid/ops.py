"""Euclidean-distance kernel: compilettes, wrappers, cost model.

Two compilette backends share one tuning space:

  * ``jnp``    — generates a *CPU/XLA program variant* per tuning point
                 (chunking, unrolled accumulators, MXU-vs-VPU formulation,
                 loop order). This is the container's **real platform**:
                 XLA:CPU emits genuinely different machine code per point
                 and the variants have measurably different run times —
                 the deGoal-on-ARM role.
  * ``pallas`` — the TPU kernel (interpret-mode validated on CPU).

The analytical cost model drives the 11 simulated device profiles.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.compilette import Compilette
from repro.core.profiles import TPU_V5E, DeviceProfile
from repro.core.tuning_space import Param, Point, TuningSpace
from repro.kernels.catalog import KernelDef, example_fill
from repro.kernels.euclid.euclid import euclid_pallas
from repro.kernels.euclid.ref import euclid_ref

DEFAULT_POINT: Point = {
    "block_n": 128, "block_m": 64, "block_d": 32, "unroll": 1,
    "vectorize": 1, "order": "nm", "scratch": 1, "lookahead": 1,
}


def make_space(
    N: int, M: int, D: int,
    *,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> TuningSpace:
    params = (
        Param("block_n", (64, 128, 256), phase=1, switch_rank=0),   # coldUF
        Param("block_m", (32, 64, 128), phase=1, switch_rank=1),
        Param("block_d", (16, 32, 64, 128), phase=1, switch_rank=2),  # vectLen
        Param("unroll", (1, 2, 4), phase=1, switch_rank=3),          # hotUF
        Param("vectorize", (1, 0), phase=1, switch_rank=4),          # VE
        Param("order", ("nm", "mn"), phase=2),                       # IS
        Param("scratch", (1, 0), phase=2),                           # SM
        Param("lookahead", (0, 1, 2), phase=2),                      # pld
    )

    def validator(p: Point) -> bool:
        bd = min(p["block_d"], D)
        if bd % p["unroll"] != 0:
            return False
        if p["block_d"] > D:
            return False           # over-tiling the specialized dimension
        if p["block_n"] > N or p["block_m"] > M:
            return False
        words = p["block_n"] * bd + p["block_m"] * bd + p["block_n"] * p["block_m"]
        if p["scratch"]:
            words += p["block_n"] * p["block_m"]
        if not p["vectorize"]:
            # VPU path materializes the (bn, bm, sub) diff cube in VMEM —
            # the register-pressure hole of the paper's SISD variants.
            words += p["block_n"] * p["block_m"] * (bd // p["unroll"])
        return words * 4 <= vmem_kb * 1024

    def no_leftover(p: Point) -> float:
        waste = 1.0
        for dim, blk in ((N, p["block_n"]), (M, p["block_m"]), (D, min(p["block_d"], D))):
            n = math.ceil(dim / blk)
            waste *= (n * blk) / dim
        return waste - 1.0

    return TuningSpace(params=params, validator=validator, no_leftover=no_leftover)


# ------------------------------------------------------------- jnp variants
def generate_jnp_variant(point: Point, *, dim: int):
    """Build a specialized XLA:CPU program for this tuning point.

    ``dim`` is the run-time constant being specialized (the paper
    specializes the Streamcluster point dimension into the compilette).
    """
    bd = min(point["block_d"], dim)
    unroll = point["unroll"]
    vect = bool(point["vectorize"])
    order = point.get("order", "nm")
    scratch = bool(point.get("scratch", 1))
    n_chunks = math.ceil(dim / bd)

    def chunk_dist(xs, cs):
        if vect:
            xx = jnp.sum(xs * xs, axis=-1, keepdims=True)
            cc = jnp.sum(cs * cs, axis=-1, keepdims=True).T
            return xx + cc - 2.0 * jnp.dot(xs, cs.T, preferred_element_type=jnp.float32)
        diff = xs[:, None, :] - cs[None, :, :]
        return jnp.sum(diff * diff, axis=-1)

    @jax.jit
    def fn(x, c):
        x = x.astype(jnp.float32)
        c = c.astype(jnp.float32)
        if order == "mn":
            x, c = c, x  # compute transposed, swap back at the end
        # hotUF: `unroll` independent accumulator chains over d-chunks.
        accs = [None] * unroll
        for i in range(n_chunks):
            sl = slice(i * bd, min((i + 1) * bd, dim))
            part = chunk_dist(x[:, sl], c[:, sl])
            j = i % unroll
            accs[j] = part if accs[j] is None else accs[j] + part
        live = [a for a in accs if a is not None]
        if scratch:
            out = jnp.sum(jnp.stack(live), axis=0) if len(live) > 1 else live[0]
        else:
            out = live[0]
            for a in live[1:]:
                out = out + a
        return out.T if order == "mn" else out

    return fn


# --------------------------------------------------------------------- cost
def euclid_cost_model(
    point: Point, spec: dict[str, Any], profile: DeviceProfile
) -> float:
    N, M, D = spec["N"], spec["M"], spec["D"]
    bn, bm = point["block_n"], point["block_m"]
    bd = min(point["block_d"], D)
    unroll, vect = point["unroll"], bool(point["vectorize"])
    scratch, lookahead = point["scratch"], point["lookahead"]

    words = bn * bd + bm * bd + bn * bm + (bn * bm if scratch else 0)
    if not vect:
        words += bn * bm * (bd // unroll)
    if words * 4 > profile.vmem_kb * 1024:
        return float("inf")

    n_n, n_m, n_d = math.ceil(N / bn), math.ceil(M / bm), math.ceil(D / bd)
    if vect:
        flops = 2.0 * N * M * D + 2.0 * (N + M) * D
        if profile.overlap:
            eff_u = max(0.88, unroll / (unroll + 0.35))
        else:
            eff_u = unroll / (unroll + 1.2)
        eff_k = bd / (bd + 64.0)
        compute_s = flops / (profile.peak_flops * eff_u * eff_k)
    else:
        flops = 3.0 * N * M * D
        # VPU path: lean single-VPU cores stall badly without unrolling
        # (the paper's non-pipelined VFP story on the Cortex-A8).
        if profile.overlap:
            eff_u = max(0.80, unroll / (unroll + 0.5))
        else:
            eff_u = unroll / (unroll + 2.0)
        compute_s = flops / (profile.vpu_gflops * 1e9 * eff_u)

    bytes_total = (N * D * n_m + M * D * n_n + N * M) * 4.0
    mem_s = bytes_total / (profile.hbm_gbps * 1e9)

    steps = n_n * n_m * n_d
    good_order = (point["order"] == "nm") == (N >= M)
    overhead_s = steps * profile.grid_step_overhead_ns * (0.8 if good_order else 1.0) * 1e-9

    t = profile.exec_time_s(compute_s, mem_s, overhead_s)
    if not profile.overlap and lookahead > 0:
        t -= min(compute_s, mem_s) * min(0.35 * lookahead, 0.7)
    return t


def euclid_flops(N: int, M: int, D: int, vectorize: bool = True) -> float:
    return (2.0 if vectorize else 3.0) * N * M * D


# --------------------------------------------------------------- compilette
def make_euclid_compilette(
    N: int, M: int, D: int,
    *,
    backend: str = "jnp",
    interpret: bool = True,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> Compilette:
    space = make_space(N, M, D, vmem_kb=vmem_kb)

    def generate(point: Point, **spec: Any):
        dim = spec.get("dim", D)
        if backend == "jnp":
            return generate_jnp_variant(point, dim=dim)
        elif backend == "pallas":
            @jax.jit
            def fn(x, c):
                return euclid_pallas(x, c, point, interpret=interpret)
            return fn
        raise ValueError(f"unknown backend {backend!r}")

    def cost_model(point: Point, spec: dict[str, Any], profile: DeviceProfile) -> float:
        full = {"N": N, "M": M, "D": D}
        full.update(spec)
        return euclid_cost_model(point, full, profile)

    return Compilette("euclid", space, generate, cost_model=cost_model)


# ------------------------------------------------------------- references
def reference_sisd(dim: int):
    """The 'compiler default' scalar reference (paper's PARSEC C code)."""
    @jax.jit
    def fn(x, c):
        return euclid_ref(x, c)
    return fn


def reference_simd(dim: int):
    """Hand-vectorized reference (paper's PARVEC NEON code analogue)."""
    @jax.jit
    def fn(x, c):
        x = x.astype(jnp.float32)
        c = c.astype(jnp.float32)
        xx = jnp.sum(x * x, axis=-1, keepdims=True)
        cc = jnp.sum(c * c, axis=-1, keepdims=True).T
        return xx + cc - 2.0 * jnp.dot(x, c.T, preferred_element_type=jnp.float32)
    return fn


# ---------------------------------------------------------- kernel catalog
def _catalog_generate(point: Point, spec: dict[str, Any], *,
                      interpret: bool = True):
    return generate_jnp_variant(point, dim=spec["D"])


def _extract_spec(x, c, **overrides: Any) -> dict[str, Any]:
    N, D = x.shape
    M, _ = c.shape
    return {"N": int(N), "M": int(M), "D": int(D),
            "dtype": str(x.dtype), **overrides}


def _shapes(spec: dict[str, Any]):
    dt = spec.get("dtype", "float32")
    return (((spec["N"], spec["D"]), dt), ((spec["M"], spec["D"]), dt))


def _abstract_args(spec: dict[str, Any]) -> tuple:
    return tuple(jax.ShapeDtypeStruct(s, d) for s, d in _shapes(spec))


def _example_args(spec: dict[str, Any]) -> tuple:
    # non-constant fill: with identical rows every distance is exactly 0
    # and the variant gate's oracle comparison can't see corruption
    return tuple(example_fill(s, d) for s, d in _shapes(spec))


KERNEL = KernelDef(
    name="euclid",
    make_space=lambda spec: make_space(spec["N"], spec["M"], spec["D"]),
    generate=_catalog_generate,
    cost_model=euclid_cost_model,
    extract_spec=_extract_spec,
    abstract_args=_abstract_args,
    example_args=_example_args,
    default_point=DEFAULT_POINT,
    oracle=euclid_ref,
    # chunked/unrolled f32 accumulation vs the naive single-axis sum
    tolerance={"rtol": 1e-3, "atol": 1e-5},
)


__all__ = [
    "DEFAULT_POINT",
    "KERNEL",
    "make_space",
    "make_euclid_compilette",
    "generate_jnp_variant",
    "euclid_cost_model",
    "euclid_flops",
    "euclid_ref",
    "euclid_pallas",
    "reference_sisd",
    "reference_simd",
]
