"""Pure-jnp oracle for decode attention (naive full-softmax over the cache).

Mirrors :func:`repro.kernels.attention.ops.decode_attention` semantics —
one new query token attending over a (possibly partially filled) KV cache
with GQA head grouping — without any chunking or online softmax, so the
tuned flash-decoding variants have a ground truth to be gated against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jax.Array,      # (B, 1, H, Dh) — one new token
    k: jax.Array,      # (B, S, Hk, Dh) KV cache
    v: jax.Array,
    length: jax.Array | int | None = None,
    scale: float | None = None,
) -> jax.Array:
    B, Tq, H, Dh = q.shape
    _, S, Hk, _ = k.shape
    G = H // Hk
    scale = float(scale if scale is not None else Dh ** -0.5)

    qg = q.reshape(B, Tq, Hk, G, Dh).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32)) * scale
    if length is not None:
        len_b = jnp.asarray(length).reshape(-1, 1)      # scalar or per-batch
        valid = jnp.arange(S)[None, :] < len_b          # (1 or B, S)
        s = jnp.where(valid[:, None, None, None, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, H, Dh).astype(q.dtype)
