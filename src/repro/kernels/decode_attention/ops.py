"""Decode-attention (flash-decoding) catalog kernel.

The serving decode path reads the WHOLE KV cache for every generated
token, scanning it in ``k_chunk``-sized blocks (online softmax,
:func:`repro.kernels.attention.ops.decode_attention`). Until PR 5 that
chunk was tuned only at the *program* level (the ``serve_decode``
step-program compilette); this ``KernelDef`` makes the kernel itself a
plane-managed unit, so the KV-chunk tunes **per cache-length bucket** —
the run-time constant that actually decides the best chunk — with its
own search strategy, registry warm-start key and generation-cache lines.

The spec keys on the allocated cache extent ``S`` (registration sites
pre-bucket it, e.g. serve's pow2 ``max_len`` bucket); the per-token
filled length stays a runtime argument, so one compiled variant serves
every step of a request.
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.profiles import TPU_V5E, DeviceProfile
from repro.core.tuning_space import (
    Param,
    Point,
    TuningSpace,
    clamped_options,
)
from repro.kernels.attention.ops import decode_attention
from repro.kernels.catalog import KernelDef, example_fill
from repro.kernels.decode_attention.ref import decode_attention_ref

DEFAULT_POINT: Point = {"k_chunk": 512}

K_CHUNK_OPTIONS = (128, 256, 512, 1024, 4096)


def make_space(
    S: int, B: int, H: int, Hk: int, Dh: int,
    *,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> TuningSpace:
    params = (
        Param("k_chunk", clamped_options(K_CHUNK_OPTIONS, S), phase=1),
    )

    def _words(kc: int) -> int:
        # live working set of one scan step: a K and a V chunk across
        # batch and KV heads, the score block, and the running acc
        return 2 * B * kc * Hk * Dh + B * H * kc + B * H * Dh

    def validator(p: Point) -> bool:
        return _words(min(p["k_chunk"], S)) * 4 <= vmem_kb * 1024

    def no_leftover(p: Point) -> float:
        kc = min(p["k_chunk"], S)
        n = math.ceil(S / kc)
        return (n * kc) / S - 1.0

    return TuningSpace(params=params, validator=validator,
                       no_leftover=no_leftover)


def decode_attention_cost_model(
    point: Point, spec: dict[str, Any], profile: DeviceProfile
) -> float:
    B, S, H, Hk, Dh = (spec["B"], spec["S"], spec["H"], spec["Hk"],
                       spec["Dh"])
    kc = min(point["k_chunk"], S)
    words = 2 * B * kc * Hk * Dh + B * H * kc + B * H * Dh
    if words * 4 > profile.vmem_kb * 1024:
        return float("inf")
    flops = 4.0 * B * H * S * Dh              # qk scores + pv accumulate
    eff = kc / (kc + 256.0)                   # short chunks waste issue slots
    compute_s = flops / (profile.peak_flops * eff)
    # memory-bound by construction: the whole KV cache streams once per
    # decoded token (2 bytes/elem), q/o traffic is negligible beside it
    bytes_total = (2.0 * B * S * Hk * Dh + 2.0 * B * H * Dh) * 2.0
    mem_s = bytes_total / (profile.hbm_gbps * 1e9)
    steps = math.ceil(S / kc)
    overhead_s = steps * profile.grid_step_overhead_ns * 1e-9
    return profile.exec_time_s(compute_s, mem_s, overhead_s)


# ---------------------------------------------------------- kernel catalog
def _catalog_generate(point: Point, spec: dict[str, Any], *,
                      interpret: bool = True):
    del interpret  # chunked-jnp path: nothing to interpret
    kc = int(point["k_chunk"])

    @jax.jit
    def fn(q, k, v, length):
        return decode_attention(q, k, v, length=length, k_chunk=kc)

    return fn


def _extract_spec(q, k, v, length=None, **overrides: Any) -> dict[str, Any]:
    del length  # runtime argument, not a spec constant
    B, _, H, Dh = q.shape
    _, S, Hk, _ = k.shape
    return {"B": int(B), "S": int(S), "H": int(H), "Hk": int(Hk),
            "Dh": int(Dh), "dtype": str(q.dtype), **overrides}


def _shapes(spec: dict[str, Any]):
    dt = spec.get("dtype", "float32")
    q = (spec["B"], 1, spec["H"], spec["Dh"])
    kv = (spec["B"], spec["S"], spec["Hk"], spec["Dh"])
    return ((q, dt), (kv, dt), (kv, dt))


def _abstract_args(spec: dict[str, Any]) -> tuple:
    arrays = tuple(jax.ShapeDtypeStruct(s, d) for s, d in _shapes(spec))
    return arrays + (jax.ShapeDtypeStruct((), "int32"),)


def _example_args(spec: dict[str, Any]) -> tuple:
    arrays = tuple(example_fill(s, d, scale=0.1) for s, d in _shapes(spec))
    return arrays + (jnp.int32(spec["S"]),)


KERNEL = KernelDef(
    name="decode_attention",
    make_space=lambda spec: make_space(
        spec["S"], spec["B"], spec["H"], spec["Hk"], spec["Dh"]),
    generate=_catalog_generate,
    cost_model=decode_attention_cost_model,
    extract_spec=_extract_spec,
    abstract_args=_abstract_args,
    example_args=_example_args,
    default_point=DEFAULT_POINT,
    oracle=decode_attention_ref,
    # online-softmax accumulation vs the naive full softmax: f32 math,
    # but the rescaling path reorders every sum
    tolerance={"rtol": 2e-3, "atol": 1e-5},
)


__all__ = [
    "DEFAULT_POINT",
    "KERNEL",
    "K_CHUNK_OPTIONS",
    "make_space",
    "decode_attention_cost_model",
]
