"""Tiled matmul Pallas TPU kernel with an auto-tunable variant space.

Tuning-point fields (TPU analogues of the paper's deGoal parameters):

  block_m   — rows per program instance        (coldUF: grid coarsening)
  block_n   — lanes per program instance       (vectLen: vector length)
  block_k   — reduction chunk per grid step
  unroll    — independent sub-accumulators within block_k (hotUF: unrolling
              with distinct registers to hide MXU latency)
  order     — "mn" | "nm" grid traversal       (IS: scheduling analogue)
  scratch   — 1: accumulate in a VMEM scratch buffer, publish once
              0: accumulate straight into the output block ("stack
              minimization": fewer live buffers)
  lookahead — DMA pipeline-depth hint (pldStride analogue). Functionally
              inert here (Mosaic double-buffers automatically); consumed by
              the analytical cost model and, on real hardware, by
              emit_pipeline depth.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels._pallas_compat import CompilerParams

Point = dict[str, Any]


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, unroll: int, n_k: int,
               k_rem: int):
    k = pl.program_id(2)
    acc = acc_ref if acc_ref is not None else o_ref

    @pl.when(k == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    a = a_ref[...]
    b = b_ref[...]
    bk = a.shape[-1]
    if k_rem:
        # Leftover handling (deGoal "leftover code" analogue): the final
        # partial K block is masked so padding cannot poison the reduction.
        valid = jnp.where(k == n_k - 1, k_rem, bk)
        kcol = jax.lax.broadcasted_iota(jnp.int32, a.shape, 1)
        a = jnp.where(kcol < valid, a, 0)
        krow = jax.lax.broadcasted_iota(jnp.int32, b.shape, 0)
        b = jnp.where(krow < valid, b, 0)
    # hotUF: split the K chunk into `unroll` independent accumulators so the
    # MXU pipeline sees independent chains; summed pairwise at the end.
    sub = bk // unroll
    partials = []
    for u in range(unroll):
        au = a[:, u * sub:(u + 1) * sub]
        bu = b[u * sub:(u + 1) * sub, :]
        partials.append(
            jnp.dot(au, bu, preferred_element_type=jnp.float32)
        )
    total = functools.reduce(jnp.add, partials)
    acc[...] += total.astype(acc.dtype)

    if acc_ref is not None:
        @pl.when(k == n_k - 1)
        def _publish():
            o_ref[...] = acc_ref[...].astype(o_ref.dtype)


def matmul_pallas(
    a: jax.Array,
    b: jax.Array,
    point: Point,
    *,
    out_dtype=jnp.float32,
    interpret: bool = True,
) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N] with the variant described by ``point``."""
    M, K = a.shape
    K2, N = b.shape
    assert K == K2, (a.shape, b.shape)
    bm, bn, bk = point["block_m"], point["block_n"], point["block_k"]
    unroll = point.get("unroll", 1)
    order = point.get("order", "mn")
    use_scratch = bool(point.get("scratch", 1))

    n_m, n_n, n_k = pl.cdiv(M, bm), pl.cdiv(N, bn), pl.cdiv(K, bk)
    if order == "mn":
        grid = (n_m, n_n, n_k)
        a_map = lambda i, j, k: (i, k)
        b_map = lambda i, j, k: (k, j)
        o_map = lambda i, j, k: (i, j)
    else:  # "nm": swap traversal of the parallel dims
        grid = (n_n, n_m, n_k)
        a_map = lambda j, i, k: (i, k)
        b_map = lambda j, i, k: (k, j)
        o_map = lambda j, i, k: (i, j)

    if not use_scratch and out_dtype != jnp.float32:
        raise ValueError("scratch=0 requires fp32 output (in-place accumulation)")

    kernel = functools.partial(
        _mm_kernel if use_scratch else _mm_kernel_noscratch,
        unroll=unroll,
        n_k=n_k,
        k_rem=K % bk,
    )
    scratch_shapes = [pltpu.VMEM((bm, bn), jnp.float32)] if use_scratch else []

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), a_map),
            pl.BlockSpec((bk, bn), b_map),
        ],
        out_specs=pl.BlockSpec((bm, bn), o_map),
        out_shape=jax.ShapeDtypeStruct((M, N), out_dtype),
        scratch_shapes=scratch_shapes,
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")
        ),
        interpret=interpret,
    )(a, b)


def _mm_kernel_noscratch(a_ref, b_ref, o_ref, *, unroll: int, n_k: int,
                         k_rem: int):
    _mm_kernel(a_ref, b_ref, o_ref, None, unroll=unroll, n_k=n_k, k_rem=k_rem)
