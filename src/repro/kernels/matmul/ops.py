"""Matmul kernel: jit wrapper, compilette factory, analytical cost model.

This is the framework's hot-spot kernel. The online auto-tuner owns the
choice of tuning point per (shape × device); model code calls
``tuned_matmul`` which consults the tuned registry.
"""

from __future__ import annotations

import math
from typing import Any

import jax.numpy as jnp

from repro.core.compilette import Compilette
from repro.core.profiles import TPU_V5E, DeviceProfile
from repro.core.tuning_space import Param, Point, TuningSpace
from repro.kernels.catalog import KernelDef, example_fill
from repro.kernels.matmul.matmul import matmul_pallas
from repro.kernels.matmul.ref import matmul_ref

DEFAULT_POINT: Point = {
    "block_m": 128, "block_n": 128, "block_k": 256,
    "unroll": 1, "order": "mn", "scratch": 1, "lookahead": 1,
}


def make_space(
    M: int, N: int, K: int,
    *,
    dtype_bytes: int = 4,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> TuningSpace:
    # block_k options past K are all holes (validator: block_k > K), so a
    # small-K problem would otherwise have an EMPTY space; keep the pow2
    # options that fit and fall back to the exact extent when none do.
    bk_options = tuple(v for v in (128, 256, 512) if v <= K) or (int(K),)
    params = (
        # phase 1 — structural (analogues: coldUF, vectLen, chunking, hotUF)
        Param("block_m", (64, 128, 256, 512), phase=1, switch_rank=0),
        Param("block_n", (128, 256, 512), phase=1, switch_rank=1),
        Param("block_k", bk_options, phase=1, switch_rank=2),
        Param("unroll", (1, 2, 4), phase=1, switch_rank=3),
        # phase 2 — codegen options (IS, SM, pldStride analogues)
        Param("order", ("mn", "nm"), phase=2),
        Param("scratch", (1, 0), phase=2),
        Param("lookahead", (0, 1, 2), phase=2),
    )

    def validator(p: Point) -> bool:
        if p["block_k"] % p["unroll"] != 0:
            return False
        if p["block_m"] > M + 8 or p["block_n"] > N + 128 or p["block_k"] > K:
            return False  # degenerate over-tiling
        # VMEM footprint hole (the register-pressure analogue)
        words = (
            p["block_m"] * p["block_k"]
            + p["block_k"] * p["block_n"]
            + p["block_m"] * p["block_n"] * (2 if p["scratch"] else 1)
        )
        return words * dtype_bytes <= vmem_kb * 1024

    def no_leftover(p: Point) -> float:
        # fraction of padded (wasted) grid cells; 0 = leftover-free
        waste = 1.0
        for dim, blk in ((M, p["block_m"]), (N, p["block_n"]), (K, p["block_k"])):
            n = math.ceil(dim / blk)
            waste *= (n * blk) / dim
        return waste - 1.0

    return TuningSpace(params=params, validator=validator, no_leftover=no_leftover)


# --------------------------------------------------------------------- cost
def matmul_cost_model(
    point: Point, spec: dict[str, Any], profile: DeviceProfile
) -> float:
    """Analytical execution-time estimate of a matmul variant (seconds)."""
    M, N, K = spec["M"], spec["N"], spec["K"]
    b = spec.get("dtype_bytes", 4)
    bm, bn, bk = point["block_m"], point["block_n"], point["block_k"]
    unroll, order = point["unroll"], point["order"]
    scratch, lookahead = point["scratch"], point["lookahead"]

    words = bm * bk + bk * bn + bm * bn * (2 if scratch else 1)
    if words * b > profile.vmem_kb * 1024:
        return float("inf")  # late-discovered hole on this device

    n_m, n_n, n_k = math.ceil(M / bm), math.ceil(N / bn), math.ceil(K / bk)
    flops = 2.0 * (n_m * bm) * (n_n * bn) * (n_k * bk)  # padded work counts

    # MXU pipeline efficiency: unrolling supplies independent chains (hotUF);
    # fat (OOO-analogue) cores extract them in hardware.
    if profile.overlap:
        eff_u = max(0.88, unroll / (unroll + 0.35))
    else:
        eff_u = unroll / (unroll + 1.2)
    eff_k = bk / (bk + 64.0)  # per-step MXU drain
    compute_s = flops / (profile.peak_flops * eff_u * eff_k)

    bytes_a = M * K * n_n * b
    bytes_b = K * N * n_m * b
    bytes_c = M * N * (2 * n_k - 1 if not scratch else 1) * b
    mem_s = (bytes_a + bytes_b + bytes_c) / (profile.hbm_gbps * 1e9)

    steps = n_m * n_n * n_k
    # order (IS analogue): the right traversal keeps the streamed operand
    # contiguous; wrong choice pays extra per-step latency.
    good_order = (order == "nm") == (M >= N)
    step_ns = profile.grid_step_overhead_ns * (0.8 if good_order else 1.0)
    overhead_s = steps * step_ns * 1e-9

    t = profile.exec_time_s(compute_s, mem_s, overhead_s)
    if not profile.overlap and lookahead > 0:
        # pldStride analogue: deeper DMA lookahead recovers part of the
        # serialization on lean cores.
        t -= min(compute_s, mem_s) * min(0.35 * lookahead, 0.7)
    return t


def matmul_flops_bytes(spec: dict[str, Any], point: Point) -> tuple[float, float]:
    M, N, K = spec["M"], spec["N"], spec["K"]
    b = spec.get("dtype_bytes", 4)
    bm, bn = point["block_m"], point["block_n"]
    n_m, n_n = math.ceil(M / bm), math.ceil(N / bn)
    return 2.0 * M * N * K, float((M * K * n_n + K * N * n_m + M * N) * b)


# --------------------------------------------------------------- compilette
def make_matmul_compilette(
    M: int, N: int, K: int,
    *,
    dtype=jnp.float32,
    interpret: bool = True,
    vmem_kb: int = TPU_V5E.vmem_kb,
) -> Compilette:
    import jax

    space = make_space(M, N, K, dtype_bytes=jnp.dtype(dtype).itemsize, vmem_kb=vmem_kb)

    def generate(point: Point, **spec: Any):
        @jax.jit
        def fn(a, b):
            return matmul_pallas(a, b, point, out_dtype=jnp.float32, interpret=interpret)
        return fn

    def cost_model(point: Point, spec: dict[str, Any], profile: DeviceProfile) -> float:
        full = {"M": M, "N": N, "K": K, "dtype_bytes": jnp.dtype(dtype).itemsize}
        full.update(spec)
        return matmul_cost_model(point, full, profile)

    return Compilette("matmul", space, generate, cost_model=cost_model)


def tuned_matmul(a, b, *, point: Point | None = None, interpret: bool = True):
    """Public wrapper: run the kernel with a tuned (or default) point."""
    point = dict(DEFAULT_POINT if point is None else point)
    return matmul_pallas(a, b, point, out_dtype=jnp.float32, interpret=interpret)


# ---------------------------------------------------------- kernel catalog
def _catalog_space(spec: dict[str, Any]) -> TuningSpace:
    return make_space(
        spec["M"], spec["N"], spec["K"],
        dtype_bytes=jnp.dtype(spec.get("dtype", "float32")).itemsize)


def _catalog_generate(point: Point, spec: dict[str, Any], *,
                      interpret: bool = True):
    import jax

    @jax.jit
    def fn(a, b):
        return matmul_pallas(a, b, point, out_dtype=jnp.float32,
                             interpret=interpret)
    return fn


def _catalog_cost(point: Point, spec: dict[str, Any], profile) -> float:
    full = {"dtype_bytes": jnp.dtype(spec.get("dtype", "float32")).itemsize}
    full.update(spec)
    return matmul_cost_model(point, full, profile)


def _extract_spec(a, b, **overrides: Any) -> dict[str, Any]:
    M, K = a.shape
    _, N = b.shape
    return {"M": int(M), "N": int(N), "K": int(K),
            "dtype": str(a.dtype), **overrides}


def _shapes(spec: dict[str, Any]):
    dt = spec.get("dtype", "float32")
    return ((spec["M"], spec["K"]), dt), ((spec["K"], spec["N"]), dt)


def _abstract_args(spec: dict[str, Any]) -> tuple:
    import jax

    return tuple(jax.ShapeDtypeStruct(s, d) for s, d in _shapes(spec))


def _example_args(spec: dict[str, Any]) -> tuple:
    return tuple(example_fill(s, d) for s, d in _shapes(spec))


KERNEL = KernelDef(
    name="matmul",
    make_space=_catalog_space,
    generate=_catalog_generate,
    cost_model=_catalog_cost,
    extract_spec=_extract_spec,
    abstract_args=_abstract_args,
    example_args=_example_args,
    default_point=DEFAULT_POINT,
    oracle=matmul_ref,
    # tiled f32 accumulation vs one fused dot: order-of-summation only
    tolerance={"rtol": 1e-3, "atol": 1e-5},
)


__all__ = [
    "DEFAULT_POINT",
    "KERNEL",
    "make_space",
    "make_matmul_compilette",
    "matmul_cost_model",
    "matmul_flops_bytes",
    "tuned_matmul",
    "matmul_ref",
]
