"""Pure-jnp oracle for the tiled matmul kernel."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, *, out_dtype=jnp.float32) -> jax.Array:
    return jnp.dot(
        a, b, preferred_element_type=jnp.float32
    ).astype(out_dtype)
