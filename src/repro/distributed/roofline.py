"""Roofline-term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), all in seconds:

  compute    = HLO_FLOPs_per_device / peak_FLOPs          (197 TF bf16/chip)
  memory     = HLO_bytes_per_device / HBM_bw              (819 GB/s/chip)
  collective = ring-model link bytes per device / link_bw (50 GB/s/link)

``cost_analysis()`` of the SPMD-partitioned module is already per-device.
Collective bytes are parsed from the optimized HLO: for each collective op
we take the output shape and apply a ring-traffic model
(all-reduce ≈ 2×N, all-gather/all-to-all/permute ≈ N, reduce-scatter ≈ N×g)
— equivalent to summing operand sizes, which post-optimization HLO no
longer prints inline.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Any

PEAK_FLOPS = 197e12       # bf16 per chip
HBM_BW = 819e9            # bytes/s per chip
LINK_BW = 50e9            # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?\s*(?P<shapes>[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*[a-z0-9]+\[[0-9,]*\][^ )]*)*)\s*\)?\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute|ragged-all-to-all)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=(\{\{[^}]*\}[^,]*\}|\[[0-9,]+\]<=\[[0-9]+\])")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    # iota form: [2,16]<=[32] → groups shaped (2, 16): size = last dim
    dims = g.split("<=")[0].strip("[]").split(",")
    return int(dims[-1])


@dataclasses.dataclass
class CollectiveStats:
    per_op_bytes: dict[str, float]
    link_bytes: float          # ring-model bytes crossing one chip's links
    n_ops: dict[str, int]


def collective_stats(hlo_text: str) -> CollectiveStats:
    per_op: dict[str, float] = {}
    n_ops: dict[str, int] = {}
    link_bytes = 0.0
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue  # async pair: count the -start only
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        out_bytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(m.group("shapes"))
        )
        g = _group_size(line)
        if op == "all-reduce":
            traffic = 2.0 * out_bytes * (g - 1) / g
        elif op == "reduce-scatter":
            traffic = out_bytes * (g - 1)          # input = out×g
        else:  # all-gather / all-to-all / collective-permute
            traffic = out_bytes * (g - 1) / g
        per_op[op] = per_op.get(op, 0.0) + traffic
        n_ops[op] = n_ops.get(op, 0) + 1
        link_bytes += traffic
    return CollectiveStats(per_op_bytes=per_op, link_bytes=link_bytes, n_ops=n_ops)


@dataclasses.dataclass
class Roofline:
    flops: float               # per device
    bytes_hbm: float           # per device
    bytes_link: float          # per device
    compute_s: float
    memory_s: float
    collective_s: float
    bound: str
    model_flops: float         # analytic useful flops (global)
    n_chips: int
    useful_ratio: float        # MODEL_FLOPS / (HLO_FLOPs × chips)
    roofline_frac: float       # ideal compute time / dominant term

    def row(self) -> dict[str, Any]:
        return dataclasses.asdict(self)


def roofline_from(
    cost: dict[str, float],
    hlo_text: str,
    *,
    n_chips: int,
    model_flops: float,
    peak: float = PEAK_FLOPS,
    hbm: float = HBM_BW,
    link: float = LINK_BW,
) -> Roofline:
    """Derive the three terms from the compiled HLO.

    ``xla cost_analysis`` counts while bodies once, so FLOPs/bytes come
    from the trip-count-aware HLO walker (repro.distributed.hlo_analysis);
    the raw cost dict is kept for cross-checking only.
    """
    from repro.distributed.hlo_analysis import analyze_hlo

    t = analyze_hlo(hlo_text)
    flops = t.flops or float(cost.get("flops", 0.0))
    bytes_hbm = t.bytes or float(cost.get("bytes accessed", 0.0))
    coll = CollectiveStats(per_op_bytes=t.coll_per_op,
                           link_bytes=t.coll_bytes, n_ops={})
    compute_s = flops / peak
    memory_s = bytes_hbm / hbm
    collective_s = coll.link_bytes / link
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bound = max(terms, key=terms.get)
    total_hlo_flops = flops * n_chips
    useful = model_flops / total_hlo_flops if total_hlo_flops else 0.0
    ideal_s = model_flops / (n_chips * peak)
    dominant = max(terms.values())
    return Roofline(
        flops=flops,
        bytes_hbm=bytes_hbm,
        bytes_link=coll.link_bytes,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bound=bound,
        model_flops=model_flops,
        n_chips=n_chips,
        useful_ratio=useful,
        roofline_frac=ideal_s / dominant if dominant > 0 else 0.0,
    )
