"""Gradient compression: int8 quantization with error feedback (EF-SGD).

On a real cluster the quantized tensors are what crosses the DP axis
(quantize → all-reduce int8/fp32-scale → dequantize), cutting gradient
all-reduce bytes 4×. The numerics (quantize/dequantize + error feedback)
are exactly what we implement and test here; the collective hookup is a
sharding annotation away (grads are already FSDP-sharded, so GSPMD emits
reduce-scatters over the quantized representation when enabled inside
shard_map — see DESIGN.md §5).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(g)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_tree(grads: Any) -> Any:
    return jax.tree.map(quantize_int8, grads)


class ErrorFeedback:
    """Residual accumulator: e ← g + e − deq(quant(g + e))."""

    def init(self, params: Any) -> Any:
        return jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)

    def apply(self, grads: Any, errors: Any) -> tuple[Any, Any]:
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = quantize_int8(corrected)
            deq = dequantize_int8(q, scale)
            return deq, corrected - deq

        out = jax.tree.map(one, grads, errors)
        new_g = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_e = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_g, new_e
