"""Trip-count-aware cost analysis of optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each ``while`` body ONCE, so any
program built around ``lax.scan`` (layers, microbatches, attention chunks)
under-reports FLOPs/bytes by orders of magnitude. This module re-derives
the roofline inputs from the HLO text itself:

  * parses every computation into ops (result shape, opcode, operands),
  * resolves the call graph (while bodies, fusions, calls, conditionals),
  * extracts while-loop trip counts from the canonical XLA pattern
    (condition: ``compare(iv, constant(N)), direction=LT``),
  * rolls up, multiplying by enclosing trip counts:
      - FLOPs: dot/convolution ops (2 × output elems × contraction size),
      - HBM bytes: operand + result bytes of materializing ops (XLA's
        fusion memory model: fusion internals are free),
      - collective link traffic (ring model, as in roofline.py).

The result is a per-device estimate faithful to what the compiled SPMD
program would execute on hardware, including remat recompute and GSPMD
padding waste.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0, "s2": 1, "u2": 1,
}

# Ops that force a value through HBM even under TPU-grade fusion.
# Elementwise ops / converts / broadcasts are assumed fused into their
# neighbours (XLA:TPU does; XLA:CPU wraps each in a trivial kLoop fusion,
# which must not be double-counted as traffic).
_MATERIALIZING = {
    "dot", "convolution", "copy", "transpose",
    "concatenate", "pad", "scatter", "reduce", "reduce-window", "sort",
    "select-and-scatter", "all-reduce", "all-gather", "reduce-scatter",
    "all-to-all", "collective-permute", "rng", "cholesky",
    "triangular-solve", "fft", "custom-call",
}

#: ops inside a fusion that make the fusion's result a real materialization
_HEAVY_IN_FUSION = {
    "dot", "convolution", "reduce", "reduce-window", "scatter", "gather",
    "dynamic-slice", "dynamic-update-slice", "concatenate", "pad", "sort",
    "transpose", "copy",
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute", "ragged-all-to-all")

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?(?P<name>[\w.\-]+)\s*=\s*"
    r"(?P<shape>\([^()]*\)|[a-z][a-z0-9]*\[[0-9,]*\][^\s]*)\s+"
    r"(?P<opcode>[\w\-]+)\("
)
_CALL_ATTR_RE = re.compile(
    r"(?:body|to_apply|calls|branch_computations)=\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_COND_ATTR_RE = re.compile(r"condition=%?([\w.\-]+)")
_GROUPS_RE = re.compile(
    r"replica_groups=(\{\{[^}]*\}[^,]*\}|\[[0-9,]+\]<=\[[0-9]+\])")
_OPERANDS_RE = re.compile(r"\(([^()]*(?:\([^()]*\)[^()]*)*)\)")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dt, 4)
    return total


def _shape_elems(text: str) -> int:
    m = _SHAPE_RE.search(text)
    if not m:
        return 0
    n = 1
    for d in m.group(2).split(","):
        if d:
            n *= int(d)
    return n


def _group_size(line: str, default: int = 2) -> int:
    m = _GROUPS_RE.search(line)
    if not m:
        return default
    g = m.group(1)
    if g.startswith("{{"):
        first = g[2:].split("}")[0]
        return max(len([x for x in first.split(",") if x.strip() != ""]), 1)
    dims = g.split("<=")[0].strip("[]").split(",")
    return int(dims[-1])


@dataclasses.dataclass
class Totals:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_per_op: dict = dataclasses.field(default_factory=dict)

    def add(self, other: "Totals", mult: float = 1.0) -> None:
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.coll_bytes += other.coll_bytes * mult
        for k, v in other.coll_per_op.items():
            self.coll_per_op[k] = self.coll_per_op.get(k, 0.0) + v * mult


@dataclasses.dataclass
class _Op:
    name: str
    shape: str
    opcode: str
    line: str


@dataclasses.dataclass
class _Computation:
    name: str
    ops: list


def _parse_computations(text: str) -> dict[str, _Computation]:
    comps: dict[str, _Computation] = {}
    current: _Computation | None = None
    for line in text.splitlines():
        stripped = line.strip()
        if stripped.endswith("{") and "->" in stripped and "=" not in \
                stripped.split("(")[0]:
            m = re.match(r"^(?:ENTRY\s+)?%?([\w.\-]+)", stripped)
            if m:
                current = _Computation(m.group(1), [])
                comps[current.name] = current
                continue
        if stripped.startswith("}"):
            continue
        m = _OP_RE.match(line)
        if m and current is not None:
            current.ops.append(_Op(
                m.group("name"), m.group("shape"), m.group("opcode"), stripped))
    return comps


def _dot_flops(op: _Op, symtab: dict[str, str]) -> float:
    """2 × output elems × contraction size for dot/convolution."""
    out_elems = _shape_elems(op.shape)
    if op.opcode == "dot":
        mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", op.line)
        operands = _first_paren_operands(op.line)
        if mm and operands:
            lhs_shape = symtab.get(operands[0], "")
            sm = _SHAPE_RE.search(lhs_shape)
            if sm:
                dims = [int(d) for d in sm.group(2).split(",") if d]
                k = 1
                for idx in (int(i) for i in mm.group(1).split(",") if i):
                    if idx < len(dims):
                        k *= dims[idx]
                return 2.0 * out_elems * k
        return 2.0 * out_elems
    if op.opcode == "convolution":
        mm = re.search(r"window=\{size=([0-9x]+)", op.line)
        k = 1
        if mm:
            for d in mm.group(1).split("x"):
                k *= int(d)
        # multiply by input feature count when available
        return 2.0 * out_elems * k
    return 0.0


def _first_paren_operands(line: str) -> list[str]:
    # text after 'opcode(' up to matching ')': first-level %names
    m = re.search(r"[\w\-]+\((.*)\)", line)
    if not m:
        return []
    inner = m.group(1)
    names = re.findall(r"%([\w.\-]+)", inner)
    return names


_TRIP_RE = re.compile(
    r"compare\([^)]*\)[^,]*, direction=LT")


def _trip_count(cond: _Computation) -> float:
    """Extract N from the scan-style condition: compare(iv, const N), LT.

    XLA may wrap the compare in a kLoop fusion; the loop-bound constant
    then feeds the fusion in the condition computation itself, so the
    largest integer constant in the condition is the trip count."""
    consts: dict[str, int] = {}
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.search(r"constant\((-?[0-9]+)\)", op.line)
            if m:
                consts[op.name] = int(m.group(1))
    for op in cond.ops:
        if op.opcode == "compare" and "direction=LT" in op.line:
            for nm in _first_paren_operands(op.line):
                if nm in consts:
                    return float(max(consts[nm], 1))
    if consts:
        return float(max(max(consts.values()), 1))
    return 1.0


def compiled_cost_analysis(compiled) -> dict:
    """``Compiled.cost_analysis()`` across jax versions.

    jax 0.4.x returns a one-element list of dicts (one per device
    partition); current jax returns the dict directly.
    """
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost)


def analyze_hlo(text: str, entry: str | None = None) -> Totals:
    comps = _parse_computations(text)
    if not comps:
        return Totals()
    if entry is None:
        m = re.search(r"^ENTRY\s+%?([\w.\-]+)", text, re.MULTILINE)
        entry = m.group(1) if m else next(iter(comps))

    memo: dict[str, Totals] = {}

    def visit(name: str, stack: frozenset) -> Totals:
        if name in memo:
            return memo[name]
        if name not in comps or name in stack:
            return Totals()
        comp = comps[name]
        symtab = {op.name: op.shape for op in comp.ops}
        # values that live in HBM at body boundaries (loop carries, weights)
        hbm_resident = {
            op.name for op in comp.ops
            if op.opcode in ("parameter", "get-tuple-element")
        }
        t = Totals()
        stack2 = stack | {name}
        for op in comp.ops:
            if op.opcode == "while":
                m = re.search(r"body=%?([\w.\-]+)", op.line)
                c = _COND_ATTR_RE.search(op.line)
                trips = 1.0
                if c and c.group(1) in comps:
                    trips = _trip_count(comps[c.group(1)])
                if m:
                    t.add(visit(m.group(1), stack2), trips)
                continue
            if op.opcode == "conditional":
                m = _CALL_ATTR_RE.search(op.line)
                if m:
                    branches = [visit(b.strip().lstrip("%"), stack2)
                                for b in m.group(1).split(",")]
                    if branches:
                        worst = max(branches, key=lambda b: b.flops + b.bytes)
                        t.add(worst)
                continue
            if op.opcode in ("call", "fusion", "custom-call", "map",
                             "reduce", "reduce-window", "sort", "scatter",
                             "select-and-scatter", "all-reduce",
                             "reduce-scatter"):
                m = _CALL_ATTR_RE.search(op.line)
                if m and op.opcode in ("call", "map"):
                    for b in m.group(1).split(","):
                        t.add(visit(b.strip().lstrip("%"), stack2))
                elif m and op.opcode == "fusion":
                    # fusion body: count its dot flops (fused matmuls),
                    # bytes counted at the fusion boundary below
                    sub = visit(m.group(1).strip().lstrip("%"), stack2)
                    t.flops += sub.flops
            # --- flops ---
            t.flops += _dot_flops(op, symtab)
            # --- collectives ---
            base = op.opcode.replace("-start", "")
            if base in _COLLECTIVES and not op.opcode.endswith("-done"):
                out_b = _shape_bytes(op.shape)
                # XLA:CPU float-normalization rewrites bf16 collectives as
                # convert→f32-collective→convert; TPU reduces in bf16
                # natively, so charge such collectives at bf16 width.
                if "f32[" in op.shape:
                    ops_ = _first_paren_operands(op.line)
                    prod = next((o for o in comp.ops
                                 if ops_ and o.name == ops_[0]), None)
                    if prod is not None and (
                            prod.opcode == "convert"
                            or (prod.opcode == "fusion"
                                and "convert" in prod.name)):
                        out_b //= 2
                g = _group_size(op.line)
                if base == "all-reduce":
                    traffic = 2.0 * out_b * (g - 1) / g
                elif base == "reduce-scatter":
                    traffic = out_b * (g - 1)
                else:
                    traffic = out_b * (g - 1) / g
                t.coll_bytes += traffic
                t.coll_per_op[base] = t.coll_per_op.get(base, 0.0) + traffic
            # --- bytes (HBM traffic model) ---
            # Each materialized value is written once and read once by its
            # consumer (2 × result bytes); reads of HBM-resident inputs
            # (loop carries / weights / entry params) are counted at the
            # consuming op. Counting every operand of every op would
            # multiply-count values shared by several fusions.
            base_op = op.opcode.replace("-start", "")
            if base_op in ("dynamic-slice", "slice", "gather"):
                t.bytes += 2.0 * _shape_bytes(op.shape)   # touches the slice
            elif base_op == "dynamic-update-slice":
                ops_ = _first_paren_operands(op.line)
                upd = symtab.get(ops_[1], "") if len(ops_) > 1 else op.shape
                t.bytes += 2.0 * _shape_bytes(upd)        # in-place update
            elif base_op == "fusion":
                mm = _CALL_ATTR_RE.search(op.line)
                callee = mm.group(1).split(",")[0].strip().lstrip("%") \
                    if mm else None
                kinds = {o.opcode for o in comps[callee].ops} \
                    if callee in comps else set()
                compute_heavy = kinds & {
                    "dot", "convolution", "reduce", "reduce-window",
                    "scatter", "sort", "concatenate", "pad", "copy",
                    "transpose"}
                if compute_heavy:
                    b = 2.0 * _shape_bytes(op.shape)
                    for nm in _first_paren_operands(op.line):
                        if nm in hbm_resident:
                            b += _shape_bytes(symtab.get(nm, ""))
                    t.bytes += b
                elif "dynamic-update-slice" in kinds:
                    # in-place update: traffic = the updated slice only
                    sub = comps[callee]
                    subtab = {o.name: o.shape for o in sub.ops}
                    for o in sub.ops:
                        if o.opcode == "dynamic-update-slice":
                            ops_ = _first_paren_operands(o.line)
                            upd = subtab.get(ops_[1], "") if len(ops_) > 1 \
                                else ""
                            t.bytes += 2.0 * _shape_bytes(upd)
                elif kinds & {"dynamic-slice", "slice", "gather"}:
                    # slice + elementwise: touches the slice, not the operand
                    t.bytes += 2.0 * _shape_bytes(op.shape)
                # pure-elementwise fusions fuse into neighbours: free
            elif base_op in _MATERIALIZING:
                b = 2.0 * _shape_bytes(op.shape)
                for nm in _first_paren_operands(op.line):
                    if nm in hbm_resident:
                        b += _shape_bytes(symtab.get(nm, ""))
                t.bytes += b
        memo[name] = t
        return t

    # While bodies and fusion computations must only be counted through
    # their call sites, so visit only the entry.
    return visit(entry, frozenset())
