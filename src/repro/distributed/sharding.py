"""Logical-axis sharding rules (DP/FSDP/TP/EP + decode-SP).

Models annotate params/activations with *logical* axes ("embed", "heads",
"batch", …). Rules map logical axes to mesh axes:

  batch   → (pod, data)     data parallelism across pods and the data axis
  embed   → (pod, data)     FSDP (ZeRO-3) weight sharding on the embed dim
  heads / kv / ffn / expert / vocab → model   tensor/expert parallelism
  kv_seq  → model            decode-time KV sequence parallelism (SP) used
                             when kv head sharding is unavailable
  layers / seq / state → None (replicated / unsharded)

GSPMD pads transparently when an axis size is not divisible by the mesh
axis (e.g. 40 heads over model=16) — padding waste shows up honestly in
the roofline's MODEL_FLOPS/HLO_FLOPs ratio.
"""

from __future__ import annotations

import contextlib
from typing import Any

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE_RULES: dict[str, Any] | None = None


def default_rules(multi_pod: bool = False, **overrides: Any) -> dict[str, Any]:
    fsdp = ("pod", "data") if multi_pod else ("data",)
    rules: dict[str, Any] = {
        "batch": fsdp,
        "embed": fsdp,
        "heads": "model",
        "kv": "model",
        "ffn": "model",
        "expert": "model",
        "vocab": "model",
        "kv_seq": None,
        "kv_dh": None,     # decode-cache head_dim sharding (awkward kv counts)
        "seq": None,
        "layers": None,
        "state": None,
        "groups": fsdp,     # MoE dispatch groups follow the batch
        # Activations: the residual (embed) dim stays unsharded — "embed"
        # means FSDP only for *weights*; shard() translates it.
        "act_embed": None,
    }
    rules.update(overrides)
    return rules


def resolve(axis: str | None):
    if axis is None:
        return None
    if _ACTIVE_RULES is None:
        return None
    return _ACTIVE_RULES.get(axis)


def resolver():
    """Capture the current rules into a resolve callable (for spec_tree)."""
    rules = dict(_ACTIVE_RULES or {})

    def _resolve(axis: str | None):
        if axis is None:
            return None
        return rules.get(axis)

    return _resolve


@contextlib.contextmanager
def use_rules(rules: dict[str, Any] | None):
    global _ACTIVE_RULES
    prev = _ACTIVE_RULES
    _ACTIVE_RULES = rules
    try:
        yield
    finally:
        _ACTIVE_RULES = prev


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Logical with_sharding_constraint; no-op outside a mesh/rules scope.

    Activation-side translation: "embed" (a *weight* FSDP axis) resolves to
    the activation rule "act_embed" (unsharded by default) so batch/embed
    never collide on one tensor.
    """
    if _ACTIVE_RULES is None:
        return x
    axes = tuple("act_embed" if a == "embed" else a for a in axes)
    spec = P(*(resolve(a) for a in axes))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except (ValueError, RuntimeError):
        return x  # no ambient mesh (single-device smoke tests)
