"""GPipe-style pipeline parallelism via shard_map + ppermute.

Demonstrates the PP capability on a host mesh: layer stages are sharded
over a ``pipe`` mesh axis; microbatches stream through the stages with
``jax.lax.ppermute`` moving activations stage→stage. The schedule is the
classic GPipe fill-drain: with S stages and M microbatches, S+M−1 ticks.

This is exercised by tests on 8 host devices and offered as an optional
execution mode for the dense transformer (config ``pipeline_stages``); it
is intentionally not part of the 40-cell dry-run matrix (see DESIGN.md §5).
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

# jax.shard_map is the current spelling; jax 0.4.x only has the
# experimental module.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:
    from jax.experimental.shard_map import shard_map as _shard_map

# jax.lax.pvary marks an array device-varying for the newer shard_map
# replication checker; older jax has no such notion — identity is correct.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def pipeline_apply(
    stage_params,          # pytree, leaves with leading axis S (stages)
    x,                     # (M, mb, ...) microbatched input
    layer_fn: Callable,    # layer_fn(stage_params_slice, h) -> h
    mesh,
    axis: str = "pipe",
):
    """Run x through S pipeline stages laid over mesh axis ``axis``."""
    S = mesh.shape[axis]
    M = x.shape[0]

    def stage_program(params_local, x_local):
        # params_local: leaves (1, ...) — this device's stage
        # x_local: (M, mb, ...) — full microbatch stream (stage 0 uses it)
        idx = jax.lax.axis_index(axis)
        params_me = jax.tree.map(lambda a: a[0], params_local)
        mb_shape = x_local.shape[1:]
        h = _pvary(jnp.zeros(mb_shape, x_local.dtype), (axis,))
        outs = _pvary(jnp.zeros((M,) + mb_shape, x_local.dtype), (axis,))
        perm = [(i, (i + 1) % S) for i in range(S)]

        def tick(t, carry):
            h, outs = carry
            # stage 0 injects microbatch t (if still filling)
            inject = jax.lax.dynamic_index_in_dim(
                x_local, jnp.clip(t, 0, M - 1), keepdims=False)
            h = jnp.where(jnp.logical_and(idx == 0, t < M), inject, h)
            h = layer_fn(params_me, h)
            # last stage emits microbatch t-(S-1)
            emit_t = t - (S - 1)
            idx_c = jnp.clip(emit_t, 0, M - 1)
            old = jax.lax.dynamic_index_in_dim(outs, idx_c, 0, keepdims=False)
            emit = jnp.logical_and(idx == S - 1, emit_t >= 0)
            new = jnp.where(emit, h.astype(outs.dtype), old)
            outs = jax.lax.dynamic_update_index_in_dim(outs, new, idx_c, 0)
            h = jax.lax.ppermute(h, axis, perm)
            return h, outs

        h, outs = jax.lax.fori_loop(0, M + S - 1, tick, (h, outs))
        # broadcast results from the last stage to all (psum of one-hot)
        mask = (idx == S - 1).astype(outs.dtype)
        outs = jax.lax.psum(outs * mask, axis)
        return outs

    fn = _shard_map(
        stage_program,
        mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
    )
    return fn(stage_params, x)
