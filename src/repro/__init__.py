"""repro — online auto-tuning at the level of machine-code generation.

``repro.tune`` / ``repro.tuned`` / ``repro.TuningSession`` are the one
front door to the tuning machinery (see :mod:`repro.api`); the
subpackages (``repro.core``, ``repro.kernels``, ``repro.runtime``, …)
remain importable directly. Exports resolve lazily so ``import
repro.core`` never drags the runtime stack in.
"""

_API_EXPORTS = (
    "KERNEL_TUNING_MODES",
    "TunedFunction",
    "TuningConfig",
    "TuningSession",
    "default_session",
    "set_default_session",
    "tune",
    "tuned",
)

__all__ = list(_API_EXPORTS)


def __getattr__(name):
    if name in _API_EXPORTS:
        from repro import api

        return getattr(api, name)
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(globals()) | set(_API_EXPORTS))
