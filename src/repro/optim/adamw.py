"""AdamW with global-norm clipping and warmup+cosine schedule.

Pure-pytree implementation (no optax dependency). Optimizer state mirrors
the parameter tree (same shardings apply), so FSDP sharding of m/v follows
from the parameter PartitionSpecs.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 10000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    min_lr_frac: float = 0.1


def schedule(cfg: OptimizerConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / max(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0)
    cos = cfg.lr * (cfg.min_lr_frac
                    + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(math.pi * prog)))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


class AdamW:
    def __init__(self, cfg: OptimizerConfig | None = None) -> None:
        self.cfg = cfg or OptimizerConfig()

    def init(self, params: Any) -> dict:
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {
            "m": zeros,
            "v": jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            "step": jnp.zeros((), jnp.int32),
        }

    def init_abstract(self, params: Any) -> dict:
        like = jax.tree.map(
            lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params)
        return {
            "m": like,
            "v": jax.tree.map(
                lambda p: jax.ShapeDtypeStruct(p.shape, jnp.float32), params),
            "step": jax.ShapeDtypeStruct((), jnp.int32),
        }

    def update(self, grads: Any, state: dict, params: Any):
        cfg = self.cfg
        step = state["step"] + 1
        # global-norm clip in fp32
        sq = jax.tree.reduce(
            lambda a, g: a + jnp.sum(jnp.square(g.astype(jnp.float32))),
            grads, jnp.zeros((), jnp.float32))
        gnorm = jnp.sqrt(sq)
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        lr = schedule(cfg, step)
        bc1 = 1 - cfg.b1 ** step.astype(jnp.float32)
        bc2 = 1 - cfg.b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32) * scale
            m = cfg.b1 * m + (1 - cfg.b1) * g
            v = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
            mh = m / bc1
            vh = v / bc2
            delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(
            lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(
            lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(
            lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
