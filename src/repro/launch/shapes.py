"""Cell construction: (arch × shape × mesh) → jit-able step + abstract args.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation. ``build_cell`` bundles
the step function, abstract arguments and NamedShardings for the dry-run
and launcher.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeSpec
from repro.distributed import sharding as shlib
from repro.models.model import build_model
from repro.models.params import abstract_tree, spec_tree
from repro.optim.adamw import AdamW, OptimizerConfig

KV_AXES = ("layers", "batch", "kv_seq", "kv", "kv_dh")


def cache_axes(cfg: ModelConfig) -> tuple[tuple, ...]:
    if cfg.family in ("dense", "moe", "vlm"):
        return (KV_AXES, KV_AXES)
    if cfg.family == "rwkv":
        return (
            ("layers", "batch", "heads", None, None),
            ("layers", "batch", None),
            ("layers", "batch", None),
        )
    if cfg.family == "hybrid":
        return (
            KV_AXES, KV_AXES,
            ("layers", "batch", None, "heads"),
            ("layers", "batch", "heads", None),
        )
    if cfg.family == "encdec":
        return (KV_AXES, KV_AXES, KV_AXES, KV_AXES)
    raise ValueError(cfg.family)


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """Cells that are skipped by design (recorded in EXPERIMENTS.md)."""
    if shape.name == "long_500k" and not cfg.supports_long_decode:
        return ("full-attention arch: 500k dense-KV decode unsupported "
                "without an algorithmic change (see DESIGN.md §6)")
    return None


# --------------------------------------------------------------- input specs
def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, jax.ShapeDtypeStruct]:
    """Abstract model inputs for one cell (the data batch only)."""
    B, T = shape.global_batch, shape.seq_len
    tok = lambda b, t: jax.ShapeDtypeStruct((b, t), jnp.int32)
    emb = cfg.compute_dtype
    if shape.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            return {
                "audio_embeds": jax.ShapeDtypeStruct(
                    (B, cfg.enc_frames, cfg.d_model), emb),
                "tokens": tok(B, T),
                "labels": tok(B, T),
            }
        if cfg.family == "vlm":
            Pv = cfg.vision_patches
            return {
                "vision": jax.ShapeDtypeStruct((B, Pv, cfg.d_model), emb),
                "tokens": tok(B, T - Pv),
                "labels": tok(B, T - Pv),
            }
        return {"tokens": tok(B, T), "labels": tok(B, T)}
    # decode: one new token against a cache of length T
    return {"tokens": tok(B, 1)}


def batch_axes(cfg: ModelConfig, shape: ShapeSpec) -> dict[str, tuple]:
    ax: dict[str, tuple] = {}
    for name in input_specs(cfg, shape):
        if name in ("audio_embeds", "vision"):
            ax[name] = ("batch", None, None)
        else:
            ax[name] = ("batch", None)
    return ax


# ------------------------------------------------------------- MODEL_FLOPS
def model_flops(cfg: ModelConfig, shape: ShapeSpec) -> float:
    """Analytic useful FLOPs for the cell (global, fwd+bwd for train).

    6·N·D (dense) / 6·N_active·D (MoE) plus the attention term
    12·L·T·d_attn per token (causal halves it), which matters at 32k+.
    """
    n_active = cfg.n_active_params()
    B, T = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        tokens = B * T
        base = 6.0 * n_active * tokens
        attn = 0.0
        if cfg.family not in ("rwkv",):
            d_attn = cfg.n_heads * cfg.d_head
            layers = cfg.n_layers
            eff_ctx = min(cfg.window, T) if cfg.window else T
            attn = 12.0 * layers * d_attn * eff_ctx * 0.5 * tokens
        return base + attn
    if shape.kind == "prefill":
        tokens = B * T
        base = 2.0 * n_active * tokens
        attn = 0.0
        if cfg.family not in ("rwkv",):
            d_attn = cfg.n_heads * cfg.d_head
            eff_ctx = min(cfg.window, T) if cfg.window else T
            attn = 4.0 * cfg.n_layers * d_attn * eff_ctx * 0.5 * tokens
        return base + attn
    # decode: one token per sequence
    tokens = B
    base = 2.0 * n_active * tokens
    attn = 0.0
    if cfg.family not in ("rwkv",):
        eff_ctx = min(cfg.window, T) if cfg.window else T
        attn = 2.0 * cfg.n_layers * cfg.n_heads * cfg.d_head * eff_ctx * 2.0 * tokens
    if cfg.family in ("rwkv", "hybrid"):
        # state update ~ H·C² (rwkv) or di·state (ssm) per layer per token
        attn += 4.0 * cfg.n_layers * cfg.d_model * max(
            cfg.rwkv_head_size, cfg.ssm_state) * tokens
    return base + attn


# ------------------------------------------------------------------- cells
@dataclasses.dataclass
class Cell:
    cfg: ModelConfig
    shape: ShapeSpec
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate_argnums: tuple[int, ...]
    model_flops: float


def _named(mesh, spec_pytree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_pytree,
        is_leaf=lambda x: isinstance(x, P))


def _fit_spec(spec: P, shape: tuple[int, ...], mesh) -> P:
    """Drop mesh axes that do not divide the dim (top-level args must
    divide exactly; GSPMD pads only intermediates)."""
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, ax in zip(shape, entries):
        if ax is None:
            out.append(None)
            continue
        axes = ax if isinstance(ax, tuple) else (ax,)
        size = 1
        for a in axes:
            size *= mesh.shape[a]
        out.append(ax if dim % size == 0 else None)
    return P(*out)


def sanitize(abs_tree, spec_pytree, mesh):
    return jax.tree.map(
        lambda a, s: _fit_spec(s, a.shape, mesh),
        abs_tree, spec_pytree,
        is_leaf=lambda x: isinstance(x, (P, jax.ShapeDtypeStruct)))


def auto_microbatches(cfg: ModelConfig, shape: ShapeSpec, data_shards: int,
                      budget_bytes: float = 4e9) -> int:
    """Gradient-accumulation factor so the scan carry chain fits HBM.

    The layer-scan saves one residual-stream carry per layer per
    microbatch: L × tokens_per_device × d_model × 2B must fit the budget.
    """
    if cfg.microbatches:
        return cfg.microbatches
    tokens_per_dev = shape.global_batch * shape.seq_len / max(data_shards, 1)
    carry = cfg.n_layers * tokens_per_dev * cfg.d_model * 2.0
    micro = max(1, int(math.ceil(carry / budget_bytes)))
    # round up to a divisor of the per-device batch
    while shape.global_batch % micro or (shape.global_batch // micro) % 1:
        micro += 1
    return min(micro, shape.global_batch)


def build_cell(
    cfg: ModelConfig,
    shape: ShapeSpec,
    mesh,
    *,
    rules: dict | None = None,
    optimizer: AdamW | None = None,
) -> Cell:
    multi_pod = "pod" in mesh.axis_names
    tp = mesh.shape["model"]
    kv_div = cfg.n_kv_heads % tp == 0
    if rules is None:
        if shape.kind == "decode":
            # Decode: KV heads on the model axis when divisible. Otherwise
            # shard the cache head_dim (always divisible) — the score
            # contraction becomes a psum, which is the honest cost of
            # TP > kv_heads. A shard_map-local seq-sharded cache update is
            # the §Perf upgrade path.
            rules = shlib.default_rules(
                multi_pod=multi_pod,
                kv="model" if kv_div else None,
                kv_dh=None if kv_div else "model",
                kv_seq=None)
        elif shape.kind == "prefill":
            # Prefill caches are produced once (no in-place update): shard
            # KV heads when divisible, else shard the sequence axis.
            rules = shlib.default_rules(
                multi_pod=multi_pod,
                kv="model" if kv_div else None,
                kv_seq=None if kv_div else "model")
        else:
            # Train (§Perf H2): padding kv heads (e.g. 8 over model=16)
            # makes GSPMD insert pad-copies and all-gathers inside the
            # attention chunk loops; replicating the small kv activations
            # is strictly cheaper.
            rules = shlib.default_rules(
                multi_pod=multi_pod, kv="model" if kv_div else None)
    model = build_model(cfg)
    optimizer = optimizer or AdamW(OptimizerConfig())

    with shlib.use_rules(rules):
        resolve = shlib.resolver()
    defs = model.param_defs()
    params_abs = abstract_tree(defs, cfg.param_dtype)
    params_spec = sanitize(params_abs, spec_tree(defs, resolve), mesh)

    batch_abs = input_specs(cfg, shape)
    batch_spec = {
        k: _fit_spec(P(*(resolve(a) for a in ax)), batch_abs[k].shape, mesh)
        for k, ax in batch_axes(cfg, shape).items()
    }

    mf = model_flops(cfg, shape)

    if shape.kind == "train":
        opt_abs = optimizer.init_abstract(params_abs)
        opt_spec = {"m": params_spec,
                    "v": jax.tree.map(lambda s: s, params_spec),
                    "step": P()}
        data_shards = 1
        for ax in (rules.get("batch") or ()):
            data_shards *= mesh.shape[ax]
        micro = auto_microbatches(cfg, shape, data_shards)

        def train_step(params, opt_state, batch):
            with shlib.use_rules(rules):
                if micro > 1:
                    mb = jax.tree.map(
                        lambda x: x.reshape(
                            micro, x.shape[0] // micro, *x.shape[1:]),
                        batch)

                    def micro_step(carry, b):
                        loss_sum, grads = carry
                        l, g = jax.value_and_grad(model.loss)(params, b)
                        grads = jax.tree.map(jnp.add, grads, g)
                        return (loss_sum + l, grads), None

                    zeros = jax.tree.map(
                        lambda p: jnp.zeros(p.shape, jnp.float32), params)
                    (loss_sum, grads), _ = jax.lax.scan(
                        micro_step, (jnp.zeros((), jnp.float32), zeros), mb)
                    loss = loss_sum / micro
                    grads = jax.tree.map(lambda g: g / micro, grads)
                else:
                    loss, grads = jax.value_and_grad(model.loss)(params, batch)
                params, opt_state, gnorm = optimizer.update(
                    grads, opt_state, params)
            return loss, params, opt_state

        return Cell(
            cfg=cfg, shape=shape, fn=train_step,
            args=(params_abs, opt_abs, batch_abs),
            in_shardings=(_named(mesh, params_spec), _named(mesh, opt_spec),
                          _named(mesh, batch_spec)),
            out_shardings=(NamedSharding(mesh, P()),
                           _named(mesh, params_spec), _named(mesh, opt_spec)),
            donate_argnums=(0, 1),
            model_flops=mf,
        )

    cache_abs_pre = tuple(model.init_cache_shape(shape.global_batch, shape.seq_len))
    cache_spec = tuple(
        _fit_spec(P(*(resolve(a) for a in ax)), c.shape, mesh)
        for ax, c in zip(cache_axes(cfg), cache_abs_pre))

    if shape.kind == "prefill":
        def prefill_step(params, batch):
            with shlib.use_rules(rules):
                return model.prefill(params, batch)

        logits_spec = _fit_spec(
            P(resolve("batch"), None, resolve("vocab")),
            (shape.global_batch, 1, cfg.vocab), mesh)
        return Cell(
            cfg=cfg, shape=shape, fn=prefill_step,
            args=(params_abs, batch_abs),
            in_shardings=(_named(mesh, params_spec), _named(mesh, batch_spec)),
            out_shardings=(NamedSharding(mesh, logits_spec),
                           _named(mesh, cache_spec)),
            donate_argnums=(),
            model_flops=mf,
        )

    # decode
    cache_abs = cache_abs_pre

    def decode_step(params, cache, tokens, pos):
        with shlib.use_rules(rules):
            return model.decode_step(params, cache, tokens, pos)

    logits_spec = _fit_spec(
        P(resolve("batch"), None, resolve("vocab")),
        (shape.global_batch, 1, cfg.vocab), mesh)
    return Cell(
        cfg=cfg, shape=shape, fn=decode_step,
        args=(params_abs, cache_abs, batch_abs["tokens"],
              jax.ShapeDtypeStruct((), jnp.int32)),
        in_shardings=(_named(mesh, params_spec), _named(mesh, cache_spec),
                      NamedSharding(mesh, batch_spec["tokens"]),
                      NamedSharding(mesh, P())),
        out_shardings=(NamedSharding(mesh, logits_spec),
                       _named(mesh, cache_spec)),
        donate_argnums=(1,),
        model_flops=mf,
    )
