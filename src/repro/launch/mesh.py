"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches JAX device
state (the dry-run sets XLA_FLAGS before any jax import; tests see one
device).
"""

from __future__ import annotations

import jax


def _mk(shape, axes):
    return jax.make_mesh(
        shape, axes, axis_types=(jax.sharding.AxisType.Auto,) * len(axes)
    )


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 v5e pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh_for(n_devices: int, model_axis: int = 2):
    """Small host meshes for tests/examples (e.g. 8 = 4×2)."""
    data = n_devices // model_axis
    return _mk((data, model_axis), ("data", "model"))
