"""Production mesh builders.

Defined as FUNCTIONS so importing this module never touches JAX device
state (the dry-run sets XLA_FLAGS before any jax import; tests see one
device).

Version portability: ``jax.sharding.AxisType`` and ``jax.set_mesh``
appeared after jax 0.4.x. ``_mk``/``set_mesh`` degrade gracefully so the
same call sites work on both old and new jax.
"""

from __future__ import annotations

import contextlib

import jax


def _mk(shape, axes):
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is not None:
        return jax.make_mesh(
            shape, axes, axis_types=(axis_type.Auto,) * len(axes)
        )
    return jax.make_mesh(shape, axes)


def set_mesh(mesh):
    """Ambient-mesh context manager across jax versions.

    New jax: ``jax.set_mesh(mesh)``. Old jax (no ``set_mesh``): a
    ``Mesh`` is itself a context manager that installs the ambient mesh;
    fall back to a null context if even that is unavailable.
    """
    setter = getattr(jax, "set_mesh", None)
    if setter is not None:
        return setter(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 v5e pod (256 chips) or 2×16×16 two-pod (512 chips) mesh."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mk(shape, axes)


def make_mesh_for(n_devices: int, model_axis: int = 2):
    """Small host meshes for tests/examples (e.g. 8 = 4×2)."""
    data = n_devices // model_axis
    return _mk((data, model_axis), ("data", "model"))
