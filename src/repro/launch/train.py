"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --reduced --steps 50 --ckpt-dir /tmp/ckpt [--autotune]

On a real TPU cluster this process runs once per host (jax.distributed
initializes from the environment); the CPU container runs the same code
single-host. Checkpoints are elastic: restarts may use a different mesh.
Tuning knobs are the canonical ``repro.tune`` flag set
(:meth:`repro.TuningConfig.add_flags`); the train loop drives them
through one :class:`repro.TuningSession`.
"""

import argparse


def main() -> None:
    # repro.api is jax-free: --help and flag errors stay fast; the
    # jax-heavy loop modules load only after parsing succeeds
    from repro.api import TuningConfig, train_tuning_defaults

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (recovery demo)")
    base = train_tuning_defaults()
    TuningConfig.add_flags(ap, base=base)
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.runtime.train_loop import TrainLoopConfig, train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    loop = TrainLoopConfig(
        steps=args.steps, ckpt_every=max(args.steps // 10, 1),
        ckpt_dir=args.ckpt_dir,
        compress_grads=args.compress_grads, fail_at_step=args.fail_at,
        tuning=TuningConfig.from_flags(args, base=base))
    out = train(cfg, shape, loop)
    print({k: v for k, v in out.items() if k != "losses"})


if __name__ == "__main__":
    main()
