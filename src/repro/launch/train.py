"""Training launcher CLI.

    PYTHONPATH=src python -m repro.launch.train --arch deepseek-7b \
        --reduced --steps 50 --ckpt-dir /tmp/ckpt [--autotune]

On a real TPU cluster this process runs once per host (jax.distributed
initializes from the environment); the CPU container runs the same code
single-host. Checkpoints are elastic: restarts may use a different mesh.
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (recovery demo)")
    args = ap.parse_args()

    from repro.configs import get_config
    from repro.configs.base import ShapeSpec
    from repro.runtime.train_loop import TrainLoopConfig, train

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    shape = ShapeSpec("cli", "train", args.seq, args.batch)
    loop = TrainLoopConfig(
        steps=args.steps, ckpt_every=max(args.steps // 10, 1),
        ckpt_dir=args.ckpt_dir, autotune=args.autotune,
        compress_grads=args.compress_grads, fail_at_step=args.fail_at)
    out = train(cfg, shape, loop)
    print({k: v for k, v in out.items() if k != "losses"})


if __name__ == "__main__":
    main()
