"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        [--autotune --requests 4 --registry /tmp/serve_tuned.json]

With ``--autotune`` the prefill and decode step-programs are tuned online
by the process-wide TuningCoordinator; ``--requests N`` issues N identical
requests through ONE coordinator, so later requests ride the variants the
earlier ones discovered (and ``--registry`` persists them across restarts).
``--strategy`` picks the search strategy (two_phase/random/greedy/...),
``--seq-buckets/--no-seq-buckets`` controls power-of-two bucketing of the
per-shape serve tuners.

``--kernel-tuning`` selects the tuning granularity: ``program`` (whole
step-programs, the pre-PR-4 behaviour), ``kernel`` (the model's matmul /
attention / rmsnorm Pallas kernels tune as independent coordinator-managed
compilettes), ``both`` (hierarchical: step-programs plus their constituent
kernels under one shared budget) or ``off``. ``--kernel-strategy
name=strategy`` (repeatable) assigns a search strategy per kernel, e.g.
``--kernel-strategy matmul=greedy --kernel-strategy attention=random``.
``--slo-quantile 0.99`` makes the latency-headroom gate tail-aware (gates
on the log-histogram p99 instead of the per-call EWMA).
"""

import argparse


def main() -> None:
    from repro.core import available_strategies

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--registry", default=None,
                    help="tuned-point registry path (warm-start)")
    ap.add_argument("--tune-overhead", type=float, default=0.05,
                    help="serving overhead cap (fraction of busy time)")
    ap.add_argument("--strategy", default="two_phase",
                    choices=available_strategies(),
                    help="search strategy for every serve tuner")
    ap.add_argument("--seq-buckets", dest="seq_buckets",
                    action="store_true", default=True,
                    help="pow2-bucket seq/max_len tuner keys (default)")
    ap.add_argument("--no-seq-buckets", dest="seq_buckets",
                    action="store_false",
                    help="one tuner per exact (seq, batch) shape")
    ap.add_argument("--slo", type=float, default=None,
                    help="per-step latency SLO in seconds "
                         "(headroom-gates tuning)")
    ap.add_argument("--slo-quantile", type=float, default=None,
                    help="gate on this latency quantile (e.g. 0.99 for "
                         "p99) instead of the per-call EWMA; needs --slo")
    ap.add_argument("--kernel-tuning", default="program",
                    choices=["off", "program", "kernel", "both"],
                    help="tuning granularity: whole step-programs, "
                         "individual Pallas kernels, or both levels "
                         "hierarchically under one shared budget")
    ap.add_argument("--kernel-strategy", action="append", default=[],
                    metavar="KERNEL=STRATEGY",
                    help="per-kernel search strategy override "
                         "(repeatable), e.g. matmul=greedy")
    ap.add_argument("--sync-generation", dest="async_generation",
                    action="store_false", default=True,
                    help="compile candidate variants inline on the "
                         "request path (paper's original synchronous "
                         "cycle) instead of the background pipeline")
    ap.add_argument("--prefetch", type=int, default=1,
                    help="speculative compiles per tuning slot (0=off)")
    args = ap.parse_args()
    if args.slo_quantile is not None and args.slo is None:
        ap.error("--slo-quantile has no effect without --slo (the "
                 "headroom gate only exists when an SLO is set)")

    import jax

    from repro.configs import get_config
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    from repro.runtime.kernel_plane import parse_kernel_strategies

    kernel_strategies = parse_kernel_strategies(args.kernel_strategy)
    serve = ServeConfig(
        max_new_tokens=args.tokens,
        autotune=args.autotune,
        tune_max_overhead=args.tune_overhead,
        tune_strategy=args.strategy,
        tune_slo_s=args.slo,
        tune_slo_quantile=args.slo_quantile,
        seq_buckets=args.seq_buckets,
        registry_path=args.registry,
        async_generation=args.async_generation,
        prefetch=args.prefetch,
        kernel_tuning=args.kernel_tuning,
        kernel_strategies=kernel_strategies,
    )
    # kernel_tuning="off" disables tuning even with --autotune: no
    # coordinator, and generate() emits no "autotune" stats block
    tuning_on = args.autotune and args.kernel_tuning != "off"
    coordinator = make_serve_coordinator(serve) if tuning_on else None

    for req in range(args.requests):
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(req), (args.batch, args.prompt_len),
            0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
        if cfg.family == "vlm":
            batch["vision"] = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05
        out = generate(cfg, batch, serve, coordinator=coordinator)
        line = (f"req {req}: {out['decode_tokens_per_s']:.1f} tok/s, "
                f"prefill {out['prefill_s']*1e3:.0f} ms")
        if tuning_on:
            a = out["autotune"]
            lc = a["lifecycle"]
            gc = a["generation_cache"]
            line += (f"  [tuning({args.strategy}/{args.kernel_tuning}): "
                     f"{a['regenerations']} regens, {a['swaps']} swaps, "
                     f"overhead {a['overhead_frac']*100:.1f}%, "
                     f"gen stall {a['gen_stall_s']*1e3:.0f} ms, "
                     f"cache {gc['hit_rate']*100:.0f}% hit, "
                     f"tuners {a['n_kernels']} "
                     f"({lc['converged']} converged, "
                     f"{lc['retired']} retired)]")
            if args.kernel_tuning in ("kernel", "both"):
                per = ", ".join(
                    f"{name}:{k['strategy']}×{k['regenerations']}"
                    for name, k in sorted(a["kernels"].items())
                    if k.get("plane_managed"))
                line += f"\n        kernels: {per}"
        print(line)


if __name__ == "__main__":
    main()
