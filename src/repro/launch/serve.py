"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.runtime.serve_loop import ServeConfig, generate

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    batch = {"tokens": jax.random.randint(
        jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0, cfg.vocab)}
    if cfg.family == "encdec":
        batch["audio_embeds"] = jax.random.normal(
            jax.random.PRNGKey(1),
            (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
    if cfg.family == "vlm":
        batch["vision"] = jax.random.normal(
            jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05
    out = generate(cfg, batch, ServeConfig(max_new_tokens=args.tokens))
    print(f"{out['decode_tokens_per_s']:.1f} tok/s, "
          f"prefill {out['prefill_s']*1e3:.0f} ms")


if __name__ == "__main__":
    main()
