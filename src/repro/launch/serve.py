"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        [--autotune --requests 4 --registry /tmp/serve_tuned.json]

With ``--autotune`` the prefill and decode step-programs are tuned online
by the process-wide TuningCoordinator; ``--requests N`` issues N identical
requests through ONE coordinator, so later requests ride the variants the
earlier ones discovered (and ``--registry`` persists them across restarts).
``--strategy`` picks the search strategy (two_phase/random/greedy/...),
``--seq-buckets/--no-seq-buckets`` controls power-of-two bucketing of the
per-shape serve tuners.
"""

import argparse


def main() -> None:
    from repro.core import available_strategies

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--registry", default=None,
                    help="tuned-point registry path (warm-start)")
    ap.add_argument("--tune-overhead", type=float, default=0.05,
                    help="serving overhead cap (fraction of busy time)")
    ap.add_argument("--strategy", default="two_phase",
                    choices=available_strategies(),
                    help="search strategy for every serve tuner")
    ap.add_argument("--seq-buckets", dest="seq_buckets",
                    action="store_true", default=True,
                    help="pow2-bucket seq/max_len tuner keys (default)")
    ap.add_argument("--no-seq-buckets", dest="seq_buckets",
                    action="store_false",
                    help="one tuner per exact (seq, batch) shape")
    ap.add_argument("--slo", type=float, default=None,
                    help="per-step latency SLO in seconds "
                         "(headroom-gates tuning)")
    ap.add_argument("--sync-generation", dest="async_generation",
                    action="store_false", default=True,
                    help="compile candidate variants inline on the "
                         "request path (paper's original synchronous "
                         "cycle) instead of the background pipeline")
    ap.add_argument("--prefetch", type=int, default=1,
                    help="speculative compiles per tuning slot (0=off)")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    serve = ServeConfig(
        max_new_tokens=args.tokens,
        autotune=args.autotune,
        tune_max_overhead=args.tune_overhead,
        tune_strategy=args.strategy,
        tune_slo_s=args.slo,
        seq_buckets=args.seq_buckets,
        registry_path=args.registry,
        async_generation=args.async_generation,
        prefetch=args.prefetch,
    )
    coordinator = make_serve_coordinator(serve) if args.autotune else None

    for req in range(args.requests):
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(req), (args.batch, args.prompt_len),
            0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
        if cfg.family == "vlm":
            batch["vision"] = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05
        out = generate(cfg, batch, serve, coordinator=coordinator)
        line = (f"req {req}: {out['decode_tokens_per_s']:.1f} tok/s, "
                f"prefill {out['prefill_s']*1e3:.0f} ms")
        if args.autotune:
            a = out["autotune"]
            lc = a["lifecycle"]
            gc = a["generation_cache"]
            line += (f"  [tuning({args.strategy}): "
                     f"{a['regenerations']} regens, {a['swaps']} swaps, "
                     f"overhead {a['overhead_frac']*100:.1f}%, "
                     f"gen stall {a['gen_stall_s']*1e3:.0f} ms, "
                     f"cache {gc['hit_rate']*100:.0f}% hit, "
                     f"tuners {a['n_kernels']} "
                     f"({lc['converged']} converged, "
                     f"{lc['retired']} retired)]")
        print(line)


if __name__ == "__main__":
    main()
