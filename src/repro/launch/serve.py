"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        [--autotune --requests 4 --registry /tmp/serve_tuned.json]

All tuning knobs are the canonical ``repro.tune`` flag set, declared once
by :meth:`repro.TuningConfig.add_flags` (strategy, kernel granularity and
per-kernel strategies, budget caps, SLO gate, bucketing, async pipeline);
the CLI builds one :class:`repro.TuningSession` and every request rides
it, so later requests reuse the variants earlier ones discovered (and
``--registry`` persists them across restarts).

``--kernel-tuning`` selects the tuning granularity: ``program`` (whole
step-programs), ``kernel`` (the model's matmul / attention / rmsnorm /
decode_attention Pallas kernels tune as independent session-managed
compilettes), ``both`` (hierarchical: step-programs plus their
constituent kernels under one shared budget) or ``off``.
"""

import argparse


def main() -> None:
    # repro.api is jax-free: --help and flag errors stay fast; the
    # jax-heavy loop modules load only after parsing succeeds
    from repro.api import (
        TuningConfig, TuningSession, serve_tuning_defaults)

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--requests", type=int, default=1)
    # the canonical tuning flag set, declared once; the serving regime
    # (busy-time budget, charged init, 5% cap) seeds the flag defaults
    base = serve_tuning_defaults()
    TuningConfig.add_flags(ap, base=base)
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.runtime.serve_loop import ServeConfig, generate

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    tcfg = TuningConfig.from_flags(args, base=base)
    serve = ServeConfig(max_new_tokens=args.tokens, tuning=tcfg)
    # kernel_tuning="off" disables tuning even with --autotune: no
    # session, and generate() emits no "autotune" stats block
    session = TuningSession(tcfg) if tcfg.active else None

    for req in range(args.requests):
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(req), (args.batch, args.prompt_len),
            0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
        if cfg.family == "vlm":
            batch["vision"] = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05
        out = generate(cfg, batch, serve, session=session)
        line = (f"req {req}: {out['decode_tokens_per_s']:.1f} tok/s, "
                f"prefill {out['prefill_s']*1e3:.0f} ms")
        if session is not None:
            a = out["autotune"]
            lc = a["lifecycle"]
            gc = a["generation_cache"]
            line += (f"  [tuning({args.strategy}/{args.kernel_tuning}): "
                     f"{a['regenerations']} regens, {a['swaps']} swaps, "
                     f"overhead {a['overhead_frac']*100:.1f}%, "
                     f"gen stall {a['gen_stall_s']*1e3:.0f} ms, "
                     f"cache {gc['hit_rate']*100:.0f}% hit, "
                     f"tuners {a['n_kernels']} "
                     f"({lc['converged']} converged, "
                     f"{lc['retired']} retired)]")
            if args.kernel_tuning in ("kernel", "both"):
                per = ", ".join(
                    f"{name}:{k['strategy']}×{k['regenerations']}"
                    for name, k in sorted(a["kernels"].items())
                    if k.get("plane_managed"))
                line += f"\n        kernels: {per}"
        print(line)
    if session is not None:
        session.close()


if __name__ == "__main__":
    main()
