"""Serving launcher CLI.

    PYTHONPATH=src python -m repro.launch.serve --arch rwkv6-1.6b --reduced \
        [--autotune --requests 4 --registry /tmp/serve_tuned.json]

With ``--autotune`` the prefill and decode step-programs are tuned online
by the process-wide TuningCoordinator; ``--requests N`` issues N identical
requests through ONE coordinator, so later requests ride the variants the
earlier ones discovered (and ``--registry`` persists them across restarts).
"""

import argparse


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--autotune", action="store_true")
    ap.add_argument("--requests", type=int, default=1)
    ap.add_argument("--registry", default=None,
                    help="tuned-point registry path (warm-start)")
    ap.add_argument("--tune-overhead", type=float, default=0.05,
                    help="serving overhead cap (fraction of wall time)")
    args = ap.parse_args()

    import jax

    from repro.configs import get_config
    from repro.runtime.serve_loop import (
        ServeConfig, generate, make_serve_coordinator)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    serve = ServeConfig(
        max_new_tokens=args.tokens,
        autotune=args.autotune,
        tune_max_overhead=args.tune_overhead,
        registry_path=args.registry,
    )
    coordinator = make_serve_coordinator(serve) if args.autotune else None

    for req in range(args.requests):
        batch = {"tokens": jax.random.randint(
            jax.random.PRNGKey(req), (args.batch, args.prompt_len),
            0, cfg.vocab)}
        if cfg.family == "encdec":
            batch["audio_embeds"] = jax.random.normal(
                jax.random.PRNGKey(1),
                (args.batch, cfg.enc_frames, cfg.d_model)) * 0.05
        if cfg.family == "vlm":
            batch["vision"] = jax.random.normal(
                jax.random.PRNGKey(1), (args.batch, 16, cfg.d_model)) * 0.05
        out = generate(cfg, batch, serve, coordinator=coordinator)
        line = (f"req {req}: {out['decode_tokens_per_s']:.1f} tok/s, "
                f"prefill {out['prefill_s']*1e3:.0f} ms")
        if args.autotune:
            a = out["autotune"]
            line += (f"  [tuning: {a['regenerations']} regens, "
                     f"{a['swaps']} swaps, "
                     f"overhead {a['overhead_frac']*100:.1f}%]")
        print(line)


if __name__ == "__main__":
    main()
