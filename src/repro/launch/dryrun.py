import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

The two lines above MUST stay first: JAX locks the device count on first
initialization, and the dry-run needs 512 placeholder host devices to build
the production meshes. Smoke tests and benchmarks do NOT import this module
(they see 1 device).

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch command-r-35b \
        --shape train_4k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
"""

import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

jax.config.update("jax_compilation_cache_dir", "/root/repo/.jax_cache")
jax.config.update("jax_persistent_cache_min_compile_time_secs", 1.0)

from repro.configs import REGISTRY, ALL_SHAPES
from repro.distributed.roofline import roofline_from
from repro.launch.mesh import make_production_mesh, set_mesh
from repro.launch.shapes import build_cell, skip_reason

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "../../../dryrun_artifacts")


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: str,
             overrides: dict | None = None) -> dict:
    cfg = REGISTRY[arch]
    base = {"compute_dtype": jnp.bfloat16, "remat": "dots"}
    base.update(overrides or {})
    cfg = dataclasses.replace(cfg, **base)
    shape = next(s for s in ALL_SHAPES if s.name == shape_name)
    multi_pod = mesh_kind == "multi"
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.size

    reason = skip_reason(cfg, shape)
    record: dict = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "n_chips": n_chips, "status": None,
    }
    if reason:
        record["status"] = "skipped"
        record["skip_reason"] = reason
        return record

    t0 = time.time()
    cell = build_cell(cfg, shape, mesh)
    with set_mesh(mesh):
        lowered = jax.jit(
            cell.fn,
            in_shardings=cell.in_shardings,
            out_shardings=cell.out_shardings,
            donate_argnums=cell.donate_argnums,
        ).lower(*cell.args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

    from repro.distributed.hlo_analysis import compiled_cost_analysis
    mem = compiled.memory_analysis()
    cost = compiled_cost_analysis(compiled)
    print(mem)     # proves it fits
    print({k: cost.get(k) for k in ("flops", "bytes accessed")})
    hlo = compiled.as_text()
    from repro.distributed.hlo_analysis import analyze_hlo
    totals = analyze_hlo(hlo)
    coll = type("C", (), {"link_bytes": totals.coll_bytes,
                          "per_op_bytes": totals.coll_per_op,
                          "n_ops": {}})
    roof = roofline_from(cost, hlo, n_chips=n_chips,
                         model_flops=cell.model_flops)

    record.update({
        "status": "ok",
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "peak_per_device_gb": round(
                (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                 + mem.output_size_in_bytes - mem.alias_size_in_bytes) / 2**30, 3),
        },
        "cost": {k: cost.get(k) for k in ("flops", "bytes accessed")},
        "collectives": {
            "link_bytes": coll.link_bytes,
            "per_op": coll.per_op_bytes,
            "n_ops": coll.n_ops,
        },
        "roofline": roof.row(),
    })
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"), default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=ARTIFACT_DIR)
    ap.add_argument("--tag", default="")
    ap.add_argument("--micro", type=int, default=None,
                    help="override gradient-accumulation factor")
    ap.add_argument("--moe-group", type=int, default=None)
    ap.add_argument("--remat", default=None, choices=("none", "dots", "full"))
    ap.add_argument("--attn-q-chunk", type=int, default=None)
    ap.add_argument("--attn-k-chunk", type=int, default=None)
    ap.add_argument("--scan-chunk", type=int, default=None)
    ap.add_argument("--scores-bf16", action="store_true")
    args = ap.parse_args()
    overrides = {}
    if args.micro is not None:
        overrides["microbatches"] = args.micro
    if args.moe_group is not None:
        overrides["moe_group_size"] = args.moe_group
    if args.remat is not None:
        overrides["remat"] = args.remat
    if args.attn_q_chunk is not None:
        overrides["attn_q_chunk"] = args.attn_q_chunk
    if args.attn_k_chunk is not None:
        overrides["attn_k_chunk"] = args.attn_k_chunk
    if args.scan_chunk is not None:
        overrides["scan_chunk"] = args.scan_chunk
    if args.scores_bf16:
        overrides["attn_scores_f32"] = False

    os.makedirs(args.out, exist_ok=True)
    archs = sorted(REGISTRY) if (args.all or not args.arch) else [args.arch]
    shapes = [s.name for s in ALL_SHAPES] if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                name = f"{arch}_{shape}_{mesh_kind}{args.tag}"
                path = os.path.join(args.out, name + ".json")
                try:
                    rec = run_cell(arch, shape, mesh_kind, args.out,
                                   overrides=overrides)
                except Exception as e:  # a failure here is a bug in the system
                    failures += 1
                    rec = {
                        "arch": arch, "shape": shape, "mesh": mesh_kind,
                        "status": "FAILED", "error": repr(e),
                        "traceback": traceback.format_exc()[-4000:],
                    }
                with open(path, "w") as f:
                    json.dump(rec, f, indent=1)
                print(f"[{rec['status']:>7s}] {name} "
                      + (f"compile={rec.get('compile_s')}s "
                         f"mem={rec.get('memory', {}).get('peak_per_device_gb')}GB "
                         f"bound={rec.get('roofline', {}).get('bound')}"
                         if rec["status"] == "ok" else
                         rec.get("skip_reason", rec.get("error", ""))[:120]))
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
